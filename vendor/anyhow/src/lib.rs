//! Minimal offline reimplementation of the `anyhow` API surface used by
//! this workspace: [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]
//! macros and the [`Context`] extension trait.
//!
//! Errors are stored as a flattened message chain (outermost context
//! first). `{:#}` formatting prints the whole chain separated by `: `,
//! matching anyhow's alternate display; `{}` prints the outermost
//! message only.

use std::fmt;

/// A type-erased error: an ordered chain of human-readable messages.
pub struct Error {
    /// `chain[0]` is the outermost message/context.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost to innermost.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost message.
    pub fn root_cause_message(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — full chain, colon-separated (anyhow convention).
            let mut first = true;
            for msg in &self.chain {
                if !first {
                    write!(f, ": ")?;
                }
                write!(f, "{msg}")?;
                first = false;
            }
            Ok(())
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for msg in &self.chain[1..] {
                write!(f, "\n    {msg}")?;
            }
        }
        Ok(())
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`, so
// the blanket conversion below cannot conflict with `From<T> for T`
// (the same trick the real anyhow uses).
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `std::result::Result` defaulted to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::anyhow!($($tt)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($tt:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($tt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_outermost_and_alternate_chain() {
        let e: Error = io_err().into();
        let e = e.context("reading config");
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
    }

    #[test]
    fn macro_forms() {
        let a = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let x = 7;
        let b = anyhow!("value {x}");
        assert_eq!(b.to_string(), "value 7");
        let c = anyhow!("value {}", 9);
        assert_eq!(c.to_string(), "value 9");
        let d = anyhow!(String::from("owned"));
        assert_eq!(d.to_string(), "owned");
    }

    #[test]
    fn bail_and_question_mark() {
        fn inner(fail: bool) -> Result<u32> {
            if fail {
                bail!("boom {}", 1);
            }
            let _f = std::str::from_utf8(&[0x61])?; // From<Utf8Error>
            Ok(5)
        }
        assert_eq!(inner(false).unwrap(), 5);
        assert_eq!(inner(true).unwrap_err().to_string(), "boom 1");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening").unwrap_err();
        assert_eq!(format!("{e:#}"), "opening: gone");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(e.to_string(), "missing x");
    }
}
