//! Minimal offline reimplementation of the `log` facade surface used by
//! this workspace: severity levels, the [`Log`] trait, a global logger
//! with a max-level filter, and the `error!`..`trace!` macros.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        write!(f, "{s}")
    }
}

/// Max-level filter; `Off` disables everything.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl PartialEq<LevelFilter> for Level {
    fn eq(&self, other: &LevelFilter) -> bool {
        (*self as usize) == (*other as usize)
    }
}

impl PartialOrd<LevelFilter> for Level {
    fn partial_cmp(&self, other: &LevelFilter) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

impl PartialEq<Level> for LevelFilter {
    fn eq(&self, other: &Level) -> bool {
        (*self as usize) == (*other as usize)
    }
}

impl PartialOrd<Level> for LevelFilter {
    fn partial_cmp(&self, other: &Level) -> Option<std::cmp::Ordering> {
        (*self as usize).partial_cmp(&(*other as usize))
    }
}

/// Metadata of a log event (level only in this subset).
#[derive(Debug, Clone, Copy)]
pub struct Metadata {
    level: Level,
}

impl Metadata {
    pub fn level(&self) -> Level {
        self.level
    }
}

/// One log event.
#[derive(Clone, Copy)]
pub struct Record<'a> {
    metadata: Metadata,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn metadata(&self) -> &Metadata {
        &self.metadata
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging sink.
pub trait Log: Sync + Send {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

struct NopLogger;

impl Log for NopLogger {
    fn enabled(&self, _: &Metadata) -> bool {
        false
    }
    fn log(&self, _: &Record) {}
    fn flush(&self) {}
}

static NOP: NopLogger = NopLogger;
static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// The installed logger (no-op if none).
pub fn logger() -> &'static dyn Log {
    match LOGGER.get() {
        Some(l) => *l,
        None => &NOP,
    }
}

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro back end: dispatch one event to the global logger.
pub fn __log(level: Level, args: fmt::Arguments<'_>) {
    if level <= max_level() {
        let metadata = Metadata { level };
        let l = logger();
        if l.enabled(&metadata) {
            l.log(&Record { metadata, args });
        }
    }
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Error, format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Warn, format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Info, format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Debug, format_args!($($arg)+)) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::__log($crate::Level::Trace, format_args!($($arg)+)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filter_ordering() {
        assert!(Level::Error <= LevelFilter::Warn);
        assert!(Level::Debug > LevelFilter::Info);
        assert!(!(Level::Error <= LevelFilter::Off));
    }

    #[test]
    fn macros_do_not_panic_without_logger() {
        set_max_level(LevelFilter::Trace);
        error!("e {}", 1);
        warn!("w");
        info!("i");
        debug!("d");
        trace!("t");
    }
}
