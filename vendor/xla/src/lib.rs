//! Graceful-failure stub of the xla-rs PJRT API used by
//! `rust/src/runtime/pjrt.rs`.
//!
//! The offline build environment has no XLA toolchain, so
//! [`PjRtClient::cpu`] returns an error and every downstream operation is
//! unreachable in practice. This keeps the PJRT wiring type-checked and
//! the `artifacts/`-gated tests skipping cleanly; substitute the real
//! bindings with a `[patch."xla"]` entry when XLA is available.

use std::fmt;

/// Error type matching how call sites consume xla-rs errors (`{e:?}`).
pub struct Error(String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: XLA/PJRT runtime unavailable (offline stub build — \
         see vendor/xla)"
    )))
}

/// PJRT client handle. The stub can never be constructed.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("buffer_from_host_buffer")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compile")
    }
}

/// Device buffer handle (never produced by the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("to_literal_sync")
    }
}

/// Compiled executable handle (never produced by the stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _inputs: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("execute_b")
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping a module proto.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Host literal (never produced by the stub).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_tuple2(self) -> Result<(Literal, Literal)> {
        unavailable("to_tuple2")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_gracefully() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        let msg = format!("{err:?}");
        assert!(msg.contains("offline stub"), "{msg}");
    }
}
