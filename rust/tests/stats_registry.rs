//! Conformance and property suite for the declarative metrics registry
//! ([`ragcache::metrics::registry`]) — the one schema driving the stats
//! wire format, the cross-engine merge, the tree-counter aggregation,
//! the bench column/tolerance metadata and the CI schema snapshot.
//!
//! The refactor it pins was behavior-preserving by construction, so the
//! suite holds it to that:
//! - the wire bytes of a fully-populated `stats` response are pinned as
//!   a golden string (and the committed schema snapshot as another);
//! - randomized encode → parse roundtrips recover every field exactly,
//!   and the wire never carries NaN/inf;
//! - the registry merge equals the retired hand-written `merge_stats`,
//!   replicated verbatim in-test, over randomized multi-engine parts —
//!   including the NaN-skip weighting, the `slo_enabled` gating and the
//!   one-snapshot shard-array rule. The ONE deliberate divergence (the
//!   per-tenant mean is now request-weighted, not completed-weighted)
//!   is folded into the replica and pinned by its own regression test;
//! - adding a metric is exactly two edits: an `ExtCounter` registry
//!   entry plus its increment site flows through encode, parse, merge,
//!   the bench column set and the schema dump with zero other changes.

use ragcache::metrics::registry::{
    descriptors, merge_tenant_lines, schema_dump, serving_bench_columns,
    tolerance_of, wire_mean_ms, ExtCounter, MergeKind, Registry,
    Tolerance, TREE_COUNTER_FIELDS,
};
use ragcache::server::proto::{
    encode_response, parse_response, Response, StatsResult, TenantLine,
};
use ragcache::tree::TreeCounters;
use ragcache::util::Rng;

/// The fully-populated fixture the proto roundtrip test ships: every
/// standard field non-default, multi-element shard arrays, two tenant
/// lines.
fn populated_stats() -> StatsResult {
    StatsResult {
        requests: 10,
        mean_ttft_ms: 5.5,
        hit_rate: 0.75,
        engines: 2,
        tree_inserts: 40,
        tree_gpu_evictions: 7,
        tree_host_evictions: 3,
        spec_started: 9,
        spec_wasted: 2,
        spec_promoted: 5,
        tree_gpu_hit_bytes: 4096,
        chunk_hits: 6,
        chunk_hit_bytes: 768,
        boundary_recompute_tokens: 48,
        rebalance_recomputes: 3,
        rebalance_moved_bytes: 1024,
        shard_gpu_used: vec![512, 0, 256, 128],
        shard_gpu_capacity: vec![2048, 512, 768, 768],
        goodput_rps: 1.25,
        ttft_p999_ms: 87.5,
        shed_requests: 4,
        downgraded_requests: 2,
        slo_attainment: 0.9,
        slo_enabled: true,
        disk_spills: 11,
        disk_spill_bytes: 5632,
        disk_restage_hits: 8,
        disk_restage_bytes: 4096,
        disk_used: 9216,
        disk_capacity: 65536,
        tenants: vec![
            TenantLine {
                tenant: 0,
                requests: 6,
                completed: 5,
                shed: 1,
                downgraded: 1,
                slo_ok: 4,
                mean_ttft_ms: 7.25,
                mode: 2,
            },
            TenantLine {
                tenant: 1,
                requests: 4,
                completed: 4,
                shed: 0,
                downgraded: 0,
                slo_ok: 3,
                mean_ttft_ms: 11.5,
                mode: 1,
            },
        ],
        ext: Vec::new(),
    }
}

/// Golden wire bytes: the JSON object is a sorted map, so the encoding
/// of [`populated_stats`] is exactly this string. A changed field name,
/// a dropped field, or a numeric formatting change all fail here.
#[test]
fn golden_wire_bytes() {
    let want = concat!(
        "{\"boundary_recompute_tokens\":48,",
        "\"chunk_hit_bytes\":768,\"chunk_hits\":6,",
        "\"disk_capacity\":65536,\"disk_restage_bytes\":4096,",
        "\"disk_restage_hits\":8,\"disk_spill_bytes\":5632,",
        "\"disk_spills\":11,\"disk_used\":9216,",
        "\"downgraded_requests\":2,\"engines\":2,",
        "\"goodput_rps\":1.25,\"hit_rate\":0.75,\"mean_ttft_ms\":5.5,",
        "\"rebalance_moved_bytes\":1024,\"rebalance_recomputes\":3,",
        "\"requests\":10,",
        "\"shard_gpu_capacity\":[2048,512,768,768],",
        "\"shard_gpu_used\":[512,0,256,128],\"shed_requests\":4,",
        "\"slo_attainment\":0.9,\"slo_enabled\":true,",
        "\"spec_promoted\":5,\"spec_started\":9,\"spec_wasted\":2,",
        "\"tenants\":[",
        "{\"completed\":5,\"downgraded\":1,\"mean_ttft_ms\":7.25,",
        "\"mode\":2,\"requests\":6,\"shed\":1,\"slo_ok\":4,\"tenant\":0},",
        "{\"completed\":4,\"downgraded\":0,\"mean_ttft_ms\":11.5,",
        "\"mode\":1,\"requests\":4,\"shed\":0,\"slo_ok\":3,\"tenant\":1}",
        "],",
        "\"tree_gpu_evictions\":7,\"tree_gpu_hit_bytes\":4096,",
        "\"tree_host_evictions\":3,\"tree_inserts\":40,",
        "\"ttft_p999_ms\":87.5,\"type\":\"stats\"}",
    );
    let enc = encode_response(&Response::Stats(populated_stats()));
    assert_eq!(enc, want);
    // And the golden bytes parse back to the exact struct.
    assert_eq!(
        parse_response(want).unwrap(),
        Response::Stats(populated_stats())
    );
}

/// A random stats answer with every field fuzzed: counters up to 2^50
/// (exact on the f64 wire), finite floats, shard arrays of 0..=4
/// elements, tenant vectors of 0..=3 lines.
fn rand_stats(rng: &mut Rng) -> StatsResult {
    let big = |rng: &mut Rng| rng.below(1 << 50);
    let shards = |rng: &mut Rng| -> Vec<u64> {
        (0..rng.index(5)).map(|_| rng.below(1 << 40)).collect()
    };
    StatsResult {
        requests: rng.below(1 << 30) as usize,
        mean_ttft_ms: rng.f64() * 1e4,
        hit_rate: rng.f64(),
        engines: 1 + rng.index(8),
        tree_inserts: big(rng),
        tree_gpu_evictions: big(rng),
        tree_host_evictions: big(rng),
        spec_started: big(rng),
        spec_wasted: big(rng),
        spec_promoted: big(rng),
        tree_gpu_hit_bytes: big(rng),
        chunk_hits: big(rng),
        chunk_hit_bytes: big(rng),
        boundary_recompute_tokens: big(rng),
        rebalance_recomputes: big(rng),
        rebalance_moved_bytes: big(rng),
        shard_gpu_used: shards(rng),
        shard_gpu_capacity: shards(rng),
        goodput_rps: rng.f64() * 100.0,
        ttft_p999_ms: rng.f64() * 1e5,
        shed_requests: big(rng),
        downgraded_requests: big(rng),
        slo_attainment: rng.f64(),
        slo_enabled: rng.chance(0.5),
        disk_spills: big(rng),
        disk_spill_bytes: big(rng),
        disk_restage_hits: big(rng),
        disk_restage_bytes: big(rng),
        disk_used: big(rng),
        disk_capacity: big(rng),
        tenants: (0..rng.index(4))
            .map(|i| TenantLine {
                tenant: i as u32,
                requests: rng.below(1 << 30),
                completed: rng.below(1 << 30),
                shed: rng.below(1 << 20),
                downgraded: rng.below(1 << 20),
                slo_ok: rng.below(1 << 30),
                mean_ttft_ms: rng.f64() * 1e3,
                mode: rng.index(3) as u8,
            })
            .collect(),
        ext: Vec::new(),
    }
}

/// Property: encode → parse recovers every field exactly, over fully
/// randomized answers (including empty and multi-element shard arrays
/// and tenant vectors), and the wire never carries NaN or inf — JSON
/// cannot represent either.
#[test]
fn randomized_wire_roundtrip() {
    let mut rng = Rng::new(0x57A7_5_2E6);
    for _ in 0..200 {
        let s = rand_stats(&mut rng);
        let enc = encode_response(&Response::Stats(s.clone()));
        assert!(
            !enc.contains("NaN") && !enc.contains("inf"),
            "non-finite value escaped onto the wire: {enc}"
        );
        assert_eq!(parse_response(&enc).unwrap(), Response::Stats(s));
    }
}

/// The NaN-safe mean encoding producers use: finite values pass
/// through, NaN/inf (a mean over zero completions) report 0.0.
#[test]
fn wire_mean_is_nan_safe() {
    assert_eq!(wire_mean_ms(3.5), 3.5);
    assert_eq!(wire_mean_ms(0.0), 0.0);
    assert_eq!(wire_mean_ms(f64::NAN), 0.0);
    assert_eq!(wire_mean_ms(f64::INFINITY), 0.0);
    assert_eq!(wire_mean_ms(f64::NEG_INFINITY), 0.0);
}

/// The retired hand-written `server::merge_tenant_lines`, replicated
/// for the conformance comparison — with the ONE deliberate change
/// folded in: the mean weights by `requests` under the zero-served
/// guard (the old code weighted by `completed`; see
/// `tenant_mean_merges_request_weighted` for the regression pin).
fn legacy_merge_tenant_lines(parts: &[StatsResult]) -> Vec<TenantLine> {
    use std::collections::BTreeMap;
    let mut by: BTreeMap<u32, TenantLine> = BTreeMap::new();
    let mut ttft_weight: BTreeMap<u32, f64> = BTreeMap::new();
    for p in parts {
        for t in &p.tenants {
            let e = by.entry(t.tenant).or_insert_with(|| TenantLine {
                tenant: t.tenant,
                ..Default::default()
            });
            e.requests += t.requests;
            e.completed += t.completed;
            e.shed += t.shed;
            e.downgraded += t.downgraded;
            e.slo_ok += t.slo_ok;
            e.mode = e.mode.max(t.mode);
            if t.requests > 0
                && t.completed > 0
                && t.mean_ttft_ms.is_finite()
            {
                let w = t.requests as f64;
                e.mean_ttft_ms += t.mean_ttft_ms * w;
                *ttft_weight.entry(t.tenant).or_insert(0.0) += w;
            }
        }
    }
    for (tenant, line) in by.iter_mut() {
        let w = ttft_weight.get(tenant).copied().unwrap_or(0.0);
        line.mean_ttft_ms =
            if w > 0.0 { line.mean_ttft_ms / w } else { 0.0 };
    }
    by.into_values().collect()
}

/// The retired hand-written `server::merge_stats`, replicated verbatim
/// for the conformance comparison (modulo the tenant-mean fix above
/// and the `ext` field the old struct predates).
fn legacy_merge_stats(parts: &[StatsResult]) -> StatsResult {
    let requests: usize = parts.iter().map(|p| p.requests).sum();
    let weighted = |f: fn(&StatsResult) -> f64| -> f64 {
        let (sum, weight) = parts
            .iter()
            .filter(|p| p.requests > 0 && f(p).is_finite())
            .fold((0.0, 0usize), |(s, w), p| {
                (s + f(p) * p.requests as f64, w + p.requests)
            });
        if weight == 0 {
            0.0
        } else {
            sum / weight as f64
        }
    };
    let slo_attainment = {
        let (sum, weight) = parts
            .iter()
            .filter(|p| {
                p.slo_enabled
                    && p.requests > 0
                    && p.slo_attainment.is_finite()
            })
            .fold((0.0, 0usize), |(s, w), p| {
                (s + p.slo_attainment * p.requests as f64, w + p.requests)
            });
        if weight == 0 {
            0.0
        } else {
            sum / weight as f64
        }
    };
    let freshest = parts.iter().max_by_key(|p| {
        (p.shard_gpu_capacity.len(), p.rebalance_recomputes)
    });
    StatsResult {
        requests,
        mean_ttft_ms: weighted(|p| p.mean_ttft_ms),
        hit_rate: weighted(|p| p.hit_rate),
        engines: parts.len(),
        tree_inserts: parts
            .iter()
            .map(|p| p.tree_inserts)
            .max()
            .unwrap_or(0),
        tree_gpu_evictions: parts
            .iter()
            .map(|p| p.tree_gpu_evictions)
            .max()
            .unwrap_or(0),
        tree_host_evictions: parts
            .iter()
            .map(|p| p.tree_host_evictions)
            .max()
            .unwrap_or(0),
        spec_started: parts.iter().map(|p| p.spec_started).sum(),
        spec_wasted: parts.iter().map(|p| p.spec_wasted).sum(),
        spec_promoted: parts.iter().map(|p| p.spec_promoted).sum(),
        tree_gpu_hit_bytes: parts
            .iter()
            .map(|p| p.tree_gpu_hit_bytes)
            .max()
            .unwrap_or(0),
        chunk_hits: parts.iter().map(|p| p.chunk_hits).max().unwrap_or(0),
        chunk_hit_bytes: parts
            .iter()
            .map(|p| p.chunk_hit_bytes)
            .max()
            .unwrap_or(0),
        boundary_recompute_tokens: parts
            .iter()
            .map(|p| p.boundary_recompute_tokens)
            .max()
            .unwrap_or(0),
        rebalance_recomputes: parts
            .iter()
            .map(|p| p.rebalance_recomputes)
            .max()
            .unwrap_or(0),
        rebalance_moved_bytes: parts
            .iter()
            .map(|p| p.rebalance_moved_bytes)
            .max()
            .unwrap_or(0),
        shard_gpu_used: freshest
            .map(|p| p.shard_gpu_used.clone())
            .unwrap_or_default(),
        shard_gpu_capacity: freshest
            .map(|p| p.shard_gpu_capacity.clone())
            .unwrap_or_default(),
        goodput_rps: parts.iter().map(|p| p.goodput_rps).sum(),
        ttft_p999_ms: parts
            .iter()
            .map(|p| p.ttft_p999_ms)
            .fold(0.0, f64::max),
        shed_requests: parts.iter().map(|p| p.shed_requests).sum(),
        downgraded_requests: parts
            .iter()
            .map(|p| p.downgraded_requests)
            .sum(),
        slo_attainment,
        slo_enabled: parts.iter().any(|p| p.slo_enabled),
        disk_spills: parts
            .iter()
            .map(|p| p.disk_spills)
            .max()
            .unwrap_or(0),
        disk_spill_bytes: parts
            .iter()
            .map(|p| p.disk_spill_bytes)
            .max()
            .unwrap_or(0),
        disk_restage_hits: parts
            .iter()
            .map(|p| p.disk_restage_hits)
            .max()
            .unwrap_or(0),
        disk_restage_bytes: parts
            .iter()
            .map(|p| p.disk_restage_bytes)
            .max()
            .unwrap_or(0),
        disk_used: freshest.map(|p| p.disk_used).unwrap_or(0),
        disk_capacity: freshest.map(|p| p.disk_capacity).unwrap_or(0),
        tenants: legacy_merge_tenant_lines(parts),
        ext: Vec::new(),
    }
}

/// Conformance: the table-driven merge equals the hand-written one over
/// randomized multi-engine parts — NaN means, zero-request engines,
/// disabled-SLO engines, ragged shard arrays, overlapping tenant ids
/// and the empty fan-out all included. The arithmetic runs in the same
/// order on both sides, so equality is bit-exact, not approximate.
#[test]
fn merge_matches_legacy_merge() {
    let mut rng = Rng::new(0xCAFE_F00D);
    let reg = Registry::standard();
    assert_eq!(reg.merge(&[]), legacy_merge_stats(&[]));
    for _ in 0..200 {
        let parts: Vec<StatsResult> = (0..1 + rng.index(5))
            .map(|_| {
                let mut p = rand_stats(&mut rng);
                // NaN arrives in in-process parts (a mean over zero
                // completions), not off the wire: inject some so the
                // skip rules are exercised, in the mean, the
                // attainment and the tenant lines.
                if rng.chance(0.25) {
                    p.mean_ttft_ms = f64::NAN;
                }
                if rng.chance(0.25) {
                    p.slo_attainment = f64::NAN;
                }
                if rng.chance(0.25) {
                    p.requests = 0;
                }
                for t in &mut p.tenants {
                    if rng.chance(0.2) {
                        t.mean_ttft_ms = f64::NAN;
                    }
                    if rng.chance(0.2) {
                        t.completed = 0;
                    }
                }
                p
            })
            .collect();
        assert_eq!(reg.merge(&parts), legacy_merge_stats(&parts));
    }
}

/// Regression (the one deliberate merge change): the per-tenant mean
/// TTFT merges request-weighted — matching the top-level mean and the
/// wire doc — with lines that served nothing (zero requests, zero
/// completions) or report a non-finite mean contributing neither value
/// nor weight.
#[test]
fn tenant_mean_merges_request_weighted() {
    let line = |requests, completed, mean| TenantLine {
        tenant: 3,
        requests,
        completed,
        mean_ttft_ms: mean,
        ..Default::default()
    };
    let part = |l: TenantLine| StatsResult {
        tenants: vec![l],
        ..Default::default()
    };
    let parts = [
        part(line(9, 3, 12.0)),
        part(line(1, 1, 2.0)),
        part(line(5, 2, f64::NAN)), // skipped: non-finite
        part(line(4, 0, 8.0)),      // skipped: nothing served
    ];
    let merged = merge_tenant_lines(&parts);
    assert_eq!(merged.len(), 1);
    assert_eq!(merged[0].requests, 19);
    assert_eq!(merged[0].completed, 6);
    // Request-weighted over the two measuring lines: 11.0 — NOT the
    // completed-weighted 9.5 the old merge reported.
    let want = (12.0 * 9.0 + 2.0 * 1.0) / 10.0;
    assert!((merged[0].mean_ttft_ms - want).abs() < 1e-12);
    // Every line guarded out → 0.0, never NaN.
    let none = merge_tenant_lines(&[part(line(5, 0, 7.0))]);
    assert_eq!(none[0].mean_ttft_ms, 0.0);
}

/// The one-snapshot rule: both shard arrays and the disk gauges come
/// wholly from the freshest part (most shard gauges, then most
/// rebalance progress; ties keep the LAST part) — never mixed
/// element-wise across snapshots.
#[test]
fn shard_arrays_merge_from_one_snapshot() {
    let snap = |cap: Vec<u64>, used: Vec<u64>, rec, du, dc| StatsResult {
        shard_gpu_capacity: cap,
        shard_gpu_used: used,
        rebalance_recomputes: rec,
        disk_used: du,
        disk_capacity: dc,
        ..Default::default()
    };
    let a = snap(vec![100, 50], vec![10, 20], 5, 1, 10);
    let b = snap(vec![30, 200], vec![90, 1], 9, 2, 20);
    let m = Registry::standard().merge(&[a.clone(), b.clone()]);
    assert_eq!(m.shard_gpu_capacity, b.shard_gpu_capacity);
    assert_eq!(m.shard_gpu_used, b.shard_gpu_used);
    assert_eq!((m.disk_used, m.disk_capacity), (2, 20));
    // Exact tie on (len, recomputes): the last part wins.
    let c = snap(vec![7, 7], vec![3, 3], 9, 4, 40);
    let m = Registry::standard().merge(&[b.clone(), c.clone()]);
    assert_eq!(m.shard_gpu_used, c.shard_gpu_used);
    assert_eq!((m.disk_used, m.disk_capacity), (4, 40));
    // But rebalance counters themselves still max-merge.
    assert_eq!(m.rebalance_recomputes, 9);
}

/// Add-a-metric demonstration: ONE `ExtCounter` registry entry plus its
/// increment site (`StatsResult::ext`) flows through wire encode,
/// parse, merge, the bench column set, the tolerance table and the
/// schema dump — with zero edits to the structs, the encoder, the
/// merge, or the bench emitters, and zero effect on the standard
/// schema.
#[test]
fn add_a_metric_is_two_edits() {
    let reg = Registry::standard().with_counter(ExtCounter {
        name: "throwaway_total",
        merge: MergeKind::Sum,
        tolerance: Tolerance::Tight,
        bench: true,
    });
    // Increment site: the producer pushes the counter into `ext`.
    let mut s = populated_stats();
    s.ext.push(("throwaway_total", 7));

    // Wire encode carries it...
    let enc = reg.encode_stats(&s);
    assert_eq!(
        enc.get("throwaway_total").and_then(|v| v.as_u64()),
        Some(7)
    );
    // ...and parse recovers it.
    let parsed = reg.parse_stats(&enc);
    assert_eq!(parsed.ext, vec![("throwaway_total", 7)]);

    // Merge applies the registered semantics (Sum), and a part that
    // predates the counter simply carries no entry.
    let mut other = populated_stats();
    other.ext.push(("throwaway_total", 5));
    let merged = reg.merge(&[s.clone(), other]);
    assert_eq!(merged.ext, vec![("throwaway_total", 12)]);
    let merged = reg.merge(&[s.clone(), populated_stats()]);
    assert_eq!(merged.ext, vec![("throwaway_total", 7)]);

    // Bench metadata: the column set appends it, the tolerance table
    // knows it.
    let std_cols = serving_bench_columns(&Registry::standard());
    let ext_cols = serving_bench_columns(&reg);
    assert_eq!(ext_cols[..std_cols.len()], std_cols[..]);
    assert_eq!(ext_cols.last(), Some(&"throwaway_total"));
    assert_eq!(
        tolerance_of(&reg, "throwaway_total"),
        Some(Tolerance::Tight)
    );

    // Schema dump lists it, marked as an extension.
    let dump = schema_dump(&reg);
    assert!(dump.contains(
        "stat throwaway_total kind=counter scope=per_engine \
         merge=sum tolerance=tight ext\n"
    ));
    assert!(dump.contains("bench_serving_column throwaway_total\n"));

    // The standard registry is untouched: an unregistered ext entry
    // stays off the wire, and the standard schema has never heard of
    // the counter.
    let std_enc = Registry::standard().encode_stats(&s);
    assert!(std_enc.get("throwaway_total").is_none());
    assert!(!schema_dump(&Registry::standard())
        .contains("throwaway_total"));
}

/// The BENCH_serving column set is pinned: the registry must reproduce
/// exactly the columns the hand-written emitter declared, in order —
/// the bench_diff baselines depend on this set not drifting.
#[test]
fn serving_bench_columns_are_unchanged() {
    assert_eq!(
        serving_bench_columns(&Registry::standard()),
        vec![
            "chunk_cache",
            "requests",
            "ttft_p50_ms",
            "ttft_p99_ms",
            "throughput_rps",
            "sum_prefill_tokens",
            "ttft_proxy_s",
            "gpu_hit_bytes",
            "chunk_hits",
            "chunk_hit_bytes",
            "boundary_recompute_tokens",
            "tree_inserts",
            "swap_out_bytes",
            "goodput_rps",
            "ttft_p999_ms",
            "shed_requests",
            "disk",
            "disk_spills",
            "disk_restage_hits",
            "disk_restage_bytes",
        ]
    );
}

/// The registry's tolerance classes reproduce the wall-clock suffix
/// rule bench_diff used before the registry existed: loose iff the
/// wire name ends `_ms` or `_rps`, and every tree counter tight — so
/// swapping bench_diff onto `tolerance_of` changed no band.
#[test]
fn tolerance_classes_match_the_suffix_rule() {
    let reg = Registry::standard();
    for d in descriptors() {
        let suffix_loose =
            d.wire.ends_with("_ms") || d.wire.ends_with("_rps");
        assert_eq!(
            d.tolerance == Tolerance::Loose,
            suffix_loose,
            "{} would change its bench_diff band",
            d.wire
        );
        assert_eq!(tolerance_of(&reg, d.wire), Some(d.tolerance));
    }
    for f in TREE_COUNTER_FIELDS.iter() {
        assert!(!f.name.ends_with("_ms") && !f.name.ends_with("_rps"));
        assert_eq!(tolerance_of(&reg, f.name), Some(Tolerance::Tight));
    }
    // Unregistered columns stay on bench_diff's own fallback.
    assert_eq!(tolerance_of(&reg, "ttft_p50_ms"), None);
    assert_eq!(tolerance_of(&reg, "chunk_cache"), None);
}

/// Registry hygiene: wire names are unique and labels non-empty — the
/// schema is a function from name to descriptor.
#[test]
fn descriptor_names_are_unique() {
    let mut seen = std::collections::BTreeSet::new();
    for d in descriptors() {
        assert!(seen.insert(d.wire), "duplicate metric {}", d.wire);
        assert!(!d.label.is_empty());
    }
}

/// The tree-counter field table is exhaustive: setting every field
/// through the table reproduces a full struct literal (which fails to
/// compile if `TreeCounters` grows a field the table misses), and
/// `TreeCounters::merge` is the field-wise sum the table drives.
#[test]
fn tree_counter_table_is_exhaustive() {
    let mut c = TreeCounters::default();
    for (i, f) in TREE_COUNTER_FIELDS.iter().enumerate() {
        (f.set)(&mut c, (i as u64 + 1) * 3);
    }
    let want = TreeCounters {
        gpu_evictions: 3,
        host_evictions: 6,
        swap_out_bytes: 9,
        zero_copy_evictions: 12,
        inserts: 15,
        rejected_inserts: 18,
        gpu_hit_bytes: 21,
        chunk_hits: 24,
        chunk_hit_bytes: 27,
        boundary_recompute_tokens: 30,
        disk_spills: 33,
        disk_spill_bytes: 36,
        disk_restage_hits: 39,
        disk_restage_bytes: 42,
    };
    assert_eq!(c, want);
    let mut m = c;
    m.merge(c);
    for f in TREE_COUNTER_FIELDS.iter() {
        assert_eq!((f.get)(&m), 2 * (f.get)(&c));
    }
}

/// The generated schema matches the committed snapshot byte for byte —
/// the same gate ci.sh runs via `ragcache stats-schema`, held here so
/// plain `cargo test` catches drift too.
#[test]
fn schema_dump_matches_committed_snapshot() {
    let committed = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/bench_baselines/stats_schema.txt"
    ))
    .expect("bench_baselines/stats_schema.txt is committed");
    assert_eq!(
        schema_dump(&Registry::standard()),
        committed,
        "metric schema drifted from the committed snapshot; \
         regenerate it deliberately with \
         `cargo run --release --bin ragcache -- stats-schema`"
    );
}
