//! Property-based integration tests over the caching stack: the
//! knowledge tree + policies under adversarial random workloads, checking
//! structural invariants and semantic guarantees after every operation.

use ragcache::config::PolicyKind;
use ragcache::kvcache::{KvPayload, PageSpec, Tier};
use ragcache::policy::{make_policy, AccessCtx};
use ragcache::prop_assert;
use ragcache::testing::{check_with, PropConfig};
use ragcache::tree::{DocId, KnowledgeTree};
use ragcache::util::Rng;

fn page() -> PageSpec {
    PageSpec {
        block_tokens: 8,
        kv_bytes_per_token: 16,
    }
}

fn build(gpu_tokens: usize, host_tokens: usize, policy: PolicyKind) -> KnowledgeTree {
    let p = page();
    KnowledgeTree::new(
        p.bytes(gpu_tokens),
        p.bytes(host_tokens),
        p,
        make_policy(policy),
        true,
        0,
    )
}

fn ctx(tokens: usize, now: f64, cached: bool) -> AccessCtx {
    AccessCtx {
        alpha: 0,
        beta: tokens.max(1),
        estimated_time: tokens as f64 * 1e-4,
        was_cached: cached,
        now,
        tokens,
    }
}

/// Drive a random request mix through a tree, validating invariants
/// after every step. Exercises lookup/promote/insert/evict and payload
/// consistency under all four policies.
#[test]
fn invariants_under_random_traffic_all_policies() {
    for policy in [
        PolicyKind::Pgdsf,
        PolicyKind::Gdsf,
        PolicyKind::Lru,
        PolicyKind::Lfu,
    ] {
        check_with(
            PropConfig {
                cases: 25,
                seed: 0xCAFE + policy as u64,
            },
            "cache_invariants",
            |rng: &mut Rng| {
                let mut tree =
                    build(64 + rng.index(4) * 32, 128 + rng.index(4) * 64, policy);
                let n_docs = 3 + rng.below(10) as u32;
                let kv_per_tok = 4usize; // floats per token for payloads
                let mut now = 0.0;
                for _ in 0..80 {
                    now += 1.0;
                    let len = 1 + rng.index(3);
                    let docs: Vec<DocId> = (0..len)
                        .map(|_| rng.below(n_docs as u64) as u32)
                        .collect();
                    let tokens = 8 * (1 + rng.index(2));

                    let m = tree.lookup(&docs);
                    prop_assert!(
                        m.matched_docs <= docs.len(),
                        "match bounded"
                    );
                    prop_assert!(
                        m.gpu_tokens + m.host_tokens == m.cached_tokens,
                        "tier split adds up"
                    );
                    tree.pin(&m.path);
                    if !tree.promote(&m.path).complete(m.path.len()) {
                        tree.unpin(&m.path);
                        continue;
                    }
                    // After promote, the whole matched path is GPU.
                    for &n in &m.path {
                        prop_assert!(
                            tree.node_tier(n) == Some(Tier::Gpu),
                            "promoted node in GPU"
                        );
                    }
                    let mut parent =
                        m.path.last().copied().unwrap_or(tree.root());
                    let mut pinned = m.path.clone();
                    for &d in &docs[m.matched_docs..] {
                        let payload = KvPayload::new(
                            vec![d as f32; tokens * kv_per_tok],
                            tokens,
                        );
                        match tree.insert_child(
                            parent,
                            d,
                            tokens,
                            Some(payload),
                        ) {
                            (_, Some(id)) => {
                                tree.pin(&[id]);
                                pinned.push(id);
                                tree.on_access(
                                    id,
                                    &ctx(tokens, now, false),
                                );
                                parent = id;
                            }
                            (_, None) => break,
                        }
                    }
                    for &n in &m.path {
                        tree.on_access(
                            n,
                            &ctx(tree.node_tokens(n), now, true),
                        );
                    }
                    tree.unpin(&pinned);
                    tree.check_invariants();
                }
                Ok(())
            },
        );
    }
}

/// Payload identity: whatever survives in the cache returns byte-for-byte
/// the payload stored at insertion.
#[test]
fn payloads_survive_eviction_roundtrips() {
    check_with(
        PropConfig {
            cases: 40,
            seed: 0xD00D,
        },
        "payload_identity",
        |rng: &mut Rng| {
            let mut tree = build(32, 96, PolicyKind::Pgdsf);
            let mut stored: Vec<(DocId, Vec<f32>)> = Vec::new();
            for d in 0..8u32 {
                let tokens = 8;
                let data: Vec<f32> =
                    (0..tokens * 2).map(|_| rng.f32()).collect();
                if tree
                    .insert_child(
                        tree.root(),
                        d,
                        tokens,
                        Some(KvPayload::new(data.clone(), tokens)),
                    )
                    .1
                    .is_some()
                {
                    stored.push((d, data));
                }
                tree.check_invariants();
            }
            for (d, data) in &stored {
                let m = tree.lookup(&[*d]);
                if m.matched_docs == 1 {
                    let p = tree
                        .node_payload(m.path[0])
                        .expect("cached node keeps payload");
                    prop_assert!(
                        p.floats() == data.as_slice(),
                        "payload intact for doc {d}"
                    );
                }
            }
            Ok(())
        },
    );
}

/// The GPU segment stays a connected top region of the tree under every
/// policy and any eviction pattern (the paper's hierarchical partition).
#[test]
fn gpu_segment_always_connected() {
    check_with(
        PropConfig {
            cases: 40,
            seed: 0xF00,
        },
        "gpu_connectivity",
        |rng: &mut Rng| {
            let mut tree = build(48, 200, PolicyKind::Lru);
            let mut now = 0.0;
            for _ in 0..60 {
                now += 1.0;
                let chain_len = 1 + rng.index(4);
                let mut parent = tree.root();
                for _ in 0..chain_len {
                    let d = rng.below(6) as u32;
                    match tree.insert_child(parent, d, 8, None) {
                        (_, Some(id)) => {
                            tree.on_access(id, &ctx(8, now, false));
                            parent = id;
                        }
                        (_, None) => break,
                    }
                }
                tree.check_invariants(); // asserts GPU-parent rule
            }
            Ok(())
        },
    );
}

/// Hit-rate definition (§7.3): prefix-order-sensitive partial hits.
#[test]
fn hit_rate_definition_matches_paper_example() {
    let mut tree = build(1000, 1000, PolicyKind::Pgdsf);
    // Store [D1, D2].
    let a = tree.insert_child(tree.root(), 1, 8, None).1.unwrap();
    tree.insert_child(a, 2, 8, None).1.unwrap();
    // Request [D1, D3]: 1 of 2 docs hit => 50% (the paper's example).
    let m = tree.lookup(&[1, 3]);
    assert_eq!(m.matched_docs, 1);
    assert_eq!(m.matched_docs as f64 / 2.0, 0.5);
    // Request [D2, D1]: order matters => 0 hits.
    let m2 = tree.lookup(&[2, 1]);
    assert_eq!(m2.matched_docs, 0);
}
