//! Integration: load AOT artifacts (made by `make artifacts`) through the
//! PJRT CPU client and validate the numerics against properties the
//! Python tests established (KV-reuse invariance, determinism).

use ragcache::runtime::{ArtifactManifest, PjrtModel};
use std::path::Path;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn load(model: &str) -> Option<PjrtModel> {
    let dir = artifacts_dir()?;
    let manifest = ArtifactManifest::load(&dir).expect("manifest parses");
    let mm = manifest.model(model).expect("model in manifest");
    Some(PjrtModel::load(mm).expect("model loads"))
}

macro_rules! require_artifacts {
    ($m:expr) => {
        match $m {
            Some(m) => m,
            None => {
                eprintln!("skipping: artifacts not built");
                return;
            }
        }
    };
}

#[test]
fn loads_and_prefills() {
    let model = require_artifacts!(load("tiny-gqa"));
    let tokens: Vec<i32> = (1..17).collect();
    let out = model.prefill(&[], &tokens).expect("prefill");
    let arch = &model.manifest().arch;
    assert_eq!(out.last_logits.len(), arch.vocab);
    assert_eq!(
        out.new_kv.len(),
        tokens.len() * arch.kv_floats_per_token()
    );
    assert!(out.last_logits.iter().all(|x| x.is_finite()));
}

#[test]
fn prefill_is_deterministic() {
    let model = require_artifacts!(load("tiny-gqa"));
    let tokens: Vec<i32> = vec![5, 9, 200, 37, 42];
    let a = model.prefill(&[], &tokens).unwrap();
    let b = model.prefill(&[], &tokens).unwrap();
    assert_eq!(a.last_logits, b.last_logits);
    assert_eq!(a.new_kv, b.new_kv);
}

#[test]
fn kv_reuse_matches_full_prefill() {
    // The load-bearing property for RAGCache: prefill(prefix-cached +
    // rest) == prefill(full), across bucket boundaries.
    let model = require_artifacts!(load("tiny-gqa"));
    let tokens: Vec<i32> = (0..40).map(|i| (i * 7 + 3) % 500).collect();

    let full = model.prefill(&[], &tokens).unwrap();

    for split in [8usize, 20, 39] {
        let first = model.prefill(&[], &tokens[..split]).unwrap();
        let rest = model
            .prefill(&first.new_kv, &tokens[split..])
            .unwrap();
        let max_err = full
            .last_logits
            .iter()
            .zip(&rest.last_logits)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        assert!(
            max_err < 2e-4,
            "split {split}: logits diverge by {max_err}"
        );
    }
}

#[test]
fn mha_variant_also_works() {
    let model = require_artifacts!(load("tiny-mha"));
    let tokens: Vec<i32> = vec![1, 2, 3, 4, 5, 6, 7, 8];
    let full = model.prefill(&[], &tokens).unwrap();
    let first = model.prefill(&[], &tokens[..4]).unwrap();
    let rest = model.prefill(&first.new_kv, &tokens[4..]).unwrap();
    let max_err = full
        .last_logits
        .iter()
        .zip(&rest.last_logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_err < 2e-4, "mha logits diverge by {max_err}");
}

#[test]
fn generate_reuses_kv() {
    let model = require_artifacts!(load("tiny-gqa"));
    let (tokens, kv) = model.generate(&[10, 20, 30], 5).unwrap();
    assert_eq!(tokens.len(), 5);
    let arch = &model.manifest().arch;
    // 3 prompt rows + one row per fed-back token; the final generated
    // token is never fed back, so steps - 1 decode rows.
    assert_eq!(
        kv.len() / arch.kv_floats_per_token(),
        3 + 5 - 1,
        "prompt + decoded KV rows"
    );
    // Deterministic.
    let (tokens2, _) = model.generate(&[10, 20, 30], 5).unwrap();
    assert_eq!(tokens, tokens2);
}

#[test]
fn bucket_overflow_is_clean_error() {
    let model = require_artifacts!(load("tiny-gqa"));
    let arch_kv = model.manifest().arch.kv_floats_per_token();
    let max_alpha = model.manifest().max_alpha();
    let too_long = vec![0f32; (max_alpha + 1) * arch_kv];
    let err = model.prefill(&too_long, &[1, 2, 3]).unwrap_err();
    assert!(err.to_string().contains("no bucket"), "{err}");
}
