//! Integration: the sharded knowledge-tree service under concurrency —
//! randomized interleavings across shards from many threads, and a
//! deterministic proof that shards do not convoy on one another's
//! locks. PJRT-free so it runs everywhere.

use ragcache::config::PolicyKind;
use ragcache::controller::ShardedCacheService;
use ragcache::kvcache::PageSpec;
use ragcache::policy::make_policy;
use ragcache::tree::KnowledgeTree;
use ragcache::util::Rng;
use std::sync::mpsc;

fn page() -> PageSpec {
    PageSpec {
        block_tokens: 8,
        kv_bytes_per_token: 16,
    }
}

fn sharded(
    k: usize,
    gpu_tokens: usize,
    host_tokens: usize,
) -> ShardedCacheService {
    let p = page();
    ShardedCacheService::build(k, |_| {
        KnowledgeTree::new(
            p.bytes(gpu_tokens),
            p.bytes(host_tokens),
            p,
            make_policy(PolicyKind::Pgdsf),
            true,
            0,
        )
    })
}

/// Randomized interleaving: ≥6 threads hammer admit/commit/release and
/// mid-flight GPU failures across 4 shards with tiny tier budgets
/// (constant eviction pressure). Afterwards every shard's structural
/// invariants hold and every pin has been returned.
#[test]
fn randomized_interleaving_across_shards_respects_invariants() {
    let svc = sharded(4, 64, 256);
    let threads = 8;
    let ops = 250;
    let mut handles = Vec::new();
    for t in 0..threads {
        let svc = svc.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0x5AD + t as u64);
            for i in 0..ops {
                let a = rng.below(16) as u32;
                let b = rng.below(16) as u32;
                let docs = [(a, 16usize), (b, 16usize)];
                let adm = svc.admit(&docs, 8);
                assert_eq!(adm.shard, a as usize % 4, "first-doc routing");
                assert_eq!(
                    adm.path.len(),
                    adm.matched_docs,
                    "pinned path covers exactly the matched prefix"
                );
                match i % 7 {
                    0 => svc.release(&adm), // aborted speculation
                    1 => {
                        // GPU failure on the owning shard while this
                        // admission is in flight; commit must still
                        // return the pins and degrade gracefully.
                        svc.shard(adm.shard).fail_gpu();
                        svc.commit(&adm, 1e-3, i as f64, None);
                    }
                    _ => {
                        svc.touch_hits(&adm, 1e-3, i as f64);
                        svc.commit(&adm, 1e-3, i as f64, None);
                    }
                }
                if i % 50 == 0 {
                    // Per-shard invariants hold mid-flight too (pins
                    // excepted — other threads legitimately hold some).
                    svc.check_invariants();
                }
            }
        }));
    }
    for h in handles {
        h.join().expect("no hammering thread panicked");
    }
    svc.check_invariants();
    assert_eq!(
        svc.pinned_nodes(),
        0,
        "quiescent: every admission was committed or released"
    );
    let total = svc.counters();
    assert!(total.inserts > 0, "traffic exercised insertion: {total:?}");
    for s in 0..svc.num_shards() {
        assert!(
            svc.shard(s).counters().inserts > 0,
            "shard {s} saw no traffic"
        );
    }
}

/// Acceptance (no lock convoying): while one shard's tree lock is HELD,
/// admissions against another shard run to completion. Under a single
/// global tree lock this test would deadlock — admission on shard 1
/// could never start until the blocked "shard 0" accessor returned.
#[test]
fn shards_admit_concurrently_while_another_shard_is_locked() {
    let svc = sharded(2, 1024, 2048);
    let (locked_tx, locked_rx) = mpsc::channel();
    let (release_tx, release_rx) = mpsc::channel::<()>();
    let holder = {
        let svc = svc.clone();
        std::thread::spawn(move || {
            // Occupy shard 0's tree lock until told to let go.
            svc.shard(0).with(|_tree| {
                locked_tx.send(()).unwrap();
                release_rx.recv().unwrap();
            });
        })
    };
    locked_rx.recv().unwrap();

    // Shard 1 admits, commits, hits and releases — all while shard 0's
    // lock is held by the other thread.
    let adm = svc.admit(&[(1, 16), (3, 16)], 8);
    assert_eq!(adm.shard, 1);
    assert_eq!(adm.matched_docs, 0);
    svc.commit(&adm, 1e-3, 0.0, None);
    let hit = svc.admit(&[(1, 16), (3, 16)], 8);
    assert_eq!(hit.matched_docs, 2, "warmed path hits on shard 1");
    svc.release(&hit);

    release_tx.send(()).unwrap();
    holder.join().unwrap();
    svc.check_invariants();
    assert_eq!(svc.pinned_nodes(), 0);
}

/// Benchmark-style: threads pinned to distinct shards admit in parallel;
/// per-shard counters sum to the aggregate, and no shard starves.
#[test]
fn distinct_shards_admit_in_parallel_and_counters_aggregate() {
    let k = 4;
    let svc = sharded(k, 4096, 8192);
    let per_thread = 100u32;
    let mut handles = Vec::new();
    for s in 0..k as u32 {
        let svc = svc.clone();
        handles.push(std::thread::spawn(move || {
            // Thread `s` only ever touches docs congruent to its shard.
            for i in 0..per_thread {
                let d = s + (i % 8) * k as u32;
                let adm = svc.admit(&[(d, 16)], 8);
                assert_eq!(adm.shard, s as usize);
                svc.commit(&adm, 1e-3, i as f64, None);
            }
        }));
    }
    for h in handles {
        h.join().expect("no admitting thread panicked");
    }
    let total = svc.counters();
    let summed: u64 = (0..k)
        .map(|s| svc.shard(s).counters().inserts)
        .sum();
    assert_eq!(total.inserts, summed, "aggregate = per-shard sum");
    for s in 0..k {
        assert_eq!(
            svc.shard(s).counters().inserts,
            8,
            "shard {s}: 8 distinct docs inserted once each"
        );
    }
    svc.check_invariants();
    assert_eq!(svc.pinned_nodes(), 0);
}
