//! Integration: SLO admission control on the REAL serving path — the
//! shed ladder ported from the open-loop simulator to `RealServer`.
//!
//! Covers the PR's contract from both ends:
//! - `--shed off` conformance: the timed entry points are bit-identical
//!   to the untimed PR 7 path (PJRT-backed, skipped without artifacts);
//! - deterministic shedding with exact `completed + shed == submitted`
//!   accounting and zero leaked pins, in the blocking batch path AND
//!   the session multiplexer;
//! - the new wire-level SLO fields (`slo_enabled` + goodput/attainment)
//!   parse and merge across engines over a real TCP round trip
//!   (PJRT-free, runs everywhere).

use ragcache::controller::real::{BatchRequest, RealConfig, RealServer};
use ragcache::embed::EmbeddingModel;
use ragcache::runtime::{ArtifactManifest, PjrtModel};
use ragcache::server::{
    proto, Client, QueryHandler, Server, ServerOptions,
};
use ragcache::util::Rng;
use ragcache::vectordb::{FlatIndex, VectorIndex};
use std::path::Path;
use std::time::{Duration, Instant};

fn build_server(
    num_docs: usize,
    cfg: &RealConfig,
) -> Option<RealServer> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let manifest = ArtifactManifest::load(&dir).unwrap();
    let model =
        PjrtModel::load(manifest.model("tiny-gqa").unwrap()).unwrap();
    let mut rng = Rng::new(4);
    let doc_tokens: Vec<Vec<i32>> = (0..num_docs)
        .map(|_| (0..32).map(|_| rng.index(256) as i32).collect())
        .collect();
    let dim = 16;
    let em = EmbeddingModel::new(dim, 8);
    let vecs: Vec<Vec<f32>> =
        (0..num_docs as u32).map(|d| em.document(d)).collect();
    let index: Box<dyn VectorIndex> =
        Box::new(FlatIndex::build(dim, &vecs));
    Some(RealServer::new(model, index, em, doc_tokens, cfg).unwrap())
}

fn reqs(targets: &[u32]) -> Vec<BatchRequest> {
    targets
        .iter()
        .map(|&t| BatchRequest {
            target_doc: t,
            query_tokens: (10..26).collect(),
            max_new: 3,
        })
        .collect()
}

/// `--shed off` conformance: `serve_batch_timed` must be the PR 7
/// `serve_batch`, bit for bit, no matter what waits ride along — the
/// ladder stays disabled and never observes them.
#[test]
fn shed_off_timed_path_is_bit_identical() {
    let cfg = RealConfig {
        query_noise: 0.0,
        ..RealConfig::default()
    };
    assert!(!cfg.shed, "off is the default");
    let (Some(mut a), Some(mut b)) =
        (build_server(24, &cfg), build_server(24, &cfg))
    else {
        return;
    };
    let batch = reqs(&[3, 7, 3, 11]);
    let waits = [0.0, 123.0, 4.5, 9999.0]; // ignored with shed off
    let plain = a.serve_batch(&batch, &cfg);
    let timed = b.serve_batch_timed(&batch, &waits, &cfg);
    assert_eq!(plain.len(), timed.len());
    for (p, t) in plain.iter().zip(timed.iter()) {
        let (p, t) = (p.as_ref().unwrap(), t.as_ref().unwrap());
        assert_eq!(p.docs, t.docs);
        assert_eq!(p.output_tokens, t.output_tokens);
        assert_eq!(p.cached_tokens, t.cached_tokens);
        assert_eq!(p.computed_tokens, t.computed_tokens);
        assert_eq!(p.docs_hit, t.docs_hit);
    }
    for s in [a.proto_stats(), b.proto_stats()] {
        assert!(!s.slo_enabled, "off path must say so on the wire");
        assert_eq!(s.shed_requests, 0);
        assert_eq!(s.downgraded_requests, 0);
        assert_eq!(s.goodput_rps, 0.0);
        assert_eq!(s.slo_attainment, 0.0);
        assert_eq!(s.requests, 4);
        // p99.9 TTFT is a pure measurement: reported even with the
        // ladder off (the old wire path zero-filled it).
        assert!(s.ttft_p999_ms > 0.0);
    }
}

/// Blocking path: members whose measured queue wait already exceeds the
/// TTFT SLO are shed deterministically — exact accounting, no pins left
/// behind, and the wire stats report the ladder's work end to end.
#[test]
fn blocking_shed_exact_accounting_no_leaked_pins() {
    let cfg = RealConfig {
        query_noise: 0.0,
        shed: true,
        ttft_slo_s: 30.0,
        ..RealConfig::default()
    };
    let Some(mut server) = build_server(24, &cfg) else {
        return;
    };
    let batch = reqs(&[2, 5, 8, 2, 9]);
    // Members 1 and 3 were queued past the 30 s SLO; the rest were
    // popped immediately. Deterministic: shedding keys off the supplied
    // wait, not off wall-clock races.
    let waits = [0.0, 31.0, 0.0, 40.0, 0.0];
    let results = server.serve_batch_timed(&batch, &waits, &cfg);
    assert_eq!(results.len(), 5);
    for (i, r) in results.iter().enumerate() {
        if waits[i] > cfg.ttft_slo_s {
            let msg = r.as_ref().err().expect("expired member sheds");
            assert!(msg.to_string().contains("shed"), "{msg}");
        } else {
            assert!(r.is_ok(), "unexpired member serves: {r:?}");
        }
    }
    let stats = server.stats();
    assert_eq!(stats.requests, 5, "shed members are still recorded");
    assert_eq!(stats.shed_requests, 2);
    assert!(stats.slo_enabled);
    // 3 completions + 2 sheds == 5 submitted, exactly.
    let completed =
        results.iter().filter(|r| r.is_ok()).count() as u64;
    assert_eq!(completed + stats.shed_requests, 5);
    // Shed members never touched admission; served members released
    // their pins at commit. Nothing may remain pinned.
    assert_eq!(server.cache().pinned_nodes(), 0, "leaked pins");
    server.cache().check_invariants();
    let wire = server.proto_stats();
    assert!(wire.slo_enabled);
    assert_eq!(wire.shed_requests, 2);
    assert!(wire.goodput_rps > 0.0, "served-in-SLO over the horizon");
    assert!(wire.slo_attainment > 0.0);
    assert!(wire.slo_attainment < 1.0, "sheds miss the SLO");
}

/// Session multiplexer: a session whose TTFT deadline expires while the
/// staged search is still running is shed by `poll_sessions` — its
/// staged retrieval cancelled, any speculation pins released — exactly
/// like the sim path's `DeadlineExpired`.
#[test]
fn session_shed_on_slow_retrieval_releases_everything() {
    let cfg = RealConfig {
        query_noise: 0.0,
        speculate: true,
        stages: 4,
        retrieval_threads: 1,
        // 4 stages x 250 ms: the final stage lands ~1 s after submit,
        // far past the 300 ms SLO — every session must shed, some after
        // stage 0 already started a speculative prefill.
        stage_latency_s: 0.25,
        shed: true,
        ttft_slo_s: 0.3,
        ..RealConfig::default()
    };
    let Some(mut server) = build_server(24, &cfg) else {
        return;
    };
    let mut ids = Vec::new();
    for r in reqs(&[4, 9, 4]) {
        ids.push(server.submit_timed(&r, 0.0, &cfg).unwrap());
    }
    let mut done = Vec::new();
    let deadline = Instant::now() + Duration::from_secs(20);
    while done.len() < ids.len() && Instant::now() < deadline {
        done.extend(server.poll_sessions(Duration::from_millis(20), &cfg));
    }
    assert_eq!(done.len(), 3, "every session answers");
    for (id, r) in &done {
        let msg = r.as_ref().err().expect("expired session sheds");
        assert!(msg.to_string().contains("shed"), "session {id}: {msg}");
    }
    let stats = server.stats();
    assert_eq!(stats.shed_requests, 3);
    assert_eq!(stats.requests, 3);
    assert_eq!(server.in_flight_sessions(), 0);
    assert_eq!(server.cache().pinned_nodes(), 0, "leaked pins");
    server.cache().check_invariants();

    // Second server, same ladder but a feasible SLO: sessions complete,
    // nothing sheds, and the SLO wire fields are live (non-zero goodput
    // and attainment with `slo_enabled`).
    let cfg2 = RealConfig {
        query_noise: 0.0,
        speculate: true,
        shed: true,
        ttft_slo_s: 30.0,
        ..RealConfig::default()
    };
    let Some(mut ok_server) = build_server(24, &cfg2) else {
        return;
    };
    let results = ok_server.serve_batch_timed(
        &reqs(&[6, 12]),
        &[0.0, 0.0],
        &cfg2,
    );
    assert!(results.iter().all(|r| r.is_ok()), "{results:?}");
    let wire = ok_server.proto_stats();
    assert!(wire.slo_enabled);
    assert_eq!(wire.shed_requests, 0);
    assert!(wire.goodput_rps > 0.0);
    assert!((wire.slo_attainment - 1.0).abs() < 1e-9);
    assert_eq!(ok_server.cache().pinned_nodes(), 0);
}

/// Mock engine that answers `Stats` with a preset report — lets the
/// wire/merge assertions run PJRT-free.
struct SloStatsHandler {
    stats: proto::StatsResult,
}

impl QueryHandler for SloStatsHandler {
    fn query(
        &mut self,
        target_doc: u32,
        _query: &str,
        max_new: usize,
    ) -> anyhow::Result<proto::QueryResult> {
        Ok(proto::QueryResult {
            id: 1,
            docs: vec![target_doc],
            docs_hit: 0,
            cached_tokens: 0,
            computed_tokens: max_new,
            ttft_ms: 1.0,
            total_ms: 1.0,
            text: String::new(),
        })
    }

    fn stats(&self) -> proto::StatsResult {
        self.stats.clone()
    }
}

/// The new SLO fields survive a real TCP round trip and merge correctly
/// across engines: shed/downgrade/goodput counters sum, p99.9 TTFT
/// max-merges, `slo_enabled` ORs, and attainment is weighted ONLY over
/// engines that measured an SLO — a ladder-off engine's (meaningless)
/// attainment can no longer read as "0% attained" and dilute the fleet.
#[test]
fn slo_fields_roundtrip_and_merge_over_tcp() {
    let opts = ServerOptions {
        engines: 2,
        ..ServerOptions::default()
    };
    let server = Server::spawn_sharded(0, opts, |engine| {
        Ok(SloStatsHandler {
            stats: if engine == 0 {
                // Ladder-off engine. Its attainment slot holds junk on
                // purpose: `slo_enabled: false` must gate it out of the
                // merge entirely (the old wire format had no such flag
                // and zero-filled everything).
                proto::StatsResult {
                    requests: 10,
                    slo_enabled: false,
                    slo_attainment: 0.25,
                    ..Default::default()
                }
            } else {
                proto::StatsResult {
                    requests: 30,
                    goodput_rps: 1.5,
                    ttft_p999_ms: 250.0,
                    shed_requests: 4,
                    downgraded_requests: 2,
                    slo_attainment: 0.8,
                    slo_enabled: true,
                    ..Default::default()
                }
            },
        })
    })
    .expect("spawn");
    let mut client = Client::connect(server.addr).unwrap();
    match client.call(&proto::Request::Stats).unwrap() {
        proto::Response::Stats(s) => {
            assert_eq!(s.engines, 2, "both engines answered");
            assert_eq!(s.requests, 40);
            assert!(s.slo_enabled, "one SLO engine flips the flag");
            assert_eq!(s.shed_requests, 4, "summed");
            assert_eq!(s.downgraded_requests, 2, "summed");
            assert!((s.goodput_rps - 1.5).abs() < 1e-9, "summed");
            assert!(
                (s.ttft_p999_ms - 250.0).abs() < 1e-9,
                "max-merged"
            );
            assert!(
                (s.slo_attainment - 0.8).abs() < 1e-9,
                "weighted only over SLO-measuring engines, \
                 got {}",
                s.slo_attainment
            );
        }
        other => panic!("unexpected {other:?}"),
    }
    server.stop();
}
