//! Discrete-event simulator acceptance suite.
//!
//! Two pillars of the open-loop refactor land here:
//!
//! 1. **Conformance** — `LegacySim` below is a faithful port of the
//!    iteration-driven simulation driver this PR replaced (the
//!    `EventQueue` + `pump()` controller), rebuilt from the crate's
//!    public APIs. With shedding off, the event-handler rewrite must be
//!    *bit-identical* to it on closed-feasible traces: every per-request
//!    timestamp, every counter, every PCIe byte.
//! 2. **Overload acceptance** — at far-beyond-sustainable arrival rates
//!    the open loop must build queues without deadlocking, `--shed on`
//!    must strictly win goodput-under-SLO over `--shed off`, and the
//!    per-tenant breakdown must sum exactly to the aggregate.

use std::collections::HashMap;

use ragcache::config::{PolicyKind, SystemConfig, SystemKind};
use ragcache::controller::pipeline::{
    request_of, Admission, Pipeline, PipelineDriver,
};
use ragcache::controller::{
    split_budget, BatchAdmission, RebalanceConfig, RetrievalTiming,
    ShardedCacheService, SimOutcome, SimServer, StagedRetrieval,
};
use ragcache::kvcache::{PageSpec, TransferModel};
use ragcache::llm::cost_model::{CostModel, CostProfile};
use ragcache::llm::engine::{AbortOutcome, Engine, SeqEvent, SeqSpec};
use ragcache::llm::models::{GpuSpec, ModelSpec};
use ragcache::metrics::Recorder;
use ragcache::policy::make_policy;
use ragcache::sched::PendingRequest;
use ragcache::sim::{Clock, EventQueue, SimClock};
use ragcache::spec::SpecAction;
use ragcache::tree::{DocId, KnowledgeTree};
use ragcache::util::Rng;
use ragcache::workload::{
    datasets::MMLU, ArrivalProcess, Corpus, Trace, TraceOptions,
};

// ---------------------------------------------------------------------
// LegacySim: the pre-refactor iteration-driven driver, ported verbatim
// (minus wall-clock sched-time accounting, which is excluded from the
// comparison anyway). Its `pump()` ran after every popped event; the
// rewrite calls the same logic `service_queues()` after every handled
// event — conformance holds iff both pop the identical event sequence
// and perform the identical per-event work.
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
enum Event {
    Arrival(usize),
    Stage { req: usize, stage: usize },
    EngineDone(u64),
}

struct LegacyDriver {
    clock: SimClock,
    transfer: TransferModel,
    profile: CostProfile,
}

impl PipelineDriver for LegacyDriver {
    fn now(&self) -> f64 {
        self.clock.now()
    }

    fn transfer_time(&self, bytes: u64) -> f64 {
        self.transfer.transfer_time(bytes)
    }
}

struct LegacyOutcome {
    recorder: Recorder,
    tree_counters: Option<ragcache::tree::TreeCounters>,
    spec_started: u64,
    spec_wasted: u64,
    spec_promoted: u64,
    completed: usize,
    pcie_h2g_bytes: u64,
    pcie_g2h_bytes: u64,
}

struct LegacySim {
    driver: LegacyDriver,
    events: EventQueue<Event>,
    engine: Engine,
    pipeline: Pipeline,
    timing: RetrievalTiming,
    spec_enabled: bool,
    max_batch: usize,
    batch_token_budget: usize,
    admit_infos: HashMap<u64, Admission>,
    gen_docs: HashMap<u64, Vec<DocId>>,
    trace: Trace,
    rng: Rng,
    num_docs: usize,
    deferred_commit_s: f64,
    inflight_epoch: Option<u64>,
    next_epoch: u64,
    pcie_h2g_bytes: u64,
    pcie_g2h_bytes: u64,
}

impl LegacySim {
    fn build(
        cfg: &SystemConfig,
        trace: Trace,
        num_docs: usize,
        timing: RetrievalTiming,
        seed: u64,
    ) -> LegacySim {
        let model = ModelSpec::lookup(&cfg.engine.model).unwrap();
        let gpu = GpuSpec::lookup(&cfg.engine.gpu).unwrap();
        let cost = CostModel::new(model.clone(), gpu.clone());
        let profile = cost.profile(65536, 65536);
        let engine = Engine::new(
            cost,
            cfg.engine.max_batch,
            cfg.engine.max_prefill_tokens,
        );
        let page = PageSpec {
            block_tokens: cfg.cache.block_tokens,
            kv_bytes_per_token: model.kv_bytes_per_token,
        };
        let kind = *cfg.kind;
        let cache = match kind {
            SystemKind::VllmLike => None,
            SystemKind::SglangLike => {
                Some(ShardedCacheService::single(KnowledgeTree::new(
                    cfg.cache.gpu_bytes,
                    0,
                    page,
                    make_policy(PolicyKind::Lru),
                    false,
                    0,
                )))
            }
            SystemKind::RagCache => {
                let k = cfg.cache.shards.max(1);
                let gpu_slices = split_budget(cfg.cache.gpu_bytes, k);
                let host_slices = split_budget(cfg.cache.host_bytes, k);
                let mut svc = ShardedCacheService::build(k, |i| {
                    let mut tree = KnowledgeTree::new(
                        gpu_slices[i],
                        host_slices[i],
                        page,
                        make_policy(cfg.cache.policy),
                        cfg.cache.swap_out_only_once,
                        0,
                    );
                    if cfg.cache.chunk_cache {
                        tree.enable_chunk_cache(
                            cfg.cache.boundary_tokens,
                        );
                    }
                    tree
                });
                if cfg.cache.rebalance {
                    svc.enable_rebalancing(RebalanceConfig {
                        interval: cfg.cache.rebalance_interval.max(1)
                            as u64,
                        ..RebalanceConfig::default()
                    });
                }
                Some(svc)
            }
        };
        let reorder = kind == SystemKind::RagCache && cfg.sched.reorder;
        let spec_enabled =
            kind == SystemKind::RagCache && cfg.spec.enabled;
        let transfer = if cfg.engine.gpu == "h800x2" {
            TransferModel::pcie5()
        } else {
            TransferModel::pcie4()
        };
        let mut pipeline =
            Pipeline::new(cache, reorder, cfg.sched.window);
        pipeline.reserve_requests(trace.requests.len());
        LegacySim {
            driver: LegacyDriver {
                clock: SimClock::new(),
                transfer,
                profile,
            },
            events: EventQueue::new(),
            engine,
            pipeline,
            timing,
            spec_enabled,
            max_batch: cfg.engine.max_batch,
            batch_token_budget: cfg.engine.max_prefill_tokens,
            admit_infos: HashMap::new(),
            gen_docs: HashMap::new(),
            trace,
            rng: Rng::new(seed ^ 0x51_C0_FF_EE),
            num_docs,
            deferred_commit_s: 0.0,
            inflight_epoch: None,
            next_epoch: 0,
            pcie_h2g_bytes: 0,
            pcie_g2h_bytes: 0,
        }
    }

    fn run(mut self) -> LegacyOutcome {
        for i in 0..self.trace.requests.len() {
            let at = self.trace.requests[i].arrival;
            self.events.schedule(at, Event::Arrival(i));
        }
        while let Some((t, ev)) = self.events.next() {
            self.driver.clock.advance_to(t);
            match ev {
                Event::Arrival(i) => self.on_arrival(i),
                Event::Stage { req, stage } => self.on_stage(req, stage),
                Event::EngineDone(epoch) => self.on_engine_done(epoch),
            }
            self.pump();
        }
        let completed =
            self.pipeline.requests.iter().filter(|r| r.done).count();
        LegacyOutcome {
            tree_counters: self
                .pipeline
                .cache
                .as_ref()
                .map(|c| c.counters()),
            spec_started: self
                .pipeline
                .requests
                .iter()
                .map(|r| r.spec.started)
                .sum(),
            spec_wasted: self
                .pipeline
                .requests
                .iter()
                .map(|r| r.spec.wasted)
                .sum(),
            spec_promoted: self
                .pipeline
                .requests
                .iter()
                .map(|r| r.spec.promoted)
                .sum(),
            completed,
            pcie_h2g_bytes: self.pcie_h2g_bytes,
            pcie_g2h_bytes: self.pcie_g2h_bytes,
            recorder: self.pipeline.recorder,
        }
    }

    fn now(&self) -> f64 {
        self.driver.now()
    }

    fn on_arrival(&mut self, i: usize) {
        let now = self.now();
        self.pipeline.recorder.arrival(i as u64, now);
        let docs = self.trace.requests[i].docs.clone();
        let plan = if self.spec_enabled {
            StagedRetrieval::plan(
                &docs,
                self.num_docs,
                &self.timing,
                &mut self.rng,
            )
        } else {
            StagedRetrieval::single(&docs, &self.timing)
        };
        for (s, stage) in plan.stages.iter().enumerate() {
            self.events.schedule(
                now + stage.offset,
                Event::Stage { req: i, stage: s },
            );
        }
        self.pipeline.requests[i].active_docs = Vec::new();
        self.pipeline.requests[i].plan = Some(plan);
    }

    fn on_stage(&mut self, req: usize, stage: usize) {
        let now = self.now();
        let sp = self.pipeline.requests[req]
            .plan
            .as_ref()
            .expect("stage plan exists")
            .stages[stage]
            .clone();
        let pool_len =
            self.engine.waiting_len() + self.pipeline.queue.len();
        let action = self.pipeline.requests[req].spec.on_stage(
            &sp.docs,
            pool_len,
            self.max_batch,
            sp.is_final,
        );
        match action {
            SpecAction::Start { terminate_prev } => {
                if terminate_prev {
                    self.abort_generation(req);
                }
                self.start_generation(req, &sp.docs);
            }
            SpecAction::Keep => {}
            SpecAction::Defer { terminate_prev } => {
                if terminate_prev {
                    self.abort_generation(req);
                }
            }
        }
        if sp.is_final {
            let output_tokens = self.trace.requests[req].output_tokens;
            self.pipeline.confirm_final(
                req,
                now,
                output_tokens,
                self.timing.full_search_s,
            );
        }
    }

    fn abort_generation(&mut self, req: usize) {
        let Some(seq) = self.pipeline.requests[req].active_seq.take()
        else {
            return;
        };
        self.pipeline.queue.remove(seq);
        match self.engine.abort(seq) {
            AbortOutcome::Deferred => {
                if self.engine.in_flight_fully_killed() {
                    for id in self.engine.cancel_in_flight() {
                        if let Some(adm) = self.admit_infos.remove(&id)
                        {
                            self.pipeline.abort_admission(&adm);
                        }
                    }
                    self.inflight_epoch = None;
                }
            }
            AbortOutcome::Removed | AbortOutcome::NotFound => {
                if let Some(adm) = self.admit_infos.remove(&seq) {
                    self.pipeline.abort_admission(&adm);
                }
            }
        }
        self.pipeline.requests[req].spec_first_token_at = None;
        self.pipeline.requests[req].spec_finished_at = None;
    }

    fn start_generation(&mut self, req: usize, docs: &[DocId]) {
        let now = self.now();
        let doc_tokens_total: usize =
            docs.iter().map(|&d| self.doc_tokens(req, d)).sum();
        let tr = &self.trace.requests[req];
        let arrival = tr.arrival;
        let request_tokens = tr.request_tokens;
        let is_final_docs = docs == tr.docs.as_slice();
        let (cached, compute) = self.pipeline.queue_lengths(
            docs,
            doc_tokens_total,
            request_tokens,
        );
        let seq =
            self.pipeline.requests[req].begin_generation(req, docs);
        if is_final_docs
            && self.pipeline.requests[req].final_enqueue_at.is_none()
        {
            self.pipeline.requests[req].final_enqueue_at = Some(now);
        }
        self.gen_docs.insert(seq, docs.to_vec());
        self.pipeline.queue.push(PendingRequest {
            id: seq,
            arrival,
            cached_tokens: cached,
            compute_tokens: compute,
            bypassed: 0,
        });
    }

    /// The historical O(k) linear scan + mean fallback — the satellite
    /// fix replaced it with per-request maps; values must be identical.
    fn doc_tokens(&self, req: usize, doc: DocId) -> usize {
        let tr = &self.trace.requests[req];
        for (i, &d) in tr.docs.iter().enumerate() {
            if d == doc {
                return tr.doc_tokens[i];
            }
        }
        let sum: usize = tr.doc_tokens.iter().sum();
        (sum / tr.doc_tokens.len().max(1)).max(1)
    }

    fn pump(&mut self) {
        if let Some(cache) = &self.pipeline.cache {
            if let Some(moved) = cache.maintenance_tick() {
                self.pcie_h2g_bytes += moved.h2g_bytes;
                self.pcie_g2h_bytes += moved.g2h_bytes;
                self.deferred_commit_s += self
                    .driver
                    .transfer_time(moved.h2g_bytes + moved.g2h_bytes);
            }
        }
        loop {
            let in_engine =
                self.engine.waiting_len() + self.engine.decoding_len();
            if in_engine >= self.max_batch
                || self.pipeline.queue.is_empty()
            {
                break;
            }
            let slots = self.max_batch - in_engine;
            let pending = self
                .pipeline
                .queue
                .pop_batch(slots, self.batch_token_budget);
            self.admit_batch(pending);
        }
        if self.inflight_epoch.is_none() {
            if let Some(plan) = self.engine.plan() {
                let epoch = self.next_epoch;
                self.next_epoch += 1;
                self.inflight_epoch = Some(epoch);
                let commit_burst = std::mem::replace(
                    &mut self.deferred_commit_s,
                    0.0,
                );
                self.events.schedule(
                    self.now() + plan.duration + commit_burst,
                    Event::EngineDone(epoch),
                );
            }
        }
    }

    fn admit_batch(&mut self, pending: Vec<PendingRequest>) {
        let now = self.now();
        let mut batch = BatchAdmission::new();
        let mut specs: Vec<SeqSpec> = Vec::new();
        for p in pending {
            let req = request_of(p.id);
            if !self.pipeline.requests[req].is_live(p.id) {
                continue;
            }
            let docs = self.gen_docs[&p.id].clone();
            let docs_tokens: Vec<(DocId, usize)> = docs
                .iter()
                .map(|&d| (d, self.doc_tokens(req, d)))
                .collect();
            let tr = &self.trace.requests[req];
            let request_tokens = tr.request_tokens;
            let output_tokens = tr.output_tokens;
            let is_final_docs = docs == tr.docs.as_slice();

            let mut adm =
                self.pipeline.admit_one(&docs_tokens, request_tokens);
            let estimated_time =
                self.driver.profile.estimate(adm.alpha, adm.beta);
            adm.estimated_time = estimated_time;
            self.pipeline.touch_hits(&adm, estimated_time, now);
            if is_final_docs {
                self.pipeline
                    .record_admission(req as u64, docs.len(), &adm);
            }
            specs.push(SeqSpec {
                id: p.id,
                alpha: adm.alpha,
                beta: adm.beta,
                output_tokens,
                extra_time: 0.0,
            });
            self.pcie_h2g_bytes += adm.transfers.h2g_bytes;
            self.pcie_g2h_bytes += adm.transfers.g2h_bytes;
            batch.push(p.id, adm);
        }
        let burst = batch.seal(&self.driver);
        if let Some(first) = specs.first_mut() {
            first.extra_time = burst;
        }
        for spec in specs {
            self.engine.admit(spec);
        }
        for (id, adm) in batch.into_members() {
            self.admit_infos.insert(id, adm);
        }
    }

    fn on_engine_done(&mut self, epoch: u64) {
        if self.inflight_epoch != Some(epoch) {
            return;
        }
        self.inflight_epoch = None;
        let now = self.now();
        let events = self.engine.complete();
        let mut commits = BatchAdmission::new();
        for ev in events {
            match ev {
                SeqEvent::FirstToken { id } => {
                    let moved = self.on_first_token(id, now);
                    commits.push_commit(moved);
                }
                SeqEvent::Finished { id } => self.on_finished(id, now),
            }
        }
        self.deferred_commit_s += commits.seal_commit(&self.driver);
    }

    fn on_first_token(
        &mut self,
        seq: u64,
        now: f64,
    ) -> ragcache::tree::Transfers {
        let req = request_of(seq);
        let mut moved = ragcache::tree::Transfers::default();
        if let Some(adm) = self.admit_infos.remove(&seq) {
            let out = self.pipeline.commit_prefill(
                &adm,
                adm.estimated_time,
                now,
                None,
            );
            moved = out.transfers;
            self.pcie_h2g_bytes += moved.h2g_bytes;
            self.pcie_g2h_bytes += moved.g2h_bytes;
        }
        self.pipeline.deliver_first_token(
            req,
            seq,
            &self.trace.requests[req].docs,
            now,
        );
        moved
    }

    fn on_finished(&mut self, seq: u64, now: f64) {
        let req = request_of(seq);
        self.pipeline.deliver_finished(
            req,
            seq,
            &self.trace.requests[req].docs,
            self.trace.requests[req].output_tokens,
            now,
        );
    }
}

// ---------------------------------------------------------------------
// Helpers
// ---------------------------------------------------------------------

fn cfg_for(kind: &str) -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.kind = ragcache::config::SystemKindField(
        SystemKind::parse(kind).unwrap(),
    );
    cfg.cache.gpu_bytes = 8 * (1 << 30);
    cfg.cache.host_bytes = 192 * (1 << 30);
    cfg
}

/// Bit-exact comparison of every per-request lifecycle record.
fn assert_records_identical(a: &Recorder, b: &Recorder, n: usize) {
    assert_eq!(a.len(), b.len());
    for i in 0..n as u64 {
        let (ra, rb) = (a.record(i).unwrap(), b.record(i).unwrap());
        let bits = |x: Option<f64>| x.map(f64::to_bits);
        assert_eq!(
            ra.arrival.to_bits(),
            rb.arrival.to_bits(),
            "req {i} arrival"
        );
        assert_eq!(
            bits(ra.retrieval_done),
            bits(rb.retrieval_done),
            "req {i} retrieval_done"
        );
        assert_eq!(
            bits(ra.first_token),
            bits(rb.first_token),
            "req {i} first_token"
        );
        assert_eq!(
            bits(ra.finished),
            bits(rb.finished),
            "req {i} finished"
        );
        assert_eq!(ra.shed, rb.shed, "req {i} shed");
        assert_eq!(ra.docs_retrieved, rb.docs_retrieved, "req {i}");
        assert_eq!(ra.docs_hit, rb.docs_hit, "req {i}");
        assert_eq!(ra.cached_tokens, rb.cached_tokens, "req {i}");
        assert_eq!(ra.computed_tokens, rb.computed_tokens, "req {i}");
        assert_eq!(
            ra.non_overlapped_search.to_bits(),
            rb.non_overlapped_search.to_bits(),
            "req {i} non_overlapped_search"
        );
        assert_eq!(ra.output_tokens, rb.output_tokens, "req {i}");
    }
}

// ---------------------------------------------------------------------
// 1. Conformance: shed off == the iteration-driven predecessor, bit
//    for bit, across all three system kinds.
// ---------------------------------------------------------------------

#[test]
fn shed_off_matches_iteration_driven_predecessor() {
    let corpus = Corpus::wikipedia_like(2_000, 1);
    for kind in ["ragcache", "vllm", "sglang"] {
        let cfg = cfg_for(kind);
        assert!(!cfg.shed.enabled, "shed must default off");
        let n = 60;
        let mk = || Trace::generate(&MMLU, &corpus, 0.5, n, 2, 11);
        let new = SimServer::build(
            &cfg,
            mk(),
            2_000,
            RetrievalTiming::default(),
            5,
        )
        .unwrap()
        .run();
        let old = LegacySim::build(
            &cfg,
            mk(),
            2_000,
            RetrievalTiming::default(),
            5,
        )
        .run();
        assert_eq!(new.completed, old.completed, "{kind}");
        assert_eq!(new.completed, n, "{kind}: trace is feasible");
        assert_eq!(new.shed_requests, 0, "{kind}");
        assert_eq!(new.downgraded_requests, 0, "{kind}");
        assert_eq!(new.spec_started, old.spec_started, "{kind}");
        assert_eq!(new.spec_wasted, old.spec_wasted, "{kind}");
        assert_eq!(new.spec_promoted, old.spec_promoted, "{kind}");
        assert_eq!(new.pcie_h2g_bytes, old.pcie_h2g_bytes, "{kind}");
        assert_eq!(new.pcie_g2h_bytes, old.pcie_g2h_bytes, "{kind}");
        // Integer counter structs: exact via their Debug rendering.
        assert_eq!(
            format!("{:?}", new.tree_counters),
            format!("{:?}", old.tree_counters),
            "{kind}"
        );
        assert_records_identical(&new.recorder, &old.recorder, n);
        assert_eq!(
            new.recorder.ttft().mean().to_bits(),
            old.recorder.ttft().mean().to_bits(),
            "{kind}"
        );
    }
}

/// Conformance also holds for the sharded + rebalancing configuration:
/// the maintenance ticks run at identical event boundaries.
#[test]
fn shed_off_matches_predecessor_with_rebalancing() {
    let corpus = Corpus::wikipedia_like(2_000, 1);
    let mut cfg = cfg_for("ragcache");
    cfg.cache.shards = 4;
    cfg.cache.rebalance = true;
    cfg.cache.rebalance_interval = 8;
    let mk = || Trace::generate(&MMLU, &corpus, 0.5, 60, 2, 17);
    let new = SimServer::build(
        &cfg,
        mk(),
        2_000,
        RetrievalTiming::default(),
        9,
    )
    .unwrap()
    .run();
    let old = LegacySim::build(
        &cfg,
        mk(),
        2_000,
        RetrievalTiming::default(),
        9,
    )
    .run();
    assert_eq!(new.completed, old.completed);
    assert_eq!(new.pcie_h2g_bytes, old.pcie_h2g_bytes);
    assert_eq!(new.pcie_g2h_bytes, old.pcie_g2h_bytes);
    assert_records_identical(&new.recorder, &old.recorder, 60);
}

// ---------------------------------------------------------------------
// 2. Overload acceptance.
// ---------------------------------------------------------------------

fn overload_trace(corpus: &Corpus, rate: f64) -> Trace {
    Trace::generate_open_loop(
        &MMLU,
        corpus,
        rate,
        120,
        &TraceOptions {
            tenants: 4,
            ..TraceOptions::default()
        },
        11,
    )
}

fn run_shed(
    cfg: &SystemConfig,
    trace: Trace,
    num_docs: usize,
) -> SimOutcome {
    SimServer::build(cfg, trace, num_docs, RetrievalTiming::default(), 5)
        .unwrap()
        .run()
}

/// At ~2x+ the sustainable rate: queues build without deadlock (both
/// runs terminate), shedding strictly wins goodput under the SLO, and
/// the per-tenant breakdown sums exactly to the aggregate.
#[test]
fn shed_on_strictly_wins_goodput_under_overload() {
    let corpus = Corpus::wikipedia_like(2_000, 1);
    // Calibrate: SLO = 3x the uncongested mean TTFT (closed-feasible
    // trickle), then offer load far beyond what batch-4 prefill drains.
    let base_trace = Trace::generate(&MMLU, &corpus, 0.3, 40, 2, 11);
    let mut cfg = cfg_for("ragcache");
    let base = run_shed(&cfg, base_trace, 2_000);
    assert_eq!(base.completed, 40);
    let slo = (3.0 * base.recorder.ttft().mean()).max(0.2);
    cfg.shed.ttft_slo_s = slo;

    let off = run_shed(&cfg, overload_trace(&corpus, 50.0), 2_000);
    cfg.shed.enabled = true;
    let on = run_shed(&cfg, overload_trace(&corpus, 50.0), 2_000);

    // Open loop without shedding: everything eventually completes, but
    // the tail blows far past the SLO (queues really built up).
    assert_eq!(off.completed, 120);
    assert_eq!(off.shed_requests, 0);
    let mut off_ttft = off.recorder.ttft();
    assert!(off_ttft.p999() > slo, "overload must violate the SLO");

    // Shedding: strictly better goodput, exact accounting.
    assert!(on.shed_requests > 0);
    assert_eq!(on.completed + on.shed_requests, 120);
    let (g_on, g_off) =
        (on.recorder.goodput(slo), off.recorder.goodput(slo));
    assert!(
        g_on > g_off,
        "shed on must strictly win goodput: {g_on} vs {g_off}"
    );
    assert!(
        on.recorder.slo_attainment(slo)
            >= off.recorder.slo_attainment(slo)
    );

    let per = on.recorder.per_tenant(slo);
    assert_eq!(per.len(), 4);
    assert_eq!(per.iter().map(|t| t.requests).sum::<usize>(), 120);
    assert_eq!(
        per.iter().map(|t| t.completed).sum::<usize>(),
        on.completed
    );
    assert_eq!(
        per.iter().map(|t| t.shed).sum::<usize>(),
        on.shed_requests
    );
    assert_eq!(
        per.iter().map(|t| t.downgraded).sum::<usize>(),
        on.downgraded_requests
    );
    let agg_ok =
        (on.recorder.slo_attainment(slo) * 120.0).round() as usize;
    assert_eq!(per.iter().map(|t| t.slo_ok).sum::<usize>(), agg_ok);
}

/// The full CLI matrix of arrival processes × tenancy runs through the
/// event core: every combination terminates with every request either
/// completed or shed, and non-poisson arrivals parse their defaults.
#[test]
fn arrival_matrix_terminates_with_exact_accounting() {
    let corpus = Corpus::wikipedia_like(500, 2);
    for arrivals in ["poisson", "bursty", "diurnal"] {
        for tenants in [1usize, 4] {
            let trace = Trace::generate_open_loop(
                &MMLU,
                &corpus,
                8.0,
                48,
                &TraceOptions {
                    arrivals: ArrivalProcess::parse(arrivals).unwrap(),
                    tenants,
                    ..TraceOptions::default()
                },
                23,
            );
            assert_eq!(trace.num_tenants(), tenants);
            let mut cfg = cfg_for("ragcache");
            cfg.shed.enabled = true;
            cfg.shed.ttft_slo_s = 0.5;
            let out = run_shed(&cfg, trace, 500);
            assert_eq!(
                out.completed + out.shed_requests,
                48,
                "{arrivals}/{tenants}: every request accounted once"
            );
            let per = out.recorder.per_tenant(0.5);
            assert_eq!(per.len(), tenants);
            assert_eq!(
                per.iter().map(|t| t.requests).sum::<usize>(),
                48
            );
        }
    }
}
