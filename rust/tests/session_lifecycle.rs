//! Session-lifecycle property and conformance suite (PJRT-free).
//!
//! Covers the event-driven serving redesign end to end:
//! 1. **Exactly-once terminal event** per session under randomized
//!    multi-engine interleavings, with speculative admissions pinning a
//!    shared sharded cache — and **no pinned pages leaked** after
//!    `SpecCancelled` (every cancellation releases its pins).
//! 2. **`--speculate off` conformance**: the blocking path's substrate
//!    — §5.2 batched pops + the coalesced admit burst — reproduces an
//!    independent replay of the PR 3 semantics bit for bit (pop order,
//!    bypass counters, f64 admit-charge bits). The commit-side burst is
//!    the one sanctioned extension: a second one-per-batch charge over
//!    the summed commit bytes, which on the real (zero-cost) link model
//!    is 0.0 — bitwise identical to PR 3's absence of a commit charge.
//! 3. **Acceptance**: with a cold cache and retrieval-heavy timing
//!    (staged search latency ≥ prefill latency), serving through the
//!    session lifecycle with speculation cuts summed TTFT strictly
//!    below the blocking retrieve-then-prefill path.
//! 4. The `--speculate on` TCP engine loop actually multiplexes:
//!    queries flow through `submit_session`/`poll_sessions`, and with
//!    `--speculate off` the session API is never touched.

use ragcache::config::PolicyKind;
use ragcache::controller::{
    Admission, BatchAdmission, FinishPath, PipelineDriver,
    RetrievalConfig, RetrievalService, RetrievalTask, SessionEvent,
    SessionTable, ShardedCacheService, StageReady,
};
use ragcache::embed::EmbeddingModel;
use ragcache::kvcache::PageSpec;
use ragcache::policy::make_policy;
use ragcache::sched::{PendingRequest, ReorderQueue};
use ragcache::server::{
    proto, Client, QueryHandler, Server, ServerOptions, SessionDone,
};
use ragcache::tree::{KnowledgeTree, Transfers};
use ragcache::util::Rng;
use ragcache::vectordb::{FlatIndex, VectorIndex};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

const DOC_TOKENS: usize = 16;

fn sharded(
    shards: usize,
    gpu_tokens: usize,
    host_tokens: usize,
) -> ShardedCacheService {
    let page = PageSpec {
        block_tokens: 8,
        kv_bytes_per_token: 16,
    };
    ShardedCacheService::build(shards, |_| {
        KnowledgeTree::new(
            page.bytes(gpu_tokens),
            page.bytes(host_tokens),
            page,
            make_policy(PolicyKind::Pgdsf),
            true,
            0,
        )
    })
}

/// One synthetic staged-retrieval plan: candidate evolution over
/// `stages` snapshots, converging to `final_docs` at `converge_at`.
fn synth_plan(
    final_docs: &[u32],
    stages: usize,
    converge_at: usize,
    rng: &mut Rng,
) -> Vec<Vec<u32>> {
    (0..stages)
        .map(|s| {
            if s >= converge_at || final_docs.len() <= 1 {
                final_docs.to_vec()
            } else {
                let mut d = final_docs.to_vec();
                let last = d.len() - 1;
                d[last] = 1000 + rng.index(50) as u32; // wrong tail
                d
            }
        })
        .collect()
}

/// Property test 1: two engines, one shared sharded cache, randomized
/// per-engine interleaving of many sessions' stage events. Every
/// session gets exactly one terminal event, every cancellation releases
/// its pins (zero pins leaked at the end), and the speculation ledger
/// balances: every started speculation is cancelled or promoted.
#[test]
fn randomized_multi_engine_exactly_once_and_no_pin_leaks() {
    let svc = sharded(2, 64, 4096);
    let engines = 2;
    let sessions_per_engine = 40usize;
    let mut handles = Vec::new();
    for e in 0..engines {
        let svc = svc.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0x5E55_0000 + e as u64);
            let mut table: SessionTable<Admission> =
                SessionTable::new(3);
            // Build every session's plan, then interleave their stage
            // feeds randomly.
            struct Live {
                plan: Vec<Vec<u32>>,
                next: usize,
            }
            let mut live: HashMap<u64, Live> = HashMap::new();
            for i in 0..sessions_per_engine {
                let id = (e * 1000 + i) as u64;
                let stages = 2 + rng.index(4);
                let k = 1 + rng.index(3);
                let final_docs: Vec<u32> =
                    (0..k).map(|_| rng.index(24) as u32).collect();
                let plan = synth_plan(
                    &final_docs,
                    stages,
                    rng.index(stages + 1),
                    &mut rng,
                );
                table.submit(id, 0.0);
                live.insert(id, Live { plan, next: 0 });
            }
            let admit = |svc: &ShardedCacheService, docs: &[u32]| {
                let docs_tokens: Vec<(u32, usize)> = docs
                    .iter()
                    .map(|&d| (d, DOC_TOKENS))
                    .collect();
                svc.admit(&docs_tokens, 4)
            };
            let mut events: Vec<SessionEvent> = Vec::new();
            while !live.is_empty() {
                // Pick a random live session and feed its next stage.
                let ids: Vec<u64> = live.keys().copied().collect();
                let id = ids[rng.index(ids.len())];
                let (docs, stage, is_final) = {
                    let l = &live[&id];
                    (
                        l.plan[l.next].clone(),
                        l.next,
                        l.next + 1 == l.plan.len(),
                    )
                };
                let step = table.on_stage(id, stage, &docs, is_final);
                if let Some(work) = step.cancelled {
                    svc.release(&work.payload);
                }
                if let Some(docs) = step.start {
                    // Occasionally the speculative "prefill" fails.
                    if rng.chance(0.1) {
                        table.spec_aborted(id);
                    } else {
                        let adm = admit(&svc, &docs);
                        table.spec_started(id, docs, adm);
                    }
                }
                if let Some(finish) = step.finish {
                    let adm = match finish {
                        FinishPath::Promote(work) => work.payload,
                        FinishPath::Fallback => admit(&svc, &docs),
                    };
                    table.prefilled(id, stage as f64);
                    table.decoding(id);
                    svc.touch_hits(&adm, 1e-3, stage as f64);
                    svc.commit(&adm, 1e-3, stage as f64, None);
                    // A few sessions fail after commit (decode error).
                    if rng.chance(0.05) {
                        table.fail(id, "synthetic decode error".into());
                    } else {
                        table.complete(id);
                    }
                    live.remove(&id);
                } else {
                    // Non-final stages never finish a session.
                    let l = live.get_mut(&id).expect("live");
                    l.next += 1;
                }
                events.extend(table.take_events());
            }
            (table.totals(), table.terminals(), events)
        }));
    }

    let mut terminal_by_session: HashMap<u64, usize> = HashMap::new();
    for h in handles {
        let (totals, terminals, events) = h.join().expect("engine");
        assert_eq!(terminals, sessions_per_engine as u64);
        let mut started = 0u64;
        let mut cancelled = 0u64;
        for ev in &events {
            match ev {
                SessionEvent::SpecStarted { .. } => started += 1,
                SessionEvent::SpecCancelled { .. } => cancelled += 1,
                SessionEvent::Completed { session }
                | SessionEvent::Failed { session, .. } => {
                    *terminal_by_session.entry(*session).or_insert(0) +=
                        1;
                }
                _ => {}
            }
        }
        // Ledger: every realized speculation is cancelled or promoted
        // (aborted prefills never became SpecStarted events).
        assert_eq!(
            started,
            cancelled + totals.promoted,
            "speculation ledger out of balance: started {started}, \
             cancelled {cancelled}, promoted {}",
            totals.promoted
        );
        assert!(totals.started >= started, "SpecState counts aborts too");
    }
    assert_eq!(
        terminal_by_session.len(),
        2 * sessions_per_engine,
        "every session reached a terminal event"
    );
    for (id, n) in &terminal_by_session {
        assert_eq!(*n, 1, "session {id} got {n} terminal events");
    }
    // The pin contract across both engines and all cancellations.
    assert_eq!(svc.pinned_nodes(), 0, "pins leaked");
    svc.check_invariants();
}

/// Independent replay of the PR 3 `pop_batch` semantics (NOT a call
/// into the refactored queue): §5.2 single-pick rules per member,
/// mandatory first pick, token-budget cutoff, whole batch counted as
/// one bypass event against the newest member.
fn pr3_pop_batch(
    items: &mut Vec<PendingRequest>,
    window: usize,
    max_batch: usize,
    token_budget: usize,
) -> Vec<PendingRequest> {
    fn arrives_before(a: &PendingRequest, b: &PendingRequest) -> bool {
        (a.arrival, a.id) < (b.arrival, b.id)
    }
    fn select(items: &[PendingRequest], window: usize) -> Option<usize> {
        if items.is_empty() {
            return None;
        }
        let mut oldest = 0usize;
        let mut best = 0usize;
        let mut best_pri = items[0].order_priority();
        for i in 1..items.len() {
            if arrives_before(&items[i], &items[oldest]) {
                oldest = i;
            }
            let p = items[i].order_priority();
            if p > best_pri {
                best_pri = p;
                best = i;
            }
        }
        if items[oldest].bypassed >= window {
            Some(oldest)
        } else {
            Some(best)
        }
    }
    let mut batch: Vec<PendingRequest> = Vec::new();
    let mut tokens = 0usize;
    while batch.len() < max_batch.max(1) {
        let Some(idx) = select(items, window) else { break };
        let next = &items[idx];
        if !batch.is_empty()
            && tokens.saturating_add(next.compute_tokens) > token_budget
        {
            break;
        }
        tokens = tokens.saturating_add(next.compute_tokens);
        let mut r = items.swap_remove(idx);
        r.bypassed = 0;
        batch.push(r);
    }
    if !batch.is_empty() {
        let newest = batch
            .iter()
            .map(|r| (r.arrival, r.id))
            .fold((f64::NEG_INFINITY, 0u64), |a, b| {
                if b > a {
                    b
                } else {
                    a
                }
            });
        for r in items.iter_mut() {
            if (r.arrival, r.id) < newest {
                r.bypassed += 1;
            }
        }
    }
    batch
}

/// PCIe-like driver so coalescing is observable in the charge.
struct LinkDriver;

impl PipelineDriver for LinkDriver {
    fn now(&self) -> f64 {
        0.0
    }
    fn transfer_time(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            0.0
        } else {
            20e-6 + bytes as f64 / 12.0e9
        }
    }
}

/// Real-driver shape: transfers are in-process copies, charged 0 s.
struct ZeroDriver;

impl PipelineDriver for ZeroDriver {
    fn now(&self) -> f64 {
        0.0
    }
    fn transfer_time(&self, _bytes: u64) -> f64 {
        0.0
    }
}

/// Conformance (acceptance): the `--speculate off` substrate — batched
/// pops + coalesced admit burst — is bit-identical to the PR 3 replay,
/// and the commit-side burst (the one sanctioned extension) charges
/// exactly one `transfer_time` over the summed commit bytes, which on
/// the real zero-cost link is bitwise PR 3's 0.0.
#[test]
fn speculate_off_matches_pr3_pop_order_and_charge_bits() {
    let admit_bytes = |id: u64| -> u64 { (id % 7) * 4096 };
    let commit_bytes = |id: u64| -> u64 { (id % 5) * 1024 };
    let adm_of = |id: u64| -> Admission {
        Admission {
            transfers: Transfers {
                h2g_bytes: admit_bytes(id),
                g2h_bytes: 0,
            },
            ..Admission::default()
        }
    };
    let mut rng = Rng::new(0x0FF);
    for _round in 0..25 {
        let window = 1 + rng.index(5);
        let max_batch = 1 + rng.index(6);
        let budget = if rng.chance(0.5) {
            usize::MAX
        } else {
            200 + rng.index(400)
        };
        let mut reference: Vec<PendingRequest> = Vec::new();
        let mut queue = ReorderQueue::new(true, window);
        let mut next_id = 0u64;
        let mut ref_charges: Vec<u64> = Vec::new();
        let mut new_charges: Vec<u64> = Vec::new();
        let mut real_charges: Vec<u64> = Vec::new();
        for _op in 0..60 {
            if rng.chance(0.55) {
                let r = PendingRequest {
                    id: next_id,
                    arrival: rng.index(6) as f64,
                    cached_tokens: rng.index(400),
                    compute_tokens: 1 + rng.index(300),
                    bypassed: 0,
                };
                next_id += 1;
                reference.push(r.clone());
                queue.push(r);
            } else {
                let want = pr3_pop_batch(
                    &mut reference,
                    window,
                    max_batch,
                    budget,
                );
                let got = queue.pop_batch(max_batch, budget);
                assert_eq!(
                    want.len(),
                    got.len(),
                    "batch size diverged"
                );
                for (w, g) in want.iter().zip(&got) {
                    assert_eq!(w.id, g.id, "pop order diverged");
                    assert_eq!(
                        w.bypassed, g.bypassed,
                        "bypass state diverged"
                    );
                }
                if got.is_empty() {
                    continue;
                }
                // PR 3 reference: ONE admit-burst charge per batch.
                let total: u64 =
                    want.iter().map(|r| admit_bytes(r.id)).sum();
                ref_charges
                    .push(LinkDriver.transfer_time(total).to_bits());
                // Actual path: BatchAdmission admit + commit phases.
                let mut ba = BatchAdmission::admit_with(
                    &LinkDriver,
                    got.iter().map(|r| r.id),
                    |id| Ok(adm_of(id)),
                );
                new_charges.push(ba.transfer_time().to_bits());
                for r in &got {
                    ba.push_commit(Transfers {
                        h2g_bytes: 0,
                        g2h_bytes: commit_bytes(r.id),
                    });
                }
                let commit_total: u64 =
                    got.iter().map(|r| commit_bytes(r.id)).sum();
                assert_eq!(
                    ba.seal_commit(&LinkDriver).to_bits(),
                    LinkDriver.transfer_time(commit_total).to_bits(),
                    "commit burst must be ONE charge over the summed \
                     commit bytes"
                );
                // Real-mode shape: with the zero-cost link the full
                // charge sequence (admit AND commit) is bitwise
                // identical to PR 3's (0.0 everywhere).
                let mut zb = BatchAdmission::admit_with(
                    &ZeroDriver,
                    got.iter().map(|r| r.id),
                    |id| Ok(adm_of(id)),
                );
                zb.push_commit(Transfers {
                    h2g_bytes: 0,
                    g2h_bytes: commit_total,
                });
                real_charges.push(zb.transfer_time().to_bits());
                real_charges
                    .push(zb.seal_commit(&ZeroDriver).to_bits());
            }
        }
        assert_eq!(
            ref_charges, new_charges,
            "admit-burst charges not bit-identical to the PR 3 replay"
        );
        assert!(
            real_charges.iter().all(|&b| b == 0f64.to_bits()),
            "real-driver charge sequence must be PR 3's zeros"
        );
        // Residual queue state agrees too.
        loop {
            let want =
                pr3_pop_batch(&mut reference, window, 1, usize::MAX);
            let got = queue.pop_batch(1, usize::MAX);
            match (want.first(), got.first()) {
                (None, None) => break,
                (Some(w), Some(g)) => {
                    assert_eq!(w.id, g.id);
                    assert_eq!(w.bypassed, g.bypassed);
                }
                (w, g) => panic!("tail diverged: {w:?} vs {g:?}"),
            }
        }
    }
}

const NUM_DOCS: usize = 64;

/// Serve `targets` through the session lifecycle (speculate on) or the
/// blocking retrieve-then-prefill shape (off), one request at a time on
/// a cold cache; returns the summed TTFT in seconds. Synthetic
/// latencies: `search` (staged over 4 stages when speculating) and
/// `prefill` per request.
fn run_ttft_mode(
    speculate: bool,
    targets: &[u32],
    search: Duration,
    prefill: Duration,
) -> f64 {
    let em = EmbeddingModel::new(16, 9);
    let vecs: Vec<Vec<f32>> =
        (0..NUM_DOCS as u32).map(|d| em.document(d)).collect();
    let index: Arc<dyn VectorIndex> =
        Arc::new(FlatIndex::build(16, &vecs));
    let svc = sharded(1, 4096, 8192);
    let admit = |docs: &[u32]| {
        let docs_tokens: Vec<(u32, usize)> =
            docs.iter().map(|&d| (d, DOC_TOKENS)).collect();
        svc.admit(&docs_tokens, 4)
    };
    let mut sum = 0.0f64;
    if !speculate {
        for &t in targets {
            let t0 = Instant::now();
            std::thread::sleep(search); // blocking full search
            let hits = index.search(&em.document(t), 1);
            let docs: Vec<u32> = hits.iter().map(|h| h.1).collect();
            let adm = admit(&docs);
            std::thread::sleep(prefill);
            sum += t0.elapsed().as_secs_f64(); // first token ready
            svc.commit(&adm, 1e-3, 1.0, None);
        }
    } else {
        let stages = 4;
        let (tx, rx) = mpsc::channel();
        let service = RetrievalService::spawn(
            Arc::clone(&index),
            RetrievalConfig {
                threads: 2,
                stages,
                stage_latency: search / stages as u32,
            },
            tx,
        );
        let mut table: SessionTable<Admission> = SessionTable::new(4);
        for (i, &t) in targets.iter().enumerate() {
            let id = i as u64;
            let t0 = Instant::now();
            table.submit(id, 0.0);
            assert!(service.submit(RetrievalTask {
                session: id,
                query: em.document(t),
                top_k: 1,
                stages: None,
            }));
            'drive: loop {
                let ev: StageReady =
                    rx.recv_timeout(Duration::from_secs(10))
                        .expect("stage event");
                let step = table.on_stage(
                    ev.session,
                    ev.stage,
                    &ev.docs,
                    ev.is_final,
                );
                if let Some(work) = step.cancelled {
                    svc.release(&work.payload);
                }
                if let Some(docs) = step.start {
                    let adm = admit(&docs);
                    std::thread::sleep(prefill); // speculative prefill
                    table.spec_started(id, docs, adm);
                }
                if let Some(finish) = step.finish {
                    let adm = match finish {
                        FinishPath::Promote(work) => work.payload,
                        FinishPath::Fallback => {
                            let adm = admit(&ev.docs);
                            std::thread::sleep(prefill);
                            adm
                        }
                    };
                    sum += t0.elapsed().as_secs_f64(); // first token
                    table.prefilled(id, 0.0);
                    table.decoding(id);
                    svc.commit(&adm, 1e-3, 1.0, None);
                    table.complete(id);
                    table.take_events();
                    break 'drive;
                }
                table.take_events();
            }
        }
        drop(service);
    }
    assert_eq!(svc.pinned_nodes(), 0, "mode leaked pins");
    svc.check_invariants();
    sum
}

/// Acceptance: retrieval-heavy timing (staged search ≥ prefill), cold
/// cache, identical workload — speculation strictly lowers summed TTFT.
/// Targets live in the first quarter of the (id-ordered) flat scan, so
/// the top-1 candidate converges at stage 1 and the speculative prefill
/// hides behind stages 2..4 of the search.
#[test]
fn speculation_cuts_summed_ttft_on_retrieval_heavy_workload() {
    let targets: Vec<u32> = (0..8).collect(); // ids < NUM_DOCS/4
    let search = Duration::from_millis(60);
    let prefill = Duration::from_millis(25);
    let off = run_ttft_mode(false, &targets, search, prefill);
    let on = run_ttft_mode(true, &targets, search, prefill);
    // off ≈ 8 × 85 ms = 680 ms; on ≈ 8 × 60 ms = 480 ms. The gap (≈25
    // ms/request) dwarfs scheduler noise on the sleeps.
    assert!(
        on < off,
        "speculation-on summed TTFT {on:.3}s !< off {off:.3}s"
    );
}

/// TCP-level coverage of the `--speculate on` engine loop: a handler
/// whose queries complete asynchronously via the session API. With
/// speculation off, the session API must never be touched.
struct SessionProbeHandler {
    speculate: bool,
    pending: Vec<(u64, u32, Instant)>,
    submitted: Arc<AtomicUsize>,
    sync_served: Arc<AtomicUsize>,
}

impl QueryHandler for SessionProbeHandler {
    fn query(
        &mut self,
        target_doc: u32,
        _query: &str,
        _max_new: usize,
    ) -> anyhow::Result<proto::QueryResult> {
        self.sync_served.fetch_add(1, Ordering::SeqCst);
        Ok(proto::QueryResult {
            id: target_doc as u64,
            docs: vec![target_doc],
            docs_hit: 0,
            cached_tokens: 0,
            computed_tokens: 1,
            ttft_ms: 1.0,
            total_ms: 1.0,
            text: "sync".into(),
        })
    }

    fn submit_session(
        &mut self,
        ticket: u64,
        target_doc: u32,
        query: &str,
        max_new: usize,
    ) -> Option<anyhow::Result<proto::QueryResult>> {
        if !self.speculate {
            return Some(self.query(target_doc, query, max_new));
        }
        self.submitted.fetch_add(1, Ordering::SeqCst);
        self.pending.push((ticket, target_doc, Instant::now()));
        None
    }

    fn poll_sessions(&mut self, timeout: Duration) -> Vec<SessionDone> {
        // "Retrieval" completes 15 ms after submission.
        if self.pending.is_empty() {
            std::thread::sleep(timeout.min(Duration::from_millis(5)));
            return Vec::new();
        }
        std::thread::sleep(Duration::from_millis(2));
        let ready: Vec<usize> = self
            .pending
            .iter()
            .enumerate()
            .filter(|(_, (_, _, t0))| {
                t0.elapsed() >= Duration::from_millis(15)
            })
            .map(|(i, _)| i)
            .collect();
        let mut out = Vec::new();
        for i in ready.into_iter().rev() {
            let (ticket, doc, _) = self.pending.swap_remove(i);
            out.push(SessionDone {
                ticket,
                result: Ok(proto::QueryResult {
                    id: doc as u64,
                    docs: vec![doc],
                    docs_hit: 1,
                    cached_tokens: 1,
                    computed_tokens: 1,
                    ttft_ms: 15.0,
                    total_ms: 15.0,
                    text: "session".into(),
                }),
            });
        }
        out
    }

    fn sessions_in_flight(&self) -> usize {
        self.pending.len()
    }

    fn stats(&self) -> proto::StatsResult {
        proto::StatsResult::default()
    }
}

#[test]
fn speculative_engine_loop_multiplexes_sessions_over_tcp() {
    for speculate in [true, false] {
        let submitted = Arc::new(AtomicUsize::new(0));
        let sync_served = Arc::new(AtomicUsize::new(0));
        let (s_sub, s_sync) =
            (Arc::clone(&submitted), Arc::clone(&sync_served));
        let opts = ServerOptions {
            workers: 4,
            max_batch: 8,
            speculate,
            ..ServerOptions::default()
        };
        let server = Server::spawn_with(0, opts, move || {
            Ok(SessionProbeHandler {
                speculate,
                pending: Vec::new(),
                submitted: s_sub,
                sync_served: s_sync,
            })
        })
        .expect("spawn");
        let addr = server.addr;

        // Parallel clients so several sessions are in flight at once.
        let clients = 3;
        let per_client = 4u32;
        let answered = Arc::new(Mutex::new(Vec::new()));
        let mut joins = Vec::new();
        for c in 0..clients {
            let answered = Arc::clone(&answered);
            joins.push(std::thread::spawn(move || {
                let mut cl = Client::connect(addr).unwrap();
                for i in 0..per_client {
                    let resp = cl
                        .call(&proto::Request::Query {
                            target_doc: c * 100 + i,
                            query: "q".into(),
                            max_new: 1,
                        })
                        .unwrap();
                    match resp {
                        proto::Response::Query(q) => {
                            answered.lock().unwrap().push(q.id)
                        }
                        other => panic!("unexpected {other:?}"),
                    }
                }
            }));
        }
        for j in joins {
            j.join().expect("client");
        }
        server.stop();

        let total = (clients * per_client) as usize;
        assert_eq!(
            answered.lock().unwrap().len(),
            total,
            "speculate={speculate}: every request answered"
        );
        if speculate {
            assert_eq!(
                submitted.load(Ordering::SeqCst),
                total,
                "every query flowed through submit_session"
            );
            assert_eq!(
                sync_served.load(Ordering::SeqCst),
                0,
                "no query took the blocking path"
            );
        } else {
            assert_eq!(
                submitted.load(Ordering::SeqCst),
                0,
                "--speculate off must never touch the session API"
            );
            assert_eq!(sync_served.load(Ordering::SeqCst), total);
        }
    }
}
