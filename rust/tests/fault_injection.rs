//! Failure injection (paper §6 Fault tolerance): GPU failures with and
//! without hot-node replication, and request retry/timeout handling —
//! including on the real PJRT-backed serving stack, where outputs must be
//! byte-identical across a failure.

use ragcache::config::PolicyKind;
use ragcache::controller::fault::{replicate_hot_nodes, RetryAction, RetryState};
use ragcache::kvcache::{PageSpec, Tier};
use ragcache::policy::{make_policy, AccessCtx};
use ragcache::tree::KnowledgeTree;

fn page() -> PageSpec {
    PageSpec {
        block_tokens: 16,
        kv_bytes_per_token: 64,
    }
}

fn tree(gpu_tokens: usize, host_tokens: usize) -> KnowledgeTree {
    let p = page();
    KnowledgeTree::new(
        p.bytes(gpu_tokens),
        p.bytes(host_tokens),
        p,
        make_policy(PolicyKind::Pgdsf),
        true,
        0,
    )
}

fn touch(t: &mut KnowledgeTree, id: ragcache::tree::NodeId, n: usize) {
    for i in 0..n {
        t.on_access(
            id,
            &AccessCtx {
                alpha: 0,
                beta: 16,
                estimated_time: 0.01,
                was_cached: false,
                now: i as f64,
                tokens: 16,
            },
        );
    }
}

#[test]
fn unreplicated_cache_is_wiped_by_gpu_failure() {
    let mut t = tree(1000, 1000);
    for d in 0..8u32 {
        let id = t.insert_child(t.root(), d, 16, None).1.unwrap();
        touch(&mut t, id, 1);
    }
    let (lost, recovered) = t.fail_gpu();
    t.check_invariants();
    assert_eq!(lost, 8);
    assert_eq!(recovered, 0);
    for d in 0..8u32 {
        assert_eq!(t.lookup(&[d]).matched_docs, 0);
    }
    // The tree keeps serving: re-inserts work.
    assert!(t.insert_child(t.root(), 1, 16, None).1.is_some());
    t.check_invariants();
}

#[test]
fn replication_bounds_the_loss() {
    let mut t = tree(1000, 1000);
    let mut nodes = Vec::new();
    for d in 0..10u32 {
        let id = t.insert_child(t.root(), d, 16, None).1.unwrap();
        touch(&mut t, id, (10 - d) as usize); // doc 0 hottest
        nodes.push(id);
    }
    let replicated = replicate_hot_nodes(&mut t, 4);
    assert_eq!(replicated, 4);
    let (lost, recovered) = t.fail_gpu();
    t.check_invariants();
    assert_eq!(recovered, 4, "the 4 hottest survived");
    assert_eq!(lost, 6);
    // Survivors are exactly the hottest by frequency.
    for (i, &id) in nodes.iter().enumerate() {
        let expect = if i < 4 { Some(Tier::Host) } else { None };
        assert_eq!(t.node_tier(id), expect, "doc {i}");
    }
}

#[test]
fn repeated_failures_are_survivable() {
    let mut t = tree(500, 500);
    for round in 0..5 {
        for d in 0..6u32 {
            if let (_, Some(id)) = t.insert_child(t.root(), d, 16, None) {
                touch(&mut t, id, 2);
            }
        }
        replicate_hot_nodes(&mut t, 3);
        let _ = t.fail_gpu();
        t.check_invariants();
        // Recovery path: promote what survived back to GPU.
        for d in 0..6u32 {
            let m = t.lookup(&[d]);
            if m.matched_docs == 1 {
                assert!(
                    t.promote(&m.path).complete(m.path.len()),
                    "round {round}"
                );
            }
        }
        t.check_invariants();
    }
}

#[test]
fn retry_policy_full_lifecycle() {
    let mut r = RetryState::new(0.5, 3, 0.0);
    r.begin_attempt(0.0);
    assert_eq!(r.check(0.1), RetryAction::Wait);
    // Times out before the first iteration: full recompute.
    assert_eq!(r.check(0.9), RetryAction::Recompute);
    r.begin_attempt(1.0);
    r.first_iteration_done = true;
    // Times out after the first iteration: resume from stored KV.
    assert_eq!(r.check(1.8), RetryAction::Resume);
    r.begin_attempt(2.0);
    r.begin_attempt(3.0);
    // attempts(4) > max_retries(3): give up.
    assert_eq!(r.check(9.0), RetryAction::Fail);
}

mod real_stack {
    //! GPU failure injected into the live PJRT serving stack.
    use ragcache::controller::real::{RealConfig, RealServer};
    use ragcache::embed::EmbeddingModel;
    use ragcache::runtime::{ArtifactManifest, PjrtModel};
    use ragcache::util::Rng;
    use ragcache::vectordb::{FlatIndex, VectorIndex};
    use std::path::Path;

    fn build() -> Option<(RealServer, RealConfig)> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let manifest = ArtifactManifest::load(&dir).unwrap();
        let model =
            PjrtModel::load(manifest.model("tiny-mha").unwrap()).unwrap();
        let num_docs = 16usize;
        let mut rng = Rng::new(77);
        let doc_tokens: Vec<Vec<i32>> = (0..num_docs)
            .map(|_| (0..24).map(|_| rng.index(256) as i32).collect())
            .collect();
        let em = EmbeddingModel::new(16, 5);
        let vecs: Vec<Vec<f32>> =
            (0..num_docs as u32).map(|d| em.document(d)).collect();
        let index: Box<dyn VectorIndex> =
            Box::new(FlatIndex::build(16, &vecs));
        let cfg = RealConfig {
            query_noise: 0.0,
            ..RealConfig::default()
        };
        let server =
            RealServer::new(model, index, em, doc_tokens, &cfg).unwrap();
        Some((server, cfg))
    }

    #[test]
    fn outputs_identical_across_gpu_failure() {
        let Some((mut server, cfg)) = build() else {
            return;
        };
        let query: Vec<i32> = (30..50).collect();
        // Warm the cache and capture baseline outputs.
        let mut baseline = Vec::new();
        for t in 0..6u32 {
            baseline.push(server.serve(t, &query, 3, &cfg).unwrap());
        }
        // Inject a GPU failure through the shared cache service.
        let (lost, _recovered) = server.cache().fail_gpu();
        server.cache().check_invariants();
        assert!(lost > 0, "failure actually destroyed cache state");
        // Serve the same requests again: cold (recompute) but identical.
        for t in 0..6u32 {
            let again = server.serve(t, &query, 3, &cfg).unwrap();
            assert_eq!(
                again.output_tokens,
                baseline[t as usize].output_tokens,
                "doc {t}: recompute-after-failure must match"
            );
        }
        server.cache().check_invariants();
    }
}
