//! Integration: the NVMe-backed third cache tier — `--disk off`
//! conformance with the two-tier PR 8 path (counters, occupancies and
//! the f64 charge proxy, bit for bit), the GPU → host → disk → GPU
//! demote/restage round trip preserving payload bytes exactly through
//! the slotted backing store, and randomized multi-thread interleaving
//! with GPU failures proving zero leaked pins or bytes across all
//! three tiers. PJRT-free.

use ragcache::config::PolicyKind;
use ragcache::controller::{CacheService, ShardedCacheService};
use ragcache::kvcache::{KvPayload, PageSpec};
use ragcache::policy::make_policy;
use ragcache::tree::KnowledgeTree;
use ragcache::util::Rng;

const DOC_TOKENS: usize = 16;
const REQ_TOKENS: usize = 8;

fn page() -> PageSpec {
    PageSpec {
        block_tokens: 8,
        kv_bytes_per_token: 16,
    }
}

fn tree(
    gpu_tokens: usize,
    host_tokens: usize,
    disk_tokens: usize,
) -> KnowledgeTree {
    let p = page();
    let mut t = KnowledgeTree::new(
        p.bytes(gpu_tokens),
        p.bytes(host_tokens),
        p,
        make_policy(PolicyKind::Pgdsf),
        true,
        0,
    );
    if disk_tokens > 0 {
        t.enable_disk_tier(p.bytes(disk_tokens));
    }
    t
}

/// A doc's synthetic KV rows: 4 floats per token, seeded by the doc id
/// so cross-doc payload mix-ups cannot cancel out.
fn payload(doc: u32) -> KvPayload {
    let data: Vec<f32> = (0..DOC_TOKENS * 4)
        .map(|i| (doc as f32) * 1000.0 + i as f32)
        .collect();
    KvPayload::new(data, DOC_TOKENS)
}

/// Admit + commit one doc sequence; returns the admission's
/// (beta, moved_bytes, disk_read_bytes).
fn serve(
    svc: &CacheService,
    docs: &[u32],
    now: f64,
    payloads: Option<Vec<KvPayload>>,
) -> (usize, u64, u64) {
    let dt: Vec<(u32, usize)> =
        docs.iter().map(|&d| (d, DOC_TOKENS)).collect();
    let adm = svc.admit(&dt, REQ_TOKENS);
    svc.touch_hits(&adm, 1e-3, now);
    let out = svc.commit(&adm, 1e-3, now, payloads);
    let moved = adm.transfer_bytes()
        + out.transfers.h2g_bytes
        + out.transfers.g2h_bytes;
    (adm.beta, moved, adm.disk_read_bytes())
}

/// `--disk off` conformance: the two-tier path must be bit-identical
/// to the pre-disk tree under eviction pressure — same admissions,
/// same counters and occupancies, same f64 charge-proxy bits, zero
/// disk state. And a disk tier that is ON but never pressured must be
/// indistinguishable from off: the cascade only touches it when the
/// host actually drops something.
#[test]
fn disk_off_is_bit_identical_to_pre_disk_path() {
    // Tight tiers: 4 docs of GPU, 8 of host, 24 distinct docs → the
    // stream constantly evicts through both upper tiers.
    let off = CacheService::new(tree(64, 128, 0));
    let replica = CacheService::new(tree(64, 128, 0));
    // Roomy tiers: everything fits, so the disk (when on) stays idle.
    let roomy_off = CacheService::new(tree(4096, 8192, 0));
    let roomy_on = CacheService::new(tree(4096, 8192, 1 << 16));

    let mut rng = Rng::new(0xD15C_0FF);
    let mut charge_off = 0.0f64;
    let mut charge_replica = 0.0f64;
    for i in 0..300u64 {
        let d = rng.below(24) as u32;
        let now = i as f64;
        let (b1, m1, r1) = serve(&off, &[d], now, None);
        let (b2, m2, r2) = serve(&replica, &[d], now, None);
        assert_eq!((b1, m1, r1), (b2, m2, r2), "req {i} diverged");
        assert_eq!(r1, 0, "req {i}: disk-off path read disk bytes");
        charge_off += m1 as f64 / 16e9 + b1 as f64 * 50e-6;
        charge_replica += m2 as f64 / 16e9 + b2 as f64 * 50e-6;
        let (b3, m3, r3) = serve(&roomy_off, &[d], now, None);
        let (b4, m4, r4) = serve(&roomy_on, &[d], now, None);
        assert_eq!(
            (b3, m3, r3),
            (b4, m4, r4),
            "req {i}: idle disk tier changed the roomy path"
        );
    }
    assert_eq!(
        charge_off.to_bits(),
        charge_replica.to_bits(),
        "f64 charge proxy must agree bit for bit"
    );
    let (co, cr) = (off.counters(), replica.counters());
    assert_eq!(co, cr, "off path is deterministic");
    assert!(co.gpu_evictions > 0, "stream pressured the tiers: {co:?}");
    assert_eq!(
        (co.disk_spills, co.disk_spill_bytes),
        (0, 0),
        "disk-off never spills"
    );
    assert_eq!(
        (co.disk_restage_hits, co.disk_restage_bytes),
        (0, 0),
        "disk-off never restages"
    );
    let o = off.occupancy();
    assert_eq!(o.gpu_used, replica.occupancy().gpu_used);
    assert_eq!(o.host_used, replica.occupancy().host_used);
    assert_eq!((o.disk_used, o.disk_capacity), (0, 0));
    // The idle-but-on tier holds capacity and nothing else.
    let ro = roomy_on.occupancy();
    assert_eq!(ro.gpu_used, roomy_off.occupancy().gpu_used);
    assert_eq!(ro.host_used, roomy_off.occupancy().host_used);
    assert!(ro.disk_capacity > 0);
    assert_eq!(ro.disk_used, 0, "idle disk tier stayed empty");
    assert_eq!(roomy_on.counters().disk_spills, 0);
    for svc in [&off, &replica, &roomy_off, &roomy_on] {
        svc.check_invariants();
        assert_eq!(svc.pinned_nodes(), 0);
    }
}

/// Round-trip property: a doc's KV payload demoted GPU → host → disk
/// (through serialization into the slotted backing store) and restaged
/// disk → host → GPU comes back bit-identical, with every hop's byte
/// accounting balancing (`check_invariants` enforces per-tier
/// `used == Σ distinct payload bytes` at each step).
#[test]
fn demote_restage_round_trip_preserves_payload_bytes() {
    let p = page();
    // GPU fits 2 docs, host 1, disk plenty — inserting 4 distinct docs
    // pushes doc 1 all the way down the cascade.
    let svc = CacheService::new(tree(2 * DOC_TOKENS, DOC_TOKENS, 1024));
    let original = payload(1);
    serve(&svc, &[1], 0.0, Some(vec![original.clone()]));
    serve(&svc, &[2], 1.0, Some(vec![payload(2)]));
    svc.check_invariants();
    serve(&svc, &[3], 2.0, Some(vec![payload(3)])); // doc 1 → host
    svc.check_invariants();
    serve(&svc, &[4], 3.0, Some(vec![payload(4)])); // doc 1 → disk
    svc.check_invariants();

    let c = svc.counters();
    assert!(c.disk_spills >= 1, "cascade reached disk: {c:?}");
    let payload_bytes = p.payload_bytes(DOC_TOKENS);
    assert!(c.disk_spill_bytes >= payload_bytes);
    assert!(svc.occupancy().disk_used >= p.bytes(DOC_TOKENS));
    // Drain the async staging queue: the payload serializes into
    // backing-store slots, so the restage below reads real stored
    // bytes, not the in-queue copy.
    let written = svc.with(|t| {
        assert!(t.disk_staged_len() >= 1, "spill rides the queue");
        t.flush_disk_staging()
    });
    assert!(written >= 1, "flush wrote the staged entries");
    svc.check_invariants();

    // Re-admit doc 1: the walk restages it disk → host and the
    // admission promotes it back to GPU.
    let dt = [(1u32, DOC_TOKENS)];
    let adm = svc.admit(&dt, REQ_TOKENS);
    assert_eq!(adm.matched_docs, 1, "restaged doc serves the match");
    assert_eq!(adm.alpha, DOC_TOKENS, "no recompute after restage");
    assert_eq!(
        adm.disk_read_bytes(),
        payload_bytes,
        "the restage read is charged once, at payload size"
    );
    let id = *adm.path.last().expect("matched path");
    svc.touch_hits(&adm, 1e-3, 4.0);
    svc.commit(&adm, 1e-3, 4.0, None);
    svc.check_invariants();

    let c = svc.counters();
    assert_eq!(c.disk_restage_hits, 1);
    assert_eq!(c.disk_restage_bytes, payload_bytes);
    svc.with(|t| {
        let got = t.node_payload(id).expect("payload restaged");
        assert_eq!(got.tokens(), original.tokens());
        assert_eq!(
            got.floats(),
            original.floats(),
            "payload bytes must survive the full tier round trip"
        );
    });
    assert_eq!(svc.pinned_nodes(), 0);
}

/// Randomized multi-thread interleaving over all three tiers: threads
/// hammer a sharded, chunk-enabled, disk-backed cache with reordered
/// pairs, aborted speculation, mid-flight GPU failures and periodic
/// staging flushes under constant eviction pressure. The ledger must
/// balance on every tier and every pin must come back.
#[test]
fn randomized_interleaving_three_tiers_leaks_nothing() {
    let p = page();
    let svc = ShardedCacheService::build(4, |_| {
        let mut t = KnowledgeTree::new(
            p.bytes(64),
            p.bytes(128),
            p,
            make_policy(PolicyKind::Pgdsf),
            true,
            0,
        );
        t.enable_chunk_cache(4);
        // Small on purpose: the NoRoom refusal path (spill degrades to
        // the pre-disk drop) gets exercised alongside stores.
        t.enable_disk_tier(p.bytes(256));
        t
    });
    let threads = 8;
    let ops = 250;
    let mut handles = Vec::new();
    for t in 0..threads {
        let svc = svc.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xD15C + t as u64);
            for i in 0..ops {
                let a = rng.below(32) as u32;
                let b = rng.below(32) as u32;
                let docs = if i % 2 == 0 {
                    [(a, DOC_TOKENS), (b, DOC_TOKENS)]
                } else {
                    [(b, DOC_TOKENS), (a, DOC_TOKENS)]
                };
                let adm = svc.admit(&docs, REQ_TOKENS);
                match i % 7 {
                    0 => svc.release(&adm), // aborted speculation
                    1 => {
                        // Device failure with restaged KV in flight:
                        // whatever the walk pulled off disk dies with
                        // the GPU tier; commit must still balance.
                        svc.shard(adm.shard).fail_gpu();
                        svc.commit(&adm, 1e-3, i as f64, None);
                    }
                    _ => {
                        svc.touch_hits(&adm, 1e-3, i as f64);
                        svc.commit(&adm, 1e-3, i as f64, None);
                    }
                }
                if i % 25 == 0 {
                    // Stand-in for the async staging writer.
                    svc.flush_disk_staging();
                }
                if i % 50 == 0 {
                    svc.check_invariants();
                }
            }
        }));
    }
    for h in handles {
        h.join().expect("no hammering thread panicked");
    }
    svc.flush_disk_staging();
    svc.check_invariants();
    assert_eq!(
        svc.pinned_nodes(),
        0,
        "quiescent: every path and chunk pin was returned"
    );
    let total = svc.counters();
    assert!(total.inserts > 0, "traffic exercised insertion");
    assert!(
        total.disk_spills > 0,
        "pressure drove the cascade to disk: {total:?}"
    );
    assert!(
        total.disk_restage_hits > 0,
        "spilled docs were served back out of disk: {total:?}"
    );
    for s in 0..svc.num_shards() {
        let o = svc.shard(s).occupancy();
        assert!(o.gpu_used <= o.gpu_capacity, "shard {s} gpu over");
        assert!(o.host_used <= o.host_capacity, "shard {s} host over");
        assert!(o.disk_used <= o.disk_capacity, "shard {s} disk over");
    }
}
