//! Integration: the REAL three-layer path — Rust retrieval + knowledge
//! tree + PJRT-compiled JAX/Pallas prefill — with numeric checks that
//! cached-KV serving produces identical logits to uncached serving.

use ragcache::controller::real::{RealConfig, RealServer};
use ragcache::embed::EmbeddingModel;
use ragcache::runtime::{ArtifactManifest, PjrtModel};
use ragcache::util::Rng;
use ragcache::vectordb::{FlatIndex, VectorIndex};
use std::path::Path;

fn build_server(num_docs: usize) -> Option<(RealServer, RealConfig)> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let manifest = ArtifactManifest::load(&dir).unwrap();
    let model = PjrtModel::load(manifest.model("tiny-gqa").unwrap()).unwrap();
    let mut rng = Rng::new(4);
    let doc_tokens: Vec<Vec<i32>> = (0..num_docs)
        .map(|_| (0..32).map(|_| rng.index(256) as i32).collect())
        .collect();
    let dim = 16;
    let em = EmbeddingModel::new(dim, 8);
    let vecs: Vec<Vec<f32>> =
        (0..num_docs as u32).map(|d| em.document(d)).collect();
    let index: Box<dyn VectorIndex> = Box::new(FlatIndex::build(dim, &vecs));
    let cfg = RealConfig {
        query_noise: 0.0, // deterministic retrieval for the tests
        ..RealConfig::default()
    };
    let server =
        RealServer::new(model, index, em, doc_tokens, &cfg).unwrap();
    Some((server, cfg))
}

#[test]
fn warm_request_hits_and_matches_cold_output() {
    let Some((mut server, cfg)) = build_server(32) else {
        return;
    };
    let query: Vec<i32> = (10..30).collect();
    let cold = server.serve(5, &query, 4, &cfg).unwrap();
    assert_eq!(cold.docs_hit, 0, "first request misses");
    assert_eq!(cold.docs[0], 5, "retrieval finds the target");

    let warm = server.serve(5, &query, 4, &cfg).unwrap();
    assert_eq!(
        warm.docs_hit,
        warm.docs.len(),
        "second request fully hits"
    );
    assert!(warm.cached_tokens > 0);
    assert!(
        warm.computed_tokens < cold.computed_tokens,
        "cache cut the prefill"
    );
    // The decisive numeric check: cached-prefix serving must generate
    // exactly the same tokens as the cold pass.
    assert_eq!(
        cold.output_tokens, warm.output_tokens,
        "KV reuse changes nothing about the output"
    );
}

#[test]
fn different_doc_order_is_different_cache_entry() {
    let Some((mut server, cfg)) = build_server(32) else {
        return;
    };
    let query: Vec<i32> = (40..60).collect();
    // Request targeting doc 3 then doc 7 produce different top-k orders;
    // each order caches its own path (§5.1 order sensitivity).
    let a = server.serve(3, &query, 2, &cfg).unwrap();
    let b = server.serve(7, &query, 2, &cfg).unwrap();
    assert_ne!(a.docs, b.docs);
    assert_eq!(b.docs_hit, 0, "different prefix: no (full) hit");
    // Re-serving each target hits its own path.
    assert!(server.serve(3, &query, 2, &cfg).unwrap().docs_hit > 0);
    assert!(server.serve(7, &query, 2, &cfg).unwrap().docs_hit > 0);
}

#[test]
fn eviction_under_tiny_cache_keeps_serving_correctly() {
    let Some((mut server, mut cfg)) = build_server(24) else {
        return;
    };
    // Shrink the cache hard so constant eviction happens.
    cfg.gpu_cache_bytes = 64 * 1024;
    cfg.host_cache_bytes = 128 * 1024;
    let mut baseline = Vec::new();
    let query: Vec<i32> = (0..16).collect();
    for target in 0..12u32 {
        let r = server.serve(target, &query, 2, &cfg).unwrap();
        baseline.push(r.output_tokens);
    }
    // Second sweep: outputs identical regardless of hit/miss history.
    for target in 0..12u32 {
        let r = server.serve(target, &query, 2, &cfg).unwrap();
        assert_eq!(
            r.output_tokens, baseline[target as usize],
            "doc {target}: eviction must never change results"
        );
    }
    server.cache().check_invariants();
}

#[test]
fn iterative_retrieval_reuses_round_kv() {
    // Paper §9: intermediate iterations are separate requests whose doc
    // KV is cached — a later session touching the same docs hits.
    let Some((mut server, cfg)) = build_server(32) else {
        return;
    };
    let query: Vec<i32> = (60..80).collect();
    let first = server
        .serve_iterative(&[4, 9, 4], &query, 3, &cfg)
        .unwrap();
    assert_eq!(first.rounds.len(), 3);
    // Round 3 revisits target 4: its documents were cached by round 1.
    assert!(
        first.rounds[2].docs_hit > 0,
        "revisited round hits: {:?}",
        first.rounds[2]
    );
    // A whole second session is warm.
    let second = server
        .serve_iterative(&[4, 9], &query, 3, &cfg)
        .unwrap();
    assert_eq!(second.total_docs_hit(), second.total_docs());
    server.cache().check_invariants();
}

#[test]
fn recorder_tracks_real_metrics() {
    let Some((mut server, cfg)) = build_server(16) else {
        return;
    };
    let query: Vec<i32> = (5..25).collect();
    for t in [1u32, 1, 2, 1] {
        server.serve(t, &query, 2, &cfg).unwrap();
    }
    let r = server.recorder();
    assert_eq!(r.len(), 4);
    assert!(r.hit_rate() > 0.0);
    let mut ttft = r.ttft();
    assert!(ttft.mean() > 0.0);
    assert!(ttft.percentile(100.0) < 60.0, "sane wall-clock bounds");
}
