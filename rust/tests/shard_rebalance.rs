//! Cross-shard tier rebalancing: skewed-routing divergence, the
//! demand-driven rebalancer's invariants, static-split conformance and
//! the acceptance comparison (rebalance-on must win aggregate GPU
//! cache-hit bytes on a Zipfian workload without raising the summed
//! transfer-time TTFT proxy), plus the `build_sharded_cache`
//! remainder-bytes regression.

use ragcache::config::PolicyKind;
use ragcache::controller::real::{RealConfig, RealServer};
use ragcache::controller::{
    split_budget, Admission, RebalanceConfig, ShardedCacheService,
};
use ragcache::kvcache::{PageSpec, Tier, TransferModel};
use ragcache::policy::make_policy;
use ragcache::tree::KnowledgeTree;
use ragcache::util::Rng;

const DOC_TOKENS: usize = 32;

fn page() -> PageSpec {
    PageSpec {
        block_tokens: 8,
        kv_bytes_per_token: 16,
    }
}

/// K=4 cache over EXACT slices of awkward (non-multiple-of-K) totals.
fn build_cache(
    gpu_total: u64,
    host_total: u64,
    k: usize,
) -> ShardedCacheService {
    let p = page();
    let gpu = split_budget(gpu_total, k);
    let host = split_budget(host_total, k);
    ShardedCacheService::build(k, |i| {
        KnowledgeTree::new(
            gpu[i],
            host[i],
            p,
            make_policy(PolicyKind::Pgdsf),
            true,
            0,
        )
    })
}

fn gpu_caps(svc: &ShardedCacheService) -> u64 {
    svc.shard_occupancies()
        .iter()
        .map(|o| o.gpu_capacity)
        .sum()
}

fn host_caps(svc: &ShardedCacheService) -> u64 {
    svc.shard_occupancies()
        .iter()
        .map(|o| o.host_capacity)
        .sum()
}

/// Deterministic Zipfian request stream over K=4 shards: hot doc of
/// rank r (all routing to shard 0 — ids ≡ 0 mod 4) appears once every
/// `r + 1` rounds (harmonic = Zipf s≈1 frequencies), and each cold
/// shard's single doc appears once every 8 rounds. Every hot doc is
/// requested at least once, so the hot working set is fully exercised.
fn zipfian_requests(hot_docs: usize, rounds: usize) -> Vec<u32> {
    let mut out = Vec::new();
    for round in 0..rounds {
        for r in 0..hot_docs {
            if round % (r + 1) == 0 {
                out.push(4 * r as u32);
            }
        }
        if round % 8 == 7 {
            out.push(1 + (round as u32 / 8) % 3); // shards 1..3
        }
    }
    out
}

/// Serve one single-doc request through the admit → commit protocol,
/// returning the TTFT transfer-time proxy its byte movement costs on a
/// PCIe-4 link (admission H2D burst + commit write-back burst — what
/// the sim driver would charge).
fn serve_one(svc: &ShardedCacheService, doc: u32, now: f64) -> f64 {
    let link = TransferModel::pcie4();
    let adm = svc.admit(&[(doc, DOC_TOKENS)], 4);
    let mut secs = link.transfer_time(adm.transfer_bytes());
    svc.touch_hits(&adm, 1e-3, now);
    let out = svc.commit(&adm, 1e-3, now, None);
    secs += link
        .transfer_time(out.transfers.h2g_bytes + out.transfers.g2h_bytes);
    secs
}

/// Satellite regression: `build_sharded_cache` used to truncate
/// `budget / K`, silently dropping up to K−1 bytes of configured cache
/// per tier. The slices must sum to the configured budgets exactly,
/// for awkward K.
#[test]
fn build_sharded_cache_preserves_configured_budget() {
    for k in [1usize, 2, 3, 4, 5, 7] {
        let cfg = RealConfig {
            gpu_cache_bytes: 1_000_003,
            host_cache_bytes: 777_778,
            ..RealConfig::default()
        };
        let svc = RealServer::build_sharded_cache(4, &cfg, k);
        assert_eq!(svc.num_shards(), k);
        assert_eq!(
            gpu_caps(&svc),
            cfg.gpu_cache_bytes,
            "K={k}: GPU remainder bytes dropped"
        );
        assert_eq!(
            host_caps(&svc),
            cfg.host_cache_bytes,
            "K={k}: host remainder bytes dropped"
        );
    }
}

/// Skewed routing under the STATIC split: the Zipfian hot shard
/// saturates its 1/K GPU slice and thrashes (evictions), while the
/// cold shards strand idle GPU bytes — the divergence that motivates
/// rebalancing.
#[test]
fn zipfian_routing_diverges_per_shard_occupancy() {
    let p = page();
    // 8 GPU doc-slots per shard; the hot shard's working set is 12.
    let svc = build_cache(p.bytes(32 * DOC_TOKENS), p.bytes(4096), 4);
    for (i, &doc) in zipfian_requests(12, 40).iter().enumerate() {
        serve_one(&svc, doc, i as f64);
    }
    let occ = svc.shard_occupancies();
    assert_eq!(
        occ[0].gpu_used, occ[0].gpu_capacity,
        "hot shard saturated: {occ:?}"
    );
    for i in 1..4 {
        assert!(
            occ[i].gpu_used <= occ[0].gpu_capacity / 4,
            "cold shard {i} should strand idle bytes: {occ:?}"
        );
        assert_eq!(
            svc.shard(i).counters().gpu_evictions,
            0,
            "cold shard {i} never under pressure"
        );
    }
    assert!(
        svc.shard(0).counters().gpu_evictions > 0,
        "hot shard thrashes its static slice"
    );
    svc.check_invariants();
}

/// Acceptance: on the Zipfian workload, rebalance-on yields strictly
/// more aggregate GPU cache-hit bytes than the static 1/K split, with
/// no higher summed transfer-time (TTFT proxy) — including the
/// rebalancer's own donor swap-out bursts — and exact budget
/// conservation after every tick.
#[test]
fn zipfian_rebalance_beats_static_split() {
    let p = page();
    let link = TransferModel::pcie4();
    let gpu_total = p.bytes(32 * DOC_TOKENS);
    let host_total = p.bytes(4096);
    let requests = zipfian_requests(12, 40);

    let mut results = Vec::new();
    for rebalance in [false, true] {
        let mut svc = build_cache(gpu_total, host_total, 4);
        if rebalance {
            svc.enable_rebalancing(RebalanceConfig {
                interval: 8,
                ..RebalanceConfig::default()
            });
        }
        let mut ttft_proxy = 0.0;
        for (i, &doc) in requests.iter().enumerate() {
            ttft_proxy += serve_one(&svc, doc, i as f64);
            if let Some(moved) = svc.maintenance_tick() {
                // The rebalancer's own burst counts against it.
                ttft_proxy += link
                    .transfer_time(moved.h2g_bytes + moved.g2h_bytes);
            }
            assert_eq!(gpu_caps(&svc), gpu_total, "conservation");
            assert_eq!(host_caps(&svc), host_total, "conservation");
        }
        svc.check_invariants();
        assert_eq!(svc.pinned_nodes(), 0);
        results.push((svc.counters().gpu_hit_bytes, ttft_proxy));
    }
    let (hits_static, ttft_static) = results[0];
    let (hits_dyn, ttft_dyn) = results[1];
    assert!(
        hits_dyn > hits_static,
        "rebalancing must strictly win GPU hit bytes on skew: \
         {hits_dyn} !> {hits_static}"
    );
    assert!(
        ttft_dyn <= ttft_static,
        "rebalancing must not raise the summed transfer-time proxy: \
         {ttft_dyn} > {ttft_static}"
    );
}

/// Randomized property test: across random admit/commit/hold/release
/// interleavings with per-request rebalance ticks, every tick conserves
/// both tier budgets bit-exactly, keeps `used <= capacity` on every
/// shard, and never evicts a pinned node out of GPU.
#[test]
fn randomized_rebalancer_preserves_invariants() {
    let p = page();
    let mut rng = Rng::new(0xC0FFEE);
    for case in 0..6u64 {
        let k = 2 + (case as usize % 3); // 2..=4 shards
        // Awkward budgets: not multiples of K or the page size.
        let gpu_total = p.bytes(24 * DOC_TOKENS) + 3 * case + 1;
        let host_total = p.bytes(2048) + 7 * case;
        let mut svc = build_cache(gpu_total, host_total, k);
        svc.enable_rebalancing(RebalanceConfig {
            interval: 1 + case % 4,
            min_share: 0.2,
            hysteresis: if case % 2 == 0 { 0.0 } else { 1.0 / 16.0 },
        });
        let mut held: Vec<Admission> = Vec::new();
        for step in 0..160 {
            let now = step as f64;
            let doc = rng.below(24) as u32;
            match rng.index(8) {
                0..=4 => {
                    let adm = svc.admit(&[(doc, DOC_TOKENS)], 4);
                    svc.commit(&adm, 1e-3, now, None);
                }
                5 => {
                    // Hold an admission pinned across future ticks.
                    let adm = svc.admit(&[(doc, DOC_TOKENS)], 4);
                    held.push(adm);
                }
                6 if !held.is_empty() => {
                    let adm = held.swap_remove(rng.index(held.len()));
                    svc.release(&adm);
                }
                _ => {
                    let adm = svc.admit(
                        &[(doc, DOC_TOKENS), (doc + 1, DOC_TOKENS)],
                        8,
                    );
                    svc.commit(&adm, 1e-3, now, None);
                }
            }
            svc.maintenance_tick();
            assert_eq!(gpu_caps(&svc), gpu_total, "case {case}");
            assert_eq!(host_caps(&svc), host_total, "case {case}");
            for (i, o) in svc.shard_occupancies().iter().enumerate() {
                assert!(
                    o.gpu_used <= o.gpu_capacity
                        && o.host_used <= o.host_capacity,
                    "case {case} shard {i} over budget: {o:?}"
                );
            }
            // Pinned (held) paths must still be GPU-resident: the
            // rebalancer's evict-to-fit may never touch a pinned node.
            for adm in &held {
                svc.shard(adm.shard).with(|t| {
                    for &n in &adm.path {
                        assert_eq!(
                            t.node_tier(n),
                            Some(Tier::Gpu),
                            "case {case}: pinned node evicted"
                        );
                    }
                });
            }
        }
        for adm in held.drain(..) {
            svc.commit(&adm, 1e-3, 1e6, None);
        }
        assert_eq!(svc.pinned_nodes(), 0, "case {case}: pins leaked");
        svc.check_invariants();
    }
}

/// Concurrency: engines admit while maintenance ticks run; after the
/// dust settles the budgets are conserved and nothing leaked.
#[test]
fn concurrent_ticks_with_admissions_stay_sound() {
    let p = page();
    let gpu_total = p.bytes(32 * DOC_TOKENS) + 5;
    let host_total = p.bytes(4096) + 11;
    let mut svc = build_cache(gpu_total, host_total, 4);
    svc.enable_rebalancing(RebalanceConfig {
        interval: 4,
        ..RebalanceConfig::default()
    });
    let mut joins = Vec::new();
    for worker in 0..4u64 {
        let svc = svc.clone();
        joins.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xAB1E ^ worker);
            for step in 0..200 {
                let doc = rng.below(48) as u32;
                let adm = svc.admit(&[(doc, DOC_TOKENS)], 4);
                svc.commit(&adm, 1e-3, step as f64, None);
                svc.maintenance_tick();
            }
        }));
    }
    for j in joins {
        j.join().expect("worker");
    }
    assert_eq!(gpu_caps(&svc), gpu_total);
    assert_eq!(host_caps(&svc), host_total);
    assert_eq!(svc.pinned_nodes(), 0);
    assert!(svc.rebalance_stats().recomputes > 0);
    svc.check_invariants();
}

/// Conformance: with rebalancing OFF, `maintenance_tick` is a no-op —
/// a served workload leaves counters, occupancies and lookups
/// bit-identical to a cache that never heard of the rebalancer.
#[test]
fn rebalance_off_is_bit_identical_to_static() {
    let p = page();
    let requests = zipfian_requests(12, 24);
    let plain = build_cache(p.bytes(32 * DOC_TOKENS), p.bytes(4096), 4);
    let ticked = build_cache(p.bytes(32 * DOC_TOKENS), p.bytes(4096), 4);
    for (i, &doc) in requests.iter().enumerate() {
        serve_one(&plain, doc, i as f64);
        serve_one(&ticked, doc, i as f64);
        assert!(ticked.maintenance_tick().is_none(), "off = no-op");
    }
    assert_eq!(plain.counters(), ticked.counters());
    assert_eq!(
        plain.shard_occupancies(),
        ticked.shard_occupancies(),
        "occupancy gauges identical"
    );
    for i in 0..4 {
        assert_eq!(
            plain.shard(i).counters(),
            ticked.shard(i).counters(),
            "shard {i} counters identical"
        );
    }
    for doc in 0..48u32 {
        let a = plain.lookup(&[doc]);
        let b = ticked.lookup(&[doc]);
        assert_eq!(a.matched_docs, b.matched_docs);
        assert_eq!(a.gpu_tokens, b.gpu_tokens);
        assert_eq!(a.host_tokens, b.host_tokens);
    }
}
