//! Integration: the concurrent serving substrate — the thread-safe
//! [`CacheService`] hammered from many threads, and the multi-worker TCP
//! runtime serving overlapping connections with cross-request cache hits.
//! PJRT-free so it runs everywhere.

use ragcache::config::PolicyKind;
use ragcache::controller::{CacheService, ShardedCacheService};
use ragcache::kvcache::PageSpec;
use ragcache::policy::make_policy;
use ragcache::sched::PendingRequest;
use ragcache::server::{
    proto, Client, PriorityEstimator, QueryHandler, Server,
    ServerOptions, ShardFn,
};
use ragcache::tree::KnowledgeTree;
use ragcache::util::Rng;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

const DOC_TOKENS: usize = 32;

fn page() -> PageSpec {
    PageSpec {
        block_tokens: 8,
        kv_bytes_per_token: 16,
    }
}

fn service(gpu_tokens: usize, host_tokens: usize) -> CacheService {
    let p = page();
    CacheService::new(KnowledgeTree::new(
        p.bytes(gpu_tokens),
        p.bytes(host_tokens),
        p,
        make_policy(PolicyKind::Pgdsf),
        true,
        0,
    ))
}

/// Satellite: ≥4 threads interleaving match/pin/insert/evict through the
/// shared service; afterwards the tree invariants hold (parent-tier
/// ordering, allocator accounting) and every pin has been returned.
#[test]
fn cache_service_survives_multithreaded_hammering() {
    // Small GPU tier so admissions constantly contend over eviction.
    let svc = service(64, 256);
    let threads = 6;
    let ops = 300;
    let mut handles = Vec::new();
    for t in 0..threads {
        let svc = svc.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xBEEF + t as u64);
            for i in 0..ops {
                let a = rng.below(8) as u32;
                let b = rng.below(8) as u32;
                let docs = [(a, 16usize), (b, 16usize)];
                let adm = svc.admit(&docs, 8);
                assert!(adm.matched_docs <= 2);
                assert_eq!(
                    adm.path.len(),
                    adm.matched_docs,
                    "pinned path covers exactly the matched prefix"
                );
                if i % 5 == 0 {
                    // Simulated aborted speculation: pins must drop
                    // without inserting.
                    svc.release(&adm);
                } else {
                    svc.touch_hits(&adm, 1e-3, i as f64);
                    svc.commit(&adm, 1e-3, i as f64, None);
                }
                if i % 64 == 0 {
                    // Invariants hold mid-flight too (pins excepted —
                    // other threads legitimately hold some).
                    svc.check_invariants();
                }
            }
        }));
    }
    for h in handles {
        h.join().expect("no hammering thread panicked");
    }
    svc.check_invariants();
    assert_eq!(
        svc.pinned_nodes(),
        0,
        "all admissions were committed or released"
    );
    let c = svc.counters();
    assert!(c.inserts > 0, "traffic actually exercised insertion: {c:?}");
}

/// The §5.2 queue is safe to feed and drain across threads *through the
/// serving runtime types* (the sched unit tests cover the bound itself).
#[test]
fn pending_request_priorities_survive_concurrent_feed() {
    use ragcache::sched::SharedReorderQueue;
    let q: Arc<SharedReorderQueue<usize>> =
        Arc::new(SharedReorderQueue::new(true, 8));
    let feeders: Vec<_> = (0..4)
        .map(|t| {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                for i in 0..50u64 {
                    assert!(q.push(
                        PendingRequest {
                            id: t * 1000 + i,
                            arrival: i as f64,
                            cached_tokens: (t as usize) * 100,
                            compute_tokens: 10,
                            bypassed: 0,
                        },
                        t as usize,
                    ));
                }
            })
        })
        .collect();
    for f in feeders {
        f.join().unwrap();
    }
    let mut popped = 0;
    while q.pop_timeout(Duration::from_millis(5)).is_some() {
        popped += 1;
    }
    assert_eq!(popped, 200, "every pushed request drains exactly once");
}

/// PJRT-free handler backed by the real CacheService admission path: a
/// query for `target_doc` retrieves the ordered pair `[d, d+1]`, admits
/// it against the shared tree, and reports the hit split.
struct CacheHandler {
    cache: CacheService,
    served: u64,
    /// Artificial per-query engine latency (models prefill time).
    delay: Duration,
}

impl QueryHandler for CacheHandler {
    fn query(
        &mut self,
        target_doc: u32,
        query: &str,
        _max_new: usize,
    ) -> anyhow::Result<proto::QueryResult> {
        let docs = [target_doc, target_doc + 1];
        let docs_tokens: Vec<(u32, usize)> =
            docs.iter().map(|&d| (d, DOC_TOKENS)).collect();
        let adm = self.cache.admit(&docs_tokens, query.len().max(1));
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let now = self.served as f64;
        self.cache.touch_hits(&adm, 1e-3, now);
        self.cache.commit(&adm, 1e-3, now, None);
        self.served += 1;
        Ok(proto::QueryResult {
            id: self.served,
            docs: docs.to_vec(),
            docs_hit: adm.matched_docs,
            cached_tokens: adm.alpha,
            computed_tokens: adm.beta,
            ttft_ms: 1.0,
            total_ms: 2.0,
            text: format!("echo:{query}"),
        })
    }

    fn stats(&self) -> proto::StatsResult {
        let c = self.cache.counters();
        proto::StatsResult {
            requests: self.served as usize,
            mean_ttft_ms: 1.0,
            hit_rate: 0.0,
            engines: 1,
            tree_inserts: c.inserts,
            tree_gpu_evictions: c.gpu_evictions,
            tree_host_evictions: c.host_evictions,
            ..Default::default()
        }
    }
}

fn spawn_cache_server(workers: usize, delay_ms: u64) -> (Server, CacheService) {
    let svc = service(4096, 8192);
    let handler_svc = svc.clone();
    // Cache-aware priority estimator running on connection workers — the
    // same shared service the engine thread admits against.
    let est_svc = svc.clone();
    let estimator: PriorityEstimator = Arc::new(move |req| match req {
        proto::Request::Query { target_doc, .. } => {
            let m = est_svc.lookup(&[*target_doc, *target_doc + 1]);
            let total = 2 * DOC_TOKENS;
            (m.cached_tokens, total.saturating_sub(m.cached_tokens).max(1))
        }
        _ => (0, 1),
    });
    let opts = ServerOptions {
        workers,
        estimator: Some(estimator),
        ..ServerOptions::default()
    };
    let server = Server::spawn_with(0, opts, move || {
        Ok(CacheHandler {
            cache: handler_svc,
            served: 0,
            delay: Duration::from_millis(delay_ms),
        })
    })
    .expect("spawn");
    (server, svc)
}

fn query(target: u32) -> proto::Request {
    proto::Request::Query {
        target_doc: target,
        query: "q".into(),
        max_new: 1,
    }
}

/// Acceptance: ≥2 concurrent connections. An idle open connection must
/// not stall another client — the old runtime served connections
/// strictly sequentially and would hang here.
#[test]
fn idle_connection_does_not_block_other_clients() {
    let (server, _svc) = spawn_cache_server(2, 0);
    let idle = TcpStream::connect(server.addr).expect("idle connect");
    // Second connection with a hard read deadline: a response must
    // arrive while the idle connection stays open.
    let stream = TcpStream::connect(server.addr).expect("connect");
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writeln!(writer, "{}", proto::encode_request(&query(7))).unwrap();
    let mut line = String::new();
    reader
        .read_line(&mut line)
        .expect("response while another connection is open");
    match proto::parse_response(&line).expect("valid response") {
        proto::Response::Query(q) => assert_eq!(q.docs, vec![7, 8]),
        other => panic!("unexpected {other:?}"),
    }
    drop(idle);
    server.stop();
}

/// Acceptance: cross-request cache hits across concurrent connections —
/// one client warms the tree, four parallel clients hit it.
#[test]
fn concurrent_clients_share_cache_hits() {
    let (server, svc) = spawn_cache_server(4, 0);
    let addr = server.addr;

    // Warm phase: insert the doc pairs for targets 10, 20, 30, 40.
    let mut warm = Client::connect(addr).unwrap();
    for t in [10u32, 20, 30, 40] {
        match warm.call(&query(t)).unwrap() {
            proto::Response::Query(q) => {
                assert_eq!(q.docs_hit, 0, "cold request misses")
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    // Hit phase: four clients in parallel, one per warmed target.
    let clients: Vec<_> = [10u32, 20, 30, 40]
        .into_iter()
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                match c.call(&query(t)).unwrap() {
                    proto::Response::Query(q) => q,
                    other => panic!("unexpected {other:?}"),
                }
            })
        })
        .collect();
    for c in clients {
        let q = c.join().expect("client thread");
        assert_eq!(q.docs_hit, 2, "warmed path fully hits: {q:?}");
        assert_eq!(q.cached_tokens, 2 * DOC_TOKENS);
    }
    svc.check_invariants();
    assert_eq!(svc.pinned_nodes(), 0, "serving returned every pin");
    server.stop();
}

/// PJRT-free engine-replica handler over the shared sharded cache:
/// every engine admits against the same `ShardedCacheService`, just as
/// the real multi-engine deployment does.
struct ShardedHandler {
    cache: ShardedCacheService,
    engine: usize,
    served: u64,
}

impl QueryHandler for ShardedHandler {
    fn query(
        &mut self,
        target_doc: u32,
        query: &str,
        _max_new: usize,
    ) -> anyhow::Result<proto::QueryResult> {
        let docs = [target_doc, target_doc + 1];
        let docs_tokens: Vec<(u32, usize)> =
            docs.iter().map(|&d| (d, DOC_TOKENS)).collect();
        let adm = self.cache.admit(&docs_tokens, query.len().max(1));
        let now = self.served as f64;
        self.cache.touch_hits(&adm, 1e-3, now);
        self.cache.commit(&adm, 1e-3, now, None);
        self.served += 1;
        Ok(proto::QueryResult {
            id: self.served,
            docs: docs.to_vec(),
            docs_hit: adm.matched_docs,
            cached_tokens: adm.alpha,
            computed_tokens: adm.beta,
            ttft_ms: 1.0,
            total_ms: 2.0,
            text: format!("engine{}:{query}", self.engine),
        })
    }

    fn stats(&self) -> proto::StatsResult {
        let c = self.cache.counters();
        proto::StatsResult {
            requests: self.served as usize,
            mean_ttft_ms: 1.0,
            hit_rate: 0.0,
            engines: 1,
            tree_inserts: c.inserts,
            tree_gpu_evictions: c.gpu_evictions,
            tree_host_evictions: c.host_evictions,
            ..Default::default()
        }
    }
}

/// Acceptance: M = 2 engine replicas over a shared 2-shard cache. Warm
/// requests from one client land on their affinity engines; parallel
/// clients then hit the warmed shards regardless of which engine warmed
/// them (the cache is shared), and one `stats` round trip merges both
/// engines' counts while counting the shared tree exactly once.
#[test]
fn multi_engine_dispatch_shares_cache_and_aggregates_stats() {
    let p = page();
    let svc = ShardedCacheService::build(2, |_| {
        KnowledgeTree::new(
            p.bytes(4096),
            p.bytes(8192),
            p,
            make_policy(PolicyKind::Pgdsf),
            true,
            0,
        )
    });
    let est = svc.clone();
    let estimator: PriorityEstimator = Arc::new(move |req| match req {
        proto::Request::Query { target_doc, .. } => {
            let m = est.lookup(&[*target_doc, *target_doc + 1]);
            let total = 2 * DOC_TOKENS;
            (m.cached_tokens, total.saturating_sub(m.cached_tokens).max(1))
        }
        _ => (0, 1),
    });
    let route = svc.clone();
    let router: ShardFn = Arc::new(move |req| match req {
        proto::Request::Query { target_doc, .. } => {
            route.shard_of_doc(*target_doc)
        }
        _ => 0,
    });
    let opts = ServerOptions {
        workers: 4,
        engines: 2,
        estimator: Some(estimator),
        router: Some(router),
        ..ServerOptions::default()
    };
    let handler_svc = svc.clone();
    let server = Server::spawn_sharded(0, opts, move |engine| {
        Ok(ShardedHandler {
            cache: handler_svc.clone(),
            engine,
            served: 0,
        })
    })
    .expect("spawn");
    let addr = server.addr;

    // Warm both shards: even first docs (shard 0 → engine 0) and odd
    // ones (shard 1 → engine 1).
    let targets = [10u32, 11, 20, 21];
    let mut warm = Client::connect(addr).unwrap();
    for t in targets {
        match warm.call(&query(t)).unwrap() {
            proto::Response::Query(q) => {
                assert_eq!(q.docs_hit, 0, "cold request misses")
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    // Hit phase: parallel clients across both engines.
    let clients: Vec<_> = targets
        .into_iter()
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                match c.call(&query(t)).unwrap() {
                    proto::Response::Query(q) => q,
                    other => panic!("unexpected {other:?}"),
                }
            })
        })
        .collect();
    for c in clients {
        let q = c.join().expect("client thread");
        assert_eq!(q.docs_hit, 2, "warmed shard fully hits: {q:?}");
        assert_eq!(q.cached_tokens, 2 * DOC_TOKENS);
    }

    // One stats round trip covers both replicas.
    match warm.call(&proto::Request::Stats).unwrap() {
        proto::Response::Stats(s) => {
            assert_eq!(s.engines, 2, "both engines answered");
            assert_eq!(s.requests, 8, "requests merged across engines");
            assert_eq!(
                s.tree_inserts,
                svc.counters().inserts,
                "shared sharded tree counted once, not per engine"
            );
        }
        other => panic!("unexpected {other:?}"),
    }
    svc.check_invariants();
    assert_eq!(svc.pinned_nodes(), 0, "serving returned every pin");
    server.stop();
}

/// Graceful shutdown drains in-flight requests: queries already enqueued
/// when the shutdown op lands still get real answers.
#[test]
fn shutdown_drains_inflight_requests() {
    // Slow engine (150 ms/query) so requests are genuinely queued when
    // the shutdown arrives.
    let (server, _svc) = spawn_cache_server(4, 150);
    let addr = server.addr;
    let clients: Vec<_> = [1u32, 2, 3]
        .into_iter()
        .map(|t| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                c.call(&query(t)).expect("drained response")
            })
        })
        .collect();
    // Give the connection workers ample time to parse + enqueue all
    // three queries, then shut down mid-drain.
    std::thread::sleep(Duration::from_millis(75));
    let mut admin = Client::connect(addr).unwrap();
    assert_eq!(
        admin.call(&proto::Request::Shutdown).unwrap(),
        proto::Response::Ok
    );
    for c in clients {
        match c.join().expect("client thread") {
            proto::Response::Query(q) => assert!(q.id > 0),
            other => panic!("in-flight request lost: {other:?}"),
        }
    }
    server.join();
}
