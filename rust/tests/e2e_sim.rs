//! Integration: simulated full-stack runs across systems, datasets,
//! models and features — the paper's qualitative claims as assertions.

use ragcache::baselines;
use ragcache::config::{PolicyKind, SystemConfig};
use ragcache::controller::{RetrievalTiming, SimServer};
use ragcache::workload::{
    datasets::{DatasetProfile, MMLU, NATURAL_QUESTIONS},
    Corpus, Trace,
};

const NUM_DOCS: usize = 30_000;

fn run(
    cfg: &SystemConfig,
    profile: &DatasetProfile,
    rate: f64,
    n: usize,
    timing: RetrievalTiming,
) -> ragcache::controller::SimOutcome {
    let corpus = Corpus::wikipedia_like(NUM_DOCS, 1);
    let trace = Trace::generate(profile, &corpus, rate, n, cfg.retrieval.top_k, 77);
    SimServer::build(cfg, trace, NUM_DOCS, timing, 5)
        .expect("server builds")
        .run()
}

#[test]
fn fig13_ordering_ragcache_sglang_vllm() {
    // Fig. 13: RAGCache < SGLang < vLLM on mean TTFT (MMLU, Mistral-7B).
    let base = SystemConfig::default();
    let mut ttfts = Vec::new();
    for (name, cfg) in baselines::all(&base) {
        let out = run(&cfg, &MMLU, 1.2, 500, RetrievalTiming::default());
        assert_eq!(out.completed, 500, "{name} completed all");
        ttfts.push((name, out.recorder.ttft().mean()));
    }
    let (rag, sgl, vllm) = (ttfts[0].1, ttfts[1].1, ttfts[2].1);
    assert!(rag < sgl, "ragcache {rag} < sglang {sgl}");
    assert!(sgl < vllm * 1.02, "sglang {sgl} <= vllm {vllm}");
    assert!(vllm / rag > 1.15, "meaningful gap: {}", vllm / rag);
}

#[test]
fn fig14_nq_multi_token_outputs() {
    // NQ has multi-token outputs → decode iterations in the mix.
    let base = SystemConfig::default();
    let out = run(
        &base,
        &NATURAL_QUESTIONS,
        0.8,
        300,
        RetrievalTiming::default(),
    );
    assert_eq!(out.completed, 300);
    let vllm = baselines::vllm(&base);
    let out_v = run(
        &vllm,
        &NATURAL_QUESTIONS,
        0.8,
        300,
        RetrievalTiming::default(),
    );
    assert!(
        out.recorder.ttft().mean() < out_v.recorder.ttft().mean(),
        "ragcache wins on NQ too"
    );
}

#[test]
fn fig15_larger_topk_still_wins() {
    for top_k in [1usize, 3] {
        let mut cfg = SystemConfig::default();
        cfg.retrieval.top_k = top_k;
        let out = run(&cfg, &MMLU, 0.8, 250, RetrievalTiming::default());
        let vllm = baselines::vllm(&cfg);
        let out_v = run(&vllm, &MMLU, 0.8, 250, RetrievalTiming::default());
        assert!(
            out.recorder.ttft().mean() <= out_v.recorder.ttft().mean(),
            "top-{top_k}: ragcache wins"
        );
        assert_eq!(out.completed, 250);
    }
}

#[test]
fn fig16_large_model_on_h800() {
    let mut cfg = SystemConfig::preset("h800-large").unwrap();
    cfg.engine.model = "mixtral-8x7b".to_string();
    cfg.engine.max_batch = 8;
    let out = run(&cfg, &MMLU, 1.0, 200, RetrievalTiming::default());
    assert_eq!(out.completed, 200);
    let vllm = baselines::vllm(&cfg);
    let out_v = run(&vllm, &MMLU, 1.0, 200, RetrievalTiming::default());
    assert!(
        out.recorder.ttft().mean() < out_v.recorder.ttft().mean(),
        "caching helps the MoE model too"
    );
}

#[test]
fn fig17_pgdsf_at_least_matches_baseline_policies() {
    // PGDSF optimises *recomputation cost*, not raw hit count (Table 2
    // reports TTFT); assert it is competitive on hit rate and at least
    // as good on TTFT.
    let mut results = Vec::new();
    for policy in [
        PolicyKind::Pgdsf,
        PolicyKind::Gdsf,
        PolicyKind::Lru,
        PolicyKind::Lfu,
    ] {
        let mut cfg = SystemConfig::default();
        cfg.cache.policy = policy;
        cfg.cache.host_bytes = 32 * (1u64 << 30);
        cfg.spec.enabled = false; // isolate the policy effect
        let out = run(&cfg, &MMLU, 0.8, 600, RetrievalTiming::default());
        results.push((
            policy.name(),
            out.recorder.hit_rate(),
            out.recorder.ttft().mean(),
        ));
    }
    let (_, pgdsf_hr, pgdsf_ttft) = results[0];
    for &(name, hr, ttft) in &results[1..] {
        assert!(
            pgdsf_hr >= hr * 0.90,
            "pgdsf hit {pgdsf_hr} vs {name} {hr}"
        );
        assert!(
            pgdsf_ttft <= ttft * 1.05,
            "pgdsf ttft {pgdsf_ttft} vs {name} {ttft}"
        );
    }
}

#[test]
fn fig18_reordering_helps_at_saturation() {
    let mut on = SystemConfig::default();
    on.spec.enabled = false;
    let mut off = on.clone();
    off.sched.reorder = false;
    // Slightly above capacity so the queue saturates (§7.3 setup).
    let t_on = run(&on, &MMLU, 1.35, 400, RetrievalTiming::default());
    let t_off = run(&off, &MMLU, 1.35, 400, RetrievalTiming::default());
    let (a, b) = (
        t_on.recorder.ttft().mean(),
        t_off.recorder.ttft().mean(),
    );
    assert!(a < b * 1.02, "reordering {a} vs fifo {b}");
}

#[test]
fn fig19_dsp_reduces_nonoverlapped_search() {
    let timing = RetrievalTiming {
        full_search_s: 0.4,
        stages: 4,
        early_convergence: 0.55,
    };
    let mut on = SystemConfig::default();
    on.sched.reorder = false;
    let mut off = on.clone();
    off.spec.enabled = false;
    let out_on = run(&on, &MMLU, 0.1, 200, timing);
    let out_off = run(&off, &MMLU, 0.1, 200, timing);
    let s_on = out_on.recorder.mean_non_overlapped_search();
    let s_off = out_off.recorder.mean_non_overlapped_search();
    assert!(
        s_on < s_off * 0.75,
        "DSP non-overlap {s_on} vs NoDSP {s_off} (paper: 1.5-4.3x less)"
    );
    let t_on = out_on.recorder.ttft().mean();
    let t_off = out_off.recorder.ttft().mean();
    assert!(t_on < t_off, "DSP ttft {t_on} vs {t_off}");
}

#[test]
fn deterministic_given_seed() {
    let cfg = SystemConfig::default();
    let a = run(&cfg, &MMLU, 0.8, 100, RetrievalTiming::default());
    let b = run(&cfg, &MMLU, 0.8, 100, RetrievalTiming::default());
    assert_eq!(a.recorder.ttft().mean(), b.recorder.ttft().mean());
    assert_eq!(a.spec_wasted, b.spec_wasted);
}
