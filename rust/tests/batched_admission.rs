//! Batched-admission property and conformance suite (PJRT-free).
//!
//! Covers the three tentpole guarantees end to end:
//! 1. **Exactly-once admission** under randomized multi-thread traffic
//!    at two engines, including members that fail mid-batch and
//!    re-queue.
//! 2. **Coalesced transfer accounting**: every batch's `Transfers`
//!    total equals the sum of its members' promotion bytes — no
//!    double-charge, no loss — even with `fail_gpu` injected
//!    concurrently and members failing mid-batch.
//! 3. **§5.2 starvation bound per batch event**: a popped batch counts
//!    as ONE bypass event, and the victim is served within
//!    `window + 1` batches.
//!
//! Plus the conformance half: `--max-batch 1` reproduces the unbatched
//! (PR 2) per-request accounting bit for bit, the sim and real drivers
//! agree on the coalesced byte accounting through the shared core, and
//! with the deterministic cost model a batch of B cache-miss requests
//! reports strictly lower summed TTFT than B serialized singletons.

use ragcache::config::{PolicyKind, SystemConfig, SystemKind};
use ragcache::controller::{
    Admission, BatchAdmission, PipelineDriver, RetrievalTiming,
    ShardedCacheService, SimServer,
};
use ragcache::kvcache::PageSpec;
use ragcache::policy::make_policy;
use ragcache::sched::{PendingRequest, ReorderQueue, SharedReorderQueue};
use ragcache::server::{
    proto, Client, QueryHandler, Server, ServerOptions,
};
use ragcache::tree::{KnowledgeTree, Transfers};
use ragcache::util::Rng;
use ragcache::workload::{datasets::MMLU, Corpus, Trace};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

const DOC_TOKENS: usize = 16;

/// PCIe-like driver (setup latency + bandwidth), so coalescing is
/// observable in the charge.
struct LinkDriver;

impl PipelineDriver for LinkDriver {
    fn now(&self) -> f64 {
        0.0
    }
    fn transfer_time(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            0.0
        } else {
            20e-6 + bytes as f64 / 12.0e9
        }
    }
}

/// Real-mode driver shape: transfers are in-process copies, charge 0.
struct ZeroDriver;

impl PipelineDriver for ZeroDriver {
    fn now(&self) -> f64 {
        0.0
    }
    fn transfer_time(&self, _bytes: u64) -> f64 {
        0.0
    }
}

fn sharded(gpu_tokens: usize, host_tokens: usize) -> ShardedCacheService {
    let page = PageSpec {
        block_tokens: 8,
        kv_bytes_per_token: 16,
    };
    ShardedCacheService::build(2, |_| {
        KnowledgeTree::new(
            page.bytes(gpu_tokens),
            page.bytes(host_tokens),
            page,
            make_policy(PolicyKind::Pgdsf),
            true,
            0,
        )
    })
}

/// The engine job payload: what to admit, and which attempt this is.
#[derive(Clone)]
struct Job {
    docs: Vec<(u32, usize)>,
    request_tokens: usize,
    attempt: u32,
}

/// Satellite (a)+(b)+(c): N workers push requests with overlapping doc
/// prefixes at 2 engines; each engine pops batches, admits through
/// `BatchAdmission` with injected mid-batch failures and a concurrent
/// `fail_gpu` chaos thread, and re-queues the failures.
#[test]
fn randomized_two_engine_batched_admission() {
    let window = 4usize;
    let max_batch = 4usize;
    let workers = 4usize;
    let per_worker = 60usize;
    // 2 victims + worker traffic; every id must be admitted exactly
    // once (failed attempts retry until they succeed).
    let total = 2 + workers * per_worker;

    // Small GPU tier so admissions spill to host and later promote —
    // real h2g/g2h traffic for the coalescing assertions.
    let svc = sharded(96, 4096);
    let queues: Vec<Arc<SharedReorderQueue<Job>>> = (0..2)
        .map(|_| Arc::new(SharedReorderQueue::new(true, window)))
        .collect();
    let next_id = Arc::new(AtomicUsize::new(2));
    let admitted = Arc::new(AtomicUsize::new(0));

    // Victims: one per engine, oldest arrival, worst priority. Their
    // batch-event position proves the per-batch starvation bound.
    for (e, q) in queues.iter().enumerate() {
        assert!(q.push(
            PendingRequest {
                id: e as u64,
                arrival: 0.0,
                cached_tokens: 0,
                compute_tokens: 1_000_000,
                bypassed: 0,
            },
            Job {
                docs: vec![(e as u32, DOC_TOKENS)],
                request_tokens: 4,
                attempt: 0,
            },
        ));
    }

    // Engines drain until every request has been admitted exactly once.
    let mut engines = Vec::new();
    for (e, q) in queues.iter().enumerate() {
        let q = Arc::clone(q);
        let svc = svc.clone();
        let admitted = Arc::clone(&admitted);
        engines.push(std::thread::spawn(move || {
            let driver = LinkDriver;
            let mut counts: HashMap<u64, usize> = HashMap::new();
            let mut batch_events = 0usize;
            let mut victim_event: Option<usize> = None;
            let deadline = Instant::now() + Duration::from_secs(30);
            while admitted.load(Ordering::SeqCst) < total {
                assert!(
                    Instant::now() < deadline,
                    "engine {e} timed out ({} admitted)",
                    admitted.load(Ordering::SeqCst)
                );
                let popped = q.pop_batch_timeout(
                    Duration::from_millis(10),
                    max_batch,
                    usize::MAX,
                );
                if popped.is_empty() {
                    continue;
                }
                if victim_event.is_none()
                    && popped.iter().any(|(r, _)| r.id == e as u64)
                {
                    victim_event = Some(batch_events);
                }
                batch_events += 1;

                let jobs: HashMap<u64, (PendingRequest, Job)> = popped
                    .into_iter()
                    .map(|(r, j)| (r.id, (r, j)))
                    .collect();
                // Mid-batch failure injection: deterministic ids fail
                // their first admission attempt. The failing path
                // releases its own pins and reports its partial bytes.
                let mut expected = Transfers::default();
                let ids: Vec<u64> = jobs.keys().copied().collect();
                let batch = BatchAdmission::admit_with(
                    &driver,
                    ids.iter().copied(),
                    |id| {
                        let (_, job) = &jobs[&id];
                        let adm =
                            svc.admit(&job.docs, job.request_tokens);
                        expected.merge(adm.transfers);
                        if id >= 2 && id % 7 == 3 && job.attempt == 0 {
                            let partial = adm.transfers;
                            svc.release(&adm);
                            Err(partial)
                        } else {
                            Ok(adm)
                        }
                    },
                );
                // (b) coalesced totals = exact member sum, every batch.
                assert_eq!(
                    batch.transfers(),
                    expected,
                    "engine {e}: coalesced transfers drifted"
                );
                assert_eq!(
                    batch.transfer_time(),
                    driver.transfer_time(batch.total_bytes()),
                    "engine {e}: burst charged other than once"
                );
                // Failed members re-queue (original arrival, attempt+1).
                for &id in batch.failed() {
                    let (pending, job) = jobs[&id].clone();
                    assert!(q.push(
                        pending,
                        Job {
                            attempt: job.attempt + 1,
                            ..job
                        },
                    ));
                }
                for (id, adm) in batch.into_members() {
                    svc.commit(&adm, 1e-3, 1.0, None);
                    *counts.entry(id).or_insert(0) += 1;
                    admitted.fetch_add(1, Ordering::SeqCst);
                }
            }
            (counts, victim_event)
        }));
    }

    // Workers: overlapping doc prefixes (small first-doc pool per
    // shard), routed by the first doc's shard = engine.
    let mut feeders = Vec::new();
    for w in 0..workers {
        let queues = queues.clone();
        let svc = svc.clone();
        let next_id = Arc::clone(&next_id);
        feeders.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xF00D + w as u64);
            for i in 0..per_worker {
                let id = next_id.fetch_add(1, Ordering::SeqCst) as u64;
                let first = rng.index(8) as u32;
                let mut docs = vec![(first, DOC_TOKENS)];
                for _ in 0..rng.index(3) {
                    docs.push((rng.index(32) as u32, DOC_TOKENS));
                }
                let engine = svc.shard_of_doc(first);
                let pending = PendingRequest {
                    id,
                    arrival: 1.0 + id as f64,
                    cached_tokens: rng.index(64),
                    compute_tokens: 1 + rng.index(200),
                    bypassed: 0,
                };
                let job = Job {
                    docs,
                    request_tokens: 4,
                    attempt: 0,
                };
                assert!(
                    queues[engine].push(pending, job),
                    "worker {w} push {i} refused"
                );
                if i % 16 == 0 {
                    std::thread::yield_now();
                }
            }
        }));
    }

    // Chaos: GPU failures racing the admissions.
    let chaos = {
        let svc = svc.clone();
        let admitted = Arc::clone(&admitted);
        std::thread::spawn(move || {
            let mut failures = 0;
            while admitted.load(Ordering::SeqCst) < total && failures < 6 {
                std::thread::sleep(Duration::from_millis(3));
                svc.fail_gpu();
                failures += 1;
            }
        })
    };

    for f in feeders {
        f.join().expect("feeder thread");
    }
    chaos.join().expect("chaos thread");
    let mut all_counts: HashMap<u64, usize> = HashMap::new();
    for (e, h) in engines.into_iter().enumerate() {
        let (counts, victim_event) = h.join().expect("engine thread");
        for (id, n) in counts {
            *all_counts.entry(id).or_insert(0) += n;
        }
        // (c) one bypass event per batch: the victim's 0-based batch
        // position is at most `window`.
        let at = victim_event
            .unwrap_or_else(|| panic!("engine {e}: victim never served"));
        assert!(
            at <= window,
            "engine {e}: victim served at batch event {at}, window \
             {window}"
        );
    }
    // (a) every request admitted exactly once.
    assert_eq!(all_counts.len(), total, "some request never admitted");
    for (id, n) in &all_counts {
        assert_eq!(*n, 1, "request {id} admitted {n} times");
    }
    svc.check_invariants();
    assert_eq!(svc.pinned_nodes(), 0, "every admission returned its pins");
}

/// Literal PR 2 `ReorderQueue::pop` body (pre-batching), replayed over
/// a plain `Vec` — an independent implementation, NOT a call into the
/// refactored queue, so the conformance test below can actually fail
/// if `pop_batch` ever diverges from the historical semantics.
fn pr2_pop(
    items: &mut Vec<PendingRequest>,
    reorder: bool,
    window: usize,
) -> Option<PendingRequest> {
    fn arrives_before(a: &PendingRequest, b: &PendingRequest) -> bool {
        (a.arrival, a.id) < (b.arrival, b.id)
    }
    if items.is_empty() {
        return None;
    }
    if !reorder {
        let mut oldest = 0usize;
        for i in 1..items.len() {
            if arrives_before(&items[i], &items[oldest]) {
                oldest = i;
            }
        }
        let mut r = items.swap_remove(oldest);
        r.bypassed = 0;
        return Some(r);
    }
    let mut oldest = 0usize;
    let mut best = 0usize;
    let mut best_pri = items[0].order_priority();
    for i in 1..items.len() {
        if arrives_before(&items[i], &items[oldest]) {
            oldest = i;
        }
        let p = items[i].order_priority();
        if p > best_pri {
            best_pri = p;
            best = i;
        }
    }
    if items[oldest].bypassed >= window {
        let mut r = items.swap_remove(oldest);
        r.bypassed = 0;
        return Some(r);
    }
    let chosen = (items[best].arrival, items[best].id);
    for r in items.iter_mut() {
        if (r.arrival, r.id) < chosen {
            r.bypassed += 1;
        }
    }
    let mut r = items.swap_remove(best);
    r.bypassed = 0;
    Some(r)
}

/// Conformance (acceptance): `--max-batch 1` is bit-identical to the
/// unbatched PR 2 path. The reference harness replays the historical
/// semantics via [`pr2_pop`] — an independent copy of the pre-batching
/// pop, one request at a time, charging `transfer_time(bytes)` per
/// request — against the batched path popping singleton batches
/// through `BatchAdmission`; pop order, bypass counters and the f64
/// charge sequence must match bit for bit.
#[test]
fn batch_of_one_is_bit_identical_to_unbatched_reference() {
    let driver = LinkDriver;
    // Deterministic per-request promotion bytes.
    let bytes_of = |id: u64| -> u64 { (id % 9) * 4096 };
    let adm_of = |id: u64| -> Admission {
        Admission {
            transfers: Transfers {
                h2g_bytes: bytes_of(id),
                g2h_bytes: 0,
            },
            ..Admission::default()
        }
    };

    let mut rng = Rng::new(0xC0F0);
    for _round in 0..30 {
        let window = 1 + rng.index(6);
        let mut reference: Vec<PendingRequest> = Vec::new();
        let mut batched = ReorderQueue::new(true, window);
        let mut next_id = 0u64;
        let mut ref_charges: Vec<u64> = Vec::new();
        let mut new_charges: Vec<u64> = Vec::new();
        for _op in 0..80 {
            if rng.chance(0.55) {
                let r = PendingRequest {
                    id: next_id,
                    arrival: rng.index(6) as f64,
                    cached_tokens: rng.index(400),
                    compute_tokens: 1 + rng.index(400),
                    bypassed: 0,
                };
                next_id += 1;
                reference.push(r.clone());
                batched.push(r);
            } else {
                // PR 2 reference: single pop + per-request charge.
                let old = pr2_pop(&mut reference, true, window);
                // Tentpole path: singleton batch + coalesced charge.
                let batch = batched.pop_batch(1, usize::MAX);
                match (old, batch.len()) {
                    (None, 0) => {}
                    (Some(old), 1) => {
                        assert_eq!(old.id, batch[0].id, "pop order");
                        assert_eq!(
                            old.bypassed, batch[0].bypassed,
                            "bypass state"
                        );
                        ref_charges.push(
                            driver
                                .transfer_time(bytes_of(old.id))
                                .to_bits(),
                        );
                        let mut ba = BatchAdmission::new();
                        ba.push(batch[0].id, adm_of(batch[0].id));
                        new_charges.push(ba.seal(&driver).to_bits());
                    }
                    (old, n) => {
                        panic!("diverged: {old:?} vs batch of {n}")
                    }
                }
            }
        }
        // The queues must also agree on the residual bypass state, not
        // just the served prefix: drain both to the end.
        loop {
            let old = pr2_pop(&mut reference, true, window);
            let new = batched.pop_batch(1, usize::MAX);
            match (old, new.len()) {
                (None, 0) => break,
                (Some(old), 1) => {
                    assert_eq!(old.id, new[0].id, "tail pop order");
                    assert_eq!(old.bypassed, new[0].bypassed);
                }
                (old, n) => panic!("tail diverged: {old:?} vs {n}"),
            }
        }
        assert_eq!(
            ref_charges, new_charges,
            "per-request charges not bit-identical at batch=1"
        );
    }
}

/// Conformance: the sim and real drivers share the accounting through
/// the same `BatchAdmission` — identical members, identical coalesced
/// byte totals; only the charged time differs (the real driver's
/// transfers are in-process copies, charged 0 s).
#[test]
fn sim_and_real_drivers_agree_on_coalesced_accounting() {
    // Per-shard GPU of 48 tokens holds 3 of each shard's 4 warm docs —
    // the fourth insert forces a swap-out, so host residents exist.
    let svc_sim = sharded(48, 2048);
    let svc_real = sharded(48, 2048);
    // Warm both caches identically through a GPU tier too small for the
    // working set: the overflow swaps out to host, so re-admission
    // promotes (real h2g traffic).
    for svc in [&svc_sim, &svc_real] {
        for d in 0..8u32 {
            let adm = svc.admit(&[(d, DOC_TOKENS)], 4);
            svc.commit(&adm, 1e-3, 1.0, None);
        }
    }
    let admit_all = |svc: &ShardedCacheService,
                     driver: &dyn PipelineDriver|
     -> BatchAdmission {
        BatchAdmission::admit_with(driver, 0..8u64, |id| {
            let adm = svc.admit(&[(id as u32, DOC_TOKENS)], 4);
            svc.commit(&adm, 1e-3, 2.0, None);
            Ok(adm)
        })
    };
    let sim = admit_all(&svc_sim, &LinkDriver);
    let real = admit_all(&svc_real, &ZeroDriver);
    assert_eq!(sim.len(), real.len());
    assert_eq!(
        sim.transfers(),
        real.transfers(),
        "drivers disagree on coalesced bytes"
    );
    assert!(
        sim.total_bytes() > 0,
        "host-resident warm set must actually promote"
    );
    assert_eq!(real.transfer_time(), 0.0, "real copies are pre-measured");
    assert_eq!(
        sim.transfer_time(),
        LinkDriver.transfer_time(sim.total_bytes()),
        "sim charges the burst exactly once"
    );
}

fn miss_trace(n: usize) -> Trace {
    // Distinct doc pairs per request, all arriving at t=0: pure
    // cache-miss burst.
    let corpus = Corpus::wikipedia_like(4 * n, 1);
    let mut trace = Trace::generate(&MMLU, &corpus, 1.0, n, 2, 11);
    for (i, r) in trace.requests.iter_mut().enumerate() {
        r.arrival = 0.0;
        r.docs = vec![2 * i as u32, 2 * i as u32 + 1];
        r.doc_tokens = vec![512, 512];
        r.request_tokens = 32;
        r.output_tokens = 2;
    }
    trace
}

fn run_sim(max_batch: usize, n: usize) -> ragcache::controller::SimOutcome {
    let mut cfg = SystemConfig::default();
    cfg.kind =
        ragcache::config::SystemKindField(SystemKind::parse("ragcache").unwrap());
    cfg.cache.gpu_bytes = 8 * (1 << 30);
    cfg.cache.host_bytes = 192 * (1 << 30);
    cfg.engine.max_batch = max_batch;
    cfg.sched.reorder = false;
    cfg.spec.enabled = false;
    let server = SimServer::build(
        &cfg,
        miss_trace(n),
        4 * n,
        RetrievalTiming::default(),
        5,
    )
    .unwrap();
    server.run()
}

/// Conformance (satellite): with the deterministic cost model, a batch
/// of B cache-miss requests reports strictly lower summed TTFT than B
/// serialized singleton batches (shared weight read + no queue wait),
/// and `max_batch = 1` is deterministic — two runs reproduce identical
/// per-request timestamps bit for bit.
#[test]
fn sim_batched_prefill_beats_serialized_singletons() {
    let n = 8;
    let batched = run_sim(n, n);
    let singleton = run_sim(1, n);
    assert_eq!(batched.completed, n);
    assert_eq!(singleton.completed, n);
    let sum = |o: &ragcache::controller::SimOutcome| -> f64 {
        let mut s = o.recorder.ttft();
        s.mean() * s.len() as f64
    };
    let (b, s) = (sum(&batched), sum(&singleton));
    assert!(
        b < s,
        "batch of {n} summed TTFT {b} !< serialized {s}"
    );

    // Determinism guard for the batch=1 regression surface.
    let again = run_sim(1, n);
    for i in 0..n as u64 {
        let a = singleton.recorder.record(i).unwrap();
        let b = again.recorder.record(i).unwrap();
        assert_eq!(
            a.first_token.map(f64::to_bits),
            b.first_token.map(f64::to_bits),
            "request {i} TTFT not reproducible at max_batch=1"
        );
        assert_eq!(
            a.finished.map(f64::to_bits),
            b.finished.map(f64::to_bits)
        );
    }
}

/// The TCP engine loop actually admits multi-member batches: with the
/// engine busy on a slow first query, a burst of queued requests pops
/// as one batch through `QueryHandler::query_batch`.
struct RecordingHandler {
    sizes: Arc<Mutex<Vec<usize>>>,
    first: bool,
}

impl QueryHandler for RecordingHandler {
    fn query(
        &mut self,
        target_doc: u32,
        _query: &str,
        _max_new: usize,
    ) -> anyhow::Result<proto::QueryResult> {
        if self.first {
            // Hold the engine so the burst queues behind this request.
            self.first = false;
            std::thread::sleep(Duration::from_millis(500));
        }
        Ok(proto::QueryResult {
            id: target_doc as u64 + 1,
            docs: vec![target_doc],
            docs_hit: 0,
            cached_tokens: 0,
            computed_tokens: 1,
            ttft_ms: 1.0,
            total_ms: 1.0,
            text: "ok".into(),
        })
    }

    fn query_batch(
        &mut self,
        batch: &[(u32, String, usize)],
    ) -> Vec<anyhow::Result<proto::QueryResult>> {
        self.sizes.lock().unwrap().push(batch.len());
        batch
            .iter()
            .map(|(d, q, m)| self.query(*d, q, *m))
            .collect()
    }

    fn stats(&self) -> proto::StatsResult {
        proto::StatsResult::default()
    }
}

#[test]
fn engine_loop_pops_multi_member_batches() {
    let sizes: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
    let handler_sizes = Arc::clone(&sizes);
    let opts = ServerOptions {
        workers: 6,
        max_batch: 8,
        ..ServerOptions::default()
    };
    let server = Server::spawn_with(0, opts, move || {
        Ok(RecordingHandler {
            sizes: handler_sizes,
            first: true,
        })
    })
    .expect("spawn");
    let addr = server.addr;

    // One request occupies the engine; pre-connected clients then fire
    // a burst that queues behind it.
    let blocker = std::thread::spawn(move || {
        let mut c = Client::connect(addr).unwrap();
        c.call(&proto::Request::Query {
            target_doc: 0,
            query: "slow".into(),
            max_new: 1,
        })
        .unwrap()
    });
    std::thread::sleep(Duration::from_millis(100));
    let mut burst_clients: Vec<Client> = (0..4)
        .map(|_| Client::connect(addr).unwrap())
        .collect();
    let burst: Vec<_> = burst_clients
        .drain(..)
        .enumerate()
        .map(|(i, mut c)| {
            std::thread::spawn(move || {
                c.call(&proto::Request::Query {
                    target_doc: 1 + i as u32,
                    query: "q".into(),
                    max_new: 1,
                })
                .unwrap()
            })
        })
        .collect();
    blocker.join().expect("blocker client");
    for b in burst {
        match b.join().expect("burst client") {
            proto::Response::Query(_) => {}
            other => panic!("unexpected {other:?}"),
        }
    }
    server.stop();

    let sizes = sizes.lock().unwrap();
    let served: usize = sizes.iter().sum();
    assert_eq!(served, 5, "every request answered exactly once");
    assert!(
        sizes.iter().any(|&s| s >= 2),
        "no multi-member batch ever popped: {sizes:?}"
    );
}
