//! Integration: chunk-level, position-independent KV reuse beside the
//! knowledge tree — reordered top-k property (chunk hits, strictly
//! fewer prefill tokens), `--chunk-cache off` conformance with the
//! chunk-free path, tier dedupe between tree nodes and owned chunk
//! entries (no double residency), and randomized multi-engine
//! interleaving with zero leaked pins or bytes. PJRT-free.

use ragcache::config::PolicyKind;
use ragcache::controller::{CacheService, ShardedCacheService};
use ragcache::kvcache::PageSpec;
use ragcache::policy::make_policy;
use ragcache::tree::{KnowledgeTree, Transfers};
use ragcache::util::Rng;

const DOC_TOKENS: usize = 16;
const BOUNDARY: usize = 4;
const REQ_TOKENS: usize = 8;

fn page() -> PageSpec {
    PageSpec {
        block_tokens: 8,
        kv_bytes_per_token: 16,
    }
}

fn tree(gpu_tokens: usize, host_tokens: usize, chunk: bool) -> KnowledgeTree {
    let p = page();
    let mut t = KnowledgeTree::new(
        p.bytes(gpu_tokens),
        p.bytes(host_tokens),
        p,
        make_policy(PolicyKind::Pgdsf),
        true,
        0,
    );
    if chunk {
        t.enable_chunk_cache(BOUNDARY);
    }
    t
}

fn service(chunk: bool) -> CacheService {
    CacheService::new(tree(4096, 8192, chunk))
}

fn warm(svc: &CacheService, docs: &[u32]) {
    let dt: Vec<(u32, usize)> =
        docs.iter().map(|&d| (d, DOC_TOKENS)).collect();
    let adm = svc.admit(&dt, REQ_TOKENS);
    svc.commit(&adm, 1e-3, 0.0, None);
}

/// Admit one doc sequence, commit it, and return (beta, chunk_hits).
fn serve(svc: &CacheService, docs: &[u32], now: f64) -> (usize, usize) {
    let dt: Vec<(u32, usize)> =
        docs.iter().map(|&d| (d, DOC_TOKENS)).collect();
    let adm = svc.admit(&dt, REQ_TOKENS);
    let hits = adm.chunk_hits.len();
    svc.touch_hits(&adm, 1e-3, now);
    svc.commit(&adm, 1e-3, now, None);
    (adm.beta, hits)
}

/// Reordered top-k property: after warming `[a, b]`, serving `[b, a]`
/// with the chunk cache ON reuses both documents' KV as chunk hits at
/// their new positions and pays only the boundary repair, while the
/// chunk-free path re-prefills both documents from scratch.
#[test]
fn reordered_pair_hits_chunks_and_prefills_strictly_less() {
    let on = service(true);
    let off = service(false);
    warm(&on, &[10, 11]);
    warm(&off, &[10, 11]);

    let (beta_on, hits_on) = serve(&on, &[11, 10], 1.0);
    let (beta_off, hits_off) = serve(&off, &[11, 10], 1.0);

    assert_eq!(hits_off, 0, "chunk cache off never reports hits");
    assert_eq!(hits_on, 2, "both reordered docs hit the chunk cache");
    assert_eq!(
        beta_on,
        2 * BOUNDARY + REQ_TOKENS,
        "chunk path recomputes only the boundary tokens"
    );
    assert_eq!(
        beta_off,
        2 * DOC_TOKENS + REQ_TOKENS,
        "chunk-free path re-prefills both docs"
    );
    assert!(beta_on < beta_off);

    let c = on.counters();
    assert_eq!(c.chunk_hits, 2);
    assert_eq!(
        c.boundary_recompute_tokens,
        2 * BOUNDARY as u64,
        "boundary recompute accounted per hit"
    );
    assert_eq!(
        c.chunk_hit_bytes,
        2 * page().payload_bytes(DOC_TOKENS - BOUNDARY),
        "hit bytes are the reused rows, not the whole chunk"
    );
    on.check_invariants();
    assert_eq!(on.pinned_nodes(), 0);
}

/// Randomized reordered top-k: warm random doc sets in retrieval
/// order, then replay each set under a random permutation. The chunk
/// cache must serve strictly fewer prefill tokens in aggregate, and
/// never more on any individual request.
#[test]
fn randomized_reordering_never_prefills_more_with_chunks_on() {
    let on = service(true);
    let off = service(false);
    let mut rng = Rng::new(0xC4C8E);
    let mut sum_on = 0usize;
    let mut sum_off = 0usize;
    let mut total_hits = 0usize;
    for round in 0..50u64 {
        // Distinct docs, ascending: a canonical "retrieval order".
        let base = (round as u32) * 8;
        let mut docs =
            vec![base, base + 1 + rng.below(3) as u32, base + 5];
        warm(&on, &docs);
        warm(&off, &docs);
        // Random permutation (Fisher–Yates).
        for i in (1..docs.len()).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            docs.swap(i, j);
        }
        let now = round as f64;
        let (beta_on, hits) = serve(&on, &docs, now);
        let (beta_off, _) = serve(&off, &docs, now);
        assert!(
            beta_on <= beta_off,
            "round {round}: chunk cache prefilled more ({beta_on} > \
             {beta_off}) for permutation {docs:?}"
        );
        sum_on += beta_on;
        sum_off += beta_off;
        total_hits += hits;
    }
    assert!(
        sum_on < sum_off,
        "aggregate prefill must strictly shrink: {sum_on} vs {sum_off}"
    );
    assert!(total_hits > 0, "the permutations exercised chunk hits");
    on.check_invariants();
    off.check_invariants();
    assert_eq!(on.pinned_nodes() + off.pinned_nodes(), 0);
}

/// `--chunk-cache off` conformance: the off path must be bit-identical
/// to the chunk-free tree — same admissions, same counters, zero chunk
/// state — and an IN-ORDER stream must behave identically even with
/// the cache on (the chunk machinery only engages on reordering).
#[test]
fn chunk_cache_off_is_bit_identical_to_plain_path() {
    let off = service(false);
    let replica = service(false);
    let on_inorder = service(true);
    let mut rng = Rng::new(0x0FF);
    for i in 0..200u64 {
        let a = rng.below(12) as u32 * 2;
        let docs = [a, a + 1];
        let dt: Vec<(u32, usize)> =
            docs.iter().map(|&d| (d, DOC_TOKENS)).collect();
        let x = off.admit(&dt, REQ_TOKENS);
        let y = replica.admit(&dt, REQ_TOKENS);
        let z = on_inorder.admit(&dt, REQ_TOKENS);
        for adm in [&x, &y, &z] {
            assert!(
                adm.chunk_hits.is_empty(),
                "req {i}: in-order stream must not take the chunk path"
            );
        }
        assert_eq!(x.matched_docs, y.matched_docs);
        assert_eq!(x.alpha, y.alpha);
        assert_eq!(x.beta, y.beta);
        assert_eq!(x.transfers, y.transfers);
        assert_eq!((x.alpha, x.beta), (z.alpha, z.beta));
        let now = i as f64;
        off.commit(&x, 1e-3, now, None);
        replica.commit(&y, 1e-3, now, None);
        on_inorder.commit(&z, 1e-3, now, None);
    }
    let (co, cr, cz) =
        (off.counters(), replica.counters(), on_inorder.counters());
    assert_eq!(co, cr, "off path is deterministic");
    assert_eq!(co.chunk_hits, 0);
    assert_eq!(co.chunk_hit_bytes, 0);
    assert_eq!(co.boundary_recompute_tokens, 0);
    assert_eq!(
        (cz.chunk_hits, cz.chunk_hit_bytes),
        (0, 0),
        "in-order stream leaves chunk counters untouched even when on"
    );
    assert_eq!(
        (co.inserts, co.gpu_evictions, co.swap_out_bytes),
        (cz.inserts, cz.gpu_evictions, cz.swap_out_bytes),
        "tree behaviour identical with the cache on but unused"
    );
    assert_eq!(off.occupancy().gpu_used, on_inorder.occupancy().gpu_used);
    assert_eq!(
        off.occupancy().host_used,
        on_inorder.occupancy().host_used
    );
    off.with(|t| assert_eq!(t.chunk_entry_count(), 0));
}

/// Double-residency regression: a doc is charged against the tiers as
/// a tree node OR an owned chunk entry, never both. Covers the pinned
/// (doomed, drains on last unpin) and unpinned (released immediately,
/// slot rebound to a zero-byte Ref) supersede paths; check_invariants
/// itself enforces per-tier `used == Σ distinct payload bytes` at
/// every step.
#[test]
fn tree_insert_dedupes_owned_chunk_entry() {
    let svc = service(true);
    let p = page();
    let base = svc.occupancy().gpu_used; // root
    let small = p.bytes(DOC_TOKENS);
    let big = p.bytes(2 * DOC_TOKENS);

    // Unpinned supersede: owned entry → tree insert of the same doc at
    // a different span → owned bytes released, slot rebound to a Ref.
    svc.with(|t| {
        let mut tr = Transfers::default();
        assert!(t.chunk_insert_owned(8, DOC_TOKENS, 0, None, &mut tr));
    });
    svc.check_invariants();
    assert_eq!(svc.occupancy().gpu_used, base + small);
    let adm = svc.admit(&[(8, 2 * DOC_TOKENS)], REQ_TOKENS);
    assert!(
        adm.chunk_hits.is_empty(),
        "span mismatch is a miss, not a partial hit"
    );
    svc.commit(&adm, 1e-3, 0.0, None);
    svc.check_invariants();
    assert_eq!(
        svc.occupancy().gpu_used,
        base + big,
        "owned bytes released on supersede; Ref is zero-byte"
    );
    svc.with(|t| {
        assert_eq!(
            t.chunk_estimate(8),
            Some((2 * DOC_TOKENS - BOUNDARY, BOUNDARY)),
            "Ref shares the node payload"
        );
    });
    // The Ref serves position-independent hits with no extra bytes.
    let hit = svc.admit(&[(99, DOC_TOKENS), (8, 2 * DOC_TOKENS)], REQ_TOKENS);
    assert_eq!(hit.chunk_hits.len(), 1);
    assert_eq!(svc.occupancy().gpu_used, base + big);
    svc.release(&hit);

    // Pinned supersede: a hit holds the owned entry while a wider span
    // is inserted — the entry is doomed, its bytes drain on last unpin.
    svc.with(|t| {
        let mut tr = Transfers::default();
        assert!(t.chunk_insert_owned(7, DOC_TOKENS, 0, None, &mut tr));
    });
    let pin = svc.admit(&[(7, DOC_TOKENS)], REQ_TOKENS);
    assert_eq!(pin.chunk_hits.len(), 1, "owned entry serves the hit");
    let wide = svc.admit(&[(7, 2 * DOC_TOKENS)], REQ_TOKENS);
    assert!(wide.chunk_hits.is_empty());
    svc.commit(&wide, 1e-3, 1.0, None);
    svc.check_invariants(); // doomed entry still holds its bytes
    assert_eq!(
        svc.occupancy().gpu_used,
        base + 2 * big + small,
        "doomed-but-pinned entry stays charged until its pin drains"
    );
    svc.release(&pin); // last unpin → doomed entry drained
    svc.check_invariants();
    assert_eq!(
        svc.occupancy().gpu_used,
        base + 2 * big,
        "after the drain only distinct tree payloads remain charged"
    );
    assert_eq!(svc.pinned_nodes(), 0);
}

/// Randomized multi-engine interleaving: threads hammer a sharded,
/// chunk-enabled cache with reordered pairs, aborted speculation and
/// mid-flight GPU failures under constant eviction pressure. The tiers
/// must balance (check_invariants covers node AND owned chunk bytes)
/// and every pin — path and chunk — must be returned.
#[test]
fn randomized_interleaving_with_chunks_leaks_nothing() {
    let p = page();
    let svc = ShardedCacheService::build(4, |_| {
        let mut t = KnowledgeTree::new(
            p.bytes(64),
            p.bytes(256),
            p,
            make_policy(PolicyKind::Pgdsf),
            true,
            0,
        );
        t.enable_chunk_cache(BOUNDARY);
        t
    });
    let threads = 8;
    let ops = 250;
    let mut handles = Vec::new();
    for t in 0..threads {
        let svc = svc.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xC44A + t as u64);
            for i in 0..ops {
                let a = rng.below(16) as u32;
                let b = rng.below(16) as u32;
                // Half the traffic reversed: same docs, new positions —
                // the case the chunk cache exists for.
                let docs = if i % 2 == 0 {
                    [(a, DOC_TOKENS), (b, DOC_TOKENS)]
                } else {
                    [(b, DOC_TOKENS), (a, DOC_TOKENS)]
                };
                let adm = svc.admit(&docs, REQ_TOKENS);
                match i % 7 {
                    0 => svc.release(&adm), // aborted speculation
                    1 => {
                        // Device failure with hits in flight: GPU-owned
                        // chunk entries die with their pins; commit
                        // must still balance the ledger.
                        svc.shard(adm.shard).fail_gpu();
                        svc.commit(&adm, 1e-3, i as f64, None);
                    }
                    _ => {
                        svc.touch_hits(&adm, 1e-3, i as f64);
                        svc.commit(&adm, 1e-3, i as f64, None);
                    }
                }
                if i % 50 == 0 {
                    svc.check_invariants();
                }
            }
        }));
    }
    for h in handles {
        h.join().expect("no hammering thread panicked");
    }
    svc.check_invariants();
    assert_eq!(
        svc.pinned_nodes(),
        0,
        "quiescent: every path and chunk pin was returned"
    );
    let total = svc.counters();
    assert!(total.inserts > 0, "traffic exercised insertion");
    assert!(
        total.chunk_hits > 0,
        "reversed pairs exercised the chunk path: {total:?}"
    );
    // Byte ledger: nothing leaked past the budgets.
    for s in 0..svc.num_shards() {
        let o = svc.shard(s).occupancy();
        assert!(o.gpu_used <= o.gpu_capacity, "shard {s} gpu over budget");
        assert!(o.host_used <= o.host_capacity, "shard {s} host over budget");
    }
}
