//! Satellite: `staged_search` invariants across all three index types
//! (flat / IVF / HNSW) — the contract dynamic speculative pipelining
//! relies on:
//!
//! 1. Stages are monotone in scanned fraction (work only accumulates),
//!    ending at 1.0, and the running best candidate only improves.
//! 2. The final stage equals the non-staged `search` result bit for
//!    bit — speculating on intermediate candidates can never change
//!    the answer, only its arrival time.
//! 3. Determinism under the build seed: the same index answers the
//!    same query with identical stage snapshots every time, and each
//!    stage's candidate set is drawn from a *prefix* of the index's
//!    (seed-fixed) scan order — for the exact flat index, stage `s` is
//!    literally the brute-force top-k of the first `frac·n` rows.

use ragcache::util::Rng;
use ragcache::vectordb::{
    FlatIndex, HnswIndex, IvfIndex, StageSnapshot, VectorIndex,
};

fn corpus(n: usize, dim: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..dim).map(|_| rng.f32()).collect())
        .collect()
}

fn indexes(
    vecs: &[Vec<f32>],
    dim: usize,
) -> Vec<(&'static str, Box<dyn VectorIndex>)> {
    vec![
        ("flat", Box::new(FlatIndex::build(dim, vecs))),
        ("ivf", Box::new(IvfIndex::build(dim, vecs, 16, 8, 11))),
        ("hnsw", Box::new(HnswIndex::build(dim, vecs, 12, 48, 13))),
    ]
}

fn snapshot_key(snaps: &[StageSnapshot]) -> Vec<(u64, Vec<(u64, u32)>)> {
    snaps
        .iter()
        .map(|s| {
            (
                s.frac_scanned.to_bits(),
                s.topk
                    .iter()
                    .map(|&(d, id)| (d.to_bits(), id))
                    .collect(),
            )
        })
        .collect()
}

#[test]
fn stages_monotone_and_best_only_improves() {
    let dim = 12;
    let vecs = corpus(600, dim, 1);
    let mut rng = Rng::new(2);
    for (name, idx) in indexes(&vecs, dim) {
        for _ in 0..12 {
            let q: Vec<f32> = (0..dim).map(|_| rng.f32()).collect();
            for stages in [1usize, 2, 4, 7] {
                let snaps = idx.staged_search(&q, 4, stages);
                assert!(!snaps.is_empty(), "{name}: no snapshots");
                let last = snaps.last().unwrap();
                assert!(
                    (last.frac_scanned - 1.0).abs() < 1e-9,
                    "{name}: final stage must have scanned everything"
                );
                let mut best = f64::INFINITY;
                for w in snaps.windows(2) {
                    assert!(
                        w[0].frac_scanned <= w[1].frac_scanned + 1e-12,
                        "{name}: scanned fraction regressed"
                    );
                }
                for s in &snaps {
                    // Candidates are sorted best-first…
                    for w in s.topk.windows(2) {
                        assert!(
                            w[0].0 <= w[1].0,
                            "{name}: topk not sorted"
                        );
                    }
                    // …and the running best never gets worse.
                    if let Some(h) = s.topk.first() {
                        assert!(
                            h.0 <= best + 1e-12,
                            "{name}: best candidate regressed"
                        );
                        best = h.0;
                    }
                }
            }
        }
    }
}

#[test]
fn final_stage_equals_unstaged_search_bitwise() {
    let dim = 10;
    let vecs = corpus(500, dim, 3);
    let mut rng = Rng::new(4);
    for (name, idx) in indexes(&vecs, dim) {
        for _ in 0..10 {
            let q: Vec<f32> = (0..dim).map(|_| rng.f32()).collect();
            let direct = idx.search(&q, 5);
            for stages in [1usize, 3, 4, 6] {
                let snaps = idx.staged_search(&q, 5, stages);
                let last = &snaps.last().unwrap().topk;
                assert_eq!(
                    last.len(),
                    direct.len(),
                    "{name}/{stages} stages: candidate count"
                );
                for (a, b) in last.iter().zip(&direct) {
                    assert_eq!(a.1, b.1, "{name}: ids diverge");
                    assert_eq!(
                        a.0.to_bits(),
                        b.0.to_bits(),
                        "{name}: distances diverge bitwise"
                    );
                }
            }
        }
    }
}

/// Same index (same build seed), same query → identical snapshots,
/// every field, bit for bit, across repeated calls. This is what makes
/// a speculation's candidate evolution reproducible.
#[test]
fn staged_search_deterministic_under_seed() {
    let dim = 8;
    let vecs = corpus(400, dim, 5);
    let mut rng = Rng::new(6);
    for (name, idx) in indexes(&vecs, dim) {
        for _ in 0..8 {
            let q: Vec<f32> = (0..dim).map(|_| rng.f32()).collect();
            let a = snapshot_key(&idx.staged_search(&q, 3, 4));
            let b = snapshot_key(&idx.staged_search(&q, 3, 4));
            assert_eq!(a, b, "{name}: staged search not deterministic");
        }
    }
    // Determinism extends across identically-seeded rebuilds (the seed
    // pins the scan order, so candidate sets are prefixes of the same
    // order on every replica).
    let ivf_a = IvfIndex::build(dim, &vecs, 16, 8, 11);
    let ivf_b = IvfIndex::build(dim, &vecs, 16, 8, 11);
    let q: Vec<f32> = (0..dim).map(|_| rng.f32()).collect();
    assert_eq!(
        snapshot_key(&ivf_a.staged_search(&q, 3, 4)),
        snapshot_key(&ivf_b.staged_search(&q, 3, 4)),
        "identically-seeded IVF builds must stage identically"
    );
}

/// For the exact flat index the prefix property is literal: stage `s`
/// scans rows `0 .. frac·n`, so its candidates must equal an
/// independent brute-force top-k over exactly that row prefix.
#[test]
fn flat_stage_candidates_are_prefix_topk() {
    let dim = 9;
    let n = 333; // deliberately not divisible by the stage count
    let vecs = corpus(n, dim, 7);
    let idx = FlatIndex::build(dim, &vecs);
    let mut rng = Rng::new(8);
    for _ in 0..10 {
        let q: Vec<f32> = (0..dim).map(|_| rng.f32()).collect();
        let stages = 4;
        let snaps = idx.staged_search(&q, 6, stages);
        assert_eq!(snaps.len(), stages);
        for (s, snap) in snaps.iter().enumerate() {
            let end = (n * (s + 1)) / stages;
            assert!(
                (snap.frac_scanned - end as f64 / n as f64).abs() < 1e-12
            );
            // Independent reference: naive selection over the prefix
            // (same distance kernel — the property under test is the
            // prefix/selection behavior, not float arithmetic).
            let mut naive: Vec<(f64, u32)> = vecs[..end]
                .iter()
                .enumerate()
                .map(|(i, v)| {
                    (
                        ragcache::vectordb::distance::l2_sq(&q, v),
                        i as u32,
                    )
                })
                .collect();
            naive.sort_by(|a, b| {
                a.partial_cmp(b).expect("finite distances")
            });
            naive.truncate(6);
            let got: Vec<u32> = snap.topk.iter().map(|h| h.1).collect();
            let want: Vec<u32> = naive.iter().map(|h| h.1).collect();
            assert_eq!(
                got, want,
                "stage {s}: candidates are not the prefix top-k"
            );
        }
    }
}
