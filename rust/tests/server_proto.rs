//! Integration: the TCP JSON-lines server with a mock handler (protocol
//! level) — PJRT-free so it runs everywhere.

use ragcache::server::{proto, Client, QueryHandler, Server};

struct MockHandler {
    served: usize,
}

impl QueryHandler for MockHandler {
    fn query(
        &mut self,
        target_doc: u32,
        query: &str,
        max_new: usize,
    ) -> anyhow::Result<proto::QueryResult> {
        if target_doc == 999 {
            anyhow::bail!("document out of range");
        }
        self.served += 1;
        Ok(proto::QueryResult {
            id: self.served as u64,
            docs: vec![target_doc, target_doc + 1],
            docs_hit: 1,
            cached_tokens: 64,
            computed_tokens: query.len() + max_new,
            ttft_ms: 12.0,
            total_ms: 20.0,
            text: format!("echo:{query}"),
        })
    }

    fn stats(&self) -> proto::StatsResult {
        proto::StatsResult {
            requests: self.served,
            mean_ttft_ms: 12.0,
            hit_rate: 0.5,
            engines: 1,
            ..Default::default()
        }
    }
}

fn spawn() -> Server {
    Server::spawn(0, || Ok(MockHandler { served: 0 })).expect("spawn")
}

#[test]
fn query_roundtrip_over_tcp() {
    let server = spawn();
    let mut client = Client::connect(server.addr).unwrap();
    let resp = client
        .call(&proto::Request::Query {
            target_doc: 7,
            query: "what is ragcache".into(),
            max_new: 4,
        })
        .unwrap();
    match resp {
        proto::Response::Query(q) => {
            assert_eq!(q.docs, vec![7, 8]);
            assert_eq!(q.text, "echo:what is ragcache");
            assert!(q.ttft_ms > 0.0);
        }
        other => panic!("unexpected {other:?}"),
    }
    server.stop();
}

#[test]
fn stats_reflect_served_requests() {
    let server = spawn();
    let mut client = Client::connect(server.addr).unwrap();
    for i in 0..3 {
        client
            .call(&proto::Request::Query {
                target_doc: i,
                query: "q".into(),
                max_new: 1,
            })
            .unwrap();
    }
    match client.call(&proto::Request::Stats).unwrap() {
        proto::Response::Stats(s) => {
            assert_eq!(s.requests, 3);
            assert_eq!(s.engines, 1, "single-engine merge");
        }
        other => panic!("unexpected {other:?}"),
    }
    server.stop();
}

#[test]
fn handler_errors_become_protocol_errors() {
    let server = spawn();
    let mut client = Client::connect(server.addr).unwrap();
    let resp = client
        .call(&proto::Request::Query {
            target_doc: 999,
            query: "boom".into(),
            max_new: 1,
        })
        .unwrap();
    match resp {
        proto::Response::Error { message } => {
            assert!(message.contains("out of range"), "{message}");
        }
        other => panic!("unexpected {other:?}"),
    }
    server.stop();
}

#[test]
fn malformed_requests_rejected_gracefully() {
    use std::io::{BufRead, BufReader, Write};
    let server = spawn();
    let stream = std::net::TcpStream::connect(server.addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writeln!(writer, "this is not json").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    match proto::parse_response(&line).unwrap() {
        proto::Response::Error { message } => {
            assert!(message.contains("bad request"));
        }
        other => panic!("unexpected {other:?}"),
    }
    // Connection still usable afterwards.
    writeln!(writer, "{}", proto::encode_request(&proto::Request::Stats))
        .unwrap();
    let mut line2 = String::new();
    reader.read_line(&mut line2).unwrap();
    assert!(matches!(
        proto::parse_response(&line2).unwrap(),
        proto::Response::Stats(_)
    ));
    server.stop();
}

#[test]
fn shutdown_op_stops_server() {
    let server = spawn();
    let addr = server.addr;
    let mut client = Client::connect(addr).unwrap();
    let resp = client.call(&proto::Request::Shutdown).unwrap();
    assert_eq!(resp, proto::Response::Ok);
    server.join();
    // Subsequent connections are refused (allow a scheduling beat).
    std::thread::sleep(std::time::Duration::from_millis(50));
    // Either connect fails outright or the connection is dropped: assert
    // that a round-trip cannot complete.
    if let Ok(mut c) = Client::connect(addr) {
        assert!(c.call(&proto::Request::Stats).is_err());
    }
}
