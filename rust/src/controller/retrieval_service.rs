//! Dedicated retrieval thread pool for the real serving path.
//!
//! The blocking path calls `VectorIndex::search` inline on the engine
//! thread; this service instead ticks
//! [`VectorIndex::staged_search`](crate::vectordb::VectorIndex::staged_search)
//! on its own threads and pushes one [`StageReady`] per stage into the
//! engine's event loop, which is what lets the engine run speculative
//! prefills *while the search is still refining* (paper §5.3).
//!
//! Stage pacing: the in-process indexes answer in microseconds, so with
//! zero pacing every stage of a search lands in the engine's channel at
//! once and there is nothing to overlap. [`RetrievalConfig::
//! stage_latency`] spreads the stage completions over wall-clock time —
//! the per-stage latency of the billion-scale deployments the paper
//! measures (Fig. 19's search-ratio axis), and the same stand-in role
//! `RetrievalTiming` plays for the simulator. Production deployments
//! with a remote or sharded index would emit stages at the index's real
//! pace instead.
//!
//! Ordering guarantee: one worker owns a task end-to-end, so a session's
//! stages arrive in order; different sessions' stages interleave freely
//! across the pool.

use crate::tree::DocId;
use crate::vectordb::VectorIndex;
use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// One staged-search job.
#[derive(Debug, Clone)]
pub struct RetrievalTask {
    /// Session the stage events report back to.
    pub session: u64,
    /// Query embedding.
    pub query: Vec<f32>,
    pub top_k: usize,
    /// Per-task stage-count override: `Some(1)` is the admission
    /// ladder's Downgrade — a single-stage search whose first event is
    /// already final, so the session goes straight to the blocking
    /// fallback and speculation never starts. `None` uses the pool's
    /// configured [`RetrievalConfig::stages`].
    pub stages: Option<usize>,
}

/// One completed retrieval stage, pushed into the engine's event loop.
#[derive(Debug, Clone, PartialEq)]
pub struct StageReady {
    pub session: u64,
    /// 0-based stage index.
    pub stage: usize,
    /// Total stages of this search.
    pub stages: usize,
    pub is_final: bool,
    /// Fraction of the index scanned after this stage.
    pub frac_scanned: f64,
    /// Candidate top-k document ids, best first.
    pub docs: Vec<DocId>,
}

/// Pool configuration.
#[derive(Debug, Clone)]
pub struct RetrievalConfig {
    /// Worker threads ticking searches (`--retrieval-threads`).
    pub threads: usize,
    /// Stages per search (`--stages`).
    pub stages: usize,
    /// Wall-clock pacing per stage (see the module docs).
    pub stage_latency: Duration,
}

impl Default for RetrievalConfig {
    fn default() -> Self {
        RetrievalConfig {
            threads: 2,
            stages: 4,
            stage_latency: Duration::ZERO,
        }
    }
}

/// The retrieval thread pool. Dropping it stops the workers (in-flight
/// searches stop emitting and wind down).
pub struct RetrievalService {
    tx: Option<mpsc::Sender<RetrievalTask>>,
    handles: Vec<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
    /// Sessions whose searches were aborted (shed while retrieving):
    /// workers stop emitting stages for them at the next stage
    /// boundary. Entries clear when the owning worker observes them.
    cancelled: Arc<Mutex<HashSet<u64>>>,
}

impl RetrievalService {
    /// Spawn the pool. Stage events for every submitted task arrive on
    /// `events`.
    pub fn spawn(
        index: Arc<dyn VectorIndex>,
        cfg: RetrievalConfig,
        events: mpsc::Sender<StageReady>,
    ) -> Self {
        let (tx, rx) = mpsc::channel::<RetrievalTask>();
        let rx = Arc::new(Mutex::new(rx));
        let stop = Arc::new(AtomicBool::new(false));
        let stages = cfg.stages.max(1);
        let cancelled = Arc::new(Mutex::new(HashSet::new()));
        let mut handles = Vec::new();
        for _ in 0..cfg.threads.max(1) {
            let rx = Arc::clone(&rx);
            let index = Arc::clone(&index);
            let events = events.clone();
            let stop = Arc::clone(&stop);
            let cancelled = Arc::clone(&cancelled);
            let pace = cfg.stage_latency;
            handles.push(std::thread::spawn(move || loop {
                let task = {
                    let guard = match rx.lock() {
                        Ok(g) => g,
                        Err(poisoned) => poisoned.into_inner(),
                    };
                    guard.recv_timeout(Duration::from_millis(20))
                };
                match task {
                    Ok(t) => {
                        let snaps = index.staged_search(
                            &t.query,
                            t.top_k,
                            t.stages.unwrap_or(stages).max(1),
                        );
                        let total = snaps.len();
                        for (s, snap) in snaps.into_iter().enumerate() {
                            if stop.load(Ordering::SeqCst) {
                                return;
                            }
                            if take_cancel(&cancelled, t.session) {
                                break; // session shed: stop emitting
                            }
                            if !pace.is_zero() {
                                std::thread::sleep(pace);
                            }
                            let ev = StageReady {
                                session: t.session,
                                stage: s,
                                stages: total,
                                is_final: s + 1 == total,
                                frac_scanned: snap.frac_scanned,
                                docs: snap
                                    .topk
                                    .iter()
                                    .map(|h| h.1)
                                    .collect(),
                            };
                            if events.send(ev).is_err() {
                                return; // engine gone
                            }
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        if stop.load(Ordering::SeqCst) {
                            return;
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => return,
                }
            }));
        }
        RetrievalService {
            tx: Some(tx),
            handles,
            stop,
            cancelled,
        }
    }

    /// Abort a session's staged search: its worker stops emitting at the
    /// next stage boundary. Safe to call for sessions that already
    /// finished (the stale-session check engine-side drops any stages
    /// that raced past the cancellation).
    pub fn cancel(&self, session: u64) {
        let mut guard = match self.cancelled.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        guard.insert(session);
    }

    /// Enqueue a staged search. Returns false once the pool has shut
    /// down.
    pub fn submit(&self, task: RetrievalTask) -> bool {
        match &self.tx {
            Some(tx) => tx.send(task).is_ok(),
            None => false,
        }
    }
}

/// Check-and-clear a session's cancellation mark. Session ids are never
/// reused, so an entry that outlives its task (cancel raced past the
/// final stage) is inert — it can never suppress a future search — and
/// is swept here the moment any worker observes it.
fn take_cancel(set: &Mutex<HashSet<u64>>, session: u64) -> bool {
    let mut guard = match set.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    };
    guard.remove(&session)
}

impl Drop for RetrievalService {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        self.tx.take(); // disconnect: idle workers exit immediately
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vectordb::FlatIndex;

    fn index(n: usize, dim: usize) -> Arc<dyn VectorIndex> {
        let mut rng = crate::util::Rng::new(0x9E7);
        let vecs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.f32()).collect())
            .collect();
        Arc::new(FlatIndex::build(dim, &vecs))
    }

    #[test]
    fn stages_arrive_in_order_and_final_matches_search() {
        let idx = index(200, 8);
        let (tx, rx) = mpsc::channel();
        let svc = RetrievalService::spawn(
            Arc::clone(&idx),
            RetrievalConfig {
                threads: 2,
                stages: 4,
                stage_latency: Duration::ZERO,
            },
            tx,
        );
        let q: Vec<f32> = idx_query(&idx, 42);
        assert!(svc.submit(RetrievalTask {
            session: 7,
            query: q.clone(),
            top_k: 3,
            stages: None,
        }));
        let mut got = Vec::new();
        for _ in 0..4 {
            got.push(
                rx.recv_timeout(Duration::from_secs(5))
                    .expect("stage event"),
            );
        }
        for (s, ev) in got.iter().enumerate() {
            assert_eq!(ev.session, 7);
            assert_eq!(ev.stage, s);
            assert_eq!(ev.stages, 4);
            assert_eq!(ev.is_final, s == 3);
        }
        for w in got.windows(2) {
            assert!(w[0].frac_scanned <= w[1].frac_scanned + 1e-12);
        }
        let direct: Vec<u32> =
            idx.search(&q, 3).iter().map(|h| h.1).collect();
        assert_eq!(got.last().unwrap().docs, direct);
        drop(svc);
    }

    /// One worker owns a task end to end, so per-session stage order
    /// holds even with many tasks racing across the pool.
    #[test]
    fn per_session_order_holds_across_pool() {
        let idx = index(300, 8);
        let (tx, rx) = mpsc::channel();
        let svc = RetrievalService::spawn(
            Arc::clone(&idx),
            RetrievalConfig {
                threads: 3,
                stages: 3,
                stage_latency: Duration::ZERO,
            },
            tx,
        );
        let tasks = 12u64;
        for session in 0..tasks {
            assert!(svc.submit(RetrievalTask {
                session,
                query: idx_query(&idx, session as u32),
                top_k: 2,
                stages: None,
            }));
        }
        let mut last_stage: std::collections::HashMap<u64, usize> =
            std::collections::HashMap::new();
        let mut finals = 0;
        while finals < tasks {
            let ev = rx
                .recv_timeout(Duration::from_secs(10))
                .expect("stage event");
            let prev = last_stage.insert(ev.session, ev.stage);
            match prev {
                None => assert_eq!(ev.stage, 0, "first stage is stage 0"),
                Some(p) => assert_eq!(
                    ev.stage,
                    p + 1,
                    "session {} stages out of order",
                    ev.session
                ),
            }
            if ev.is_final {
                finals += 1;
            }
        }
        drop(svc);
    }

    /// The ladder's Downgrade: a `stages: Some(1)` task emits exactly
    /// one event and it is already final, regardless of the pool's
    /// configured stage count.
    #[test]
    fn single_stage_override_is_immediately_final() {
        let idx = index(200, 8);
        let (tx, rx) = mpsc::channel();
        let svc = RetrievalService::spawn(
            Arc::clone(&idx),
            RetrievalConfig {
                threads: 1,
                stages: 4,
                stage_latency: Duration::ZERO,
            },
            tx,
        );
        let q = idx_query(&idx, 5);
        assert!(svc.submit(RetrievalTask {
            session: 11,
            query: q.clone(),
            top_k: 3,
            stages: Some(1),
        }));
        let ev = rx
            .recv_timeout(Duration::from_secs(5))
            .expect("stage event");
        assert_eq!(ev.stage, 0);
        assert_eq!(ev.stages, 1);
        assert!(ev.is_final);
        let direct: Vec<u32> =
            idx.search(&q, 3).iter().map(|h| h.1).collect();
        assert_eq!(ev.docs, direct, "single stage scans the full index");
        assert!(rx.recv_timeout(Duration::from_millis(100)).is_err());
        drop(svc);
    }

    /// A cancelled session stops emitting at the next stage boundary.
    #[test]
    fn cancel_stops_stage_emission() {
        let idx = index(200, 8);
        let (tx, rx) = mpsc::channel();
        let svc = RetrievalService::spawn(
            Arc::clone(&idx),
            RetrievalConfig {
                threads: 1,
                stages: 4,
                stage_latency: Duration::from_millis(40),
            },
            tx,
        );
        assert!(svc.submit(RetrievalTask {
            session: 3,
            query: idx_query(&idx, 9),
            top_k: 2,
            stages: None,
        }));
        let first = rx
            .recv_timeout(Duration::from_secs(5))
            .expect("stage 0");
        assert_eq!(first.stage, 0);
        svc.cancel(3);
        // Drain anything that raced past the cancel; no final stage may
        // arrive (the worker breaks before emitting it).
        let mut saw_final = false;
        while let Ok(ev) = rx.recv_timeout(Duration::from_millis(300)) {
            saw_final |= ev.is_final;
        }
        assert!(!saw_final, "cancelled search must not complete");
        drop(svc);
    }

    #[test]
    fn submit_after_drop_refuses() {
        let idx = index(50, 4);
        let (tx, _rx) = mpsc::channel();
        let svc = RetrievalService::spawn(
            idx,
            RetrievalConfig::default(),
            tx,
        );
        drop(svc);
        // A dropped service is observable as gone only through a new
        // handle; the API contract is simply that drop joins cleanly —
        // reaching this line proves no worker deadlocked.
    }

    fn idx_query(idx: &Arc<dyn VectorIndex>, seed: u32) -> Vec<f32> {
        let mut rng = crate::util::Rng::new(seed as u64 + 1);
        (0..idx.dim()).map(|_| rng.f32()).collect()
    }
}
