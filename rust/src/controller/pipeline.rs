//! The shared RAG pipeline core (paper §4, Fig. 7).
//!
//! Every RAGCache controller runs the same per-request state machine:
//!
//! ```text
//!   staged retrieval ──► DSP decision (spec::SpecState)
//!        │                    │
//!        ▼                    ▼
//!   reorder-queue ──► admission: tree match → promote → pin → (α, β)
//!        admission            │
//!                             ▼  (engine computes the prefill)
//!                  commit: unpin → policy refresh → insert new doc KV
//! ```
//!
//! This module owns that state machine so concrete controllers stay thin
//! *drivers* over it: the simulated controller ([`super::sim_server`])
//! supplies the virtual clock and the analytic cost model, the real one
//! ([`super::real`]) supplies wall-clock time and PJRT execution. The
//! [`PipelineDriver`] trait is the seam between the two.
//!
//! [`CacheService`] wraps one [`KnowledgeTree`] (and with it the
//! `TierAllocator` accounting) behind interior locking, so the admission
//! state machine can be driven from many threads at once.
//! [`ShardedCacheService`](super::ShardedCacheService) stacks K of them
//! behind first-document routing — one lock, tier-budget slice and
//! counter set per shard — which is what lets N connection workers and
//! M engine drivers admit in parallel instead of convoying on a single
//! tree mutex. The [`Pipeline`] speaks to the sharded front; an
//! unsharded deployment is simply K = 1.

use super::retrieval::StagedRetrieval;
use super::shard::ShardedCacheService;
use crate::kvcache::{KvPayload, Tier};
use crate::metrics::Recorder;
use crate::policy::AccessCtx;
use crate::sched::ReorderQueue;
use crate::spec::SpecState;
use crate::tree::{
    ChunkHit, DocId, KnowledgeTree, MatchResult, NodeId, TierOccupancy,
    Transfers, TreeCounters,
};
use std::sync::{Arc, Mutex};

/// Generation-tagged engine sequence id: `request_index * GEN_BASE + gen`.
pub const GEN_BASE: u64 = 1024;

/// The request index a generation-tagged sequence id belongs to.
pub fn request_of(seq: u64) -> usize {
    (seq / GEN_BASE) as usize
}

/// What a concrete controller supplies to the shared pipeline: a notion
/// of time and the cost of byte movement. The simulation driver answers
/// from the virtual clock and the PCIe [`crate::kvcache::TransferModel`];
/// the real driver answers from the wall clock (its transfers are
/// in-process copies already folded into measured latency).
pub trait PipelineDriver {
    /// Current time, seconds.
    fn now(&self) -> f64;
    /// Seconds charged for moving `bytes` over the GPU↔host link.
    fn transfer_time(&self, bytes: u64) -> f64;
    /// Seconds charged for one coalesced staged-read burst of `bytes`
    /// restaged from the disk tier (`--disk on`). Callers guard on
    /// `bytes > 0`, so the disk-off f64 arithmetic never sees this
    /// term; the default models no disk (0.0) for drivers that predate
    /// the third tier.
    fn disk_read_time(&self, bytes: u64) -> f64 {
        let _ = bytes;
        0.0
    }
}

/// Wall-clock admission-control ladder for the real serving path — the
/// same Normal → Downgrade → Shed discipline the simulator runs in
/// [`super::sim_server`], packaged so [`super::real::RealServer`] and
/// the PJRT-free serving matrix share one implementation.
///
/// The ladder observes per-request queueing delay at reorder-queue pop
/// time and maintains the PR 7 EWMA (`0.8 · ewma + 0.2 · wait`).
/// Because the real path has no event scheduler, the periodic decay tick
/// is folded into observation: every elapsed `ttft_slo / 4` since the
/// last decay halves the EWMA before the new sample lands — the same
/// fixed-point as the simulator's `ShedDecayTick`.
///
/// Disabled (`--shed off`) the ladder is inert: `downgrading()` and
/// `should_shed()` are always false and no state mutates, keeping the
/// off path bit-identical to the pre-shedding real path.
#[derive(Debug, Clone)]
pub struct ShedLadder {
    enabled: bool,
    ttft_slo: f64,
    downgrade_frac: f64,
    wait_ewma: f64,
    last_decay: f64,
}

impl ShedLadder {
    pub fn new(enabled: bool, ttft_slo: f64, downgrade_frac: f64) -> Self {
        ShedLadder {
            enabled,
            ttft_slo: ttft_slo.max(1e-9),
            downgrade_frac,
            wait_ewma: 0.0,
            last_decay: 0.0,
        }
    }

    /// Inert ladder (`--shed off`).
    pub fn disabled() -> Self {
        ShedLadder::new(false, 5.0, 0.5)
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn ttft_slo(&self) -> f64 {
        self.ttft_slo
    }

    /// Current queue-delay EWMA, seconds.
    pub fn wait_ewma(&self) -> f64 {
        self.wait_ewma
    }

    /// Apply every decay period that elapsed since the last one: the
    /// EWMA halves each `ttft_slo / 4` of wall clock, exactly like the
    /// simulator's decay tick. Iteration-capped: past 64 periods the
    /// EWMA is already below any meaningful threshold, so it snaps to 0.
    pub fn decay_to(&mut self, now: f64) {
        if !self.enabled {
            return;
        }
        let period = self.ttft_slo / 4.0;
        let mut steps = 0usize;
        while now - self.last_decay >= period {
            self.wait_ewma *= 0.5;
            self.last_decay += period;
            steps += 1;
            if steps >= 64 {
                self.wait_ewma = 0.0;
                self.last_decay = now;
                break;
            }
        }
    }

    /// A request popped from the reorder queue after waiting `wait`
    /// seconds: decay the EWMA to `now`, then fold the sample in with
    /// the PR 7 weights.
    pub fn observe_wait(&mut self, wait: f64, now: f64) {
        if !self.enabled {
            return;
        }
        self.decay_to(now);
        self.wait_ewma = 0.8 * self.wait_ewma + 0.2 * wait.max(0.0);
    }

    /// Downgrade new admissions (speculation off, single-stage
    /// retrieval) while the queue-delay EWMA exceeds
    /// `downgrade_frac × ttft_slo`.
    pub fn downgrading(&self) -> bool {
        self.enabled && self.wait_ewma > self.downgrade_frac * self.ttft_slo
    }

    /// Shed a request that has already waited past its TTFT SLO while
    /// still queued (its deadline cannot be met).
    pub fn should_shed(&self, wait: f64) -> bool {
        self.enabled && wait > self.ttft_slo
    }
}

/// One request's admission into the engine: the pinned cache prefix plus
/// everything needed to commit (or abandon) the prefill afterwards.
#[derive(Debug, Clone, Default)]
pub struct Admission {
    /// Matched (and pinned) tree path, root-to-leaf order.
    pub path: Vec<NodeId>,
    /// How many of the requested docs the path covers.
    pub matched_docs: usize,
    /// Cached tokens along the path (the request's α).
    pub alpha: usize,
    /// Tokens the engine must compute (the request's β).
    pub beta: usize,
    /// Docs to insert after the prefill: `(doc, tokens)`.
    pub unmatched: Vec<(DocId, usize)>,
    /// Position-independent chunk-cache hits for docs past the prefix
    /// match (`--chunk-cache on`; always empty when off). Each hit's
    /// reused rows are already counted in `alpha`, its boundary tokens
    /// in `beta`, and its h2g bytes in `transfers` — so the existing
    /// batch-burst and cost-model machinery charges them with no
    /// special cases. The pinned backing entries are released by
    /// commit/release through the recorded [`ChunkHit::source`].
    pub chunk_hits: Vec<ChunkHit>,
    /// Byte movement of this admission's promotion (h2g/g2h, coalesced
    /// across a batch into one PCIe burst by
    /// [`super::batch::BatchAdmission`]; totalled by
    /// [`Admission::transfer_bytes`]) plus its disk restage reads
    /// (d2h, coalesced into the per-batch staged-read burst; totalled
    /// by [`Admission::disk_read_bytes`]).
    pub transfers: Transfers,
    /// Estimated (sim) or measured (real) prefill seconds; set by the
    /// driver once known, consumed by the policy updates.
    pub estimated_time: f64,
    /// Which tree shard admitted this request (0 for an unsharded
    /// service); commit/release/touch route back through it.
    pub shard: usize,
}

impl Admission {
    /// Bytes moved by this admission's cache-hit loading (h2g + g2h
    /// swap-outs) — by construction the sum of the `transfers`
    /// components, so the per-request charge and the coalesced batch
    /// charge can never disagree on the byte total.
    pub fn transfer_bytes(&self) -> u64 {
        self.transfers.h2g_bytes + self.transfers.g2h_bytes
    }

    /// Disk restage-read bytes of this admission (`--disk on`; always 0
    /// off) — charged per batch as one staged-read burst beside the
    /// PCIe burst, never folded into [`Admission::transfer_bytes`].
    pub fn disk_read_bytes(&self) -> u64 {
        self.transfers.d2h_bytes
    }
}

/// Result of admission stage B ([`CacheService::commit`]): how many of
/// the newly computed documents were inserted, and the byte movement the
/// insertions performed (eviction swap-outs making room). Batched
/// callers coalesce the `transfers` of a whole batch into one
/// write-back burst via
/// [`BatchAdmission::push_commit`](super::batch::BatchAdmission::push_commit)
/// and charge it once.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommitOutcome {
    /// Documents actually inserted (insertion stops at the first doc
    /// that cannot fit — the transient oversized case).
    pub inserted: usize,
    /// Byte movement of the insertions, h2g/g2h split.
    pub transfers: Transfers,
}

/// Thread-safe knowledge-tree service: the [`KnowledgeTree`] plus its
/// `TierAllocator` accounting behind one interior lock, shared between
/// connection handlers, the engine driver and administrative tasks.
///
/// Pin/unpin refcounts on the nodes make the admit → compute → commit
/// window safe under interleaving: a pinned prefix can never be evicted
/// by a concurrent admission making room for its own documents.
#[derive(Clone)]
pub struct CacheService {
    tree: Arc<Mutex<KnowledgeTree>>,
}

impl CacheService {
    pub fn new(tree: KnowledgeTree) -> Self {
        CacheService {
            tree: Arc::new(Mutex::new(tree)),
        }
    }

    /// Run `f` with exclusive access to the tree. Lock poisoning is
    /// recovered from: tree invariants are re-checked by tests, and a
    /// panicked accessor must not wedge the serving path.
    pub fn with<R>(&self, f: impl FnOnce(&mut KnowledgeTree) -> R) -> R {
        let mut guard = match self.tree.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        f(&mut guard)
    }

    /// O(h) prefix match (no pinning; a snapshot for priority estimates).
    pub fn lookup(&self, docs: &[DocId]) -> MatchResult {
        self.with(|t| t.lookup(docs))
    }

    pub fn counters(&self) -> TreeCounters {
        self.with(|t| t.counters())
    }

    pub fn check_invariants(&self) {
        self.with(|t| t.check_invariants())
    }

    /// Nodes currently pinned by in-flight requests (excludes the root's
    /// permanent pin).
    pub fn pinned_nodes(&self) -> usize {
        self.with(|t| t.pinned_nodes())
    }

    /// Simulate a GPU failure (§6). Returns `(lost, recovered)`.
    pub fn fail_gpu(&self) -> (usize, usize) {
        self.with(|t| t.fail_gpu())
    }

    /// Tier occupancy gauge (used/capacity both tiers) — the
    /// cross-shard rebalancer's observability signal.
    pub fn occupancy(&self) -> TierOccupancy {
        self.with(|t| t.occupancy())
    }

    /// Retarget ONE tier's budget under this shard's lock, reading the
    /// other tier's current capacity atomically with the change (two
    /// independent single-tier resizes can therefore never undo each
    /// other). Shrinks evict-to-fit via the replacement policy first —
    /// see [`KnowledgeTree::resize_budgets`] — and `Err` means the
    /// shrink was refused with no capacity change, carrying the
    /// transfers of any evictions performed before the refusal.
    pub fn resize_tier(
        &self,
        tier: Tier,
        capacity: u64,
    ) -> Result<Transfers, Transfers> {
        self.with(|t| {
            let (gpu, host) = match tier {
                Tier::Gpu => (capacity, t.host_capacity()),
                Tier::Host => (t.gpu_capacity(), capacity),
            };
            t.resize_budgets(gpu, host)
        })
    }

    /// Admission stage A (Algorithm 1 `UPDATE_NODE_IN_GPU` entry): match
    /// the doc sequence, bring the host-resident part of the match into
    /// GPU node-by-node (stopping at the first node GPU space cannot be
    /// made for — the promoted prefix stays usable), pin the usable path,
    /// and compute the (α, β) split.
    ///
    /// `docs` pairs each requested doc with its token count; `request_
    /// tokens` is everything after the documents (separator + question).
    /// The returned [`Admission`] MUST be handed back via [`commit`] or
    /// [`release`] exactly once — the path stays pinned until then.
    ///
    /// [`commit`]: CacheService::commit
    /// [`release`]: CacheService::release
    pub fn admit(
        &self,
        docs: &[(DocId, usize)],
        request_tokens: usize,
    ) -> Admission {
        self.with(|tree| {
            let ids: Vec<DocId> = docs.iter().map(|&(d, _)| d).collect();
            // Prefix walk with disk restage (`--disk on`): a walk that
            // reaches a disk-resident node restages it disk → host and
            // keeps matching instead of missing; the d2h bytes join
            // this admission's transfers and are charged per batch as
            // one staged-read burst. With the disk tier off this is
            // exactly `lookup`.
            let mut transfers = Transfers::default();
            let m = tree.lookup_restage(&ids, &mut transfers);
            // Promote root-to-leaf. The promotion pins the whole match
            // for its duration (making room for a later node can never
            // evict an earlier one), stops at the first node GPU space
            // cannot be made for, and reports the transfers of
            // everything that actually moved — including the prefix
            // promoted before a mid-path stop, so PCIe time is charged
            // for real byte movement, never undercounted.
            let promo = tree.promote(&m.path);
            let matched = promo.promoted;
            // The usable prefix takes the admission pin.
            tree.pin(&m.path[..matched]);
            let use_path: Vec<NodeId> = m.path[..matched].to_vec();
            // Demand signal for cross-shard rebalancing: the KV bytes
            // this admission serves from GPU instead of recomputing.
            tree.record_gpu_hit_bytes(&use_path);
            let mut alpha: usize = use_path
                .iter()
                .map(|&n| tree.node_tokens(n))
                .sum();
            // Lookup order: prefix walk (above) → chunk probe → miss.
            // Docs past the prefix match may still hit the chunk cache
            // at a DIFFERENT position: their reused rows join α, their
            // first `r` boundary tokens join β (the cross-attention
            // repair recompute), and host-resident entries add h2g
            // bytes to the same transfers the batch burst coalesces.
            // With the chunk cache off every probe is `None` and this
            // loop reduces bit-identically to the chunk-free path.
            transfers.merge(promo.transfers);
            let mut chunk_hits: Vec<ChunkHit> = Vec::new();
            let mut unmatched: Vec<(DocId, usize)> = Vec::new();
            let mut beta: usize = 0;
            for &(doc, tokens) in &docs[matched..] {
                // Chunk lookup order: probe → disk restage → re-probe.
                // A demoted (or CAG-prestaged) entry restages into a
                // host-resident owned entry so the re-probe hits and
                // charges the usual h2g burst bytes on top of the d2h
                // restage read. chunk_restage is false with disk off.
                let hit = match tree.chunk_probe(doc, tokens) {
                    Some(hit) => Some(hit),
                    None if tree.chunk_restage(
                        doc,
                        tokens,
                        &mut transfers,
                    ) =>
                    {
                        tree.chunk_probe(doc, tokens)
                    }
                    None => None,
                };
                match hit {
                    Some(hit) => {
                        alpha += hit.reused_tokens;
                        beta += hit.boundary;
                        transfers.h2g_bytes += hit.h2g_bytes;
                        chunk_hits.push(hit);
                    }
                    None => {
                        beta += tokens;
                        unmatched.push((doc, tokens));
                    }
                }
            }
            beta += request_tokens;
            Admission {
                path: use_path,
                matched_docs: matched,
                alpha,
                beta,
                unmatched,
                chunk_hits,
                transfers,
                estimated_time: 0.0,
                shard: 0,
            }
        })
    }

    /// Concatenate the KV payloads along an admission's path into one
    /// prefix buffer (real mode; simulated nodes carry no payloads).
    pub fn concat_payloads(&self, path: &[NodeId]) -> Vec<f32> {
        self.with(|tree| {
            let parts: Vec<&KvPayload> = path
                .iter()
                .filter_map(|&n| tree.node_payload(n))
                .collect();
            debug_assert_eq!(parts.len(), path.len());
            KvPayload::concat(&parts)
        })
    }

    /// Policy refresh for the cache-hit nodes of an admission (Algorithm
    /// 1 lines 3–13 for `was_cached` nodes).
    pub fn touch_hits(&self, adm: &Admission, estimated_time: f64, now: f64) {
        self.with(|tree| {
            for &n in &adm.path {
                let tokens = tree.node_tokens(n);
                tree.on_access(
                    n,
                    &AccessCtx {
                        alpha: adm.alpha,
                        beta: adm.beta,
                        estimated_time,
                        was_cached: true,
                        now,
                        tokens,
                    },
                );
            }
        })
    }

    /// Admission stage B: the prefill ran, its KV is valid. Unpin the
    /// matched path and insert the newly computed documents as children
    /// along it, refreshing policy stats (`was_cached = false`). In real
    /// mode `payloads[i]` carries the KV rows of `unmatched[i]`.
    ///
    /// The returned [`CommitOutcome`] reports the insertion count AND
    /// the byte movement the insertions performed (eviction swap-outs
    /// making room — real link traffic, including the work done before
    /// a mid-sequence stop). Batched callers coalesce a whole batch's
    /// commit transfers into one write-back burst and charge it once
    /// ([`BatchAdmission::seal_commit`](super::batch::BatchAdmission)).
    pub fn commit(
        &self,
        adm: &Admission,
        estimated_time: f64,
        now: f64,
        payloads: Option<Vec<KvPayload>>,
    ) -> CommitOutcome {
        self.with(|tree| {
            tree.unpin(&adm.path);
            // Chunk hits: policy refresh (a doc hot through the chunk
            // path stays hot) and drop the probe-time pin.
            for hit in &adm.chunk_hits {
                tree.chunk_on_access(
                    hit,
                    &AccessCtx {
                        alpha: adm.alpha,
                        beta: adm.beta,
                        estimated_time,
                        was_cached: true,
                        now,
                        tokens: hit.tokens,
                    },
                );
                tree.chunk_unpin(hit.doc, hit.source);
            }
            let mut parent =
                adm.path.last().copied().unwrap_or(tree.root());
            let mut out = CommitOutcome::default();
            for (i, &(doc, tokens)) in adm.unmatched.iter().enumerate() {
                let payload =
                    payloads.as_ref().and_then(|ps| ps.get(i).cloned());
                let (transfers, node) =
                    tree.insert_child(parent, doc, tokens, payload);
                // A failed insert's partial work is still real byte
                // movement — merge before deciding to stop.
                out.transfers.merge(transfers);
                match node {
                    Some(id) => {
                        tree.on_access(
                            id,
                            &AccessCtx {
                                alpha: adm.alpha,
                                beta: adm.beta,
                                estimated_time,
                                was_cached: false,
                                now,
                                tokens,
                            },
                        );
                        parent = id;
                        out.inserted += 1;
                    }
                    None => {
                        // Does not fit on the prefix path: transient
                        // for the tree — but the KV was still computed.
                        // Salvage it (and the rest of the chain, which
                        // the break below would discard) as position-
                        // independent OWNED chunk entries so a later
                        // reordered request can reuse it anywhere.
                        if tree.chunk_cache_enabled() {
                            let mut off: usize = adm.alpha;
                            for (j, &(d, t)) in
                                adm.unmatched[i..].iter().enumerate()
                            {
                                let p = payloads.as_ref().and_then(
                                    |ps| ps.get(i + j).cloned(),
                                );
                                let mut tr = Transfers::default();
                                tree.chunk_insert_owned(
                                    d, t, off, p, &mut tr,
                                );
                                out.transfers.merge(tr);
                                off += t;
                            }
                        }
                        break;
                    }
                }
            }
            out
        })
    }

    /// Abandon an admission without inserting anything (aborted
    /// speculation whose prefill never ran): just drop the pins — the
    /// path's and the chunk hits'.
    pub fn release(&self, adm: &Admission) {
        self.with(|tree| {
            tree.unpin(&adm.path);
            for hit in &adm.chunk_hits {
                tree.chunk_unpin(hit.doc, hit.source);
            }
        });
    }

    /// Non-pinning chunk-aware snapshot for priority estimates: the
    /// prefix match plus the summed reused tokens the chunk cache would
    /// add for the docs past it. Zero when the chunk cache is off, so
    /// estimate arithmetic stays bit-identical to the chunk-free path.
    pub fn lookup_with_chunks(
        &self,
        docs: &[DocId],
    ) -> (MatchResult, usize) {
        self.with(|tree| {
            let m = tree.lookup(docs);
            let reused = docs[m.matched_docs..]
                .iter()
                .filter_map(|&d| tree.chunk_estimate(d))
                .map(|(r, _)| r)
                .sum();
            (m, reused)
        })
    }

    /// Concatenate an admission's full reused prefix KV (real mode):
    /// the path nodes' payloads in path order, then each chunk hit's
    /// reused rows — rows `[boundary..]` of the cached chunk, in hit
    /// order. Total rows equal the admission's α.
    pub fn concat_admission_payloads(&self, adm: &Admission) -> Vec<f32> {
        self.with(|tree| {
            let mut out = Vec::new();
            for &n in &adm.path {
                let p = tree.node_payload(n).expect("real path payload");
                out.extend_from_slice(p.floats());
            }
            for hit in &adm.chunk_hits {
                let p =
                    tree.chunk_payload(hit.doc).expect("chunk payload");
                let per_tok = p.floats().len() / p.tokens();
                out.extend_from_slice(
                    &p.floats()[hit.boundary * per_tok..],
                );
            }
            out
        })
    }
}

/// Per-request lifecycle + DSP state (paper §5.3), shared between
/// drivers. Milestones reached by a *speculative* generation are buffered
/// and only delivered once retrieval confirms the docs (Algorithm 2).
#[derive(Debug, Default)]
pub struct RequestState {
    /// DSP decision state machine (Algorithm 2).
    pub spec: SpecState,
    /// Planned candidate evolution of this request's staged retrieval.
    pub plan: Option<StagedRetrieval>,
    /// Engine/queue sequence of the live generation (if any).
    pub active_seq: Option<u64>,
    pub active_docs: Vec<DocId>,
    pub next_gen: u64,
    /// Retrieval finished; results may be surfaced to the client.
    pub confirmed: bool,
    pub retrieval_done_at: Option<f64>,
    /// When the generation carrying the *final* docs entered the queue.
    pub final_enqueue_at: Option<f64>,
    pub spec_first_token_at: Option<f64>,
    pub spec_finished_at: Option<f64>,
    pub done: bool,
}

impl RequestState {
    /// Allocate the next generation-tagged sequence id for request
    /// `req`, marking it live.
    pub fn begin_generation(&mut self, req: usize, docs: &[DocId]) -> u64 {
        let gen = self.next_gen;
        self.next_gen += 1;
        let seq = req as u64 * GEN_BASE + gen;
        self.active_seq = Some(seq);
        self.active_docs = docs.to_vec();
        seq
    }

    pub fn is_live(&self, seq: u64) -> bool {
        self.active_seq == Some(seq)
    }
}

/// The shared pipeline: cache service, reorder queue, request states and
/// metrics — everything between "retrieval produced candidates" and "the
/// engine ran an iteration" that is identical across drivers.
pub struct Pipeline {
    /// `None` for cache-less baselines (vLLM configuration).
    pub cache: Option<ShardedCacheService>,
    pub queue: ReorderQueue,
    pub recorder: Recorder,
    pub requests: Vec<RequestState>,
}

impl Pipeline {
    pub fn new(
        cache: Option<ShardedCacheService>,
        reorder: bool,
        window: usize,
    ) -> Self {
        Pipeline {
            cache,
            queue: ReorderQueue::new(reorder, window),
            recorder: Recorder::new(),
            requests: Vec::new(),
        }
    }

    /// Pre-size the request table (simulation knows the trace length).
    pub fn reserve_requests(&mut self, n: usize) {
        self.requests.resize_with(n, RequestState::default);
    }

    /// Cached/compute token split used for the §5.2 reordering priority
    /// of a not-yet-admitted generation.
    pub fn queue_lengths(
        &self,
        docs: &[DocId],
        doc_tokens_total: usize,
        request_tokens: usize,
    ) -> (usize, usize) {
        match &self.cache {
            None => (0, doc_tokens_total + request_tokens),
            Some(c) => {
                // Chunk-aware refinement: reused chunk rows count as
                // cached and leave the compute side (their boundary
                // recompute stays in it — we only subtract the reused
                // part). `reused` is 0 with the chunk cache off, which
                // keeps the arithmetic bit-identical to the old path.
                let (m, reused) = c.lookup_with_chunks(docs);
                (
                    m.cached_tokens + reused,
                    doc_tokens_total
                        .saturating_sub(m.cached_tokens)
                        .saturating_sub(reused)
                        + request_tokens,
                )
            }
        }
    }

    /// Admission stage A against the cache (identity admission for the
    /// cache-less baseline), WITHOUT charging link time: batched
    /// callers coalesce the members' promotion bytes into one burst via
    /// [`super::batch::BatchAdmission`] and charge that once.
    /// [`Pipeline::admit`] is the single-request form.
    pub fn admit_one(
        &self,
        docs: &[(DocId, usize)],
        request_tokens: usize,
    ) -> Admission {
        match &self.cache {
            Some(c) => c.admit(docs, request_tokens),
            None => Admission {
                beta: docs.iter().map(|&(_, t)| t).sum::<usize>()
                    + request_tokens,
                unmatched: docs.to_vec(),
                ..Admission::default()
            },
        }
    }

    /// Admission stage A for a singleton: [`Pipeline::admit_one`] plus
    /// the transfer time its cache-hit loading costs, per the driver's
    /// link model — exactly what a [`super::batch::BatchAdmission`] of
    /// one member charges.
    pub fn admit(
        &self,
        driver: &dyn PipelineDriver,
        docs: &[(DocId, usize)],
        request_tokens: usize,
    ) -> (Admission, f64) {
        let adm = self.admit_one(docs, request_tokens);
        let mut extra = driver.transfer_time(adm.transfer_bytes());
        // Guarded like the batch seal: a disk-off admission's charge
        // arithmetic stays bit-identical.
        if adm.disk_read_bytes() > 0 {
            extra += driver.disk_read_time(adm.disk_read_bytes());
        }
        (adm, extra)
    }

    /// Policy refresh for an admission's hit nodes (no-op without cache).
    pub fn touch_hits(&self, adm: &Admission, estimated_time: f64, now: f64) {
        if let Some(c) = &self.cache {
            c.touch_hits(adm, estimated_time, now);
        }
    }

    /// Admission stage B (no-op without cache). See
    /// [`CacheService::commit`].
    pub fn commit_prefill(
        &self,
        adm: &Admission,
        estimated_time: f64,
        now: f64,
        payloads: Option<Vec<KvPayload>>,
    ) -> CommitOutcome {
        match &self.cache {
            Some(c) => c.commit(adm, estimated_time, now, payloads),
            None => CommitOutcome::default(),
        }
    }

    /// Abandon an admission (no-op without cache).
    pub fn abort_admission(&self, adm: &Admission) {
        if let Some(c) = &self.cache {
            c.release(adm);
        }
    }

    /// Record hit/token accounting for a generation carrying the final
    /// docs (§7 metrics definitions).
    pub fn record_admission(
        &mut self,
        req: u64,
        docs_retrieved: usize,
        adm: &Admission,
    ) {
        self.recorder.docs(req, docs_retrieved, adm.matched_docs);
        self.recorder.tokens(req, adm.alpha, adm.beta);
    }

    /// Final retrieval results are in (paper §5.3 delivery rule): confirm
    /// the request and deliver any milestones the speculation already
    /// reached — they could not be surfaced before the search confirmed
    /// its docs. Also records the Table 3 non-overlapped search time.
    pub fn confirm_final(
        &mut self,
        req: usize,
        now: f64,
        output_tokens: usize,
        full_search_s: f64,
    ) {
        let r = &mut self.requests[req];
        r.retrieval_done_at = Some(now);
        r.confirmed = true;
        self.recorder.retrieval_done(req as u64, now);
        if let Some(ft) = self.requests[req].spec_first_token_at {
            self.recorder.first_token(req as u64, ft.max(now));
        }
        if let Some(fin) = self.requests[req].spec_finished_at {
            self.recorder.finished(req as u64, fin.max(now));
            self.recorder.output_tokens(req as u64, output_tokens);
            self.requests[req].done = true;
        }
        // Table 3: the part of the retrieval not hidden behind LLM-side
        // work on the final-docs generation.
        let overlap = self.requests[req]
            .final_enqueue_at
            .map(|t| (now - t).clamp(0.0, full_search_s))
            .unwrap_or(0.0);
        self.recorder
            .non_overlapped_search(req as u64, full_search_s - overlap);
    }

    /// Prefill milestone of `seq`: deliver or buffer the first token,
    /// depending on whether retrieval already confirmed `final_docs`.
    /// Stale sequences (terminated speculations) are ignored — their KV
    /// was already committed by the caller.
    pub fn deliver_first_token(
        &mut self,
        req: usize,
        seq: u64,
        final_docs: &[DocId],
        now: f64,
    ) {
        if !self.requests[req].is_live(seq) {
            return; // terminated speculation: cache filled, no delivery
        }
        let r = &mut self.requests[req];
        if r.confirmed && r.active_docs == final_docs {
            self.recorder.first_token(req as u64, now);
        } else {
            r.spec_first_token_at = Some(now);
        }
    }

    /// Completion milestone of `seq`: deliver or buffer the finish.
    pub fn deliver_finished(
        &mut self,
        req: usize,
        seq: u64,
        final_docs: &[DocId],
        output_tokens: usize,
        now: f64,
    ) {
        if !self.requests[req].is_live(seq) {
            return;
        }
        let r = &mut self.requests[req];
        if r.confirmed && r.active_docs == final_docs {
            self.recorder.finished(req as u64, now);
            self.recorder.output_tokens(req as u64, output_tokens);
            r.done = true;
        } else {
            r.spec_finished_at = Some(now);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyKind;
    use crate::kvcache::PageSpec;
    use crate::policy::make_policy;

    fn service(gpu_tokens: usize, host_tokens: usize) -> CacheService {
        let page = PageSpec {
            block_tokens: 8,
            kv_bytes_per_token: 16,
        };
        CacheService::new(KnowledgeTree::new(
            page.bytes(gpu_tokens),
            page.bytes(host_tokens),
            page,
            make_policy(PolicyKind::Pgdsf),
            true,
            0,
        ))
    }

    struct TestDriver;

    impl PipelineDriver for TestDriver {
        fn now(&self) -> f64 {
            1.0
        }
        fn transfer_time(&self, bytes: u64) -> f64 {
            bytes as f64 * 1e-9
        }
    }

    #[test]
    fn admit_commit_roundtrip_inserts_and_unpins() {
        let svc = service(1024, 1024);
        let docs = [(1u32, 16usize), (2, 16)];
        let adm = svc.admit(&docs, 8);
        assert_eq!(adm.matched_docs, 0);
        assert_eq!(adm.alpha, 0);
        assert_eq!(adm.beta, 16 + 16 + 8);
        assert_eq!(adm.unmatched, vec![(1, 16), (2, 16)]);
        let out = svc.commit(&adm, 0.01, 1.0, None);
        assert_eq!(out.inserted, 2);
        svc.check_invariants();
        assert_eq!(svc.pinned_nodes(), 0, "commit released all pins");

        // Second admission fully hits and pins the path.
        let adm2 = svc.admit(&docs, 8);
        assert_eq!(adm2.matched_docs, 2);
        assert_eq!(adm2.alpha, 32);
        assert_eq!(adm2.beta, 8);
        assert_eq!(svc.pinned_nodes(), 2);
        svc.touch_hits(&adm2, 0.005, 2.0);
        svc.commit(&adm2, 0.005, 2.0, None);
        assert_eq!(svc.pinned_nodes(), 0);
        svc.check_invariants();
    }

    /// Satellite (commit-side burst batching): commit now REPORTS the
    /// byte movement its insertions perform, so batched callers can
    /// charge it as one write-back burst instead of losing it.
    #[test]
    fn commit_reports_eviction_transfers() {
        let svc = service(16, 1024); // GPU holds exactly one 16-token doc
        let a = svc.admit(&[(1, 16)], 4);
        let out = svc.commit(&a, 0.01, 1.0, None);
        assert_eq!(out.inserted, 1);
        assert_eq!(
            out.transfers,
            Transfers::default(),
            "empty tier: insertion moved nothing"
        );
        let b = svc.admit(&[(2, 16)], 4);
        let out = svc.commit(&b, 0.01, 2.0, None);
        assert_eq!(out.inserted, 1);
        assert!(
            out.transfers.g2h_bytes > 0,
            "inserting doc 2 swapped doc 1 to host: {:?}",
            out.transfers
        );
        svc.check_invariants();
    }

    #[test]
    fn release_drops_pins_without_inserting() {
        let svc = service(1024, 1024);
        let adm = svc.admit(&[(7, 16)], 4);
        svc.commit(&adm, 0.01, 1.0, None);
        let adm2 = svc.admit(&[(7, 16), (8, 16)], 4);
        assert_eq!(adm2.matched_docs, 1);
        svc.release(&adm2);
        assert_eq!(svc.pinned_nodes(), 0);
        // Doc 8 was never inserted.
        assert_eq!(svc.lookup(&[7, 8]).matched_docs, 1);
        svc.check_invariants();
    }

    #[test]
    fn pipeline_without_cache_is_identity() {
        let p = Pipeline::new(None, false, 4);
        let (adm, extra) =
            p.admit(&TestDriver, &[(3, 100), (4, 50)], 10);
        assert_eq!(adm.alpha, 0);
        assert_eq!(adm.beta, 160);
        assert_eq!(adm.matched_docs, 0);
        assert_eq!(extra, 0.0);
        assert_eq!(p.commit_prefill(&adm, 0.1, 0.0, None).inserted, 0);
        assert_eq!(p.queue_lengths(&[3, 4], 150, 10), (0, 160));
    }

    #[test]
    fn confirm_final_delivers_buffered_milestones() {
        let mut p = Pipeline::new(None, false, 4);
        p.reserve_requests(1);
        let seq = p.requests[0].begin_generation(0, &[5, 6]);
        p.recorder.arrival(0, 0.0);
        // Speculative milestones arrive before retrieval confirms.
        p.deliver_first_token(0, seq, &[5, 6], 0.4);
        p.deliver_finished(0, seq, &[5, 6], 3, 0.6);
        assert!(p.recorder.record(0).unwrap().first_token.is_none());
        p.confirm_final(0, 0.5, 3, 0.5);
        let rec = p.recorder.record(0).unwrap();
        assert_eq!(rec.first_token, Some(0.5), "delivered at max(ft, now)");
        assert_eq!(rec.finished, Some(0.6));
        assert!(p.requests[0].done);
    }

    #[test]
    fn stale_sequences_do_not_deliver() {
        let mut p = Pipeline::new(None, false, 4);
        p.reserve_requests(1);
        let old = p.requests[0].begin_generation(0, &[1]);
        let _new = p.requests[0].begin_generation(0, &[2]);
        p.deliver_first_token(0, old, &[1], 0.3);
        assert!(p.requests[0].spec_first_token_at.is_none());
        assert!(p.recorder.record(0).is_none());
    }

    #[test]
    fn shed_ladder_disabled_is_inert() {
        let mut l = ShedLadder::disabled();
        l.observe_wait(100.0, 50.0);
        assert_eq!(l.wait_ewma(), 0.0);
        assert!(!l.downgrading());
        assert!(!l.should_shed(1e9));
    }

    #[test]
    fn shed_ladder_ewma_and_thresholds() {
        let mut l = ShedLadder::new(true, 4.0, 0.5);
        // One big sample: ewma = 0.2 * 10 = 2.0, right at the boundary
        // (not strictly above 0.5 * 4.0), so not yet downgrading.
        l.observe_wait(10.0, 0.0);
        assert!((l.wait_ewma() - 2.0).abs() < 1e-12);
        assert!(!l.downgrading());
        // Second sample in the same decay period pushes it over:
        // 0.8 * 2.0 + 0.2 * 10 = 3.6 > 2.0.
        l.observe_wait(10.0, 0.5);
        assert!(l.downgrading());
        // Shedding keys on the individual wait, not the EWMA.
        assert!(!l.should_shed(4.0));
        assert!(l.should_shed(4.0 + 1e-9));
    }

    #[test]
    fn shed_ladder_decay_halves_per_quarter_slo() {
        let mut l = ShedLadder::new(true, 4.0, 0.5);
        l.observe_wait(20.0, 0.0); // ewma = 4.0 > 2.0: downgrading
        assert!(l.downgrading());
        // Two decay periods (2 × slo/4 = 2.0 s) halve it twice: 1.0.
        l.decay_to(2.0);
        assert!((l.wait_ewma() - 1.0).abs() < 1e-12);
        assert!(!l.downgrading());
        // Far-future decay snaps to zero instead of looping forever.
        l.decay_to(1e9);
        assert_eq!(l.wait_ewma(), 0.0);
    }

    #[test]
    fn gen_base_roundtrip() {
        let mut r = RequestState::default();
        let s0 = r.begin_generation(3, &[9]);
        let s1 = r.begin_generation(3, &[9, 10]);
        assert_eq!(request_of(s0), 3);
        assert_eq!(request_of(s1), 3);
        assert_ne!(s0, s1);
        assert!(!r.is_live(s0));
        assert!(r.is_live(s1));
    }
}
