//! Fault tolerance (paper §6): hot-node replication for GPU-failure
//! recovery, and timeout/retry for request-processing failures.

use crate::tree::KnowledgeTree;

/// Replicate the `n` hottest upper-level GPU nodes into host memory so a
/// GPU failure preserves them (§6: "replicate a portion of the most
/// frequently accessed upper-level nodes in the host memory").
/// Returns the number of nodes actually replicated.
pub fn replicate_hot_nodes(tree: &mut KnowledgeTree, n: usize) -> usize {
    let mut done = 0;
    for id in tree.hot_upper_nodes(n) {
        if tree.replicate_to_host(id) {
            done += 1;
        }
    }
    done
}

/// Timeout/retry bookkeeping for one request (§6: "a timeout mechanism to
/// retry the failed requests. If a request fails before completing its
/// first iteration, it will be recomputed. Otherwise, [it] can continue
/// computation by reusing the stored KV cache").
#[derive(Debug, Clone)]
pub struct RetryState {
    pub timeout_s: f64,
    pub max_retries: u32,
    pub attempts: u32,
    /// Set once the first iteration completed (KV exists to resume from).
    pub first_iteration_done: bool,
    started_at: f64,
}

/// What to do with a request after a failure or timeout check.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RetryAction {
    /// Still within budget; keep waiting.
    Wait,
    /// Recompute from scratch (failed before first iteration).
    Recompute,
    /// Resume from stored KV (first iteration done).
    Resume,
    /// Retries exhausted.
    Fail,
}

impl RetryState {
    pub fn new(timeout_s: f64, max_retries: u32, now: f64) -> Self {
        RetryState {
            timeout_s,
            max_retries,
            attempts: 0,
            first_iteration_done: false,
            started_at: now,
        }
    }

    /// A (re)attempt begins.
    pub fn begin_attempt(&mut self, now: f64) {
        self.attempts += 1;
        self.started_at = now;
    }

    /// Periodic timeout check.
    pub fn check(&self, now: f64) -> RetryAction {
        if now - self.started_at < self.timeout_s {
            return RetryAction::Wait;
        }
        if self.attempts > self.max_retries {
            return RetryAction::Fail;
        }
        if self.first_iteration_done {
            RetryAction::Resume
        } else {
            RetryAction::Recompute
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyKind;
    use crate::kvcache::{PageSpec, Tier};
    use crate::policy::{make_policy, AccessCtx};
    use crate::tree::KnowledgeTree;

    fn page() -> PageSpec {
        PageSpec {
            block_tokens: 16,
            kv_bytes_per_token: 64,
        }
    }

    fn make_tree() -> KnowledgeTree {
        let p = page();
        KnowledgeTree::new(
            p.bytes(1000),
            p.bytes(1000),
            p,
            make_policy(PolicyKind::Pgdsf),
            true,
            0,
        )
    }

    fn touch(t: &mut KnowledgeTree, id: crate::tree::NodeId, times: usize) {
        for i in 0..times {
            t.on_access(
                id,
                &AccessCtx {
                    alpha: 0,
                    beta: 16,
                    estimated_time: 0.01,
                    was_cached: false,
                    now: i as f64,
                    tokens: 16,
                },
            );
        }
    }

    #[test]
    fn replication_protects_hot_nodes_across_gpu_failure() {
        let mut t = make_tree();
        let hot = t.insert_child(t.root(), 1, 16, None).1.unwrap();
        let cold = t.insert_child(t.root(), 2, 16, None).1.unwrap();
        touch(&mut t, hot, 10);
        touch(&mut t, cold, 1);

        let n = replicate_hot_nodes(&mut t, 1);
        assert_eq!(n, 1);
        let (lost, recovered) = t.fail_gpu();
        t.check_invariants();
        assert_eq!(recovered, 1, "hot node survived in host");
        assert_eq!(lost, 1, "cold node lost");
        assert_eq!(t.node_tier(hot), Some(Tier::Host));
        assert_eq!(t.node_tier(cold), None);
    }

    #[test]
    fn gpu_failure_invalidates_descendants_of_lost_nodes() {
        let mut t = make_tree();
        let a = t.insert_child(t.root(), 1, 16, None).1.unwrap();
        let b = t.insert_child(a, 2, 16, None).1.unwrap();
        // Replicate only the CHILD: after failure the parent is lost, so
        // the child must be dropped too (prefix sensitivity).
        assert!(t.replicate_to_host(b));
        let (lost, recovered) = t.fail_gpu();
        t.check_invariants();
        // b is first recovered to host, then dropped as an orphan: the
        // end state is that nothing survives.
        assert_eq!(recovered, 1);
        assert!(lost >= 2);
        assert_eq!(t.node_tier(a), None);
        assert_eq!(t.node_tier(b), None, "orphaned prefix dropped");
    }

    #[test]
    fn retry_state_machine() {
        let mut r = RetryState::new(1.0, 2, 0.0);
        r.begin_attempt(0.0);
        assert_eq!(r.check(0.5), RetryAction::Wait);
        assert_eq!(r.check(1.5), RetryAction::Recompute);
        r.first_iteration_done = true;
        assert_eq!(r.check(1.5), RetryAction::Resume);
        r.begin_attempt(2.0);
        r.begin_attempt(4.0);
        assert_eq!(r.check(5.5), RetryAction::Fail);
    }
}
