//! Batched admission with coalesced H2D bursts (ROADMAP "Batched H2D
//! transfers" + "Per-engine decode batching").
//!
//! One engine-driver iteration admits a whole batch popped from its
//! reorder queue ([`crate::sched::ReorderQueue::pop_batch`]) instead of
//! one request at a time. Each member's `promote()` still moves its own
//! bytes — PR 2's partial-[`Promotion`](crate::tree::Promotion)
//! accounting per member is untouched — but the PCIe *time* is charged
//! once for the whole batch: one DMA setup plus one burst at link
//! bandwidth, via a single [`PipelineDriver::transfer_time`] call over
//! the coalesced byte total, instead of one fixed setup latency per
//! member. This is the transfer-side analogue of the engine sharing its
//! weight read across a prefill batch
//! ([`crate::llm::cost_model::CostModel::prefill_batch_time`]), and the
//! reason M engines no longer serialize M bursts that the hardware
//! would issue as one.
//!
//! A batch of one degrades exactly to the per-request charge
//! (`transfer_time(bytes)`), which is what keeps `--max-batch 1`
//! bit-identical to the unbatched pipeline.
//!
//! The batch covers BOTH link phases of an engine iteration: the
//! admit-side loading burst ([`push`](BatchAdmission::push) +
//! [`seal`](BatchAdmission::seal)) and, once the members' prefills have
//! run, the commit-side write-back burst — the swap-outs their
//! `insert_child` calls perform while caching the newly computed doc KV
//! ([`push_commit`](BatchAdmission::push_commit) +
//! [`seal_commit`](BatchAdmission::seal_commit)). Each phase is one DMA
//! setup plus one burst at link bandwidth, charged exactly once per
//! batch.
//!
//! Failure semantics (all-or-per-request fallback): a member whose GPU
//! admission fails mid-batch releases its own pins and is reported in
//! [`BatchAdmission::failed`] for re-queueing; the members admitted
//! before and after it stay admitted, and the failed member's
//! already-moved bytes stay in the coalesced total — PCIe time is
//! charged for real byte movement, never uncharged (the same rule
//! PR 2's partial `Promotion` established for a mid-path stop).

use super::pipeline::{Admission, PipelineDriver};
use crate::tree::Transfers;

/// One engine-iteration's worth of admissions with their promotion
/// transfers coalesced into a single PCIe burst, charged once.
#[derive(Debug, Default)]
pub struct BatchAdmission {
    /// Successfully admitted members in admission (§5.2 pop) order,
    /// tagged with the caller's sequence/job id.
    members: Vec<(u64, Admission)>,
    /// Ids whose admission failed mid-batch (pins already released by
    /// the failing admit); the caller re-queues them.
    failed: Vec<u64>,
    /// Coalesced byte movement: every member's promotion plus the
    /// partial promotions of failed members.
    transfers: Transfers,
    /// The one-per-batch link charge, set by [`BatchAdmission::seal`].
    sealed_time: Option<f64>,
    /// Commit-phase byte movement (the members' `insert_child`
    /// swap-outs), folded in after the prefills run and charged as its
    /// own one-per-batch burst (ROADMAP "commit-side burst batching").
    commit_transfers: Transfers,
    /// The one-per-batch commit-burst charge, set by
    /// [`BatchAdmission::seal_commit`].
    commit_sealed: Option<f64>,
}

impl BatchAdmission {
    pub fn new() -> Self {
        BatchAdmission::default()
    }

    /// Admit a batch through `admit_one` and seal it: every id is
    /// admitted in order, members' bytes coalesce, and the burst is
    /// charged once through the driver. `admit_one` returns
    /// `Err(partial)` when GPU admission fails mid-member — by then the
    /// callee must have released that member's pins; its already-moved
    /// bytes fold into the burst and the id lands in
    /// [`failed`](BatchAdmission::failed) for re-queueing, while every
    /// other member proceeds (per-request fallback).
    pub fn admit_with(
        driver: &dyn PipelineDriver,
        ids: impl IntoIterator<Item = u64>,
        mut admit_one: impl FnMut(u64) -> Result<Admission, Transfers>,
    ) -> BatchAdmission {
        let mut batch = BatchAdmission::new();
        for id in ids {
            match admit_one(id) {
                Ok(adm) => batch.push(id, adm),
                Err(partial) => batch.push_failed(id, partial),
            }
        }
        batch.seal(driver);
        batch
    }

    /// Fold one successful member admission into the batch.
    pub fn push(&mut self, id: u64, adm: Admission) {
        debug_assert!(self.sealed_time.is_none(), "batch already sealed");
        self.transfers.merge(adm.transfers);
        self.members.push((id, adm));
    }

    /// Fold a failed member: its partial-promotion bytes stay accounted
    /// in the burst, the id is reported for re-queueing.
    pub fn push_failed(&mut self, id: u64, partial: Transfers) {
        debug_assert!(self.sealed_time.is_none(), "batch already sealed");
        self.transfers.merge(partial);
        self.failed.push(id);
    }

    /// Close the batch and charge the coalesced burst ONCE through the
    /// driver's link model, returning the burst seconds. With the disk
    /// tier on, the members' restage reads coalesce the same way: one
    /// staged-read burst per batch through
    /// [`PipelineDriver::disk_read_time`], added beside the PCIe burst.
    /// The disk term is guarded on `d2h > 0` so a disk-off batch's f64
    /// charge stays bit-identical to the two-tier path. Idempotent —
    /// re-sealing never double-charges.
    pub fn seal(&mut self, driver: &dyn PipelineDriver) -> f64 {
        if self.sealed_time.is_none() {
            let mut t = driver.transfer_time(self.total_bytes());
            if self.transfers.d2h_bytes > 0 {
                t += driver.disk_read_time(self.transfers.d2h_bytes);
            }
            self.sealed_time = Some(t);
        }
        self.sealed_time.expect("just sealed")
    }

    /// The one-per-batch burst charge (0.0 before [`seal`]).
    ///
    /// [`seal`]: BatchAdmission::seal
    pub fn transfer_time(&self) -> f64 {
        self.sealed_time.unwrap_or(0.0)
    }

    /// Fold one member's commit-phase byte movement (the `Transfers` its
    /// [`commit`](super::pipeline::CacheService::commit) reported —
    /// swap-outs made while inserting the newly computed doc KV) into
    /// the batch's commit burst.
    pub fn push_commit(&mut self, transfers: Transfers) {
        debug_assert!(
            self.commit_sealed.is_none(),
            "commit burst already sealed"
        );
        self.commit_transfers.merge(transfers);
    }

    /// Close the commit phase and charge its coalesced burst ONCE
    /// through the driver's link model, returning the burst seconds.
    /// Independent of [`seal`](BatchAdmission::seal): admit-side
    /// loading and commit-side write-back are two link bursts per
    /// batch, each charged exactly once. Idempotent.
    pub fn seal_commit(&mut self, driver: &dyn PipelineDriver) -> f64 {
        if self.commit_sealed.is_none() {
            self.commit_sealed =
                Some(driver.transfer_time(self.commit_bytes()));
        }
        self.commit_sealed.expect("just sealed")
    }

    /// The one-per-batch commit-burst charge (0.0 before
    /// [`seal_commit`](BatchAdmission::seal_commit)).
    pub fn commit_transfer_time(&self) -> f64 {
        self.commit_sealed.unwrap_or(0.0)
    }

    /// Coalesced commit-phase byte movement, h2g/g2h split.
    pub fn commit_transfers(&self) -> Transfers {
        self.commit_transfers
    }

    /// Coalesced commit-phase bytes (both directions).
    pub fn commit_bytes(&self) -> u64 {
        self.commit_transfers.h2g_bytes + self.commit_transfers.g2h_bytes
    }

    /// Coalesced byte movement of the whole batch, h2g/g2h split.
    pub fn transfers(&self) -> Transfers {
        self.transfers
    }

    /// Coalesced bytes of the whole batch (both PCIe directions —
    /// disk-read bytes are a separate burst, see
    /// [`disk_read_bytes`](BatchAdmission::disk_read_bytes)).
    pub fn total_bytes(&self) -> u64 {
        self.transfers.h2g_bytes + self.transfers.g2h_bytes
    }

    /// Coalesced disk restage-read bytes of the whole batch — the
    /// staged-read burst charged by [`seal`](BatchAdmission::seal)
    /// through [`PipelineDriver::disk_read_time`].
    pub fn disk_read_bytes(&self) -> u64 {
        self.transfers.d2h_bytes
    }

    /// Successfully admitted members in admission order.
    pub fn members(&self) -> &[(u64, Admission)] {
        &self.members
    }

    /// Ids whose admission failed; the caller re-queues them.
    pub fn failed(&self) -> &[u64] {
        &self.failed
    }

    /// Consume the batch, yielding the admitted members for the
    /// caller's in-flight bookkeeping (the burst charge was already
    /// taken via [`seal`](BatchAdmission::seal)).
    pub fn into_members(self) -> Vec<(u64, Admission)> {
        self.members
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A PCIe-like driver with a per-burst setup latency, so the tests
    /// can observe the one-charge-per-batch property.
    struct LinkDriver;

    impl PipelineDriver for LinkDriver {
        fn now(&self) -> f64 {
            0.0
        }
        fn transfer_time(&self, bytes: u64) -> f64 {
            if bytes == 0 {
                0.0
            } else {
                20e-6 + bytes as f64 / 12.0e9
            }
        }
    }

    fn adm(h2g: u64, g2h: u64) -> Admission {
        Admission {
            transfers: Transfers {
                h2g_bytes: h2g,
                g2h_bytes: g2h,
                ..Transfers::default()
            },
            ..Admission::default()
        }
    }

    #[test]
    fn coalesced_total_is_member_sum() {
        let b = BatchAdmission::admit_with(
            &LinkDriver,
            [1u64, 2, 3],
            |id| Ok(adm(id * 1000, id * 10)),
        );
        assert_eq!(b.len(), 3);
        assert_eq!(b.transfers().h2g_bytes, 6000);
        assert_eq!(b.transfers().g2h_bytes, 60);
        assert_eq!(b.total_bytes(), 6060);
        assert!(b.failed().is_empty());
    }

    /// Acceptance: a single-member batch charges exactly the PR 2
    /// per-request time — `--max-batch 1` is bit-identical.
    #[test]
    fn single_member_batch_charges_per_request_time() {
        let d = LinkDriver;
        let b =
            BatchAdmission::admit_with(&d, [7u64], |_| Ok(adm(4096, 0)));
        assert_eq!(b.transfer_time(), d.transfer_time(4096));
    }

    /// The tentpole win: B members pay one setup latency, not B.
    #[test]
    fn batch_charge_is_one_burst_not_b() {
        let d = LinkDriver;
        let (x, y) = (1 << 20, 3 << 20);
        let b = BatchAdmission::admit_with(&d, [1u64, 2], |id| {
            Ok(if id == 1 { adm(x, 0) } else { adm(y, 0) })
        });
        let coalesced = b.transfer_time();
        assert_eq!(coalesced, d.transfer_time(x + y));
        let serial = d.transfer_time(x) + d.transfer_time(y);
        assert!(coalesced < serial, "{coalesced} vs serial {serial}");
    }

    /// Mid-batch failure: the member re-queues, its partial bytes stay
    /// accounted, and the rest of the batch is unaffected.
    #[test]
    fn failed_member_keeps_partial_bytes_and_requeues() {
        let b = BatchAdmission::admit_with(
            &LinkDriver,
            [1u64, 2, 3],
            |id| {
                if id == 2 {
                    Err(Transfers {
                        h2g_bytes: 0,
                        g2h_bytes: 512, // swap-outs before the failure
                        ..Transfers::default()
                    })
                } else {
                    Ok(adm(1024, 0))
                }
            },
        );
        assert_eq!(b.len(), 2);
        assert_eq!(b.failed(), &[2]);
        assert_eq!(b.total_bytes(), 2048 + 512, "no loss, no double-charge");
    }

    /// Satellite (commit-side burst batching): the members' commit-time
    /// swap-outs coalesce into ONE write-back burst per batch, charged
    /// once and independently of the admit-side burst.
    #[test]
    fn commit_burst_coalesces_and_charges_once() {
        let d = LinkDriver;
        let mut b = BatchAdmission::new();
        b.push(1, adm(4096, 0));
        b.push(2, adm(8192, 0));
        b.seal(&d);
        // Prefills ran; each member's commit reports its swap-outs.
        b.push_commit(Transfers {
            h2g_bytes: 0,
            g2h_bytes: 1 << 20,
            ..Transfers::default()
        });
        b.push_commit(Transfers {
            h2g_bytes: 0,
            g2h_bytes: 3 << 20,
            ..Transfers::default()
        });
        assert_eq!(b.commit_transfer_time(), 0.0, "unsealed is zero");
        let t1 = b.seal_commit(&d);
        let t2 = b.seal_commit(&d);
        assert_eq!(t1, t2, "re-sealing never double-charges");
        assert_eq!(b.commit_bytes(), 4 << 20);
        assert_eq!(t1, d.transfer_time(4 << 20));
        // One burst, not one per member.
        let serial = d.transfer_time(1 << 20) + d.transfer_time(3 << 20);
        assert!(t1 < serial, "{t1} vs serial {serial}");
        // The admit burst is untouched by the commit phase.
        assert_eq!(b.transfer_time(), d.transfer_time(4096 + 8192));
        assert_eq!(b.total_bytes(), 4096 + 8192);
    }

    #[test]
    fn empty_commit_phase_is_free() {
        let d = LinkDriver;
        let mut b = BatchAdmission::new();
        b.push(1, adm(100, 0));
        b.seal(&d);
        assert_eq!(b.seal_commit(&d), 0.0, "no commit bytes, no charge");
    }

    #[test]
    fn seal_is_idempotent_and_empty_batch_is_free() {
        let d = LinkDriver;
        let mut b = BatchAdmission::new();
        assert_eq!(b.transfer_time(), 0.0, "unsealed charge is zero");
        b.push(1, adm(100, 0));
        let t1 = b.seal(&d);
        let t2 = b.seal(&d);
        assert_eq!(t1, t2, "re-sealing never double-charges");

        let empty = BatchAdmission::admit_with(
            &d,
            std::iter::empty::<u64>(),
            |_| Ok(adm(0, 0)),
        );
        assert!(empty.is_empty());
        assert_eq!(empty.transfer_time(), 0.0);
    }
}
