//! The global RAG controller (paper §4, Fig. 7) — the system's Layer 3.
//!
//! Orchestrates: staged vector retrieval → knowledge-tree lookup →
//! cache-aware admission → LLM engine iterations → tree insertion and
//! policy updates, with dynamic speculative pipelining overlapping the
//! first two against the last three.
//!
//! [`sim_server`] drives the whole pipeline against the virtual clock and
//! the analytic cost model (paper-scale experiments); the same tree,
//! policies, scheduler and DSP logic are driven in real time by the
//! PJRT-backed [`real`] server used in `examples/e2e_serving.rs`.

pub mod retrieval;
pub mod sim_server;
pub mod real;
pub mod fault;

pub use retrieval::{RetrievalTiming, StagePlan, StagedRetrieval};
pub use sim_server::{SimOutcome, SimServer};
