//! The global RAG controller (paper §4, Fig. 7) — the system's Layer 3.
//!
//! Orchestrates: staged vector retrieval → knowledge-tree lookup →
//! cache-aware admission → LLM engine iterations → tree insertion and
//! policy updates, with dynamic speculative pipelining overlapping the
//! first two against the last three — in the simulator AND in the real
//! serving path, where requests run as event-driven sessions.
//!
//! ```text
//!              requests (trace / TCP connections)
//!                           │
//!            ┌──────────────┴───────────────┐
//!            ▼                              ▼
//!   sim_server (driver)             real (driver)
//!   discrete-event core over        wall clock, PJRT prefill;
//!   EventScheduler (cancellable     sessions: submit → poll_sessions;
//!   handles): open-loop Arrival /   admission-control ladder
//!   RetrievalDone / EngineDone /    (pipeline::ShedLadder, wall
//!   DeadlineExpired / ShedDecayTick clock): queue wait measured at
//!   handlers + service_queues();    reorder-queue pop, same 0.8/0.2
//!   admission-control ladder        EWMA + slo/4 decay; Downgrade =
//!   Normal → Downgrade (EWMA of     single-stage retrieval (so no
//!   queue delay > frac × SLO:       speculation) for new submits,
//!   speculation off for new         Shed = queued past the TTFT SLO
//!   arrivals) → Shed (deadline at   (blocking pops AND expired
//!   arrival + TTFT SLO; admitted    sessions: pins released, staged
//!   prefills always graced);        retrieval cancelled); --shed off
//!   --shed off is bit-identical     is bit-identical to the PR 7
//!   to the iteration-driven path    real path
//!            │                              │
//!            │              retrieval_service (thread pool)
//!            │              ticks VectorIndex::staged_search,
//!            │              pushes StageReady per stage ──┐
//!            │                              │             │
//!            └──────────────┬───────────────┘             │
//!                           ▼                             ▼
//!              pipeline (shared core)          session (lifecycle)
//!     DSP decisions · reorder-queue            Submitted → Retrieving
//!     admission (batched pops:                 → SpeculativePrefill →
//!     batch::BatchAdmission coalesces          Admitted → Prefilled →
//!     admit-side promotions into ONE           Decoding → Done/Failed;
//!     H2D burst AND commit-side                SessionTable runs Alg. 2
//!     insert swap-outs into ONE                per StageReady: start /
//!     write-back burst, each charged           cancel speculations
//!     once per engine iteration) ·             (pin-only admissions),
//!     ShardedCacheService ──► K ×              promote on final match
//!       CacheService shards                    or fall back to the
//!       (route by first doc)                   blocking batched path
//!       match → restage (--disk on:
//!       disk-resident prefix nodes /
//!       chunk entries staged back to
//!       host, d2h bytes charged as
//!       ONE NVMe read burst per
//!       admitted batch, overlapped
//!       with retrieval) → promote →
//!       pin → chunk probe
//!       (--chunk-cache on: off-prefix
//!       docs reuse cached KV at ANY
//!       position, r boundary tokens
//!       join β, h2g bytes join the
//!       batch burst; tree-rejected KV
//!       is salvaged as owned chunk
//!       entries) → (α,β)
//!       → commit/release · metrics hooks
//!       + CAG admission (cag.rs):
//!         --cag auto pins tenants whose
//!         whole corpus KV fits the pin
//!         budget — corpus pre-staged to
//!         disk at build time, promoted
//!         disk→host→GPU on first touch,
//!         retrieval skipped entirely;
//!         other tenants run cold-/
//!         cached-RAG per the demand
//!         signal (first completed req)
//!       + cross-shard tier rebalancer
//!         (shard.rs): every engine
//!         iteration / session poll is a
//!         maintenance_tick; on interval
//!         boundaries, per-shard demand
//!         (Δhit bytes + Δswap-out thrash
//!         + occupancy) recomputes the
//!         tier-budget slices and moves
//!         capacity cold → hot — donors
//!         evict-to-fit and shrink FIRST,
//!         receivers grow only from bytes
//!         actually freed, so Σ slices ==
//!         configured budget, bit-exact;
//!         --rebalance off = static 1/K
//!         slices, bit-identical
//!                           │
//!                           ▼
//!        tree / kvcache / policy / sched substrates
//!        (three-tier GPU → host → NVMe-disk cascade: evictions
//!        demote down the ladder, spills are async staged writes
//!        counted but never charged; --disk off = two tiers,
//!        bit-identical to the prior path)
//!
//!   stats surface (one schema — metrics::registry):
//!        TreeCounters (shared tree, per-shard sums driven by
//!        TREE_COUNTER_FIELDS) + Recorder/SpecTotals/ShedLadder
//!        (per-engine) + shard/disk occupancy (snapshot gauges)
//!                           │
//!              real::proto_stats / the sim reports
//!              build ONE proto::StatsResult each
//!                           │
//!                           ▼
//!        registry descriptors drive encode → wire JSON →
//!        parse → merge (Sum/Max/Or/weighted means/snapshot
//!        group/by-tenant) → CLI report lines + BENCH columns
//!        + the ci.sh stats-schema drift gate
//! ```
//!
//! [`pipeline`] owns the per-request admission state machine shared by
//! both drivers; [`session`] owns the request *lifecycle* state machine
//! of the event-driven API and [`retrieval_service`] feeds it staged
//! search results from a dedicated thread pool. [`sim_server`] replays
//! paper-scale traces against the virtual clock, and the PJRT-backed
//! [`real`] server (used by `examples/e2e_serving.rs` and the
//! concurrent TCP front-end in [`crate::server`]) drives the identical
//! logic in real time — blocking (`--speculate off`, bit-identical to
//! the pre-session batched path) or event-driven (`--speculate on`).

pub mod batch;
pub mod cag;
pub mod fault;
pub mod pipeline;
pub mod real;
pub mod retrieval;
pub mod retrieval_service;
pub mod session;
pub mod shard;
pub mod sim_server;

pub use batch::BatchAdmission;
pub use cag::{CagPolicy, TenantMode};
pub use pipeline::{
    Admission, CacheService, CommitOutcome, Pipeline, PipelineDriver,
    RequestState, ShedLadder,
};
pub use retrieval::{RetrievalTiming, StagePlan, StagedRetrieval};
pub use retrieval_service::{
    RetrievalConfig, RetrievalService, RetrievalTask, StageReady,
};
pub use session::{
    FinishPath, RequestSession, SessionEvent, SessionId, SessionPhase,
    SessionTable, SpecTotals, SpecWork, StageStep,
};
pub use shard::{
    split_budget, RebalanceConfig, RebalanceStats, ShardedCacheService,
};
pub use sim_server::{SimOutcome, SimServer};
