//! The global RAG controller (paper §4, Fig. 7) — the system's Layer 3.
//!
//! Orchestrates: staged vector retrieval → knowledge-tree lookup →
//! cache-aware admission → LLM engine iterations → tree insertion and
//! policy updates, with dynamic speculative pipelining overlapping the
//! first two against the last three.
//!
//! ```text
//!              requests (trace / TCP connections)
//!                           │
//!            ┌──────────────┴───────────────┐
//!            ▼                              ▼
//!   sim_server (driver)             real (driver)
//!   virtual clock, analytic         wall clock, PJRT prefill,
//!   cost model, batching engine     real vector retrieval
//!            │                              │
//!            └──────────────┬───────────────┘
//!                           ▼
//!              pipeline (shared core)
//!     DSP decisions · reorder-queue admission (batched pops:
//!     batch::BatchAdmission coalesces the members' promotions
//!     into ONE H2D burst charged once per engine iteration) ·
//!     ShardedCacheService ──► K × CacheService shards
//!       (route by first doc)   tree match → promote → pin → (α,β)
//!                              → commit/release · metrics hooks
//!                           │
//!                           ▼
//!        tree / kvcache / policy / sched substrates
//! ```
//!
//! [`pipeline`] owns the per-request state machine shared by both
//! drivers; [`sim_server`] replays paper-scale traces against the
//! virtual clock, and the PJRT-backed [`real`] server (used by
//! `examples/e2e_serving.rs` and the concurrent TCP front-end in
//! [`crate::server`]) drives the identical logic in real time.

pub mod batch;
pub mod fault;
pub mod pipeline;
pub mod real;
pub mod retrieval;
pub mod shard;
pub mod sim_server;

pub use batch::BatchAdmission;
pub use pipeline::{
    Admission, CacheService, Pipeline, PipelineDriver, RequestState,
};
pub use retrieval::{RetrievalTiming, StagePlan, StagedRetrieval};
pub use shard::ShardedCacheService;
pub use sim_server::{SimOutcome, SimServer};
