//! Event-driven simulation server: the full RAGCache pipeline (and its
//! vLLM/SGLang baseline configurations) against the virtual clock and the
//! analytic GPU cost model. This is what every paper-scale bench drives.
//!
//! All cache/DSP/delivery semantics live in the shared
//! [`pipeline`](super::pipeline) core; this file is the *simulation
//! driver*: a discrete-event controller over the generation-stamped
//! [`EventScheduler`], the iteration-level batching engine, and a
//! [`PipelineDriver`] built from the virtual clock, the PCIe transfer
//! model and the analytic `(α, β)` cost profile.
//!
//! ```text
//!            trace (open loop: arrivals fire at their timestamps,
//!                   regardless of engine occupancy)
//!              │
//!              ▼
//!   ┌────────────────────── EventScheduler ─────────────────────┐
//!   │ Arrival ─► RetrievalDone{stage} ─► EngineDone{epoch}      │
//!   │    │            (DSP stages)            ▲                 │
//!   │    └─► DeadlineExpired (shed on)        │   ShedDecayTick │
//!   └───────┬────────────────────────────────────────┬──────────┘
//!           ▼              after every event         ▼
//!      admission control ──► service_queues() ──► engine.plan()
//!      (Normal → Downgrade → Shed)
//! ```
//!
//! **Open loop + overload.** Arrivals are scheduled from the trace up
//! front, so offered load is independent of service capacity: when the
//! engine saturates, the reorder queue grows and queueing delay shows up
//! in TTFT — the regime the paper's closed feasible traces never enter.
//!
//! **Shed/downgrade ladder** (`[shed]` config; off by default, and the
//! off path is conformance-tested bit-identical to the iteration-driven
//! predecessor):
//!
//! 1. *Normal* — every arrival gets the full staged-speculation plan.
//! 2. *Downgrade* — when the EWMA of admission queueing delay exceeds
//!    `downgrade_frac × ttft_slo_s`, new arrivals run single-stage
//!    retrieval with speculation disabled: less wasted prefill work
//!    under pressure, at the cost of the DSP overlap win.
//! 3. *Shed* — a `DeadlineExpired` event fires `ttft_slo_s` after each
//!    arrival; if the request has not produced its first token and is
//!    not already admitted to the engine (admitted prefills are always
//!    allowed to finish — aborting them refunds nothing), it is shed:
//!    pending retrieval stages are cancelled via their event handles,
//!    any queued generation is aborted, and the request is recorded as
//!    shed for the goodput/attainment metrics.
//!
//! `ShedDecayTick` (shed-on only) halves the delay EWMA every quarter
//! SLO so downgrade mode exits once a burst drains, and re-arms only
//! while unserved, unshed requests remain (an O(1) live-request counter,
//! not a scan) — guaranteeing termination.

use super::batch::BatchAdmission;
use super::cag::{CagPolicy, TenantMode};
use super::pipeline::{
    request_of, Admission, Pipeline, PipelineDriver,
};
use super::retrieval::{RetrievalTiming, StagedRetrieval};
use super::shard::{
    split_budget, RebalanceConfig, RebalanceStats, ShardedCacheService,
};
use crate::config::{SystemConfig, SystemKind};
use crate::kvcache::{PageSpec, TransferModel};
use crate::llm::cost_model::{CostModel, CostProfile};
use crate::llm::engine::{AbortOutcome, Engine, SeqEvent, SeqSpec};
use crate::llm::models::{GpuSpec, ModelSpec};
use crate::metrics::Recorder;
use crate::policy::make_policy;
use crate::sched::PendingRequest;
use crate::sim::{Clock, EventHandle, EventScheduler, SimClock};
use crate::spec::SpecAction;
use crate::tree::{DocId, KnowledgeTree};
use crate::util::Rng;
use crate::workload::{TenantCorpus, Trace};
use std::collections::HashMap;
use std::time::Instant;

#[derive(Debug, Clone)]
enum Event {
    Arrival(usize),
    /// One DSP retrieval stage of `req` delivered its (speculative or
    /// final) document candidates.
    RetrievalDone { req: usize, stage: usize },
    /// Completion of the iteration with this epoch tag (stale tags are
    /// ignored — the iteration was cancelled).
    EngineDone(u64),
    /// TTFT-SLO deadline of request `req` (scheduled only with shedding
    /// enabled; cancelled through its handle at first-token delivery).
    DeadlineExpired(usize),
    /// Periodic shed-EWMA decay (shed-on only): halves the
    /// queueing-delay EWMA every quarter SLO so downgrade mode exits
    /// once a burst drains.
    ShedDecayTick,
}

/// Admission-controller state for the shed/downgrade ladder.
#[derive(Debug, Clone)]
struct ShedState {
    enabled: bool,
    /// TTFT SLO, seconds: both the shed deadline and the goodput bar.
    ttft_slo: f64,
    /// Downgrade threshold as a fraction of the SLO.
    downgrade_frac: f64,
    /// EWMA of queueing delay observed at batch-admission pops
    /// (deterministic: pure f64 folds over simulated times).
    wait_ewma: f64,
}

impl ShedState {
    fn downgrading(&self) -> bool {
        self.enabled && self.wait_ewma > self.downgrade_frac * self.ttft_slo
    }

    fn observe_wait(&mut self, wait: f64) {
        self.wait_ewma = 0.8 * self.wait_ewma + 0.2 * wait.max(0.0);
    }
}

/// Aggregated results of one simulation run.
#[derive(Debug)]
pub struct SimOutcome {
    pub recorder: Recorder,
    pub tree_counters: Option<crate::tree::TreeCounters>,
    pub spec_started: u64,
    pub spec_wasted: u64,
    /// Speculations the final stage confirmed (their prefill was
    /// delivered instead of recomputed).
    pub spec_promoted: u64,
    /// Mean controller decision time (tree lookup/update + reordering +
    /// DSP decisions), seconds — Table 4.
    pub mean_sched_time: f64,
    pub completed: usize,
    /// Cross-shard rebalancer activity (zeros when `cache.rebalance`
    /// is off or the cache is single-shard).
    pub rebalance: RebalanceStats,
    /// Total host→GPU PCIe bytes the run charged (admission promotion
    /// bursts + chunk streaming + rebalancer moves).
    pub pcie_h2g_bytes: u64,
    /// Total GPU→host PCIe bytes (eviction swap-outs, write-back
    /// bursts, rebalancer donor evictions).
    pub pcie_g2h_bytes: u64,
    /// Requests the admission controller shed (always 0 with shedding
    /// off). Shed requests are excluded from `completed`.
    pub shed_requests: usize,
    /// Arrivals downgraded to single-stage, speculation-free service.
    pub downgraded_requests: usize,
    /// Per-tenant CAG admission modes (empty with `--cag off`),
    /// ascending tenant id.
    pub tenant_modes: Vec<(u32, TenantMode)>,
    /// Corpus KV bytes pinned under the CAG budget (0 with `--cag off`).
    pub cag_pinned_bytes: u64,
}

impl SimOutcome {
    /// The run's aggregated tree counters (all-zero when the run had no
    /// cache). The chunk-cache and disk-tier counters the reports and
    /// bench emitters read are views into this one block — they used to
    /// be mirrored as separate fields, a drift hazard the registry
    /// refactor removed.
    pub fn counters(&self) -> crate::tree::TreeCounters {
        self.tree_counters.unwrap_or_default()
    }

    /// Position-independent chunk-cache hits (`--chunk-cache on`;
    /// always 0 when off).
    pub fn chunk_hits(&self) -> u64 {
        self.counters().chunk_hits
    }

    /// KV bytes served from chunk entries (the reused `tokens − r`
    /// rows per hit).
    pub fn chunk_hit_bytes(&self) -> u64 {
        self.counters().chunk_hit_bytes
    }

    /// Boundary tokens re-prefilled across all chunk hits.
    pub fn boundary_recompute_tokens(&self) -> u64 {
        self.counters().boundary_recompute_tokens
    }

    /// Host→disk demotions staged by the NVMe tier (always 0 with
    /// `--disk off`).
    pub fn disk_spills(&self) -> u64 {
        self.counters().disk_spills
    }

    /// KV bytes those spills staged (counted, never charged — the
    /// staging queue writes asynchronously).
    pub fn disk_spill_bytes(&self) -> u64 {
        self.counters().disk_spill_bytes
    }

    /// Disk→host restages that served an admission (tree nodes and
    /// chunk entries).
    pub fn disk_restage_hits(&self) -> u64 {
        self.counters().disk_restage_hits
    }

    /// KV bytes those restages read — the bytes charged as the
    /// per-batch NVMe read burst.
    pub fn disk_restage_bytes(&self) -> u64 {
        self.counters().disk_restage_bytes
    }
}

/// Effective NVMe sequential-read bandwidth for the staged-read model
/// (PCIe 4.0 ×4 datacenter SSD class).
const NVME_READ_BPS: f64 = 3.5e9;

/// The simulation's [`PipelineDriver`]: virtual clock + analytic models.
struct SimDriver {
    clock: SimClock,
    transfer: TransferModel,
    profile: CostProfile,
    /// NVMe staged-read model (`Some` only with `--disk on`); reuses
    /// [`TransferModel`] with SSD bandwidth + the configured read
    /// latency.
    disk: Option<TransferModel>,
}

impl PipelineDriver for SimDriver {
    fn now(&self) -> f64 {
        self.clock.now()
    }

    fn transfer_time(&self, bytes: u64) -> f64 {
        self.transfer.transfer_time(bytes)
    }

    fn disk_read_time(&self, bytes: u64) -> f64 {
        self.disk.map_or(0.0, |d| d.transfer_time(bytes))
    }
}

/// The simulation server.
pub struct SimServer {
    kind: SystemKind,
    driver: SimDriver,
    events: EventScheduler<Event>,
    engine: Engine,
    pipeline: Pipeline,
    timing: RetrievalTiming,
    spec_enabled: bool,
    /// Page geometry, kept for the CAG corpus-fit computation.
    page: PageSpec,
    /// CAG admission policy (`Some` only after [`SimServer::enable_cag`]).
    /// Cag-mode tenants skip retrieval entirely: their corpus KV is
    /// pre-staged on disk as pinned chunk entries.
    cag: Option<CagPolicy>,
    shed: ShedState,
    /// Handles of each request's pending retrieval-stage events, so a
    /// shed can cancel them in O(log n) each (cancelling already-fired
    /// handles is a harmless no-op).
    stage_handles: Vec<Vec<EventHandle>>,
    /// Handle of each request's pending `DeadlineExpired` (shed-on
    /// only), cancelled at first-token delivery.
    deadline_handles: Vec<Option<EventHandle>>,
    /// Requests not yet terminal (neither finished nor shed), kept
    /// current by [`SimServer::note_terminal`]. Lets `ShedDecayTick`
    /// decide whether to re-arm in O(1) instead of scanning the trace.
    live_requests: usize,
    /// Per-request latch behind `live_requests`: a request decrements it
    /// exactly once, even if (say) a graced prefill records a finish
    /// after the ladder already counted the request.
    terminal_counted: Vec<bool>,
    max_batch: usize,
    /// Compute-token budget of one popped admission batch (mirrors the
    /// engine's per-iteration prefill token cap).
    batch_token_budget: usize,
    /// Admission context per engine sequence (pinned path + docs to
    /// insert after the prefill). Keyed by seq id so aborted-but-
    /// completing speculations still cache their KV.
    admit_infos: std::collections::HashMap<u64, Admission>,
    /// Docs of every generation ever started (for stale-seq insertion).
    gen_docs: std::collections::HashMap<u64, Vec<DocId>>,
    /// Per-request doc→token-count maps plus the mean-length fallback
    /// for speculative candidates outside the final set, built once at
    /// construction: `doc_tokens` is hit per candidate per admission,
    /// and the old per-call linear scan was quadratic in top-k.
    doc_token_maps: Vec<HashMap<DocId, usize>>,
    mean_doc_tokens: Vec<usize>,
    trace: Trace,
    rng: Rng,
    num_docs: usize,
    sched_secs: f64,
    sched_ops: u64,
    /// Commit-side write-back burst of the last completed iteration
    /// (seconds): the coalesced `insert_child` swap-outs of its
    /// members, charged once per batch by delaying the NEXT planned
    /// iteration (the link is busy writing back before it can load).
    deferred_commit_s: f64,
    /// Epoch of the currently in-flight engine iteration.
    inflight_epoch: Option<u64>,
    next_epoch: u64,
    /// Cumulative host→GPU PCIe bytes: admission promotion bursts
    /// (including chunk-hit streaming) plus rebalancer moves.
    pcie_h2g_bytes: u64,
    /// Cumulative GPU→host PCIe bytes: commit write-back swap-outs
    /// plus rebalancer donor evictions.
    pcie_g2h_bytes: u64,
}

impl SimServer {
    /// Assemble a server for the given system configuration. The
    /// `SystemKind` selects the baseline behaviour matrix (§7 Baselines):
    /// vLLM = no document cache, FIFO, no DSP; SGLang = GPU-only prefix
    /// cache with LRU, FIFO, no DSP; RAGCache = everything.
    pub fn build(
        cfg: &SystemConfig,
        trace: Trace,
        num_docs: usize,
        timing: RetrievalTiming,
        seed: u64,
    ) -> anyhow::Result<SimServer> {
        let model = ModelSpec::lookup(&cfg.engine.model)?;
        let gpu = GpuSpec::lookup(&cfg.engine.gpu)?;
        let cost = CostModel::new(model.clone(), gpu.clone());
        let profile = cost.profile(65536, 65536);
        let engine = Engine::new(
            cost,
            cfg.engine.max_batch,
            cfg.engine.max_prefill_tokens,
        );
        let page = PageSpec {
            block_tokens: cfg.cache.block_tokens,
            kv_bytes_per_token: model.kv_bytes_per_token,
        };
        let kind = *cfg.kind;
        let cache = match kind {
            SystemKind::VllmLike => None,
            SystemKind::SglangLike => {
                Some(ShardedCacheService::single(KnowledgeTree::new(
                    cfg.cache.gpu_bytes,
                    0,
                    page,
                    make_policy(crate::config::PolicyKind::Lru),
                    false,
                    0,
                )))
            }
            SystemKind::RagCache => {
                // K shards over exact (remainder-preserving) slices of
                // the configured budgets; the optional rebalancer then
                // moves the GPU/host slices with demand (disk slices
                // stay static — NVMe capacity is not the contended
                // resource).
                let k = cfg.cache.shards.max(1);
                let gpu_slices = split_budget(cfg.cache.gpu_bytes, k);
                let host_slices = split_budget(cfg.cache.host_bytes, k);
                let disk_slices = if cfg.cache.disk {
                    split_budget(cfg.cache.disk_bytes, k)
                } else {
                    vec![0; k]
                };
                let mut svc = ShardedCacheService::build(k, |i| {
                    let mut tree = KnowledgeTree::new(
                        gpu_slices[i],
                        host_slices[i],
                        page,
                        make_policy(cfg.cache.policy),
                        cfg.cache.swap_out_only_once,
                        0,
                    );
                    if cfg.cache.chunk_cache {
                        tree.enable_chunk_cache(
                            cfg.cache.boundary_tokens,
                        );
                    }
                    if disk_slices[i] > 0 {
                        tree.enable_disk_tier(disk_slices[i]);
                    }
                    tree
                });
                if cfg.cache.rebalance {
                    svc.enable_rebalancing(RebalanceConfig {
                        interval: cfg.cache.rebalance_interval.max(1)
                            as u64,
                        ..RebalanceConfig::default()
                    });
                }
                Some(svc)
            }
        };
        let reorder = kind == SystemKind::RagCache && cfg.sched.reorder;
        let spec_enabled = kind == SystemKind::RagCache && cfg.spec.enabled;
        let transfer = if cfg.engine.gpu == "h800x2" {
            TransferModel::pcie5()
        } else {
            TransferModel::pcie4()
        };
        let mut pipeline =
            Pipeline::new(cache, reorder, cfg.sched.window);
        pipeline.reserve_requests(trace.requests.len());
        let n = trace.requests.len();
        let doc_token_maps: Vec<HashMap<DocId, usize>> = trace
            .requests
            .iter()
            .map(|r| {
                r.docs
                    .iter()
                    .copied()
                    .zip(r.doc_tokens.iter().copied())
                    .collect()
            })
            .collect();
        let mean_doc_tokens: Vec<usize> = trace
            .requests
            .iter()
            .map(|r| {
                let sum: usize = r.doc_tokens.iter().sum();
                (sum / r.doc_tokens.len().max(1)).max(1)
            })
            .collect();
        Ok(SimServer {
            kind,
            driver: SimDriver {
                clock: SimClock::new(),
                transfer,
                profile,
                disk: (kind == SystemKind::RagCache && cfg.cache.disk)
                    .then(|| TransferModel {
                        bandwidth_bps: NVME_READ_BPS,
                        latency_s: cfg.cache.disk_latency_s,
                    }),
            },
            events: EventScheduler::new(),
            engine,
            pipeline,
            timing,
            spec_enabled,
            page,
            cag: None,
            shed: ShedState {
                enabled: cfg.shed.enabled,
                ttft_slo: cfg.shed.ttft_slo_s,
                downgrade_frac: cfg.shed.downgrade_frac,
                wait_ewma: 0.0,
            },
            stage_handles: vec![Vec::new(); n],
            deadline_handles: vec![None; n],
            live_requests: n,
            terminal_counted: vec![false; n],
            max_batch: cfg.engine.max_batch,
            batch_token_budget: cfg.engine.max_prefill_tokens,
            admit_infos: std::collections::HashMap::new(),
            gen_docs: std::collections::HashMap::new(),
            doc_token_maps,
            mean_doc_tokens,
            trace,
            rng: Rng::new(seed ^ 0x51_C0_FF_EE),
            num_docs,
            sched_secs: 0.0,
            sched_ops: 0,
            deferred_commit_s: 0.0,
            inflight_epoch: None,
            next_epoch: 0,
            pcie_h2g_bytes: 0,
            pcie_g2h_bytes: 0,
        })
    }

    pub fn kind(&self) -> SystemKind {
        self.kind
    }

    /// Enable CAG-style per-tenant admission: tenants whose whole
    /// corpus KV fits `pin_budget` bytes are served retrieval-free,
    /// their corpora pre-staged as pinned, position-independent chunk
    /// entries (disk-resident with `--disk on`, best-effort host
    /// entries otherwise) and promoted disk → host → GPU on first
    /// touch. Call between [`SimServer::build`] and [`SimServer::run`].
    /// No-op on the baseline systems (no cache). The config layer
    /// guarantees the chunk cache is on when CAG is.
    pub fn enable_cag(
        &mut self,
        corpora: &[TenantCorpus],
        pin_budget: u64,
    ) {
        let Some(cache) = &self.pipeline.cache else {
            return;
        };
        let policy = CagPolicy::decide(corpora, self.page, pin_budget);
        for c in corpora {
            if !policy.is_cag(c.tenant) {
                continue;
            }
            for (i, &tokens) in c.doc_tokens.iter().enumerate() {
                let doc: DocId = c.doc_base + i as u32;
                // Accounting-level prestage (payload None): startup
                // staging, neither counted nor charged.
                cache.prestage_corpus_doc(doc, tokens, 0, None);
            }
        }
        cache.flush_disk_staging();
        self.cag = Some(policy);
    }

    /// Run the trace to completion and return the outcome.
    pub fn run(mut self) -> SimOutcome {
        for i in 0..self.trace.requests.len() {
            let at = self.trace.requests[i].arrival;
            self.events.schedule(at, Event::Arrival(i));
        }
        if self.shed.enabled {
            self.events.schedule(
                self.shed.ttft_slo / 4.0,
                Event::ShedDecayTick,
            );
        }
        while let Some((t, ev)) = self.events.pop() {
            self.driver.clock.advance_to(t);
            match ev {
                Event::Arrival(i) => self.on_arrival(i),
                Event::RetrievalDone { req, stage } => {
                    self.on_retrieval_done(req, stage)
                }
                Event::EngineDone(epoch) => self.on_engine_done(epoch),
                Event::DeadlineExpired(req) => {
                    self.on_deadline_expired(req)
                }
                Event::ShedDecayTick => self.on_shed_decay_tick(),
            }
            self.service_queues();
        }
        let completed = self
            .pipeline
            .requests
            .iter()
            .filter(|r| r.done)
            .count();
        let tree_counters =
            self.pipeline.cache.as_ref().map(|c| c.counters());
        SimOutcome {
            rebalance: self
                .pipeline
                .cache
                .as_ref()
                .map(|c| c.rebalance_stats())
                .unwrap_or_default(),
            tree_counters,
            tenant_modes: self
                .cag
                .as_ref()
                .map(|p| p.modes().collect())
                .unwrap_or_default(),
            cag_pinned_bytes: self
                .cag
                .as_ref()
                .map(|p| p.pinned_bytes())
                .unwrap_or(0),
            pcie_h2g_bytes: self.pcie_h2g_bytes,
            pcie_g2h_bytes: self.pcie_g2h_bytes,
            spec_started: self
                .pipeline
                .requests
                .iter()
                .map(|r| r.spec.started)
                .sum(),
            spec_wasted: self
                .pipeline
                .requests
                .iter()
                .map(|r| r.spec.wasted)
                .sum(),
            spec_promoted: self
                .pipeline
                .requests
                .iter()
                .map(|r| r.spec.promoted)
                .sum(),
            mean_sched_time: if self.sched_ops == 0 {
                0.0
            } else {
                self.sched_secs / self.sched_ops as f64
            },
            completed,
            shed_requests: self.pipeline.recorder.shed_count(),
            downgraded_requests: self.pipeline.recorder.downgrade_count(),
            recorder: self.pipeline.recorder,
        }
    }

    fn now(&self) -> f64 {
        self.driver.now()
    }

    fn on_arrival(&mut self, i: usize) {
        let now = self.now();
        let tenant = self.trace.requests[i].tenant;
        self.pipeline.recorder.arrival(i as u64, now);
        self.pipeline.recorder.tenant(i as u64, tenant);
        let docs = self.trace.requests[i].docs.clone();
        // CAG fast path: the tenant's whole corpus is pinned, so the
        // final docs are known at arrival — no retrieval stages, no
        // speculation. The generation enqueues immediately and its KV
        // is served from the pinned chunk entries (restaged from disk
        // on first touch). The SLO deadline still arms: CAG skips
        // retrieval, not the engine queue.
        if self.cag.as_ref().is_some_and(|p| p.is_cag(tenant)) {
            if self.shed.enabled {
                self.deadline_handles[i] = Some(self.events.schedule(
                    now + self.shed.ttft_slo,
                    Event::DeadlineExpired(i),
                ));
            }
            self.start_generation(i, &docs);
            let output_tokens = self.trace.requests[i].output_tokens;
            // Zero-cost "retrieval": confirmed at arrival, no
            // non-overlapped search time.
            self.pipeline.confirm_final(i, now, output_tokens, 0.0);
            return;
        }
        // Downgrade rung of the ladder: under sustained queueing delay,
        // new arrivals skip speculation (single-stage retrieval) so the
        // engine stops burning iterations on prefills that overload
        // would terminate anyway.
        let downgrade = self.spec_enabled && self.shed.downgrading();
        let plan = if self.spec_enabled && !downgrade {
            StagedRetrieval::plan(
                &docs,
                self.num_docs,
                &self.timing,
                &mut self.rng,
            )
        } else {
            StagedRetrieval::single(&docs, &self.timing)
        };
        if downgrade {
            self.pipeline.recorder.downgraded(i as u64);
        }
        let mut handles = Vec::with_capacity(plan.stages.len());
        for (s, stage) in plan.stages.iter().enumerate() {
            handles.push(self.events.schedule(
                now + stage.offset,
                Event::RetrievalDone { req: i, stage: s },
            ));
        }
        self.stage_handles[i] = handles;
        if self.shed.enabled {
            self.deadline_handles[i] = Some(self.events.schedule(
                now + self.shed.ttft_slo,
                Event::DeadlineExpired(i),
            ));
        }
        // Stash the plan's candidate docs on the request.
        self.pipeline.requests[i].active_docs = Vec::new();
        self.pipeline.requests[i].plan = Some(plan);
    }

    fn on_retrieval_done(&mut self, req: usize, stage: usize) {
        let t0 = Instant::now();
        let now = self.now();
        let sp = self.pipeline.requests[req]
            .plan
            .as_ref()
            .expect("stage plan exists")
            .stages[stage]
            .clone();
        let pool_len = self.engine.waiting_len() + self.pipeline.queue.len();
        let action = self.pipeline.requests[req].spec.on_stage(
            &sp.docs,
            pool_len,
            self.max_batch,
            sp.is_final,
        );
        match action {
            SpecAction::Start { terminate_prev } => {
                if terminate_prev {
                    self.abort_generation(req);
                }
                self.start_generation(req, &sp.docs);
            }
            SpecAction::Keep => {}
            SpecAction::Defer { terminate_prev } => {
                if terminate_prev {
                    self.abort_generation(req);
                }
            }
        }
        if sp.is_final {
            let output_tokens = self.trace.requests[req].output_tokens;
            self.pipeline.confirm_final(
                req,
                now,
                output_tokens,
                self.timing.full_search_s,
            );
            self.note_terminal(req);
        }
        self.sched_secs += t0.elapsed().as_secs_f64();
        self.sched_ops += 1;
    }

    /// Shed rung of the ladder: the request's TTFT SLO deadline passed.
    fn on_deadline_expired(&mut self, req: usize) {
        self.deadline_handles[req] = None;
        let served = self
            .pipeline
            .recorder
            .record(req as u64)
            .and_then(|r| r.first_token)
            .is_some();
        if served || self.pipeline.requests[req].done {
            return;
        }
        // Grace for admitted prefills: the work is already scheduled on
        // the engine and aborting it refunds nothing — let it finish
        // (its TTFT misses the SLO; goodput already accounts for that).
        if let Some(seq) = self.pipeline.requests[req].active_seq {
            if self.admit_infos.contains_key(&seq) {
                return;
            }
        }
        for h in std::mem::take(&mut self.stage_handles[req]) {
            self.events.cancel(h);
        }
        self.abort_generation(req);
        let now = self.now();
        self.pipeline.recorder.shed(req as u64, now);
        self.note_terminal(req);
    }

    /// Shed-on maintenance: decay the queueing-delay EWMA so downgrade
    /// mode exits once a burst drains (pops stop happening exactly when
    /// the queue is empty, so without decay the EWMA would freeze at
    /// its burst-peak value). Re-arms only while unserved, unshed
    /// requests remain — `live_requests`, maintained at each terminal
    /// transition, makes that an O(1) check — so the event loop always
    /// terminates.
    fn on_shed_decay_tick(&mut self) {
        self.shed.wait_ewma *= 0.5;
        if self.live_requests > 0 {
            self.events.schedule(
                self.now() + self.shed.ttft_slo / 4.0,
                Event::ShedDecayTick,
            );
        }
    }

    /// Count `req`'s terminal transition (finished or shed) toward the
    /// `live_requests` drawdown, at most once per request. Mirrors the
    /// liveness predicate the decay tick used to recompute by scanning
    /// every record.
    fn note_terminal(&mut self, req: usize) {
        if self.terminal_counted[req] {
            return;
        }
        let terminal = self
            .pipeline
            .recorder
            .record(req as u64)
            .is_some_and(|r| r.finished.is_some() || r.shed.is_some());
        if terminal {
            self.terminal_counted[req] = true;
            self.live_requests -= 1;
            // CAG demand signal: a tenant's first *completed* request
            // flips it cold-RAG → cached-RAG (the shared cache has now
            // seen its demand; Cag tenants are unaffected).
            let finished = self
                .pipeline
                .recorder
                .record(req as u64)
                .is_some_and(|r| r.finished.is_some());
            if finished {
                if let Some(policy) = &mut self.cag {
                    policy.note_served(self.trace.requests[req].tenant);
                }
            }
        }
    }

    /// Abort the live generation of `req`, wherever it is. Sequences in
    /// the in-flight prefill iteration complete it (their KV is cached on
    /// the FirstToken that still fires); everything else is unpinned
    /// here.
    fn abort_generation(&mut self, req: usize) {
        let Some(seq) = self.pipeline.requests[req].active_seq.take() else {
            return;
        };
        self.pipeline.queue.remove(seq);
        match self.engine.abort(seq) {
            AbortOutcome::Deferred => {
                if self.engine.in_flight_fully_killed() {
                    // §5.3 batch-size-one case: nothing else shares the
                    // iteration, terminate immediately. Partial work is
                    // discarded (no KV cached).
                    for id in self.engine.cancel_in_flight() {
                        if let Some(adm) = self.admit_infos.remove(&id) {
                            self.pipeline.abort_admission(&adm);
                        }
                    }
                    self.inflight_epoch = None;
                }
                // Otherwise FirstToken will arrive and handle unpin +
                // insertion (the KV is computed and cached).
            }
            AbortOutcome::Removed | AbortOutcome::NotFound => {
                if let Some(adm) = self.admit_infos.remove(&seq) {
                    self.pipeline.abort_admission(&adm);
                }
            }
        }
        self.pipeline.requests[req].spec_first_token_at = None;
        self.pipeline.requests[req].spec_finished_at = None;
    }

    /// Create a generation for `docs` and enqueue it for admission.
    fn start_generation(&mut self, req: usize, docs: &[DocId]) {
        let now = self.now();
        // Cached/compute lengths for the reordering priority.
        let doc_tokens_total: usize =
            docs.iter().map(|&d| self.doc_tokens(req, d)).sum();
        let tr = &self.trace.requests[req];
        let arrival = tr.arrival;
        let request_tokens = tr.request_tokens;
        let is_final_docs = docs == tr.docs.as_slice();
        let (cached, compute) = self.pipeline.queue_lengths(
            docs,
            doc_tokens_total,
            request_tokens,
        );
        let seq = self.pipeline.requests[req].begin_generation(req, docs);
        if is_final_docs
            && self.pipeline.requests[req].final_enqueue_at.is_none()
        {
            self.pipeline.requests[req].final_enqueue_at = Some(now);
        }
        self.gen_docs.insert(seq, docs.to_vec());
        self.pipeline.queue.push(PendingRequest {
            id: seq,
            arrival,
            cached_tokens: cached,
            compute_tokens: compute,
            bypassed: 0,
        });
    }

    /// Token count of `doc` for this request: trace value when the doc is
    /// one of the final docs, corpus-independent fallback otherwise
    /// (perturbed speculative candidates use the mean doc length). O(1)
    /// against the maps built at construction.
    fn doc_tokens(&self, req: usize, doc: DocId) -> usize {
        self.doc_token_maps[req]
            .get(&doc)
            .copied()
            .unwrap_or(self.mean_doc_tokens[req])
    }

    /// Admit queued requests into free engine slots — a whole batch per
    /// queue pop, with the members' H2D transfers coalesced into one
    /// burst — then keep the engine running. Invoked after every event,
    /// so the engine restarts the moment capacity or work appears.
    fn service_queues(&mut self) {
        // Cross-shard rebalance tick (no-op unless `cache.rebalance`):
        // donor evictions' swap-outs occupy the link exactly like a
        // commit write-back burst, so they delay the next planned
        // iteration through the same deferred charge.
        if let Some(cache) = &self.pipeline.cache {
            if let Some(moved) = cache.maintenance_tick() {
                self.pcie_h2g_bytes += moved.h2g_bytes;
                self.pcie_g2h_bytes += moved.g2h_bytes;
                self.deferred_commit_s += self
                    .driver
                    .transfer_time(moved.h2g_bytes + moved.g2h_bytes);
            }
        }
        loop {
            let in_engine =
                self.engine.waiting_len() + self.engine.decoding_len();
            if in_engine >= self.max_batch || self.pipeline.queue.is_empty()
            {
                break;
            }
            let slots = self.max_batch - in_engine;
            let t0 = Instant::now();
            let pending = self
                .pipeline
                .queue
                .pop_batch(slots, self.batch_token_budget);
            let popped = pending.len();
            self.admit_batch(pending);
            self.sched_secs += t0.elapsed().as_secs_f64();
            self.sched_ops += popped.max(1) as u64;
        }
        if self.inflight_epoch.is_none() {
            if let Some(plan) = self.engine.plan() {
                let epoch = self.next_epoch;
                self.next_epoch += 1;
                self.inflight_epoch = Some(epoch);
                // The previous iteration's commit write-back burst
                // serializes with this iteration on the link: charge it
                // once, here.
                let commit_burst =
                    std::mem::replace(&mut self.deferred_commit_s, 0.0);
                self.events.schedule(
                    self.now() + plan.duration + commit_burst,
                    Event::EngineDone(epoch),
                );
            }
        }
    }

    /// Admit one popped batch: every member runs admission stage A
    /// (match → promote → pin → (α, β)) first, then the members'
    /// promotion transfers coalesce into ONE PCIe burst
    /// ([`BatchAdmission::seal`] — a single `transfer_time` call) that
    /// rides on the batch's FIRST member as its `extra_time`, so the
    /// charge lands exactly once, on the iteration that prefills the
    /// batch head — never piling several batches' bursts onto one
    /// iteration when `service_queues` pops more than one budget-limited batch
    /// back to back. With `max_batch = 1` this is exactly the
    /// historical one-pop admission: a single member carrying its own
    /// `transfer_time(bytes)`.
    fn admit_batch(&mut self, pending: Vec<PendingRequest>) {
        let now = self.now();
        if self.shed.enabled {
            // Queueing-delay signal for the downgrade rung: how long
            // each admitted member waited from arrival to this pop.
            for p in &pending {
                self.shed.observe_wait(now - p.arrival);
            }
        }
        let mut batch = BatchAdmission::new();
        let mut specs: Vec<SeqSpec> = Vec::new();
        for p in pending {
            let req = request_of(p.id);
            if !self.pipeline.requests[req].is_live(p.id) {
                continue; // stale generation: never admitted
            }
            let docs = self.gen_docs[&p.id].clone();
            let docs_tokens: Vec<(DocId, usize)> = docs
                .iter()
                .map(|&d| (d, self.doc_tokens(req, d)))
                .collect();
            let tr = &self.trace.requests[req];
            let request_tokens = tr.request_tokens;
            let output_tokens = tr.output_tokens;
            let is_final_docs = docs == tr.docs.as_slice();

            let mut adm =
                self.pipeline.admit_one(&docs_tokens, request_tokens);
            let estimated_time =
                self.driver.profile.estimate(adm.alpha, adm.beta);
            adm.estimated_time = estimated_time;
            // Policy updates for the matched (hit) nodes.
            self.pipeline.touch_hits(&adm, estimated_time, now);

            // Metrics: hit accounting against the request's final docs.
            if is_final_docs {
                self.pipeline
                    .record_admission(req as u64, docs.len(), &adm);
            }

            specs.push(SeqSpec {
                id: p.id,
                alpha: adm.alpha,
                beta: adm.beta,
                output_tokens,
                extra_time: 0.0,
            });
            self.pcie_h2g_bytes += adm.transfers.h2g_bytes;
            self.pcie_g2h_bytes += adm.transfers.g2h_bytes;
            batch.push(p.id, adm);
        }
        // One coalesced H2D burst for the whole batch (§3.2 cache-hit
        // loading), attached to the member prefilled first.
        let burst = batch.seal(&self.driver);
        if let Some(first) = specs.first_mut() {
            first.extra_time = burst;
        }
        for spec in specs {
            self.engine.admit(spec);
        }
        for (id, adm) in batch.into_members() {
            self.admit_infos.insert(id, adm);
        }
    }

    fn on_engine_done(&mut self, epoch: u64) {
        if self.inflight_epoch != Some(epoch) {
            return; // iteration was cancelled
        }
        self.inflight_epoch = None;
        let now = self.now();
        // Drain the disk staging queue once per engine iteration: the
        // async spill writes serialize into backing-store slots while
        // the GPU computes (no-op, and no state change, with --disk
        // off).
        if let Some(cache) = &self.pipeline.cache {
            cache.flush_disk_staging();
        }
        let events = self.engine.complete();
        // The iteration's commits (one per FirstToken) coalesce into
        // ONE write-back burst — the commit-phase mirror of the admit
        // burst — charged once onto the next planned iteration.
        let mut commits = BatchAdmission::new();
        for ev in events {
            match ev {
                SeqEvent::FirstToken { id } => {
                    let moved = self.on_first_token(id, now);
                    commits.push_commit(moved);
                }
                SeqEvent::Finished { id } => self.on_finished(id, now),
            }
        }
        self.deferred_commit_s += commits.seal_commit(&self.driver);
    }

    /// Returns the byte movement the commit performed (eviction
    /// swap-outs while inserting the new doc KV), for the per-iteration
    /// commit burst.
    fn on_first_token(
        &mut self,
        seq: u64,
        now: f64,
    ) -> crate::tree::Transfers {
        let req = request_of(seq);
        // Insert newly computed doc KV into the tree and update stats —
        // even for terminated speculations: the prefill ran, the KV for
        // its document sequence is valid, and caching it is precisely
        // what makes restarted generations cheap (paper §4, Thm 5.1).
        let mut moved = crate::tree::Transfers::default();
        if let Some(adm) = self.admit_infos.remove(&seq) {
            let out = self
                .pipeline
                .commit_prefill(&adm, adm.estimated_time, now, None);
            moved = out.transfers;
            self.pcie_h2g_bytes += moved.h2g_bytes;
            self.pcie_g2h_bytes += moved.g2h_bytes;
        }
        self.pipeline.deliver_first_token(
            req,
            seq,
            &self.trace.requests[req].docs,
            now,
        );
        // A recorded first token satisfies the SLO deadline: disarm it.
        let served = self
            .pipeline
            .recorder
            .record(req as u64)
            .and_then(|r| r.first_token)
            .is_some();
        if served {
            if let Some(h) = self.deadline_handles[req].take() {
                self.events.cancel(h);
            }
        }
        moved
    }

    fn on_finished(&mut self, seq: u64, now: f64) {
        let req = request_of(seq);
        self.pipeline.deliver_finished(
            req,
            seq,
            &self.trace.requests[req].docs,
            self.trace.requests[req].output_tokens,
            now,
        );
        self.note_terminal(req);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::workload::{datasets::MMLU, Corpus, Trace};

    fn cfg_for(kind: &str) -> SystemConfig {
        let mut cfg = SystemConfig::default();
        cfg.kind = crate::config::SystemKindField(
            SystemKind::parse(kind).unwrap(),
        );
        // Paper-testbed cache shares (Mistral-7B docs average ~465 MiB of
        // KV each): GPU fits ~17 docs, host ~400.
        cfg.cache.gpu_bytes = 8 * (1 << 30);
        cfg.cache.host_bytes = 192 * (1 << 30);
        cfg
    }

    fn run_kind(kind: &str, rate: f64, n: usize) -> SimOutcome {
        let corpus = Corpus::wikipedia_like(2_000, 1);
        let trace = Trace::generate(&MMLU, &corpus, rate, n, 2, 11);
        let server = SimServer::build(
            &cfg_for(kind),
            trace,
            2_000,
            RetrievalTiming::default(),
            5,
        )
        .unwrap();
        server.run()
    }

    #[test]
    fn all_requests_complete_all_systems() {
        for kind in ["ragcache", "vllm", "sglang"] {
            let out = run_kind(kind, 0.3, 40);
            assert_eq!(out.completed, 40, "{kind}");
            assert_eq!(out.recorder.ttft().len(), 40, "{kind}");
        }
    }

    #[test]
    fn ragcache_beats_vllm_ttft() {
        // The headline (Fig. 13): document caching cuts mean TTFT.
        let rag = run_kind("ragcache", 0.5, 120);
        let vllm = run_kind("vllm", 0.5, 120);
        let t_rag = rag.recorder.ttft().mean();
        let t_vllm = vllm.recorder.ttft().mean();
        assert!(
            t_rag < t_vllm,
            "ragcache {t_rag} should beat vllm {t_vllm}"
        );
        assert!(rag.recorder.hit_rate() > 0.2, "hit rate materialises");
        assert_eq!(vllm.recorder.hit_rate(), 0.0);
    }

    #[test]
    fn ragcache_beats_sglang_under_memory_pressure() {
        let rag = run_kind("ragcache", 0.5, 120);
        let sglang = run_kind("sglang", 0.5, 120);
        let t_rag = rag.recorder.ttft().mean();
        let t_sg = sglang.recorder.ttft().mean();
        assert!(
            t_rag <= t_sg * 1.05,
            "ragcache {t_rag} vs sglang {t_sg}"
        );
        // SGLang's GPU-only cache yields a lower hit rate.
        assert!(
            rag.recorder.hit_rate() >= sglang.recorder.hit_rate(),
            "multilevel cache wins on hit rate"
        );
    }

    #[test]
    fn speculation_counters_populate() {
        let out = run_kind("ragcache", 0.2, 50);
        assert!(out.spec_started >= 50);
        // Satellite: promotions (final-stage confirmations) are now
        // surfaced too, and every promotion is a started speculation.
        assert!(out.spec_promoted > 0, "some speculation confirmed");
        assert!(out.spec_promoted <= out.spec_started);
        // Baselines never speculate.
        let v = run_kind("vllm", 0.2, 20);
        assert_eq!(v.spec_wasted, 0);
        assert_eq!(v.spec_promoted, 0);
    }

    /// Tentpole: a sharded sim with rebalancing on completes the trace
    /// and actually recomputes slices; with rebalancing off the
    /// rebalancer never runs (static-split conformance stays with the
    /// dedicated shard/rebalance suites).
    #[test]
    fn sharded_sim_with_rebalancing_completes() {
        let corpus = Corpus::wikipedia_like(2_000, 1);
        let mut cfg = cfg_for("ragcache");
        cfg.cache.shards = 4;
        cfg.cache.rebalance = true;
        cfg.cache.rebalance_interval = 8;
        let trace = Trace::generate(&MMLU, &corpus, 0.5, 60, 2, 17);
        let server = SimServer::build(
            &cfg,
            trace,
            2_000,
            RetrievalTiming::default(),
            9,
        )
        .unwrap();
        let out = server.run();
        assert_eq!(out.completed, 60);
        assert!(out.rebalance.recomputes > 0, "{:?}", out.rebalance);

        cfg.cache.rebalance = false;
        let trace = Trace::generate(&MMLU, &corpus, 0.5, 60, 2, 17);
        let server = SimServer::build(
            &cfg,
            trace,
            2_000,
            RetrievalTiming::default(),
            9,
        )
        .unwrap();
        let out = server.run();
        assert_eq!(out.completed, 60);
        assert_eq!(
            out.rebalance,
            crate::controller::RebalanceStats::default()
        );
    }

    /// Tentpole acceptance (unit tier): under heavy open-loop overload
    /// queues build without deadlock; with shedding on, overload is cut
    /// and every request is accounted for exactly once — completed or
    /// shed — with per-tenant stats summing exactly to the aggregate.
    /// (The strict goodput-win margin is asserted by the overload gate
    /// and the event_sim integration suite.)
    #[test]
    fn overload_sheds_and_accounts_every_request() {
        use crate::workload::TraceOptions;
        let corpus = Corpus::wikipedia_like(2_000, 1);
        // All 120 requests arrive inside ~2.4 s — far beyond what a
        // batch-4 engine prefills in that window.
        let mk = || {
            Trace::generate_open_loop(
                &MMLU,
                &corpus,
                50.0,
                120,
                &TraceOptions {
                    tenants: 4,
                    ..TraceOptions::default()
                },
                11,
            )
        };
        // Calibrate the SLO from an uncongested run: 3× its mean TTFT.
        let base = run_kind("ragcache", 0.3, 40);
        let slo = (3.0 * base.recorder.ttft().mean()).max(0.2);
        let mut cfg = cfg_for("ragcache");
        cfg.shed.ttft_slo_s = slo;
        let off = SimServer::build(
            &cfg,
            mk(),
            2_000,
            RetrievalTiming::default(),
            5,
        )
        .unwrap()
        .run();
        cfg.shed.enabled = true;
        let on = SimServer::build(
            &cfg,
            mk(),
            2_000,
            RetrievalTiming::default(),
            5,
        )
        .unwrap()
        .run();
        // Open loop without shedding: queues grow, no deadlock,
        // everything completes eventually — but far past the SLO.
        assert_eq!(off.completed, 120);
        assert_eq!(off.shed_requests, 0);
        let mut off_ttft = off.recorder.ttft();
        assert!(off_ttft.percentile(99.0) > slo);
        assert!(on.shed_requests > 0, "overload must shed");
        assert_eq!(on.completed + on.shed_requests, 120);
        assert_eq!(on.recorder.shed_count(), on.shed_requests);
        assert!(on.recorder.goodput(slo) >= off.recorder.goodput(slo));
        let per = on.recorder.per_tenant(slo);
        assert_eq!(per.len(), 4);
        assert_eq!(per.iter().map(|t| t.requests).sum::<usize>(), 120);
        assert_eq!(
            per.iter().map(|t| t.shed).sum::<usize>(),
            on.shed_requests
        );
        assert_eq!(
            per.iter().map(|t| t.completed).sum::<usize>(),
            on.completed
        );
    }

    /// The downgrade rung: pre-load the queueing-delay EWMA so the
    /// controller starts in downgrade mode — early arrivals must be
    /// served speculation-free, and the tick decay must eventually
    /// release the mode (the run still completes everything under a
    /// loose SLO).
    #[test]
    fn downgrade_ladder_disables_speculation_under_pressure() {
        let corpus = Corpus::wikipedia_like(2_000, 1);
        let trace = Trace::generate(&MMLU, &corpus, 0.5, 40, 2, 11);
        let mut cfg = cfg_for("ragcache");
        cfg.shed.enabled = true;
        cfg.shed.ttft_slo_s = 30.0; // loose: nothing sheds
        let mut server = SimServer::build(
            &cfg,
            trace,
            2_000,
            RetrievalTiming::default(),
            5,
        )
        .unwrap();
        server.shed.wait_ewma = 100.0; // synthetic pressure
        let out = server.run();
        assert!(out.downgraded_requests > 0, "pressure must downgrade");
        assert!(
            out.downgraded_requests < 40,
            "tick decay must release downgrade mode"
        );
        assert_eq!(out.shed_requests, 0);
        assert_eq!(out.completed, 40);
        assert_eq!(
            out.recorder.downgrade_count(),
            out.downgraded_requests
        );
    }

    /// The event core replays deterministically with shedding enabled:
    /// same config + trace + seed → bit-identical outcome.
    #[test]
    fn shed_runs_are_deterministic() {
        use crate::workload::TraceOptions;
        let corpus = Corpus::wikipedia_like(1_000, 3);
        let mk = || {
            Trace::generate_open_loop(
                &MMLU,
                &corpus,
                20.0,
                60,
                &TraceOptions {
                    tenants: 2,
                    ..TraceOptions::default()
                },
                13,
            )
        };
        let mut cfg = cfg_for("ragcache");
        cfg.shed.enabled = true;
        cfg.shed.ttft_slo_s = 1.0;
        let run = |cfg: &SystemConfig| {
            SimServer::build(
                cfg,
                mk(),
                1_000,
                RetrievalTiming::default(),
                5,
            )
            .unwrap()
            .run()
        };
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.shed_requests, b.shed_requests);
        assert_eq!(a.downgraded_requests, b.downgraded_requests);
        assert_eq!(
            a.recorder.ttft().mean().to_bits(),
            b.recorder.ttft().mean().to_bits()
        );
        assert_eq!(a.pcie_h2g_bytes, b.pcie_h2g_bytes);
        assert_eq!(a.completed + a.shed_requests, 60);
    }

    /// The decay tick re-arms off the O(1) live-request counter (no
    /// per-tick trace scan), so the event loop must still drain: once
    /// every request is terminal — exercising BOTH terminal paths,
    /// finish and shed — the tick stops re-arming and `run()` returns.
    #[test]
    fn shed_decay_tick_terminates_with_mixed_terminals() {
        use crate::workload::TraceOptions;
        let corpus = Corpus::wikipedia_like(1_000, 3);
        let trace = Trace::generate_open_loop(
            &MMLU,
            &corpus,
            40.0,
            80,
            &TraceOptions::default(),
            13,
        );
        let mut cfg = cfg_for("ragcache");
        cfg.shed.enabled = true;
        cfg.shed.ttft_slo_s = 0.5; // tight: the burst must shed some
        let out = SimServer::build(
            &cfg,
            trace,
            1_000,
            RetrievalTiming::default(),
            5,
        )
        .unwrap()
        .run();
        // `run()` returning at all IS the termination property (a tick
        // that kept re-arming would loop forever on the virtual clock);
        // the exact accounting shows the counter drained through both
        // finishes and sheds, not by accident.
        assert!(out.shed_requests > 0, "tight SLO must shed");
        assert!(out.completed > 0, "graced work must still finish");
        assert_eq!(out.completed + out.shed_requests, 80);
    }

    /// Tentpole: with the NVMe tier on and both upper tiers squeezed,
    /// the GPU → host → disk cascade actually spills, the run still
    /// completes, and restages serve admissions back out of disk.
    #[test]
    fn disk_tier_spills_and_restages_under_pressure() {
        let corpus = Corpus::wikipedia_like(500, 2);
        let trace = Trace::generate(&MMLU, &corpus, 1.0, 80, 2, 13);
        let mut cfg = cfg_for("ragcache");
        cfg.cache.gpu_bytes = 128 * 1024 * 1024;
        cfg.cache.host_bytes = 192 * 1024 * 1024; // host thrashes too
        cfg.cache.disk = true;
        cfg.cache.disk_bytes = 8 * (1 << 30);
        let server = SimServer::build(
            &cfg,
            trace,
            500,
            RetrievalTiming::default(),
            7,
        )
        .unwrap();
        let out = server.run();
        assert_eq!(out.completed, 80);
        let c = out.tree_counters.unwrap();
        assert!(c.host_evictions > 0, "host tier must thrash: {c:?}");
        assert!(out.disk_spills() > 0, "cascade must reach disk");
        assert_eq!(out.disk_spills(), c.disk_spills);
        assert!(
            out.disk_restage_hits() > 0,
            "spilled KV must be served back: {c:?}"
        );
        assert!(out.disk_spill_bytes() >= out.disk_restage_bytes() / 4);
    }

    /// CAG admission: the pinned tenant's requests carry zero retrieval
    /// (retrieval confirmed at arrival), the other tenant still runs
    /// the normal RAG path, and the run completes everything.
    #[test]
    fn cag_tenant_skips_retrieval_entirely() {
        use crate::workload::{tenant_corpora, TraceOptions};
        let corpus = Corpus::wikipedia_like(400, 2);
        let opts = TraceOptions {
            tenants: 2,
            ..TraceOptions::default()
        };
        let trace = Trace::generate_open_loop(
            &MMLU, &corpus, 0.5, 40, &opts, 11,
        );
        let mut cfg = cfg_for("ragcache");
        cfg.cache.chunk_cache = true;
        cfg.cache.disk = true;
        cfg.cache.disk_bytes = 64 * (1 << 30);
        let mut server = SimServer::build(
            &cfg,
            trace.clone(),
            400,
            RetrievalTiming::default(),
            5,
        )
        .unwrap();
        let corpora = tenant_corpora(&corpus, &opts);
        let page = server.page;
        // Budget sized to the smallest corpus: exactly one tenant pins.
        let budget =
            corpora.iter().map(|c| c.kv_bytes(page)).min().unwrap();
        server.enable_cag(&corpora, budget);
        let out = server.run();
        assert_eq!(out.completed, 40);
        let cag: Vec<u32> = out
            .tenant_modes
            .iter()
            .filter(|(_, m)| *m == TenantMode::Cag)
            .map(|(t, _)| *t)
            .collect();
        assert_eq!(cag.len(), 1, "modes: {:?}", out.tenant_modes);
        assert!(out.cag_pinned_bytes > 0);
        assert!(out.cag_pinned_bytes <= budget);
        // Every request of the pinned tenant confirmed retrieval at its
        // arrival instant; every other completed request paid retrieval.
        for r in &trace.requests {
            let rec = out.recorder.record(r.id).unwrap();
            let rd = rec.retrieval_done.expect("all complete");
            if cag.contains(&r.tenant) {
                assert_eq!(rd.to_bits(), rec.arrival.to_bits());
            } else {
                assert!(rd > rec.arrival);
            }
        }
    }

    #[test]
    fn sched_time_sub_millisecond() {
        // Table 4: controller decisions stay below 1 ms.
        let out = run_kind("ragcache", 0.5, 60);
        assert!(
            out.mean_sched_time < 1e-3,
            "mean sched {}",
            out.mean_sched_time
        );
    }

    #[test]
    fn tree_invariants_hold_after_run() {
        let corpus = Corpus::wikipedia_like(500, 2);
        let trace = Trace::generate(&MMLU, &corpus, 1.0, 80, 2, 13);
        let mut cfg = cfg_for("ragcache");
        cfg.cache.gpu_bytes = 128 * 1024 * 1024; // force heavy eviction
        cfg.cache.host_bytes = 512 * 1024 * 1024;
        let server = SimServer::build(
            &cfg,
            trace,
            500,
            RetrievalTiming::default(),
            7,
        )
        .unwrap();
        // run() consumes; re-build a server to inspect the tree. Instead:
        // rely on counters + completion as the observable signal here;
        // invariants themselves are property-tested in tree::tests.
        let out = server.run();
        assert_eq!(out.completed, 80);
        let c = out.tree_counters.unwrap();
        assert!(c.gpu_evictions > 0, "eviction exercised: {c:?}");
    }
}
