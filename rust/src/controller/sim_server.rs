//! Event-driven simulation server: the full RAGCache pipeline (and its
//! vLLM/SGLang baseline configurations) against the virtual clock and the
//! analytic GPU cost model. This is what every paper-scale bench drives.

use super::retrieval::{RetrievalTiming, StagedRetrieval};
use crate::config::{SystemConfig, SystemKind};
use crate::kvcache::{PageSpec, TransferModel};
use crate::llm::cost_model::{CostModel, CostProfile};
use crate::llm::engine::{AbortOutcome, Engine, SeqEvent, SeqSpec};
use crate::llm::models::{GpuSpec, ModelSpec};
use crate::metrics::Recorder;
use crate::policy::{make_policy, AccessCtx};
use crate::sched::{PendingRequest, ReorderQueue};
use crate::sim::{Clock, EventQueue, SimClock};
use crate::spec::{SpecAction, SpecState};
use crate::tree::{DocId, KnowledgeTree, NodeId};
use crate::util::Rng;
use crate::workload::Trace;
use std::time::Instant;

/// Generation-tagged engine sequence id: `request_index * GEN_BASE + gen`.
const GEN_BASE: u64 = 1024;

#[derive(Debug, Clone)]
enum Event {
    Arrival(usize),
    Stage { req: usize, stage: usize },
    /// Completion of the iteration with this epoch tag (stale tags are
    /// ignored — the iteration was cancelled).
    EngineDone(u64),
}

/// Info captured at admission, needed when the prefill completes.
#[derive(Debug, Clone, Default)]
struct AdmitInfo {
    /// Matched (pinned) tree path.
    path: Vec<NodeId>,
    /// Docs to insert after compute: `(doc, tokens)`.
    unmatched: Vec<(DocId, usize)>,
    alpha: usize,
    beta: usize,
    estimated_time: f64,
}

#[derive(Debug, Default)]
struct ReqSim {
    spec: SpecState,
    /// Planned candidate evolution of this request's staged retrieval.
    spec_plan: Option<StagedRetrieval>,
    /// Engine/queue sequence of the live generation (if any).
    active_seq: Option<u64>,
    active_docs: Vec<DocId>,
    next_gen: u64,
    confirmed: bool,
    retrieval_done_at: Option<f64>,
    /// When the generation carrying the *final* docs entered the queue.
    final_enqueue_at: Option<f64>,
    spec_first_token_at: Option<f64>,
    spec_finished_at: Option<f64>,
    done: bool,
}

/// Aggregated results of one simulation run.
#[derive(Debug)]
pub struct SimOutcome {
    pub recorder: Recorder,
    pub tree_counters: Option<crate::tree::TreeCounters>,
    pub spec_started: u64,
    pub spec_wasted: u64,
    /// Mean controller decision time (tree lookup/update + reordering +
    /// DSP decisions), seconds — Table 4.
    pub mean_sched_time: f64,
    pub completed: usize,
}

/// The simulation server.
pub struct SimServer {
    kind: SystemKind,
    clock: SimClock,
    events: EventQueue<Event>,
    engine: Engine,
    tree: Option<KnowledgeTree>,
    queue: ReorderQueue,
    profile: CostProfile,
    transfer: TransferModel,
    timing: RetrievalTiming,
    spec_enabled: bool,
    max_batch: usize,
    requests: Vec<ReqSim>,
    /// Admission context per engine sequence (pinned path + docs to
    /// insert after the prefill). Keyed by seq id so aborted-but-
    /// completing speculations still cache their KV.
    admit_infos: std::collections::HashMap<u64, AdmitInfo>,
    /// Docs of every generation ever started (for stale-seq insertion).
    gen_docs: std::collections::HashMap<u64, Vec<DocId>>,
    trace: Trace,
    recorder: Recorder,
    rng: Rng,
    num_docs: usize,
    sched_secs: f64,
    sched_ops: u64,
    /// Epoch of the currently in-flight engine iteration.
    inflight_epoch: Option<u64>,
    next_epoch: u64,
}

impl SimServer {
    /// Assemble a server for the given system configuration. The
    /// `SystemKind` selects the baseline behaviour matrix (§7 Baselines):
    /// vLLM = no document cache, FIFO, no DSP; SGLang = GPU-only prefix
    /// cache with LRU, FIFO, no DSP; RAGCache = everything.
    pub fn build(
        cfg: &SystemConfig,
        trace: Trace,
        num_docs: usize,
        timing: RetrievalTiming,
        seed: u64,
    ) -> anyhow::Result<SimServer> {
        let model = ModelSpec::lookup(&cfg.engine.model)?;
        let gpu = GpuSpec::lookup(&cfg.engine.gpu)?;
        let cost = CostModel::new(model.clone(), gpu.clone());
        let profile = cost.profile(65536, 65536);
        let engine = Engine::new(
            cost,
            cfg.engine.max_batch,
            cfg.engine.max_prefill_tokens,
        );
        let page = PageSpec {
            block_tokens: cfg.cache.block_tokens,
            kv_bytes_per_token: model.kv_bytes_per_token,
        };
        let kind = *cfg.kind;
        let tree = match kind {
            SystemKind::VllmLike => None,
            SystemKind::SglangLike => Some(KnowledgeTree::new(
                cfg.cache.gpu_bytes,
                0,
                page,
                make_policy(crate::config::PolicyKind::Lru),
                false,
                0,
            )),
            SystemKind::RagCache => Some(KnowledgeTree::new(
                cfg.cache.gpu_bytes,
                cfg.cache.host_bytes,
                page,
                make_policy(cfg.cache.policy),
                cfg.cache.swap_out_only_once,
                0,
            )),
        };
        let reorder = kind == SystemKind::RagCache && cfg.sched.reorder;
        let spec_enabled = kind == SystemKind::RagCache && cfg.spec.enabled;
        let transfer = if cfg.engine.gpu == "h800x2" {
            TransferModel::pcie5()
        } else {
            TransferModel::pcie4()
        };
        let n = trace.requests.len();
        let mut requests = Vec::with_capacity(n);
        requests.resize_with(n, ReqSim::default);
        Ok(SimServer {
            kind,
            clock: SimClock::new(),
            events: EventQueue::new(),
            engine,
            tree,
            queue: ReorderQueue::new(reorder, cfg.sched.window),
            profile,
            transfer,
            timing,
            spec_enabled,
            max_batch: cfg.engine.max_batch,
            requests,
            admit_infos: std::collections::HashMap::new(),
            gen_docs: std::collections::HashMap::new(),
            trace,
            recorder: Recorder::new(),
            rng: Rng::new(seed ^ 0x51_C0_FF_EE),
            num_docs,
            sched_secs: 0.0,
            sched_ops: 0,
            inflight_epoch: None,
            next_epoch: 0,
        })
    }

    pub fn kind(&self) -> SystemKind {
        self.kind
    }

    /// Run the trace to completion and return the outcome.
    pub fn run(mut self) -> SimOutcome {
        for i in 0..self.trace.requests.len() {
            let at = self.trace.requests[i].arrival;
            self.events.schedule(at, Event::Arrival(i));
        }
        while let Some((t, ev)) = self.events.next() {
            self.clock.advance_to(t);
            match ev {
                Event::Arrival(i) => self.on_arrival(i),
                Event::Stage { req, stage } => self.on_stage(req, stage),
                Event::EngineDone(epoch) => self.on_engine_done(epoch),
            }
            self.pump();
        }
        let completed =
            self.requests.iter().filter(|r| r.done).count();
        SimOutcome {
            recorder: self.recorder,
            tree_counters: self.tree.as_ref().map(|t| t.counters()),
            spec_started: self
                .requests
                .iter()
                .map(|r| r.spec.started)
                .sum(),
            spec_wasted: self.requests.iter().map(|r| r.spec.wasted).sum(),
            mean_sched_time: if self.sched_ops == 0 {
                0.0
            } else {
                self.sched_secs / self.sched_ops as f64
            },
            completed,
        }
    }

    fn now(&self) -> f64 {
        self.clock.now()
    }

    fn on_arrival(&mut self, i: usize) {
        let now = self.now();
        self.recorder.arrival(i as u64, now);
        let docs = self.trace.requests[i].docs.clone();
        let plan = if self.spec_enabled {
            StagedRetrieval::plan(
                &docs,
                self.num_docs,
                &self.timing,
                &mut self.rng,
            )
        } else {
            StagedRetrieval::single(&docs, &self.timing)
        };
        for (s, stage) in plan.stages.iter().enumerate() {
            self.events
                .schedule(now + stage.offset, Event::Stage { req: i, stage: s });
        }
        // Stash the plan's candidate docs on the request.
        self.requests[i].active_docs = Vec::new();
        self.requests[i].spec_plan = Some(plan);
    }

    fn on_stage(&mut self, req: usize, stage: usize) {
        let t0 = Instant::now();
        let now = self.now();
        let plan = self.requests[req]
            .spec_plan
            .as_ref()
            .expect("stage plan exists");
        let sp = plan.stages[stage].clone();
        let pool_len = self.engine.waiting_len() + self.queue.len();
        let action = self.requests[req].spec.on_stage(
            &sp.docs,
            pool_len,
            self.max_batch,
            sp.is_final,
        );
        match action {
            SpecAction::Start { terminate_prev } => {
                if terminate_prev {
                    self.abort_generation(req);
                }
                self.start_generation(req, &sp.docs);
            }
            SpecAction::Keep => {}
            SpecAction::Defer { terminate_prev } => {
                if terminate_prev {
                    self.abort_generation(req);
                }
            }
        }
        if sp.is_final {
            self.on_retrieval_final(req, now);
        }
        self.sched_secs += t0.elapsed().as_secs_f64();
        self.sched_ops += 1;
    }

    /// Final retrieval results are in: confirm or nothing (re-generation
    /// was already started by the Start action if docs changed).
    fn on_retrieval_final(&mut self, req: usize, now: f64) {
        let r = &mut self.requests[req];
        r.retrieval_done_at = Some(now);
        self.recorder.retrieval_done(req as u64, now);
        r.confirmed = true;
        // Deliver buffered speculative results.
        if let Some(ft) = r.spec_first_token_at {
            let deliver = ft.max(now);
            self.recorder.first_token(req as u64, deliver);
        }
        if let Some(fin) = r.spec_finished_at {
            let deliver = fin.max(now);
            self.recorder.finished(req as u64, deliver);
            self.recorder
                .output_tokens(req as u64, self.trace.requests[req].output_tokens);
            self.requests[req].done = true;
        }
        // Table 3 non-overlapping search time: the part of the retrieval
        // not hidden behind LLM-side work on the final-docs generation.
        let retrieval_time = self.timing.full_search_s;
        let overlap = self.requests[req]
            .final_enqueue_at
            .map(|t| (now - t).clamp(0.0, retrieval_time))
            .unwrap_or(0.0);
        self.recorder.non_overlapped_search(
            req as u64,
            retrieval_time - overlap,
        );
    }

    /// Abort the live generation of `req`, wherever it is. Sequences in
    /// the in-flight prefill iteration complete it (their KV is cached on
    /// the FirstToken that still fires); everything else is unpinned
    /// here.
    fn abort_generation(&mut self, req: usize) {
        let Some(seq) = self.requests[req].active_seq.take() else {
            return;
        };
        self.queue.remove(seq);
        match self.engine.abort(seq) {
            AbortOutcome::Deferred => {
                if self.engine.in_flight_fully_killed() {
                    // §5.3 batch-size-one case: nothing else shares the
                    // iteration, terminate immediately. Partial work is
                    // discarded (no KV cached).
                    for id in self.engine.cancel_in_flight() {
                        if let Some(info) = self.admit_infos.remove(&id) {
                            if let Some(tree) = self.tree.as_mut() {
                                tree.unpin(&info.path);
                            }
                        }
                    }
                    self.inflight_epoch = None;
                }
                // Otherwise FirstToken will arrive and handle unpin +
                // insertion (the KV is computed and cached).
            }
            AbortOutcome::Removed | AbortOutcome::NotFound => {
                if let Some(info) = self.admit_infos.remove(&seq) {
                    if let Some(tree) = self.tree.as_mut() {
                        tree.unpin(&info.path);
                    }
                }
            }
        }
        self.requests[req].spec_first_token_at = None;
        self.requests[req].spec_finished_at = None;
    }

    /// Create a generation for `docs` and enqueue it for admission.
    fn start_generation(&mut self, req: usize, docs: &[DocId]) {
        let now = self.now();
        let gen = self.requests[req].next_gen;
        self.requests[req].next_gen += 1;
        let seq = req as u64 * GEN_BASE + gen;
        // Cached/compute lengths for the reordering priority.
        let doc_tokens: usize =
            docs.iter().map(|&d| self.doc_tokens(req, d)).sum();
        let tr = &self.trace.requests[req];
        let (cached, compute) = match self.tree.as_ref() {
            None => (0, tr.prompt_tokens()),
            Some(tree) => {
                let m = tree.lookup(docs);
                (
                    m.cached_tokens,
                    doc_tokens.saturating_sub(m.cached_tokens)
                        + tr.request_tokens,
                )
            }
        };
        let arrival = tr.arrival;
        let is_final_docs = docs == tr.docs.as_slice();
        let r = &mut self.requests[req];
        r.active_seq = Some(seq);
        r.active_docs = docs.to_vec();
        if is_final_docs && r.final_enqueue_at.is_none() {
            r.final_enqueue_at = Some(now);
        }
        self.gen_docs.insert(seq, docs.to_vec());
        self.queue.push(PendingRequest {
            id: seq,
            arrival,
            cached_tokens: cached,
            compute_tokens: compute,
            bypassed: 0,
        });
    }

    /// Token count of `doc` for this request: trace value when the doc is
    /// one of the final docs, corpus-independent fallback otherwise
    /// (perturbed speculative candidates use the mean doc length).
    fn doc_tokens(&self, req: usize, doc: DocId) -> usize {
        let tr = &self.trace.requests[req];
        for (i, &d) in tr.docs.iter().enumerate() {
            if d == doc {
                return tr.doc_tokens[i];
            }
        }
        // Speculative candidate outside the final set.
        let sum: usize = tr.doc_tokens.iter().sum();
        (sum / tr.doc_tokens.len().max(1)).max(1)
    }

    /// Admit queued requests into free engine slots, then keep the engine
    /// running.
    fn pump(&mut self) {
        loop {
            let in_engine =
                self.engine.waiting_len() + self.engine.decoding_len();
            if in_engine >= self.max_batch || self.queue.is_empty() {
                break;
            }
            let t0 = Instant::now();
            let pending = self.queue.pop().unwrap();
            self.admit(pending);
            self.sched_secs += t0.elapsed().as_secs_f64();
            self.sched_ops += 1;
        }
        if self.inflight_epoch.is_none() {
            if let Some(plan) = self.engine.plan() {
                let epoch = self.next_epoch;
                self.next_epoch += 1;
                self.inflight_epoch = Some(epoch);
                self.events.schedule(
                    self.now() + plan.duration,
                    Event::EngineDone(epoch),
                );
            }
        }
    }

    fn admit(&mut self, pending: PendingRequest) {
        let req = (pending.id / GEN_BASE) as usize;
        let now = self.now();
        if self.requests[req].active_seq != Some(pending.id) {
            return; // stale generation
        }
        let tr = &self.trace.requests[req];
        let docs = self.gen_docs[&pending.id].clone();
        let doc_token_list: Vec<(DocId, usize)> = docs
            .iter()
            .map(|&d| (d, self.doc_tokens(req, d)))
            .collect();

        let mut alpha = 0usize;
        let mut extra_time = 0.0f64;
        let mut path = Vec::new();
        let mut matched = 0usize;
        if let Some(tree) = self.tree.as_mut() {
            let m = tree.lookup(&docs);
            // Try to bring host-resident prefix into GPU; on failure fall
            // back to the GPU-resident prefix only.
            let (use_path, transfers) = match tree.promote(&m.path) {
                Some(t) => (m.path.clone(), t),
                None => {
                    let gpu_prefix: Vec<NodeId> = m
                        .path
                        .iter()
                        .take_while(|&&n| {
                            tree.node_tier(n)
                                == Some(crate::kvcache::Tier::Gpu)
                        })
                        .cloned()
                        .collect();
                    (gpu_prefix, crate::tree::Transfers::default())
                }
            };
            matched = use_path.len();
            alpha = use_path
                .iter()
                .map(|&n| tree.node_tokens(n))
                .sum::<usize>();
            extra_time += self
                .transfer
                .transfer_time(transfers.h2g_bytes + transfers.g2h_bytes);
            tree.pin(&use_path);
            path = use_path;
        }
        let beta: usize = doc_token_list[matched..]
            .iter()
            .map(|&(_, t)| t)
            .sum::<usize>()
            + tr.request_tokens;
        let estimated_time = self.profile.estimate(alpha, beta);

        // Policy updates for the matched (hit) nodes.
        if let Some(tree) = self.tree.as_mut() {
            for &n in &path {
                let tokens = tree.node_tokens(n);
                tree.on_access(
                    n,
                    &AccessCtx {
                        alpha,
                        beta,
                        estimated_time,
                        was_cached: true,
                        now,
                        tokens,
                    },
                );
            }
        }

        // Metrics: hit accounting against the request's final docs.
        if docs == tr.docs.as_slice() {
            self.recorder.docs(req as u64, docs.len(), matched);
            self.recorder.tokens(req as u64, alpha, beta);
        }

        self.admit_infos.insert(
            pending.id,
            AdmitInfo {
                path,
                unmatched: doc_token_list[matched..].to_vec(),
                alpha,
                beta,
                estimated_time,
            },
        );
        self.engine.admit(SeqSpec {
            id: pending.id,
            alpha,
            beta,
            output_tokens: tr.output_tokens,
            extra_time,
        });
    }

    fn on_engine_done(&mut self, epoch: u64) {
        if self.inflight_epoch != Some(epoch) {
            return; // iteration was cancelled
        }
        self.inflight_epoch = None;
        let now = self.now();
        let events = self.engine.complete();
        for ev in events {
            match ev {
                SeqEvent::FirstToken { id } => self.on_first_token(id, now),
                SeqEvent::Finished { id } => self.on_finished(id, now),
            }
        }
    }

    fn on_first_token(&mut self, seq: u64, now: f64) {
        let req = (seq / GEN_BASE) as usize;
        // Insert newly computed doc KV into the tree and update stats —
        // even for terminated speculations: the prefill ran, the KV for
        // its document sequence is valid, and caching it is precisely
        // what makes restarted generations cheap (paper §4, Thm 5.1).
        if let Some(info) = self.admit_infos.remove(&seq) {
            if let Some(tree) = self.tree.as_mut() {
                tree.unpin(&info.path);
                let mut parent =
                    info.path.last().copied().unwrap_or(tree.root());
                for &(doc, tokens) in &info.unmatched {
                    match tree.insert_child(parent, doc, tokens, None) {
                        Some((id, _)) => {
                            tree.on_access(
                                id,
                                &AccessCtx {
                                    alpha: info.alpha,
                                    beta: info.beta,
                                    estimated_time: info.estimated_time,
                                    was_cached: false,
                                    now,
                                    tokens,
                                },
                            );
                            parent = id;
                        }
                        None => break, // does not fit: stays transient
                    }
                }
            }
        }
        if self.requests[req].active_seq != Some(seq) {
            return; // terminated speculation: cache filled, no delivery
        }
        let r = &mut self.requests[req];
        if r.confirmed && r.active_docs == self.trace.requests[req].docs {
            self.recorder.first_token(req as u64, now);
        } else {
            r.spec_first_token_at = Some(now);
        }
    }

    fn on_finished(&mut self, seq: u64, now: f64) {
        let req = (seq / GEN_BASE) as usize;
        if self.requests[req].active_seq != Some(seq) {
            return;
        }
        let out_tokens = self.trace.requests[req].output_tokens;
        let r = &mut self.requests[req];
        if r.confirmed && r.active_docs == self.trace.requests[req].docs {
            self.recorder.finished(req as u64, now);
            self.recorder.output_tokens(req as u64, out_tokens);
            self.requests[req].done = true;
        } else {
            r.spec_finished_at = Some(now);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::workload::{datasets::MMLU, Corpus, Trace};

    fn cfg_for(kind: &str) -> SystemConfig {
        let mut cfg = SystemConfig::default();
        cfg.kind = crate::config::SystemKindField(
            SystemKind::parse(kind).unwrap(),
        );
        // Paper-testbed cache shares (Mistral-7B docs average ~465 MiB of
        // KV each): GPU fits ~17 docs, host ~400.
        cfg.cache.gpu_bytes = 8 * (1 << 30);
        cfg.cache.host_bytes = 192 * (1 << 30);
        cfg
    }

    fn run_kind(kind: &str, rate: f64, n: usize) -> SimOutcome {
        let corpus = Corpus::wikipedia_like(2_000, 1);
        let trace = Trace::generate(&MMLU, &corpus, rate, n, 2, 11);
        let server = SimServer::build(
            &cfg_for(kind),
            trace,
            2_000,
            RetrievalTiming::default(),
            5,
        )
        .unwrap();
        server.run()
    }

    #[test]
    fn all_requests_complete_all_systems() {
        for kind in ["ragcache", "vllm", "sglang"] {
            let out = run_kind(kind, 0.3, 40);
            assert_eq!(out.completed, 40, "{kind}");
            assert_eq!(out.recorder.ttft().len(), 40, "{kind}");
        }
    }

    #[test]
    fn ragcache_beats_vllm_ttft() {
        // The headline (Fig. 13): document caching cuts mean TTFT.
        let rag = run_kind("ragcache", 0.5, 120);
        let vllm = run_kind("vllm", 0.5, 120);
        let t_rag = rag.recorder.ttft().mean();
        let t_vllm = vllm.recorder.ttft().mean();
        assert!(
            t_rag < t_vllm,
            "ragcache {t_rag} should beat vllm {t_vllm}"
        );
        assert!(rag.recorder.hit_rate() > 0.2, "hit rate materialises");
        assert_eq!(vllm.recorder.hit_rate(), 0.0);
    }

    #[test]
    fn ragcache_beats_sglang_under_memory_pressure() {
        let rag = run_kind("ragcache", 0.5, 120);
        let sglang = run_kind("sglang", 0.5, 120);
        let t_rag = rag.recorder.ttft().mean();
        let t_sg = sglang.recorder.ttft().mean();
        assert!(
            t_rag <= t_sg * 1.05,
            "ragcache {t_rag} vs sglang {t_sg}"
        );
        // SGLang's GPU-only cache yields a lower hit rate.
        assert!(
            rag.recorder.hit_rate() >= sglang.recorder.hit_rate(),
            "multilevel cache wins on hit rate"
        );
    }

    #[test]
    fn speculation_counters_populate() {
        let out = run_kind("ragcache", 0.2, 50);
        assert!(out.spec_started >= 50);
        // Baselines never speculate.
        let v = run_kind("vllm", 0.2, 20);
        assert_eq!(v.spec_wasted, 0);
    }

    #[test]
    fn sched_time_sub_millisecond() {
        // Table 4: controller decisions stay below 1 ms.
        let out = run_kind("ragcache", 0.5, 60);
        assert!(
            out.mean_sched_time < 1e-3,
            "mean sched {}",
            out.mean_sched_time
        );
    }

    #[test]
    fn tree_invariants_hold_after_run() {
        let corpus = Corpus::wikipedia_like(500, 2);
        let trace = Trace::generate(&MMLU, &corpus, 1.0, 80, 2, 13);
        let mut cfg = cfg_for("ragcache");
        cfg.cache.gpu_bytes = 128 * 1024 * 1024; // force heavy eviction
        cfg.cache.host_bytes = 512 * 1024 * 1024;
        let server = SimServer::build(
            &cfg,
            trace,
            500,
            RetrievalTiming::default(),
            7,
        )
        .unwrap();
        // run() consumes; re-build a server to inspect the tree. Instead:
        // rely on counters + completion as the observable signal here;
        // invariants themselves are property-tested in tree::tests.
        let out = server.run();
        assert_eq!(out.completed, 80);
        let c = out.tree_counters.unwrap();
        assert!(c.gpu_evictions > 0, "eviction exercised: {c:?}");
    }
}
