//! CAG-style per-tenant admission policy (cache-augmented generation).
//!
//! A tenant whose *entire* retrieval corpus fits a KV pin budget can skip
//! retrieval altogether: its corpus KV is pre-staged onto disk as pinned,
//! position-independent chunk entries at server build time and promoted
//! disk → host → GPU on first touch. That tenant runs in [`TenantMode::Cag`]
//! mode; requests from it carry no retrieval stage at all. Tenants that do
//! not fit start as [`TenantMode::ColdRag`] and graduate to
//! [`TenantMode::CachedRag`] once the shared cache has seen demand from
//! them (the first completed request) — the same demand signal the PR 5
//! rebalancer consumes.
//!
//! The policy is deliberately static-at-build: corpus sizes are known from
//! the workload metadata ([`crate::workload::TenantCorpus`]) and the pin
//! budget is a config knob, so admission is a deterministic greedy fit
//! (smallest corpora first, maximising the number of retrieval-free
//! tenants per pinned byte).

use std::collections::BTreeMap;

use crate::kvcache::PageSpec;
use crate::workload::TenantCorpus;

/// Serving mode assigned to a tenant by the CAG admission policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TenantMode {
    /// Corpus KV pinned; retrieval is skipped entirely.
    Cag,
    /// Normal RAG path, but the shared cache has seen this tenant's
    /// demand (at least one completed request).
    CachedRag,
    /// Normal RAG path, no demand observed yet.
    ColdRag,
}

impl TenantMode {
    /// Stable label used in reports and bench columns.
    pub fn as_str(self) -> &'static str {
        match self {
            TenantMode::Cag => "cag",
            TenantMode::CachedRag => "cached-rag",
            TenantMode::ColdRag => "cold-rag",
        }
    }

    /// Wire code for the stats protocol (`0 = cold, 1 = cached, 2 = cag`).
    pub fn code(self) -> u8 {
        match self {
            TenantMode::ColdRag => 0,
            TenantMode::CachedRag => 1,
            TenantMode::Cag => 2,
        }
    }

    /// Inverse of [`TenantMode::code`]; unknown codes map to `ColdRag`
    /// (forward-compatible: an old reader never invents a pinned tenant).
    pub fn from_code(code: u8) -> TenantMode {
        match code {
            2 => TenantMode::Cag,
            1 => TenantMode::CachedRag,
            _ => TenantMode::ColdRag,
        }
    }
}

/// Per-tenant admission decisions for one server instance.
///
/// Built once from workload metadata via [`CagPolicy::decide`]; afterwards
/// only [`CagPolicy::note_served`] mutates it (the cold → cached demand
/// flip). Tenants absent from the map are treated as `ColdRag`.
#[derive(Debug, Default)]
pub struct CagPolicy {
    modes: BTreeMap<u32, TenantMode>,
    /// Total KV bytes admitted under the pin budget, for reporting.
    pinned_bytes: u64,
}

impl CagPolicy {
    /// Greedily admit tenants to CAG mode in ascending corpus-KV-size
    /// order while their summed KV footprint fits `pin_budget` bytes.
    ///
    /// Smallest-first maximises the number of tenants that go
    /// retrieval-free for a given budget. A tenant with an empty corpus
    /// is never admitted (there is nothing to pin — it would report CAG
    /// mode while still needing retrieval for correctness of accounting).
    pub fn decide(corpora: &[TenantCorpus], page: PageSpec, pin_budget: u64) -> CagPolicy {
        let mut sized: Vec<(u64, &TenantCorpus)> =
            corpora.iter().map(|c| (c.kv_bytes(page), c)).collect();
        // Stable sort: ties broken by tenant id via the original
        // (ascending-tenant) order of `corpora`.
        sized.sort_by_key(|(bytes, _)| *bytes);

        let mut policy = CagPolicy::default();
        let mut remaining = pin_budget;
        for (bytes, corpus) in sized {
            let fits = bytes > 0 && bytes <= remaining;
            let mode = if fits {
                remaining -= bytes;
                policy.pinned_bytes += bytes;
                TenantMode::Cag
            } else {
                TenantMode::ColdRag
            };
            policy.modes.insert(corpus.tenant, mode);
        }
        policy
    }

    /// A policy that admits nobody (CAG off). Every tenant reports
    /// `ColdRag` until demand flips it.
    pub fn disabled(corpora: &[TenantCorpus]) -> CagPolicy {
        let mut policy = CagPolicy::default();
        for corpus in corpora {
            policy.modes.insert(corpus.tenant, TenantMode::ColdRag);
        }
        policy
    }

    /// Current mode of `tenant` (`ColdRag` if unknown).
    pub fn mode(&self, tenant: u32) -> TenantMode {
        self.modes
            .get(&tenant)
            .copied()
            .unwrap_or(TenantMode::ColdRag)
    }

    /// Whether `tenant` runs retrieval-free.
    pub fn is_cag(&self, tenant: u32) -> bool {
        self.mode(tenant) == TenantMode::Cag
    }

    /// Demand signal: a request from `tenant` completed. Flips
    /// `ColdRag → CachedRag`; `Cag` tenants are unaffected.
    pub fn note_served(&mut self, tenant: u32) {
        let entry = self.modes.entry(tenant).or_insert(TenantMode::ColdRag);
        if *entry == TenantMode::ColdRag {
            *entry = TenantMode::CachedRag;
        }
    }

    /// Total KV bytes admitted under the pin budget.
    pub fn pinned_bytes(&self) -> u64 {
        self.pinned_bytes
    }

    /// Number of tenants admitted to CAG mode.
    pub fn cag_tenants(&self) -> usize {
        self.modes
            .values()
            .filter(|m| **m == TenantMode::Cag)
            .count()
    }

    /// All known tenants with their current modes, ascending tenant id.
    pub fn modes(&self) -> impl Iterator<Item = (u32, TenantMode)> + '_ {
        self.modes.iter().map(|(t, m)| (*t, *m))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn page() -> PageSpec {
        PageSpec {
            block_tokens: 8,
            kv_bytes_per_token: 16,
        }
    }

    fn corpus(tenant: u32, doc_tokens: Vec<usize>) -> TenantCorpus {
        TenantCorpus {
            tenant,
            doc_base: 0,
            doc_tokens,
        }
    }

    #[test]
    fn smallest_corpora_admitted_first() {
        // kv_bytes = sum over docs of page-rounded token bytes.
        // tenant 0: 64 tokens -> 1024 B; tenant 1: 16 tokens -> 256 B;
        // tenant 2: 32 tokens -> 512 B.
        let corpora = vec![
            corpus(0, vec![64]),
            corpus(1, vec![16]),
            corpus(2, vec![32]),
        ];
        let policy = CagPolicy::decide(&corpora, page(), 800);
        // Budget 800: tenant 1 (256) fits, then tenant 2 (512, total 768)
        // fits; tenant 0 (1024) does not.
        assert_eq!(policy.mode(1), TenantMode::Cag);
        assert_eq!(policy.mode(2), TenantMode::Cag);
        assert_eq!(policy.mode(0), TenantMode::ColdRag);
        assert_eq!(policy.pinned_bytes(), 768);
        assert_eq!(policy.cag_tenants(), 2);
    }

    #[test]
    fn empty_corpus_never_admitted() {
        let corpora = vec![corpus(0, vec![])];
        let policy = CagPolicy::decide(&corpora, page(), u64::MAX);
        assert_eq!(policy.mode(0), TenantMode::ColdRag);
        assert_eq!(policy.pinned_bytes(), 0);
    }

    #[test]
    fn demand_flips_cold_to_cached_but_not_cag() {
        let corpora = vec![corpus(0, vec![16]), corpus(1, vec![16])];
        let mut policy = CagPolicy::decide(&corpora, page(), 256);
        assert_eq!(policy.mode(0), TenantMode::Cag);
        assert_eq!(policy.mode(1), TenantMode::ColdRag);
        policy.note_served(0);
        policy.note_served(1);
        assert_eq!(policy.mode(0), TenantMode::Cag);
        assert_eq!(policy.mode(1), TenantMode::CachedRag);
        // Unknown tenants materialise as cached once served.
        policy.note_served(7);
        assert_eq!(policy.mode(7), TenantMode::CachedRag);
    }

    #[test]
    fn wire_codes_roundtrip() {
        for mode in [TenantMode::Cag, TenantMode::CachedRag, TenantMode::ColdRag] {
            assert_eq!(TenantMode::from_code(mode.code()), mode);
        }
        assert_eq!(TenantMode::from_code(99), TenantMode::ColdRag);
    }

    #[test]
    fn disabled_policy_admits_nobody() {
        let corpora = vec![corpus(0, vec![16])];
        let policy = CagPolicy::disabled(&corpora);
        assert_eq!(policy.mode(0), TenantMode::ColdRag);
        assert!(!policy.is_cag(0));
    }
}
