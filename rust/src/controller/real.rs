//! Real-mode server: the same knowledge-tree / policy / scheduling stack
//! driven in real time with *actual* computation — retrieval through the
//! Rust vector index, prefill/decode through the PJRT-compiled JAX+Pallas
//! artifacts, and real KV payloads cached in the tree.
//!
//! This is the end-to-end proof that all three layers compose; the
//! paper-scale experiments use the virtual-clock [`super::sim_server`].

use crate::embed::EmbeddingModel;
use crate::kvcache::{KvPayload, PageSpec};
use crate::llm::tokenizer::SEP;
use crate::metrics::Recorder;
use crate::policy::{make_policy, AccessCtx};
use crate::runtime::PjrtModel;
use crate::sim::{Clock, RealClock};
use crate::tree::KnowledgeTree;
use crate::util::Rng;
use crate::vectordb::VectorIndex;
use anyhow::{Context, Result};

/// Real-mode server configuration.
#[derive(Debug, Clone)]
pub struct RealConfig {
    pub top_k: usize,
    /// Logical GPU-tier budget for the document cache, bytes.
    pub gpu_cache_bytes: u64,
    pub host_cache_bytes: u64,
    pub block_tokens: usize,
    pub policy: crate::config::PolicyKind,
    /// Prefill chunk size (must fit the largest compiled beta bucket).
    pub chunk: usize,
    /// Query-embedding noise (0 = queries hit their target exactly).
    pub query_noise: f64,
}

impl Default for RealConfig {
    fn default() -> Self {
        RealConfig {
            top_k: 2,
            gpu_cache_bytes: 4 * 1024 * 1024,
            host_cache_bytes: 32 * 1024 * 1024,
            block_tokens: 16,
            policy: crate::config::PolicyKind::Pgdsf,
            chunk: 64,
            query_noise: 0.02,
        }
    }
}

/// Response of one served request.
#[derive(Debug, Clone)]
pub struct RealResponse {
    pub id: u64,
    pub docs: Vec<u32>,
    pub cached_tokens: usize,
    pub computed_tokens: usize,
    pub docs_hit: usize,
    /// Wall-clock time to first token, seconds.
    pub ttft: f64,
    pub total: f64,
    pub output_tokens: Vec<i32>,
}

/// The real-mode serving stack.
pub struct RealServer {
    model: PjrtModel,
    tree: KnowledgeTree,
    index: Box<dyn VectorIndex>,
    em: EmbeddingModel,
    /// Token ids of each knowledge document.
    doc_tokens: Vec<Vec<i32>>,
    clock: RealClock,
    recorder: Recorder,
    rng: Rng,
    next_id: u64,
}

impl RealServer {
    pub fn new(
        model: PjrtModel,
        index: Box<dyn VectorIndex>,
        em: EmbeddingModel,
        doc_tokens: Vec<Vec<i32>>,
        cfg: &RealConfig,
    ) -> Result<Self> {
        let kv_bytes =
            model.manifest().arch.kv_floats_per_token() * 4;
        let page = PageSpec {
            block_tokens: cfg.block_tokens,
            kv_bytes_per_token: kv_bytes,
        };
        let tree = KnowledgeTree::new(
            cfg.gpu_cache_bytes,
            cfg.host_cache_bytes,
            page,
            make_policy(cfg.policy),
            true,
            0,
        );
        Ok(RealServer {
            model,
            tree,
            index,
            em,
            doc_tokens,
            clock: RealClock::new(),
            recorder: Recorder::new(),
            rng: Rng::new(0xE2E),
            next_id: 0,
        })
    }

    pub fn recorder(&self) -> &Recorder {
        &self.recorder
    }

    pub fn tree(&self) -> &KnowledgeTree {
        &self.tree
    }

    /// Mutable tree access for administration and failure injection.
    pub fn tree_mut(&mut self) -> &mut KnowledgeTree {
        &mut self.tree
    }

    /// Chunked prefill through the compiled buckets: feeds `tokens` on
    /// top of `prefix_kv` in chunks, returning the final logits and all
    /// new KV rows.
    fn chunked_prefill(
        &self,
        prefix_kv: &mut Vec<f32>,
        tokens: &[i32],
        chunk: usize,
    ) -> Result<Vec<f32>> {
        let mut last_logits = Vec::new();
        let mut new_rows = Vec::new();
        for piece in tokens.chunks(chunk.max(1)) {
            let out = self
                .model
                .prefill(prefix_kv, piece)
                .context("chunked prefill")?;
            prefix_kv.extend_from_slice(&out.new_kv);
            new_rows.extend_from_slice(&out.new_kv);
            last_logits = out.last_logits;
        }
        debug_assert!(!last_logits.is_empty());
        // new_rows are returned via prefix_kv growth; keep logits.
        let _ = new_rows;
        Ok(last_logits)
    }

    /// Serve one request: retrieve, reuse cached document KV, prefill the
    /// rest, decode `max_new` tokens greedily.
    pub fn serve(
        &mut self,
        target_doc: u32,
        query_tokens: &[i32],
        max_new: usize,
        cfg: &RealConfig,
    ) -> Result<RealResponse> {
        let id = self.next_id;
        self.next_id += 1;
        let t_arrive = self.clock.now();
        self.recorder.arrival(id, t_arrive);

        // Retrieval (Rust vector index — real search).
        let q = self.em.query(target_doc, cfg.query_noise, &mut self.rng);
        let hits = self.index.search(&q, cfg.top_k);
        let docs: Vec<u32> = hits.iter().map(|h| h.1).collect();
        self.recorder.retrieval_done(id, self.clock.now());

        // Cache lookup + prefix assembly.
        let m = self.tree.lookup(&docs);
        self.tree.pin(&m.path);
        let payloads: Vec<&KvPayload> = m
            .path
            .iter()
            .filter_map(|&n| self.tree.node_payload(n))
            .collect();
        debug_assert_eq!(payloads.len(), m.path.len());
        let mut kv = KvPayload::concat(&payloads);
        let promote = self.tree.promote(&m.path);
        debug_assert!(promote.is_some());

        // Non-cached documents + separator + question.
        let unmatched: Vec<u32> = docs[m.matched_docs..].to_vec();
        let mut new_tokens: Vec<i32> = Vec::new();
        let mut doc_lens = Vec::new();
        for &d in &unmatched {
            let toks = &self.doc_tokens[d as usize];
            new_tokens.extend_from_slice(toks);
            doc_lens.push(toks.len());
        }
        let doc_token_total: usize = doc_lens.iter().sum();
        new_tokens.push(SEP);
        new_tokens.extend_from_slice(query_tokens);

        let kv_per_tok =
            self.model.manifest().arch.kv_floats_per_token();
        let kv_before = kv.len();
        let t_prefill0 = self.clock.now();
        let logits =
            self.chunked_prefill(&mut kv, &new_tokens, cfg.chunk)?;
        let t_first = self.clock.now();
        self.recorder.first_token(id, t_first);
        let prefill_secs = t_first - t_prefill0;

        // Cache the newly computed document KV (rows precede SEP+query).
        let new_kv = &kv[kv_before..];
        let doc_rows = &new_kv[..doc_token_total * kv_per_tok];
        let split = if doc_lens.is_empty() {
            Vec::new()
        } else {
            KvPayload::split(doc_rows, &doc_lens)
        };
        self.tree.unpin(&m.path);
        let beta = new_tokens.len();
        let ctx_tmpl = AccessCtx {
            alpha: m.cached_tokens,
            beta,
            estimated_time: prefill_secs,
            was_cached: false,
            now: t_first,
            tokens: 0,
        };
        for &n in &m.path {
            let tokens = self.tree.node_tokens(n);
            self.tree.on_access(
                n,
                &AccessCtx {
                    was_cached: true,
                    tokens,
                    ..ctx_tmpl
                },
            );
        }
        let mut parent = m.path.last().copied().unwrap_or(self.tree.root());
        for (i, payload) in split.into_iter().enumerate() {
            let doc = unmatched[i];
            let tokens = payload.tokens();
            match self.tree.insert_child(parent, doc, tokens, Some(payload))
            {
                Some((node, _)) => {
                    self.tree.on_access(
                        node,
                        &AccessCtx {
                            tokens,
                            ..ctx_tmpl
                        },
                    );
                    parent = node;
                }
                None => break,
            }
        }

        // Greedy decode.
        let mut out_tokens = vec![argmax(&logits) as i32];
        for _ in 1..max_new {
            let last = *out_tokens.last().unwrap();
            let step = self.model.prefill(&kv, &[last])?;
            kv.extend_from_slice(&step.new_kv);
            out_tokens.push(argmax(&step.last_logits) as i32);
        }
        let t_done = self.clock.now();
        self.recorder.finished(id, t_done);
        self.recorder.docs(id, docs.len(), m.matched_docs);
        self.recorder.tokens(id, m.cached_tokens, beta);

        Ok(RealResponse {
            id,
            docs,
            cached_tokens: m.cached_tokens,
            computed_tokens: beta,
            docs_hit: m.matched_docs,
            ttft: t_first - t_arrive,
            total: t_done - t_arrive,
            output_tokens: out_tokens,
        })
    }
}

/// Result of an iterative-retrieval session (paper §9: "RAGCache supports
/// iterative retrieval by treating the intermediate iterations as
/// separate requests and caching the corresponding KV cache of the
/// documents").
#[derive(Debug, Clone)]
pub struct IterativeResponse {
    pub rounds: Vec<RealResponse>,
}

impl IterativeResponse {
    pub fn total_docs_hit(&self) -> usize {
        self.rounds.iter().map(|r| r.docs_hit).sum()
    }

    pub fn total_docs(&self) -> usize {
        self.rounds.iter().map(|r| r.docs.len()).sum()
    }
}

impl RealServer {
    /// Iterative retrieval: run `targets.len()` retrieve→generate rounds,
    /// feeding each round's output tokens into the next round's query.
    /// Each round is a normal [`RealServer::serve`] request, so document
    /// KV computed in earlier rounds is reusable by later ones.
    pub fn serve_iterative(
        &mut self,
        targets: &[u32],
        initial_query: &[i32],
        max_new_per_round: usize,
        cfg: &RealConfig,
    ) -> Result<IterativeResponse> {
        let mut rounds = Vec::with_capacity(targets.len());
        let mut query = initial_query.to_vec();
        for &target in targets {
            let resp =
                self.serve(target, &query, max_new_per_round, cfg)?;
            // Next round's query: the original question refined by the
            // intermediate generation (clamped to vocab byte range).
            query = initial_query.to_vec();
            query.extend(
                resp.output_tokens.iter().map(|&t| t.clamp(0, 255)),
            );
            rounds.push(resp);
        }
        Ok(IterativeResponse { rounds })
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}
