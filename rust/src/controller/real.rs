//! Real-mode server: the same knowledge-tree / policy / scheduling stack
//! driven in real time with *actual* computation — retrieval through the
//! Rust vector index, prefill/decode through the PJRT-compiled JAX+Pallas
//! artifacts, and real KV payloads cached in the tree.
//!
//! This is the *real driver* over the shared [`pipeline`](super::pipeline)
//! core: admission (match → promote → pin → α/β), policy refresh and
//! post-prefill insertion are the exact code the simulated controller
//! runs; this file contributes wall-clock timing, real vector search and
//! PJRT execution. It is the end-to-end proof that all three layers
//! compose; the paper-scale experiments use the virtual-clock
//! [`super::sim_server`].

use super::batch::BatchAdmission;
use super::cag::CagPolicy;
use super::pipeline::{Admission, Pipeline, PipelineDriver, ShedLadder};
use super::retrieval_service::{
    RetrievalConfig, RetrievalService, RetrievalTask, StageReady,
};
use super::session::{FinishPath, SessionTable, SpecTotals, SpecWork};
use super::shard::{split_budget, ShardedCacheService};
use crate::embed::EmbeddingModel;
use crate::kvcache::{KvPayload, PageSpec};
use crate::llm::tokenizer::{ByteTokenizer, SEP};
use crate::metrics::Recorder;
use crate::policy::make_policy;
use crate::runtime::PjrtModel;
use crate::sim::{Clock, RealClock};
use crate::tree::{KnowledgeTree, Transfers};
use crate::util::Rng;
use crate::vectordb::VectorIndex;
use crate::workload::TenantCorpus;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

/// Real-mode server configuration.
#[derive(Debug, Clone)]
pub struct RealConfig {
    pub top_k: usize,
    /// Logical GPU-tier budget for the document cache, bytes.
    pub gpu_cache_bytes: u64,
    pub host_cache_bytes: u64,
    pub block_tokens: usize,
    pub policy: crate::config::PolicyKind,
    /// Prefill chunk size (must fit the largest compiled beta bucket).
    pub chunk: usize,
    /// Query-embedding noise (0 = queries hit their target exactly).
    pub query_noise: f64,
    /// Dynamic speculative pipelining (§5.3) on the real path: retrieve
    /// on the staged thread pool and overlap speculative prefills with
    /// the search. `false` serves through the blocking PR 3 batched
    /// path, bit for bit.
    pub speculate: bool,
    /// Stages per staged search (`--stages`).
    pub stages: usize,
    /// Retrieval thread-pool size (`--retrieval-threads`).
    pub retrieval_threads: usize,
    /// Wall-clock pacing per retrieval stage, seconds — stands in for
    /// the per-stage latency of a billion-scale index (see
    /// [`RetrievalService`]'s module docs).
    pub stage_latency_s: f64,
    /// Algorithm 2's `max_prefill_bs`: concurrent speculative prefills
    /// the engine tolerates.
    pub spec_pool: usize,
    /// Chunk-level position-independent KV reuse beside the prefix
    /// tree (`--chunk-cache on`). Off serves the PR 5 path bit for bit.
    pub chunk_cache: bool,
    /// Boundary tokens `r` re-prefilled per chunk hit (the first `r`
    /// tokens of the hit document; `--boundary-tokens`).
    pub boundary_tokens: usize,
    /// SLO admission control on the real path (`--shed on`): the
    /// Normal → Downgrade → Shed ladder over wall-clock queueing delay
    /// ([`ShedLadder`]). Off serves the PR 7 path bit for bit.
    pub shed: bool,
    /// TTFT SLO, seconds (`--ttft-slo`): requests queued past it are
    /// shed, and it anchors the goodput/attainment report.
    pub ttft_slo_s: f64,
    /// Downgrade threshold as a fraction of the SLO: new admissions run
    /// single-stage (no speculation) while the queue-delay EWMA exceeds
    /// `downgrade_frac × ttft_slo_s`.
    pub downgrade_frac: f64,
    /// NVMe-backed third cache tier (`--disk on`): host evictions
    /// demote to disk as async staged writes (drained by a background
    /// flusher thread), disk-resident prefixes restage back on hit.
    /// Off serves the two-tier PR 8 path bit for bit.
    pub disk: bool,
    /// Logical disk-tier budget, bytes (split across shards like the
    /// GPU/host budgets).
    pub disk_cache_bytes: u64,
    /// CAG-style per-tenant corpus pinning (`--cag auto`): tenants
    /// whose whole corpus KV fits `cag_pin_bytes` get the corpus
    /// precomputed and pinned at [`RealServer::enable_cag`] time and
    /// skip retrieval entirely. Requires `chunk_cache` (the pins are
    /// position-independent chunk entries).
    pub cag: bool,
    /// Total pin budget shared by all CAG-admitted tenants, bytes.
    pub cag_pin_bytes: u64,
}

impl Default for RealConfig {
    fn default() -> Self {
        RealConfig {
            top_k: 2,
            gpu_cache_bytes: 4 * 1024 * 1024,
            host_cache_bytes: 32 * 1024 * 1024,
            block_tokens: 16,
            policy: crate::config::PolicyKind::Pgdsf,
            chunk: 64,
            query_noise: 0.02,
            speculate: false,
            stages: 4,
            retrieval_threads: 2,
            stage_latency_s: 0.002,
            spec_pool: 4,
            chunk_cache: false,
            boundary_tokens: 8,
            shed: false,
            ttft_slo_s: 5.0,
            downgrade_frac: 0.5,
            disk: false,
            disk_cache_bytes: 64 * 1024 * 1024,
            cag: false,
            cag_pin_bytes: 4 * 1024 * 1024,
        }
    }
}

/// Aggregate serving metrics, cheap enough for per-poll computation.
#[derive(Debug, Clone, Copy)]
pub struct ServingStats {
    pub requests: usize,
    pub mean_ttft_s: f64,
    pub hit_rate: f64,
    /// Speculation counters (zero when `speculate` is off).
    pub spec: SpecTotals,
    /// Whether the SLO ladder ran (`--shed on`); when false, the SLO
    /// fields below are "not measured", never "0% attained".
    pub slo_enabled: bool,
    /// Requests finished within the TTFT SLO per second of trace
    /// horizon (0 with the ladder off).
    pub goodput_rps: f64,
    /// p99.9 TTFT over served requests, seconds (a pure measurement —
    /// reported with the ladder off too).
    pub ttft_p999_s: f64,
    /// Requests shed by admission control.
    pub shed_requests: u64,
    /// Admissions downgraded (single-stage retrieval, no speculation).
    pub downgraded_requests: u64,
    /// Fraction of requests meeting the TTFT SLO (0 with the ladder
    /// off).
    pub slo_attainment: f64,
}

/// One member of a batched serve call ([`RealServer::serve_batch`]).
#[derive(Debug, Clone)]
pub struct BatchRequest {
    pub target_doc: u32,
    pub query_tokens: Vec<i32>,
    pub max_new: usize,
}

/// Response of one served request.
#[derive(Debug, Clone)]
pub struct RealResponse {
    pub id: u64,
    pub docs: Vec<u32>,
    pub cached_tokens: usize,
    pub computed_tokens: usize,
    pub docs_hit: usize,
    /// Wall-clock time to first token, seconds.
    pub ttft: f64,
    pub total: f64,
    pub output_tokens: Vec<i32>,
}

impl RealResponse {
    /// Wire-protocol form of this response (`tok` decodes the output
    /// tokens into the reply text) — the one conversion every TCP
    /// handler shares, so the field mapping cannot drift between them.
    pub fn into_query_result(
        self,
        tok: &ByteTokenizer,
    ) -> crate::server::proto::QueryResult {
        crate::server::proto::QueryResult {
            id: self.id,
            docs_hit: self.docs_hit,
            cached_tokens: self.cached_tokens,
            computed_tokens: self.computed_tokens,
            ttft_ms: self.ttft * 1e3,
            total_ms: self.total * 1e3,
            text: tok.decode(&self.output_tokens),
            docs: self.docs,
        }
    }
}

/// The real-mode [`PipelineDriver`]: wall clock; GPU↔host "transfers" are
/// in-process copies whose cost is already part of measured latency.
struct RealDriver {
    clock: RealClock,
}

impl PipelineDriver for RealDriver {
    fn now(&self) -> f64 {
        self.clock.now()
    }

    fn transfer_time(&self, _bytes: u64) -> f64 {
        0.0
    }
}

/// Output of one chunked prefill: the grown KV buffer, where the new
/// rows start, the final logits and the measured seconds.
struct PrefillOut {
    kv: Vec<f32>,
    kv_before: usize,
    logits: Vec<f32>,
    prefill_secs: f64,
}

/// The speculative prefill artifact carried by a live session: the
/// pinned (uncommitted) admission plus everything the promotion needs
/// to deliver without recomputing.
struct SpecArtifact {
    adm: Admission,
    out: PrefillOut,
}

/// Per-session request context while retrieval is in flight.
struct SpecPending {
    query_tokens: Vec<i32>,
    max_new: usize,
    t_arrive: f64,
}

/// Background drain of the disk tier's async staging queue (`--disk
/// on`): host→disk spills enqueue under the shard lock with their
/// budget already charged; this thread serializes the queued payloads
/// into the slotted backing store off the serving path, so an eviction
/// sweep never waits on an NVMe write. The cache handle is a shared
/// `Arc` clone, so the flusher sees exactly the shards the server
/// serves from. Dropped (stopped + joined, with a final drain) with
/// the server.
struct StagingFlusher {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl StagingFlusher {
    fn spawn(cache: ShardedCacheService) -> Self {
        let stop = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("disk-staging".into())
            .spawn(move || {
                while !flag.load(Ordering::Relaxed) {
                    if cache.flush_disk_staging() == 0 {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                }
                cache.flush_disk_staging(); // final drain
            })
            .expect("spawn disk staging thread");
        StagingFlusher {
            stop,
            handle: Some(handle),
        }
    }
}

impl Drop for StagingFlusher {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The session-serving runtime: retrieval pool, stage-event channel and
/// the lifecycle table. Created lazily on the first speculative call.
struct SpecRuntime {
    service: RetrievalService,
    events: mpsc::Receiver<StageReady>,
    table: SessionTable<SpecArtifact>,
    pending: HashMap<u64, SpecPending>,
    /// Sessions that died at submit time (retrieval pool refused the
    /// task); drained into the next `poll_sessions` answer so no waiter
    /// ever hangs on a session that cannot produce stage events.
    dead_on_submit: Vec<u64>,
}

/// The real-mode serving stack.
pub struct RealServer {
    model: PjrtModel,
    pipeline: Pipeline,
    driver: RealDriver,
    index: Arc<dyn VectorIndex>,
    em: EmbeddingModel,
    /// Token ids of each knowledge document.
    doc_tokens: Vec<Vec<i32>>,
    rng: Rng,
    next_id: u64,
    /// Session runtime for the speculative (event-driven) path.
    spec: Option<SpecRuntime>,
    /// Wall-clock admission-control ladder (`--shed on`); inert when
    /// the config never enabled it, keeping the off path bit-identical.
    ladder: ShedLadder,
    /// Background staging-queue drain (`--disk on`); `None` keeps the
    /// two-tier path thread-free and bit-identical.
    staging: Option<StagingFlusher>,
    /// CAG per-tenant admission policy (`--cag auto`), armed by
    /// [`RealServer::enable_cag`].
    cag: Option<CagPolicy>,
    /// Document → owning tenant, derived from the corpus layout at
    /// `enable_cag` time; drives per-tenant recording and the CAG
    /// retrieval bypass.
    doc_tenants: Option<Vec<u32>>,
}

impl RealServer {
    /// The page spec this server would size its cache with — exposed so
    /// callers can pre-build a shared [`ShardedCacheService`] (e.g. for
    /// the concurrent runtime's priority estimator) before the
    /// non-`Send` PJRT model exists.
    pub fn page_spec(
        kv_floats_per_token: usize,
        cfg: &RealConfig,
    ) -> PageSpec {
        PageSpec {
            block_tokens: cfg.block_tokens,
            kv_bytes_per_token: kv_floats_per_token * 4,
        }
    }

    /// Build the knowledge tree this server would construct itself.
    pub fn build_tree(
        kv_floats_per_token: usize,
        cfg: &RealConfig,
    ) -> KnowledgeTree {
        let mut tree = KnowledgeTree::new(
            cfg.gpu_cache_bytes,
            cfg.host_cache_bytes,
            Self::page_spec(kv_floats_per_token, cfg),
            make_policy(cfg.policy),
            true,
            0,
        );
        if cfg.chunk_cache {
            tree.enable_chunk_cache(cfg.boundary_tokens);
        }
        if cfg.disk && cfg.disk_cache_bytes > 0 {
            tree.enable_disk_tier(cfg.disk_cache_bytes);
        }
        tree
    }

    /// Build a K-shard cache service for this model, splitting the
    /// configured tier budgets across shards so the slices sum to the
    /// configured bytes EXACTLY (a truncating `budget / K` silently
    /// dropped up to K−1 bytes — up to a whole page of cache — per
    /// tier). Shared between the M engine replicas of a concurrent
    /// deployment (each shard has its own lock, so replicas admit in
    /// parallel).
    pub fn build_sharded_cache(
        kv_floats_per_token: usize,
        cfg: &RealConfig,
        shards: usize,
    ) -> ShardedCacheService {
        let k = shards.max(1);
        let page = Self::page_spec(kv_floats_per_token, cfg);
        let gpu_slices = split_budget(cfg.gpu_cache_bytes, k);
        let host_slices = split_budget(cfg.host_cache_bytes, k);
        let disk_slices = if cfg.disk {
            split_budget(cfg.disk_cache_bytes, k)
        } else {
            vec![0; k]
        };
        ShardedCacheService::build(k, |i| {
            let mut tree = KnowledgeTree::new(
                gpu_slices[i],
                host_slices[i],
                page,
                make_policy(cfg.policy),
                true,
                0,
            );
            if cfg.chunk_cache {
                tree.enable_chunk_cache(cfg.boundary_tokens);
            }
            if disk_slices[i] > 0 {
                tree.enable_disk_tier(disk_slices[i]);
            }
            tree
        })
    }

    pub fn new(
        model: PjrtModel,
        index: Box<dyn VectorIndex>,
        em: EmbeddingModel,
        doc_tokens: Vec<Vec<i32>>,
        cfg: &RealConfig,
    ) -> Result<Self> {
        let kv = model.manifest().arch.kv_floats_per_token();
        let cache =
            ShardedCacheService::single(Self::build_tree(kv, cfg));
        Self::with_cache(model, index, em, doc_tokens, cache)
    }

    /// Assemble the stack around a pre-built, possibly shared cache
    /// service (its trees must have been sized with
    /// [`RealServer::page_spec`] for this model).
    pub fn with_cache(
        model: PjrtModel,
        index: Box<dyn VectorIndex>,
        em: EmbeddingModel,
        doc_tokens: Vec<Vec<i32>>,
        cache: ShardedCacheService,
    ) -> Result<Self> {
        let staging = cache
            .disk_enabled()
            .then(|| StagingFlusher::spawn(cache.clone()));
        Ok(RealServer {
            model,
            // Real-mode request ordering happens in the concurrent TCP
            // runtime's SharedReorderQueue (crate::server), not here:
            // this pipeline's own queue is unused, so it stays FIFO.
            pipeline: Pipeline::new(Some(cache), false, 1),
            driver: RealDriver {
                clock: RealClock::new(),
            },
            index: Arc::from(index),
            em,
            doc_tokens,
            rng: Rng::new(0xE2E),
            next_id: 0,
            spec: None,
            ladder: ShedLadder::disabled(),
            staging,
            cag: None,
            doc_tenants: None,
        })
    }

    /// Arm CAG-style corpus pinning (`--cag auto`): tenants whose whole
    /// corpus KV fits `cfg.cag_pin_bytes` (smallest corpus first) have
    /// every corpus document's KV computed NOW — real rows through the
    /// compiled prefill, each document at RoPE offset 0, which is what
    /// makes the pins position-independent chunk entries — and parked
    /// as pinned disk entries (owned chunk entries with the disk off).
    /// Startup staging is deliberately outside the serving clock: no
    /// request is in flight yet, mirroring the sim's uncharged
    /// build-time prestage. Tenants that do not fit run cold-/cached-
    /// RAG per the demand signal; every served request records its
    /// tenant so the stats endpoint can break SLOs down per tenant.
    pub fn enable_cag(
        &mut self,
        corpora: &[TenantCorpus],
        cfg: &RealConfig,
    ) -> Result<()> {
        let kv = self.model.manifest().arch.kv_floats_per_token();
        let page = Self::page_spec(kv, cfg);
        let policy = CagPolicy::decide(corpora, page, cfg.cag_pin_bytes);
        let mut doc_tenants = vec![0u32; self.doc_tokens.len()];
        for c in corpora {
            for i in 0..c.doc_tokens.len() {
                let d = c.doc_base as usize + i;
                if let Some(slot) = doc_tenants.get_mut(d) {
                    *slot = c.tenant;
                }
            }
        }
        for c in corpora {
            if !policy.is_cag(c.tenant) {
                continue;
            }
            for i in 0..c.doc_tokens.len() {
                let doc = c.doc_base + i as u32;
                let tokens = &self.doc_tokens[doc as usize];
                if tokens.is_empty() {
                    continue;
                }
                let mut rows = Vec::new();
                self.chunked_prefill(&mut rows, tokens, cfg.chunk)
                    .with_context(|| {
                        format!("CAG prestage of doc {doc}")
                    })?;
                self.cache().prestage_corpus_doc(
                    doc,
                    tokens.len(),
                    0,
                    Some(KvPayload::new(rows, tokens.len())),
                );
            }
        }
        self.cache().flush_disk_staging();
        self.cag = Some(policy);
        self.doc_tenants = Some(doc_tenants);
        Ok(())
    }

    /// The armed CAG policy (None until
    /// [`enable_cag`](RealServer::enable_cag) runs).
    pub fn cag_policy(&self) -> Option<&CagPolicy> {
        self.cag.as_ref()
    }

    /// Arm the ladder on the first call that carries a shedding config
    /// (the timed serving entry points and `poll_sessions` all pass
    /// through here). A `--shed off` config leaves it inert.
    fn ensure_ladder(&mut self, cfg: &RealConfig) {
        if cfg.shed && !self.ladder.enabled() {
            self.ladder = ShedLadder::new(
                true,
                cfg.ttft_slo_s,
                cfg.downgrade_frac,
            );
        }
    }

    /// Snapshot of the serving metrics. O(requests served) — intended
    /// for offline analysis (tests, examples), not the polling path; use
    /// [`RealServer::stats`] for that.
    pub fn recorder(&self) -> Recorder {
        self.pipeline.recorder.clone()
    }

    /// Cheap aggregates for observability polling (no record snapshot).
    pub fn stats(&self) -> ServingStats {
        let r = &self.pipeline.recorder;
        let mut ttft = r.ttft();
        let slo_enabled = self.ladder.enabled();
        let slo = self.ladder.ttft_slo();
        ServingStats {
            requests: r.len(),
            mean_ttft_s: ttft.mean(),
            hit_rate: r.hit_rate(),
            spec: self
                .spec
                .as_ref()
                .map(|rt| rt.table.totals())
                .unwrap_or_default(),
            slo_enabled,
            goodput_rps: if slo_enabled { r.goodput(slo) } else { 0.0 },
            ttft_p999_s: ttft.p999(),
            shed_requests: r.shed_count() as u64,
            downgraded_requests: r.downgrade_count() as u64,
            slo_attainment: if slo_enabled {
                r.slo_attainment(slo)
            } else {
                0.0
            },
        }
    }

    /// The shared, thread-safe (sharded) cache service backing this
    /// server — usable from other threads (e.g. the concurrent TCP
    /// runtime's priority estimator and sibling engine replicas) and
    /// for administration / failure injection.
    pub fn cache(&self) -> &ShardedCacheService {
        self.pipeline
            .cache
            .as_ref()
            .expect("real server always has a cache")
    }

    /// Chunked prefill through the compiled buckets: feeds `tokens` on
    /// top of `prefix_kv` in chunks, returning the final logits and all
    /// new KV rows.
    fn chunked_prefill(
        &self,
        prefix_kv: &mut Vec<f32>,
        tokens: &[i32],
        chunk: usize,
    ) -> Result<Vec<f32>> {
        let mut last_logits = Vec::new();
        for piece in tokens.chunks(chunk.max(1)) {
            let out = self
                .model
                .prefill(prefix_kv, piece)
                .context("chunked prefill")?;
            prefix_kv.extend_from_slice(&out.new_kv);
            last_logits = out.last_logits;
        }
        debug_assert!(!last_logits.is_empty());
        Ok(last_logits)
    }

    /// Serve one request: retrieve, reuse cached document KV, prefill the
    /// rest, decode `max_new` tokens greedily. A batch of one through
    /// [`RealServer::serve_batch`] — sharing the code path is what keeps
    /// `--max-batch 1` bit-identical to batched deployments serving
    /// singleton batches.
    pub fn serve(
        &mut self,
        target_doc: u32,
        query_tokens: &[i32],
        max_new: usize,
        cfg: &RealConfig,
    ) -> Result<RealResponse> {
        self.serve_batch(
            &[BatchRequest {
                target_doc,
                query_tokens: query_tokens.to_vec(),
                max_new,
            }],
            cfg,
        )
        .pop()
        .expect("one response per request")
    }

    /// Serve a batch admitted together — the engine-driver loop pops up
    /// to `--max-batch` compatible requests per iteration and hands them
    /// here. With `cfg.speculate` the batch runs through the
    /// event-driven session lifecycle (staged retrieval overlapped with
    /// speculative prefill, §5.3); otherwise every member retrieves and
    /// runs admission stage A FIRST, so the members' cache-hit
    /// promotions coalesce into one H2D burst via [`BatchAdmission`]
    /// (charged once; the real driver's transfers are in-process copies
    /// already folded into measured latency, so the charge is 0 s — but
    /// the accounting path is the simulation's, which is what the
    /// conformance tests pin). Then each member prefills, commits and
    /// decodes — the members' commit swap-outs sealing into one
    /// write-back burst — with per-request fallback on prefill error.
    pub fn serve_batch(
        &mut self,
        reqs: &[BatchRequest],
        cfg: &RealConfig,
    ) -> Vec<Result<RealResponse>> {
        if cfg.speculate {
            return self.serve_batch_speculative(reqs, cfg);
        }
        self.serve_batch_blocking(reqs, None, cfg)
    }

    /// [`serve_batch`](RealServer::serve_batch) with per-member
    /// reorder-queue waits (seconds each member spent queued before the
    /// engine popped it) — the TCP runtime's entry point. The waits
    /// drive the admission-control ladder: each pop feeds the
    /// queue-delay EWMA, members queued past the TTFT SLO are shed
    /// before retrieval ever runs, and arrival timestamps include the
    /// queue time so TTFT measures what the client saw. With `--shed
    /// off` this IS `serve_batch` (the ladder stays inert and the waits
    /// are ignored), bit for bit.
    pub fn serve_batch_timed(
        &mut self,
        reqs: &[BatchRequest],
        waits: &[f64],
        cfg: &RealConfig,
    ) -> Vec<Result<RealResponse>> {
        self.ensure_ladder(cfg);
        if !self.ladder.enabled() {
            return self.serve_batch(reqs, cfg);
        }
        if cfg.speculate {
            return self.serve_batch_speculative_timed(reqs, waits, cfg);
        }
        self.serve_batch_blocking(reqs, Some(waits), cfg)
    }

    fn serve_batch_blocking(
        &mut self,
        reqs: &[BatchRequest],
        waits: Option<&[f64]>,
        cfg: &RealConfig,
    ) -> Vec<Result<RealResponse>> {
        // Phase 1: per-member retrieval (Rust vector index — real
        // search) + the admission inputs. With the ladder armed, each
        // member's queue wait feeds the EWMA first, and members whose
        // TTFT deadline already expired in the queue are shed here —
        // before retrieval or admission touch them, so a shed member
        // never holds pins.
        struct Prep {
            id: u64,
            t_arrive: f64,
            docs: Vec<u32>,
            docs_tokens: Vec<(u32, usize)>,
            request_tokens: usize,
        }
        enum Slot {
            Served(usize),
            Shed(anyhow::Error),
        }
        let mut preps: Vec<Prep> = Vec::with_capacity(reqs.len());
        let mut slots: Vec<Slot> = Vec::with_capacity(reqs.len());
        for (i, r) in reqs.iter().enumerate() {
            let id = self.next_id;
            self.next_id += 1;
            let wait = waits
                .and_then(|w| w.get(i))
                .copied()
                .unwrap_or(0.0)
                .max(0.0);
            let now = self.driver.now();
            // Arrival is when the request entered the reorder queue,
            // not when the engine popped it: queue time is part of the
            // TTFT the client experiences (wait is 0 on the untimed
            // path, leaving it exactly the pop time as before).
            let t_arrive = now - wait;
            self.pipeline.recorder.arrival(id, t_arrive);
            if let Some(map) = &self.doc_tenants {
                let t = map
                    .get(r.target_doc as usize)
                    .copied()
                    .unwrap_or(0);
                self.pipeline.recorder.tenant(id, t);
            }
            self.ladder.observe_wait(wait, now);
            if self.ladder.should_shed(wait) {
                self.pipeline.recorder.shed(id, now);
                slots.push(Slot::Shed(anyhow::anyhow!(
                    "request {id} shed: queued {wait:.3}s past the \
                     {:.3}s TTFT SLO",
                    self.ladder.ttft_slo()
                )));
                continue;
            }
            // CAG bypass (`--cag auto`): a pinned tenant's whole corpus
            // already sits in the cache hierarchy, so the target
            // document IS the context — no query embedding, no vector
            // search, retrieval completes at arrival.
            let cag_hit = self
                .cag
                .as_ref()
                .zip(self.doc_tenants.as_ref())
                .is_some_and(|(p, map)| {
                    p.is_cag(
                        map.get(r.target_doc as usize)
                            .copied()
                            .unwrap_or(0),
                    )
                });
            let docs: Vec<u32> = if cag_hit {
                vec![r.target_doc]
            } else {
                let q = self.em.query(
                    r.target_doc,
                    cfg.query_noise,
                    &mut self.rng,
                );
                self.index
                    .search(&q, cfg.top_k)
                    .iter()
                    .map(|h| h.1)
                    .collect()
            };
            self.pipeline
                .recorder
                .retrieval_done(id, self.driver.now());
            let docs_tokens: Vec<(u32, usize)> = docs
                .iter()
                .map(|&d| (d, self.doc_tokens[d as usize].len()))
                .collect();
            // The separator + question form the request tail.
            let request_tokens = 1 + r.query_tokens.len(); // SEP + question
            slots.push(Slot::Served(preps.len()));
            preps.push(Prep {
                id,
                t_arrive,
                docs,
                docs_tokens,
                request_tokens,
            });
        }

        // Phase 2: shared batched admission — match → promote (with
        // GPU-prefix fallback) → pin → (α, β) per member, transfers
        // coalesced into one burst charged once through the driver.
        // (Shed members never reach this phase.)
        let by_id: HashMap<u64, usize> = preps
            .iter()
            .enumerate()
            .map(|(i, p)| (p.id, i))
            .collect();
        let batch = {
            let pipeline = &self.pipeline;
            BatchAdmission::admit_with(
                &self.driver,
                preps.iter().map(|p| p.id),
                |id| {
                    let p = &preps[by_id[&id]];
                    Ok(pipeline.admit_one(&p.docs_tokens, p.request_tokens))
                },
            )
        };
        debug_assert!(batch.failed().is_empty(), "real admission is total");

        // Phase 3: per-member prefill → commit → decode. Members align
        // by id, never positionally: should an admission ever fail
        // mid-batch (the `admit_with` Err path), every other member
        // keeps its own admission and the failed one reports its own
        // error instead of shifting the pairing. The members' commit
        // swap-outs accumulate and seal into ONE write-back burst per
        // batch (0 s on the real link model; the accounting mirrors the
        // sim driver's per-iteration commit burst).
        let mut admissions: HashMap<u64, Admission> =
            batch.into_members().into_iter().collect();
        let mut commit_moved = Transfers::default();
        let mut preps: Vec<Option<Prep>> =
            preps.into_iter().map(Some).collect();
        let results: Vec<Result<RealResponse>> = slots
            .into_iter()
            .zip(reqs)
            .map(|(slot, r)| {
                let prep = match slot {
                    Slot::Shed(e) => return Err(e),
                    Slot::Served(i) => {
                        preps[i].take().expect("each prep finishes once")
                    }
                };
                match admissions.remove(&prep.id) {
                    Some(adm) => self.finish_one(
                        prep.id,
                        prep.t_arrive,
                        prep.docs,
                        adm,
                        &r.query_tokens,
                        r.max_new,
                        cfg,
                        &mut commit_moved,
                    ),
                    None => Err(anyhow::anyhow!(
                        "request {}: GPU admission failed mid-batch; \
                         pins released, re-submit",
                        prep.id
                    )),
                }
            })
            .collect();
        let mut commits = BatchAdmission::new();
        commits.push_commit(commit_moved);
        commits.seal_commit(&self.driver);
        // Cross-shard rebalance tick, once per blocking engine
        // iteration (no-op unless `--rebalance on`). The donor
        // swap-outs it may perform are in-process copies already inside
        // measured wall-clock latency, mirroring admission transfers.
        self.cache().maintenance_tick();
        results
    }

    /// The TCP handlers' shared wire entry point (`ragcache serve` and
    /// the e2e example drive the identical code): build the
    /// [`BatchRequest`]s from the protocol tuples — `max_new` clamped
    /// to the compiled decode budget — serve the batch, and convert
    /// each response to its wire form.
    pub fn serve_proto_batch(
        &mut self,
        batch: &[(u32, String, usize)],
        tok: &ByteTokenizer,
        cfg: &RealConfig,
    ) -> Vec<Result<crate::server::proto::QueryResult>> {
        let reqs: Vec<BatchRequest> = batch
            .iter()
            .map(|(doc, query, max_new)| BatchRequest {
                target_doc: *doc,
                query_tokens: tok.encode(query),
                max_new: (*max_new).clamp(1, 16),
            })
            .collect();
        self.serve_batch(&reqs, cfg)
            .into_iter()
            .map(|r| r.map(|resp| resp.into_query_result(tok)))
            .collect()
    }

    /// [`serve_proto_batch`](RealServer::serve_proto_batch) with
    /// per-member reorder-queue waits — what the TCP engine loops call
    /// so queue delay reaches the admission-control ladder. With
    /// `--shed off` it IS `serve_proto_batch`, bit for bit.
    pub fn serve_proto_batch_timed(
        &mut self,
        batch: &[(u32, String, usize)],
        waits: &[f64],
        tok: &ByteTokenizer,
        cfg: &RealConfig,
    ) -> Vec<Result<crate::server::proto::QueryResult>> {
        let reqs: Vec<BatchRequest> = batch
            .iter()
            .map(|(doc, query, max_new)| BatchRequest {
                target_doc: *doc,
                query_tokens: tok.encode(query),
                max_new: (*max_new).clamp(1, 16),
            })
            .collect();
        self.serve_batch_timed(&reqs, waits, cfg)
            .into_iter()
            .map(|r| r.map(|resp| resp.into_query_result(tok)))
            .collect()
    }

    /// Prefill the non-cached tokens of an admitted request, producing
    /// the artifact [`commit_decode`](RealServer::commit_decode)
    /// finishes from. Shared by the blocking path (prefill and finish
    /// back to back) and the speculative path (prefill now, finish when
    /// the final stage confirms). A failed prefill returns the
    /// admission's pins — the contract that keeps the shared cache free
    /// of unevictable nodes.
    fn prefill_admitted(
        &self,
        adm: &Admission,
        query_tokens: &[i32],
        chunk: usize,
    ) -> Result<PrefillOut> {
        let mut kv = self.cache().concat_payloads(adm);

        // Boundary re-prefill of the chunk hits (the first `r` tokens
        // of each hit document — their reused rows are already in `kv`
        // via `concat_payloads`), then the non-cached documents +
        // separator + question. Empty with `--chunk-cache off`.
        let mut new_tokens: Vec<i32> = Vec::new();
        for hit in &adm.chunk_hits {
            new_tokens.extend_from_slice(
                &self.doc_tokens[hit.doc as usize][..hit.boundary],
            );
        }
        for &(d, _) in &adm.unmatched {
            new_tokens.extend_from_slice(&self.doc_tokens[d as usize]);
        }
        new_tokens.push(SEP);
        new_tokens.extend_from_slice(query_tokens);
        debug_assert_eq!(adm.beta, new_tokens.len());

        let kv_before = kv.len();
        let t_prefill0 = self.driver.now();
        let logits =
            match self.chunked_prefill(&mut kv, &new_tokens, chunk) {
                Ok(l) => l,
                Err(e) => {
                    self.pipeline.abort_admission(adm);
                    return Err(e);
                }
            };
        Ok(PrefillOut {
            kv,
            kv_before,
            logits,
            prefill_secs: self.driver.now() - t_prefill0,
        })
    }

    /// Post-confirmation tail of one request: deliver the first token,
    /// commit the newly computed document KV (rows precede SEP+query;
    /// byte movement merges into `commit_moved` for the caller's
    /// per-batch write-back burst), decode greedily and record the
    /// request.
    #[allow(clippy::too_many_arguments)]
    fn commit_decode(
        &mut self,
        id: u64,
        t_arrive: f64,
        docs: Vec<u32>,
        adm: Admission,
        art: PrefillOut,
        max_new: usize,
        commit_moved: &mut Transfers,
    ) -> Result<RealResponse> {
        let t_first = self.driver.now();
        self.pipeline.recorder.first_token(id, t_first);

        let kv_per_tok =
            self.model.manifest().arch.kv_floats_per_token();
        let doc_lens: Vec<usize> =
            adm.unmatched.iter().map(|&(_, t)| t).collect();
        let doc_token_total: usize = doc_lens.iter().sum();
        let mut kv = art.kv;
        let new_kv = &kv[art.kv_before..];
        // The first new rows are the chunk hits' boundary re-prefill
        // (see `prefill_admitted`); the freshly computed document rows
        // to cache start after them.
        let boundary_rows: usize = adm
            .chunk_hits
            .iter()
            .map(|h| h.boundary)
            .sum::<usize>()
            * kv_per_tok;
        let doc_rows = &new_kv
            [boundary_rows..boundary_rows + doc_token_total * kv_per_tok];
        let payloads = if doc_lens.is_empty() {
            Vec::new()
        } else {
            KvPayload::split(doc_rows, &doc_lens)
        };
        self.pipeline.touch_hits(&adm, art.prefill_secs, t_first);
        let out = self.pipeline.commit_prefill(
            &adm,
            art.prefill_secs,
            t_first,
            Some(payloads),
        );
        commit_moved.merge(out.transfers);

        // Greedy decode.
        let mut out_tokens = vec![argmax(&art.logits) as i32];
        for _ in 1..max_new {
            let last = *out_tokens.last().unwrap();
            let step = self.model.prefill(&kv, &[last])?;
            kv.extend_from_slice(&step.new_kv);
            out_tokens.push(argmax(&step.last_logits) as i32);
        }
        let t_done = self.driver.now();
        self.pipeline.recorder.finished(id, t_done);
        self.pipeline.record_admission(id, docs.len(), &adm);
        // CAG demand signal: a completed request flips its tenant's
        // cold-RAG mode to cached-RAG (never touches Cag tenants).
        if let (Some(policy), Some(map)) =
            (self.cag.as_mut(), self.doc_tenants.as_ref())
        {
            if let Some(&d) = docs.first() {
                policy.note_served(
                    map.get(d as usize).copied().unwrap_or(0),
                );
            }
        }

        Ok(RealResponse {
            id,
            docs,
            cached_tokens: adm.alpha,
            computed_tokens: adm.beta,
            docs_hit: adm.matched_docs,
            ttft: t_first - t_arrive,
            total: t_done - t_arrive,
            output_tokens: out_tokens,
        })
    }

    /// Post-admission tail of one request on the blocking path: prefill
    /// the non-cached tokens, commit the new document KV, decode.
    #[allow(clippy::too_many_arguments)]
    fn finish_one(
        &mut self,
        id: u64,
        t_arrive: f64,
        docs: Vec<u32>,
        adm: Admission,
        query_tokens: &[i32],
        max_new: usize,
        cfg: &RealConfig,
        commit_moved: &mut Transfers,
    ) -> Result<RealResponse> {
        let art = self.prefill_admitted(&adm, query_tokens, cfg.chunk)?;
        self.commit_decode(
            id,
            t_arrive,
            docs,
            adm,
            art,
            max_new,
            commit_moved,
        )
    }
}

/// The event-driven (speculative) serving API: `submit` starts a
/// non-blocking [`RequestSession`](super::session::RequestSession) whose
/// staged retrieval runs on the [`RetrievalService`] pool;
/// `poll_sessions` multiplexes the stage events — running Algorithm 2,
/// starting/cancelling speculative prefills, promoting or falling back
/// on the final stage — and returns completed responses. The blocking
/// `serve`/`serve_batch` calls become convenience wrappers that drive
/// sessions to completion when `cfg.speculate` is set.
impl RealServer {
    fn ensure_spec(&mut self, cfg: &RealConfig) {
        if self.spec.is_some() {
            return;
        }
        let (tx, rx) = mpsc::channel();
        let service = RetrievalService::spawn(
            Arc::clone(&self.index),
            RetrievalConfig {
                threads: cfg.retrieval_threads.max(1),
                stages: cfg.stages.max(1),
                stage_latency: Duration::from_secs_f64(
                    cfg.stage_latency_s.max(0.0),
                ),
            },
            tx,
        );
        self.spec = Some(SpecRuntime {
            service,
            events: rx,
            table: SessionTable::new(cfg.spec_pool.max(1)),
            pending: HashMap::new(),
            dead_on_submit: Vec::new(),
        });
    }

    /// Submit one request into the session lifecycle: embed the query,
    /// dispatch its staged search to the retrieval pool and return the
    /// session id. The response arrives through
    /// [`poll_sessions`](RealServer::poll_sessions).
    pub fn submit(&mut self, req: &BatchRequest, cfg: &RealConfig) -> u64 {
        self.submit_inner(req, cfg, 0.0, false)
    }

    /// [`submit`](RealServer::submit) with the request's reorder-queue
    /// wait. Feeds the admission-control ladder: the wait updates the
    /// queue-delay EWMA; a request queued past the TTFT SLO is shed
    /// (recorded, never submitted — `Err` carries the client-facing
    /// reason); while the EWMA sits above the downgrade threshold, new
    /// sessions run single-stage retrieval, which makes their first
    /// stage event final — speculation structurally never starts. With
    /// `--shed off` this IS `submit`, bit for bit.
    pub fn submit_timed(
        &mut self,
        req: &BatchRequest,
        wait: f64,
        cfg: &RealConfig,
    ) -> Result<u64> {
        self.ensure_ladder(cfg);
        if !self.ladder.enabled() {
            return Ok(self.submit_inner(req, cfg, 0.0, false));
        }
        let wait = wait.max(0.0);
        let now = self.driver.now();
        self.ladder.observe_wait(wait, now);
        if self.ladder.should_shed(wait) {
            let id = self.next_id;
            self.next_id += 1;
            self.pipeline.recorder.arrival(id, now - wait);
            self.pipeline.recorder.shed(id, now);
            return Err(anyhow::anyhow!(
                "request {id} shed: queued {wait:.3}s past the {:.3}s \
                 TTFT SLO",
                self.ladder.ttft_slo()
            ));
        }
        let downgrade = self.ladder.downgrading();
        Ok(self.submit_inner(req, cfg, wait, downgrade))
    }

    fn submit_inner(
        &mut self,
        req: &BatchRequest,
        cfg: &RealConfig,
        wait: f64,
        downgrade: bool,
    ) -> u64 {
        self.ensure_spec(cfg);
        let id = self.next_id;
        self.next_id += 1;
        // Arrival backdates to reorder-queue entry (wait is 0 on the
        // untimed path) so TTFT spans the queue time the client saw.
        let t_arrive = self.driver.now() - wait;
        self.pipeline.recorder.arrival(id, t_arrive);
        if downgrade {
            self.pipeline.recorder.downgraded(id);
        }
        let query =
            self.em.query(req.target_doc, cfg.query_noise, &mut self.rng);
        let rt = self.spec.as_mut().expect("just ensured");
        rt.table.submit(id, t_arrive);
        rt.pending.insert(
            id,
            SpecPending {
                query_tokens: req.query_tokens.clone(),
                max_new: req.max_new,
                t_arrive,
            },
        );
        let accepted = rt.service.submit(RetrievalTask {
            session: id,
            query,
            top_k: cfg.top_k,
            stages: if downgrade { Some(1) } else { None },
        });
        if !accepted {
            // The pool is gone (worker panic / teardown): no stage event
            // will ever arrive, so the session must die NOW — otherwise
            // it occupies an admission slot forever and its waiter hangs.
            rt.pending.remove(&id);
            rt.table
                .fail(id, "retrieval pool unavailable".to_string());
            rt.dead_on_submit.push(id);
        }
        id
    }

    /// Sessions submitted and not yet completed.
    pub fn in_flight_sessions(&self) -> usize {
        self.spec.as_ref().map(|rt| rt.table.in_flight()).unwrap_or(0)
    }

    /// Multiplex retrieval stage events for up to `timeout` (then drain
    /// whatever else already arrived), advancing every touched session:
    /// Algorithm 2 per stage against the real prefill-pool occupancy,
    /// speculative prefills started/cancelled through the shared
    /// pipeline (pins only — commits wait for confirmation), promotion
    /// or PR 3 fallback on final stages. Returns the sessions that
    /// completed, with their responses.
    pub fn poll_sessions(
        &mut self,
        timeout: Duration,
        cfg: &RealConfig,
    ) -> Vec<(u64, Result<RealResponse>)> {
        // Cross-shard rebalance tick, once per multiplexer poll (the
        // session-mode analogue of the blocking loop's per-iteration
        // tick); no-op unless `--rebalance on`.
        self.cache().maintenance_tick();
        let mut done = Vec::new();
        let Some(mut rt) = self.spec.take() else {
            return done;
        };
        // Sessions that died at submit time answer first — they have no
        // stage events to wait for.
        for id in rt.dead_on_submit.drain(..) {
            done.push((
                id,
                Err(anyhow::anyhow!(
                    "session {id}: retrieval pool unavailable"
                )),
            ));
        }
        // Ladder shed pass: sessions whose TTFT deadline expired while
        // still short of admission fail now — their speculation pins are
        // released and their staged retrieval is cancelled, exactly like
        // the sim path's DeadlineExpired handler. Admitted prefills are
        // graced inside `shed_expired` (the work is already spent).
        if self.ladder.enabled() {
            let now = self.driver.now();
            self.ladder.decay_to(now);
            let slo = self.ladder.ttft_slo();
            for (id, work) in rt.table.shed_expired(now, slo) {
                if let Some(w) = work {
                    self.pipeline.abort_admission(&w.payload.adm);
                }
                rt.pending.remove(&id);
                rt.service.cancel(id);
                self.pipeline.recorder.shed(id, now);
                done.push((
                    id,
                    Err(anyhow::anyhow!(
                        "session {id} shed: TTFT SLO ({slo:.3}s) expired \
                         before admission"
                    )),
                ));
            }
        }
        let mut batch = Vec::new();
        if done.is_empty() {
            // Nothing to report yet: wait for progress.
            if let Ok(ev) = rt.events.recv_timeout(timeout) {
                batch.push(ev);
            }
        }
        while let Ok(ev) = rt.events.try_recv() {
            batch.push(ev);
        }
        for ev in batch {
            self.on_stage_event(&mut rt, ev, cfg, &mut done);
        }
        // Lifecycle notifications are surfaced through the returned
        // completions; drain the buffer so it cannot grow unbounded.
        for ev in rt.table.take_events() {
            log::trace!("session event: {ev:?}");
        }
        self.spec = Some(rt);
        done
    }

    /// Speculation counters of this engine's sessions.
    pub fn spec_totals(&self) -> SpecTotals {
        self.spec
            .as_ref()
            .map(|rt| rt.table.totals())
            .unwrap_or_default()
    }

    /// Blocking wrapper over the session lifecycle: submit every member
    /// and poll until all complete, preserving request order.
    pub fn serve_batch_speculative(
        &mut self,
        reqs: &[BatchRequest],
        cfg: &RealConfig,
    ) -> Vec<Result<RealResponse>> {
        let ids: Vec<u64> =
            reqs.iter().map(|r| self.submit(r, cfg)).collect();
        let want: std::collections::HashSet<u64> =
            ids.iter().copied().collect();
        let mut results: HashMap<u64, Result<RealResponse>> =
            HashMap::new();
        let deadline =
            std::time::Instant::now() + Duration::from_secs(120);
        while results.len() < ids.len()
            && std::time::Instant::now() < deadline
        {
            for (id, res) in
                self.poll_sessions(Duration::from_millis(20), cfg)
            {
                // Only THIS call's members count toward completion; a
                // late completion left over from a previous timed-out
                // call must neither satisfy the wait nor shadow a live
                // member's slot.
                if want.contains(&id) {
                    results.insert(id, res);
                } else {
                    log::warn!(
                        "dropping stale session {id} completion from an \
                         earlier timed-out serve_batch_speculative call"
                    );
                }
            }
        }
        ids.into_iter()
            .map(|id| {
                results.remove(&id).unwrap_or_else(|| {
                    Err(anyhow::anyhow!(
                        "session {id}: retrieval never completed"
                    ))
                })
            })
            .collect()
    }

    /// [`serve_batch_speculative`](RealServer::serve_batch_speculative)
    /// with per-member reorder-queue waits. Members shed at submit time
    /// report their error in place; survivors run the normal session
    /// lifecycle (downgraded ones on single-stage retrieval).
    fn serve_batch_speculative_timed(
        &mut self,
        reqs: &[BatchRequest],
        waits: &[f64],
        cfg: &RealConfig,
    ) -> Vec<Result<RealResponse>> {
        let slots: Vec<Result<u64>> = reqs
            .iter()
            .enumerate()
            .map(|(i, r)| {
                let wait = waits.get(i).copied().unwrap_or(0.0);
                self.submit_timed(r, wait, cfg)
            })
            .collect();
        let want: std::collections::HashSet<u64> =
            slots.iter().filter_map(|s| s.as_ref().ok().copied()).collect();
        let mut results: HashMap<u64, Result<RealResponse>> =
            HashMap::new();
        let deadline =
            std::time::Instant::now() + Duration::from_secs(120);
        while results.len() < want.len()
            && std::time::Instant::now() < deadline
        {
            for (id, res) in
                self.poll_sessions(Duration::from_millis(20), cfg)
            {
                if want.contains(&id) {
                    results.insert(id, res);
                } else {
                    log::warn!(
                        "dropping stale session {id} completion from an \
                         earlier timed-out serve_batch call"
                    );
                }
            }
        }
        slots
            .into_iter()
            .map(|slot| match slot {
                Err(e) => Err(e),
                Ok(id) => results.remove(&id).unwrap_or_else(|| {
                    Err(anyhow::anyhow!(
                        "session {id}: retrieval never completed"
                    ))
                }),
            })
            .collect()
    }

    /// Process one retrieval stage event through the session table and
    /// perform whatever it directs: release a cancelled speculation's
    /// pins, run a speculative prefill, or finish the session.
    fn on_stage_event(
        &mut self,
        rt: &mut SpecRuntime,
        ev: StageReady,
        cfg: &RealConfig,
        done: &mut Vec<(u64, Result<RealResponse>)>,
    ) {
        let id = ev.session;
        if rt.table.session(id).is_none() {
            return; // stale event for a finished session
        }
        if ev.is_final {
            self.pipeline
                .recorder
                .retrieval_done(id, self.driver.now());
        }
        let step = rt.table.on_stage(id, ev.stage, &ev.docs, ev.is_final);
        if let Some(work) = step.cancelled {
            // Terminated speculation: release the pins and discard the
            // computed KV (counted `wasted` by the table). Restarted
            // generations stay cheap through whatever the tree already
            // caches, not through committing stale candidates.
            self.pipeline.abort_admission(&work.payload.adm);
        }
        if let Some(docs) = step.start {
            let query_tokens = match rt.pending.get(&id) {
                Some(p) => p.query_tokens.clone(),
                None => return,
            };
            match self.spec_prefill(&docs, &query_tokens, cfg) {
                Ok(artifact) => rt.table.spec_started(id, docs, artifact),
                Err(e) => {
                    log::warn!(
                        "session {id}: speculative prefill failed: {e:#}"
                    );
                    rt.table.spec_aborted(id);
                }
            }
        }
        if let Some(finish) = step.finish {
            let Some(p) = rt.pending.remove(&id) else {
                return;
            };
            let docs = ev.docs.clone();
            let result = match finish {
                FinishPath::Promote(work) => {
                    let SpecWork { payload, .. } = work;
                    self.finish_session(
                        rt,
                        id,
                        p,
                        docs,
                        payload.adm,
                        payload.out,
                    )
                }
                FinishPath::Fallback => {
                    self.fallback_session(rt, id, p, docs, cfg)
                }
            };
            done.push((id, result));
        }
    }

    /// Singleton admission (pin, no commit) for a session's candidate
    /// docs, through the shared [`BatchAdmission`] accounting path — the
    /// one implementation the speculative and fallback paths both use.
    fn admit_docs(&self, docs: &[u32], query_len: usize) -> Admission {
        let docs_tokens: Vec<(u32, usize)> = docs
            .iter()
            .map(|&d| (d, self.doc_tokens[d as usize].len()))
            .collect();
        let request_tokens = 1 + query_len; // SEP + question
        let batch = BatchAdmission::admit_with(
            &self.driver,
            std::iter::once(0u64),
            |_| Ok(self.pipeline.admit_one(&docs_tokens, request_tokens)),
        );
        batch
            .into_members()
            .pop()
            .map(|(_, a)| a)
            .expect("real admission is total")
    }

    /// Admission stage A + speculative prefill for a candidate set: the
    /// admission pins its path but commits nothing — the artifact waits
    /// for the final stage to confirm (promote) or cancel it.
    fn spec_prefill(
        &self,
        docs: &[u32],
        query_tokens: &[i32],
        cfg: &RealConfig,
    ) -> Result<SpecArtifact> {
        let adm = self.admit_docs(docs, query_tokens.len());
        let out = self.prefill_admitted(&adm, query_tokens, cfg.chunk)?;
        Ok(SpecArtifact { adm, out })
    }

    /// Finish a confirmed session from its prefill artifact: first
    /// token, commit (its own write-back burst), decode, terminal event.
    fn finish_session(
        &mut self,
        rt: &mut SpecRuntime,
        id: u64,
        p: SpecPending,
        docs: Vec<u32>,
        adm: Admission,
        out: PrefillOut,
    ) -> Result<RealResponse> {
        rt.table.prefilled(id, self.driver.now());
        rt.table.decoding(id);
        let mut moved = Transfers::default();
        let result = self.commit_decode(
            id,
            p.t_arrive,
            docs,
            adm,
            out,
            p.max_new,
            &mut moved,
        );
        let mut commits = BatchAdmission::new();
        commits.push_commit(moved);
        commits.seal_commit(&self.driver);
        match &result {
            Ok(_) => {
                rt.table.complete(id);
            }
            Err(e) => {
                rt.table.fail(id, format!("{e:#}"));
            }
        }
        result
    }

    /// Final stage without a usable speculation: the blocking PR 3 path
    /// (admit → prefill → commit → decode) on the confirmed docs.
    fn fallback_session(
        &mut self,
        rt: &mut SpecRuntime,
        id: u64,
        p: SpecPending,
        docs: Vec<u32>,
        cfg: &RealConfig,
    ) -> Result<RealResponse> {
        let adm = self.admit_docs(&docs, p.query_tokens.len());
        match self.prefill_admitted(&adm, &p.query_tokens, cfg.chunk) {
            Ok(out) => self.finish_session(rt, id, p, docs, adm, out),
            Err(e) => {
                rt.table.fail(id, format!("{e:#}"));
                Err(e)
            }
        }
    }
}

impl RealServer {
    /// The wire-protocol stats line every TCP handler reports — one
    /// shared builder so the field mapping (and the spec counters)
    /// cannot drift between the binary's handler and the examples'.
    pub fn proto_stats(&self) -> crate::server::proto::StatsResult {
        let s = self.stats();
        let c = self.cache().counters();
        let occ = self.cache().shard_occupancies();
        let rb = self.cache().rebalance_stats();
        crate::server::proto::StatsResult {
            requests: s.requests,
            mean_ttft_ms: s.mean_ttft_s * 1e3,
            hit_rate: s.hit_rate,
            engines: 1,
            tree_inserts: c.inserts,
            tree_gpu_evictions: c.gpu_evictions,
            tree_host_evictions: c.host_evictions,
            spec_started: s.spec.started,
            spec_wasted: s.spec.wasted,
            spec_promoted: s.spec.promoted,
            tree_gpu_hit_bytes: c.gpu_hit_bytes,
            chunk_hits: c.chunk_hits,
            chunk_hit_bytes: c.chunk_hit_bytes,
            boundary_recompute_tokens: c.boundary_recompute_tokens,
            rebalance_recomputes: rb.recomputes,
            rebalance_moved_bytes: rb.gpu_bytes_moved
                + rb.host_bytes_moved,
            shard_gpu_used: occ.iter().map(|o| o.gpu_used).collect(),
            shard_gpu_capacity: occ
                .iter()
                .map(|o| o.gpu_capacity)
                .collect(),
            // p99.9 TTFT is pure measurement and always reported; the
            // SLO-relative fields come from the ladder (zero — with
            // `slo_enabled: false` saying why — when `--shed off`).
            ttft_p999_ms: s.ttft_p999_s * 1e3,
            goodput_rps: s.goodput_rps,
            shed_requests: s.shed_requests,
            downgraded_requests: s.downgraded_requests,
            slo_attainment: s.slo_attainment,
            slo_enabled: s.slo_enabled,
            disk_spills: c.disk_spills,
            disk_spill_bytes: c.disk_spill_bytes,
            disk_restage_hits: c.disk_restage_hits,
            disk_restage_bytes: c.disk_restage_bytes,
            disk_used: occ.iter().map(|o| o.disk_used).sum(),
            disk_capacity: occ
                .iter()
                .map(|o| o.disk_capacity)
                .sum(),
            tenants: self.tenant_lines(),
            ext: Vec::new(),
        }
    }

    /// Per-tenant SLO breakdown for the stats wire: the recorder's
    /// per-tenant aggregates (all requests land on tenant 0 until
    /// [`enable_cag`](RealServer::enable_cag) installs the corpus
    /// layout), each stamped with its CAG mode. A tenant with no
    /// completions reports `mean_ttft_ms` 0.0 — JSON cannot carry the
    /// recorder's NaN, and the merge skips zero-completion lines
    /// anyway.
    fn tenant_lines(&self) -> Vec<crate::server::proto::TenantLine> {
        let slo = self.ladder.ttft_slo();
        self.pipeline
            .recorder
            .per_tenant(slo)
            .into_iter()
            .map(|t| {
                let mean = t.mean_ttft();
                crate::server::proto::TenantLine {
                    tenant: t.tenant,
                    requests: t.requests as u64,
                    completed: t.completed as u64,
                    shed: t.shed as u64,
                    downgraded: t.downgraded as u64,
                    slo_ok: t.slo_ok as u64,
                    mean_ttft_ms: crate::metrics::registry::wire_mean_ms(
                        mean * 1e3,
                    ),
                    mode: self
                        .cag
                        .as_ref()
                        .map(|p| p.mode(t.tenant).code())
                        .unwrap_or(0),
                }
            })
            .collect()
    }
}

/// The TCP handlers' shared session plumbing: engine-ticket bookkeeping
/// plus the wire conversions around [`RealServer::submit`] /
/// [`RealServer::poll_sessions`] — the session-mode analogue of
/// [`RealServer::serve_proto_batch`], extracted so the `ragcache serve`
/// handler and the e2e example cannot drift apart.
#[derive(Default)]
pub struct SessionProtoBridge {
    /// session id → engine ticket.
    tickets: HashMap<u64, u64>,
}

impl SessionProtoBridge {
    pub fn new() -> Self {
        SessionProtoBridge::default()
    }

    /// Non-blocking submit for `QueryHandler::submit_session`: with
    /// speculation off, serve synchronously (a batch of one through the
    /// blocking path) and answer immediately; otherwise start a session
    /// and remember its ticket.
    #[allow(clippy::too_many_arguments)]
    pub fn submit(
        &mut self,
        server: &mut RealServer,
        ticket: u64,
        target_doc: u32,
        query: &str,
        max_new: usize,
        tok: &ByteTokenizer,
        cfg: &RealConfig,
    ) -> Option<Result<crate::server::proto::QueryResult>> {
        self.submit_timed(
            server, ticket, target_doc, query, max_new, 0.0, tok, cfg,
        )
    }

    /// [`submit`](SessionProtoBridge::submit) with the request's
    /// reorder-queue wait, so the admission-control ladder sees queue
    /// delay in session mode too. A shed submit answers immediately
    /// (`Some(Err(..))`) without ever opening a session. With `--shed
    /// off` it IS `submit`, bit for bit.
    #[allow(clippy::too_many_arguments)]
    pub fn submit_timed(
        &mut self,
        server: &mut RealServer,
        ticket: u64,
        target_doc: u32,
        query: &str,
        max_new: usize,
        wait: f64,
        tok: &ByteTokenizer,
        cfg: &RealConfig,
    ) -> Option<Result<crate::server::proto::QueryResult>> {
        if !cfg.speculate {
            return server
                .serve_proto_batch_timed(
                    &[(target_doc, query.to_string(), max_new)],
                    &[wait],
                    tok,
                    cfg,
                )
                .pop();
        }
        let req = BatchRequest {
            target_doc,
            query_tokens: tok.encode(query),
            max_new: max_new.clamp(1, 16),
        };
        match server.submit_timed(&req, wait, cfg) {
            Ok(session) => {
                self.tickets.insert(session, ticket);
                None
            }
            Err(e) => Some(Err(e)),
        }
    }

    /// Drain completed sessions as `(ticket, wire result)` pairs for
    /// `QueryHandler::poll_sessions`.
    pub fn poll(
        &mut self,
        server: &mut RealServer,
        timeout: Duration,
        tok: &ByteTokenizer,
        cfg: &RealConfig,
    ) -> Vec<(u64, Result<crate::server::proto::QueryResult>)> {
        server
            .poll_sessions(timeout, cfg)
            .into_iter()
            .map(|(session, result)| {
                (
                    self.tickets.remove(&session).unwrap_or(session),
                    result.map(|r| r.into_query_result(tok)),
                )
            })
            .collect()
    }
}

impl Drop for RealServer {
    /// The cache outlives this engine replica (it is shared with
    /// siblings): any speculation still pinning it at teardown must
    /// release, or the shard accumulates unevictable nodes.
    fn drop(&mut self) {
        if let Some(mut rt) = self.spec.take() {
            for work in rt.table.abort_all() {
                self.pipeline.abort_admission(&work.payload.adm);
            }
        }
    }
}

/// Result of an iterative-retrieval session (paper §9: "RAGCache supports
/// iterative retrieval by treating the intermediate iterations as
/// separate requests and caching the corresponding KV cache of the
/// documents").
#[derive(Debug, Clone)]
pub struct IterativeResponse {
    pub rounds: Vec<RealResponse>,
}

impl IterativeResponse {
    pub fn total_docs_hit(&self) -> usize {
        self.rounds.iter().map(|r| r.docs_hit).sum()
    }

    pub fn total_docs(&self) -> usize {
        self.rounds.iter().map(|r| r.docs.len()).sum()
    }
}

impl RealServer {
    /// Iterative retrieval: run `targets.len()` retrieve→generate rounds,
    /// feeding each round's output tokens into the next round's query.
    /// Each round is a normal [`RealServer::serve`] request, so document
    /// KV computed in earlier rounds is reusable by later ones.
    pub fn serve_iterative(
        &mut self,
        targets: &[u32],
        initial_query: &[i32],
        max_new_per_round: usize,
        cfg: &RealConfig,
    ) -> Result<IterativeResponse> {
        let mut rounds = Vec::with_capacity(targets.len());
        let mut query = initial_query.to_vec();
        for &target in targets {
            let resp =
                self.serve(target, &query, max_new_per_round, cfg)?;
            // Next round's query: the original question refined by the
            // intermediate generation (clamped to vocab byte range).
            query = initial_query.to_vec();
            query.extend(
                resp.output_tokens.iter().map(|&t| t.clamp(0, 255)),
            );
            rounds.push(resp);
        }
        Ok(IterativeResponse { rounds })
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}
