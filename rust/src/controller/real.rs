//! Real-mode server: the same knowledge-tree / policy / scheduling stack
//! driven in real time with *actual* computation — retrieval through the
//! Rust vector index, prefill/decode through the PJRT-compiled JAX+Pallas
//! artifacts, and real KV payloads cached in the tree.
//!
//! This is the *real driver* over the shared [`pipeline`](super::pipeline)
//! core: admission (match → promote → pin → α/β), policy refresh and
//! post-prefill insertion are the exact code the simulated controller
//! runs; this file contributes wall-clock timing, real vector search and
//! PJRT execution. It is the end-to-end proof that all three layers
//! compose; the paper-scale experiments use the virtual-clock
//! [`super::sim_server`].

use super::batch::BatchAdmission;
use super::pipeline::{Admission, Pipeline, PipelineDriver};
use super::shard::ShardedCacheService;
use crate::embed::EmbeddingModel;
use crate::kvcache::{KvPayload, PageSpec};
use crate::llm::tokenizer::{ByteTokenizer, SEP};
use crate::metrics::Recorder;
use crate::policy::make_policy;
use crate::runtime::PjrtModel;
use crate::sim::{Clock, RealClock};
use crate::tree::KnowledgeTree;
use crate::util::Rng;
use crate::vectordb::VectorIndex;
use anyhow::{Context, Result};

/// Real-mode server configuration.
#[derive(Debug, Clone)]
pub struct RealConfig {
    pub top_k: usize,
    /// Logical GPU-tier budget for the document cache, bytes.
    pub gpu_cache_bytes: u64,
    pub host_cache_bytes: u64,
    pub block_tokens: usize,
    pub policy: crate::config::PolicyKind,
    /// Prefill chunk size (must fit the largest compiled beta bucket).
    pub chunk: usize,
    /// Query-embedding noise (0 = queries hit their target exactly).
    pub query_noise: f64,
}

impl Default for RealConfig {
    fn default() -> Self {
        RealConfig {
            top_k: 2,
            gpu_cache_bytes: 4 * 1024 * 1024,
            host_cache_bytes: 32 * 1024 * 1024,
            block_tokens: 16,
            policy: crate::config::PolicyKind::Pgdsf,
            chunk: 64,
            query_noise: 0.02,
        }
    }
}

/// Aggregate serving metrics, cheap enough for per-poll computation.
#[derive(Debug, Clone, Copy)]
pub struct ServingStats {
    pub requests: usize,
    pub mean_ttft_s: f64,
    pub hit_rate: f64,
}

/// One member of a batched serve call ([`RealServer::serve_batch`]).
#[derive(Debug, Clone)]
pub struct BatchRequest {
    pub target_doc: u32,
    pub query_tokens: Vec<i32>,
    pub max_new: usize,
}

/// Response of one served request.
#[derive(Debug, Clone)]
pub struct RealResponse {
    pub id: u64,
    pub docs: Vec<u32>,
    pub cached_tokens: usize,
    pub computed_tokens: usize,
    pub docs_hit: usize,
    /// Wall-clock time to first token, seconds.
    pub ttft: f64,
    pub total: f64,
    pub output_tokens: Vec<i32>,
}

impl RealResponse {
    /// Wire-protocol form of this response (`tok` decodes the output
    /// tokens into the reply text) — the one conversion every TCP
    /// handler shares, so the field mapping cannot drift between them.
    pub fn into_query_result(
        self,
        tok: &ByteTokenizer,
    ) -> crate::server::proto::QueryResult {
        crate::server::proto::QueryResult {
            id: self.id,
            docs_hit: self.docs_hit,
            cached_tokens: self.cached_tokens,
            computed_tokens: self.computed_tokens,
            ttft_ms: self.ttft * 1e3,
            total_ms: self.total * 1e3,
            text: tok.decode(&self.output_tokens),
            docs: self.docs,
        }
    }
}

/// The real-mode [`PipelineDriver`]: wall clock; GPU↔host "transfers" are
/// in-process copies whose cost is already part of measured latency.
struct RealDriver {
    clock: RealClock,
}

impl PipelineDriver for RealDriver {
    fn now(&self) -> f64 {
        self.clock.now()
    }

    fn transfer_time(&self, _bytes: u64) -> f64 {
        0.0
    }
}

/// The real-mode serving stack.
pub struct RealServer {
    model: PjrtModel,
    pipeline: Pipeline,
    driver: RealDriver,
    index: Box<dyn VectorIndex>,
    em: EmbeddingModel,
    /// Token ids of each knowledge document.
    doc_tokens: Vec<Vec<i32>>,
    rng: Rng,
    next_id: u64,
}

impl RealServer {
    /// The page spec this server would size its cache with — exposed so
    /// callers can pre-build a shared [`ShardedCacheService`] (e.g. for
    /// the concurrent runtime's priority estimator) before the
    /// non-`Send` PJRT model exists.
    pub fn page_spec(
        kv_floats_per_token: usize,
        cfg: &RealConfig,
    ) -> PageSpec {
        PageSpec {
            block_tokens: cfg.block_tokens,
            kv_bytes_per_token: kv_floats_per_token * 4,
        }
    }

    /// Build the knowledge tree this server would construct itself.
    pub fn build_tree(
        kv_floats_per_token: usize,
        cfg: &RealConfig,
    ) -> KnowledgeTree {
        KnowledgeTree::new(
            cfg.gpu_cache_bytes,
            cfg.host_cache_bytes,
            Self::page_spec(kv_floats_per_token, cfg),
            make_policy(cfg.policy),
            true,
            0,
        )
    }

    /// Build a K-shard cache service for this model, splitting the
    /// configured tier budgets evenly across shards. Shared between the
    /// M engine replicas of a concurrent deployment (each shard has its
    /// own lock, so replicas admit in parallel).
    pub fn build_sharded_cache(
        kv_floats_per_token: usize,
        cfg: &RealConfig,
        shards: usize,
    ) -> ShardedCacheService {
        let k = shards.max(1);
        let page = Self::page_spec(kv_floats_per_token, cfg);
        ShardedCacheService::build(k, |_| {
            KnowledgeTree::new(
                cfg.gpu_cache_bytes / k as u64,
                cfg.host_cache_bytes / k as u64,
                page,
                make_policy(cfg.policy),
                true,
                0,
            )
        })
    }

    pub fn new(
        model: PjrtModel,
        index: Box<dyn VectorIndex>,
        em: EmbeddingModel,
        doc_tokens: Vec<Vec<i32>>,
        cfg: &RealConfig,
    ) -> Result<Self> {
        let kv = model.manifest().arch.kv_floats_per_token();
        let cache =
            ShardedCacheService::single(Self::build_tree(kv, cfg));
        Self::with_cache(model, index, em, doc_tokens, cache)
    }

    /// Assemble the stack around a pre-built, possibly shared cache
    /// service (its trees must have been sized with
    /// [`RealServer::page_spec`] for this model).
    pub fn with_cache(
        model: PjrtModel,
        index: Box<dyn VectorIndex>,
        em: EmbeddingModel,
        doc_tokens: Vec<Vec<i32>>,
        cache: ShardedCacheService,
    ) -> Result<Self> {
        Ok(RealServer {
            model,
            // Real-mode request ordering happens in the concurrent TCP
            // runtime's SharedReorderQueue (crate::server), not here:
            // this pipeline's own queue is unused, so it stays FIFO.
            pipeline: Pipeline::new(Some(cache), false, 1),
            driver: RealDriver {
                clock: RealClock::new(),
            },
            index,
            em,
            doc_tokens,
            rng: Rng::new(0xE2E),
            next_id: 0,
        })
    }

    /// Snapshot of the serving metrics. O(requests served) — intended
    /// for offline analysis (tests, examples), not the polling path; use
    /// [`RealServer::stats`] for that.
    pub fn recorder(&self) -> Recorder {
        self.pipeline.recorder.clone()
    }

    /// Cheap aggregates for observability polling (no record snapshot).
    pub fn stats(&self) -> ServingStats {
        let r = &self.pipeline.recorder;
        ServingStats {
            requests: r.len(),
            mean_ttft_s: r.ttft().mean(),
            hit_rate: r.hit_rate(),
        }
    }

    /// The shared, thread-safe (sharded) cache service backing this
    /// server — usable from other threads (e.g. the concurrent TCP
    /// runtime's priority estimator and sibling engine replicas) and
    /// for administration / failure injection.
    pub fn cache(&self) -> &ShardedCacheService {
        self.pipeline
            .cache
            .as_ref()
            .expect("real server always has a cache")
    }

    /// Chunked prefill through the compiled buckets: feeds `tokens` on
    /// top of `prefix_kv` in chunks, returning the final logits and all
    /// new KV rows.
    fn chunked_prefill(
        &self,
        prefix_kv: &mut Vec<f32>,
        tokens: &[i32],
        chunk: usize,
    ) -> Result<Vec<f32>> {
        let mut last_logits = Vec::new();
        for piece in tokens.chunks(chunk.max(1)) {
            let out = self
                .model
                .prefill(prefix_kv, piece)
                .context("chunked prefill")?;
            prefix_kv.extend_from_slice(&out.new_kv);
            last_logits = out.last_logits;
        }
        debug_assert!(!last_logits.is_empty());
        Ok(last_logits)
    }

    /// Serve one request: retrieve, reuse cached document KV, prefill the
    /// rest, decode `max_new` tokens greedily. A batch of one through
    /// [`RealServer::serve_batch`] — sharing the code path is what keeps
    /// `--max-batch 1` bit-identical to batched deployments serving
    /// singleton batches.
    pub fn serve(
        &mut self,
        target_doc: u32,
        query_tokens: &[i32],
        max_new: usize,
        cfg: &RealConfig,
    ) -> Result<RealResponse> {
        self.serve_batch(
            &[BatchRequest {
                target_doc,
                query_tokens: query_tokens.to_vec(),
                max_new,
            }],
            cfg,
        )
        .pop()
        .expect("one response per request")
    }

    /// Serve a batch admitted together — the engine-driver loop pops up
    /// to `--max-batch` compatible requests per iteration and hands them
    /// here. Every member retrieves and runs admission stage A FIRST, so
    /// the members' cache-hit promotions coalesce into one H2D burst via
    /// [`BatchAdmission`] (charged once; the real driver's transfers are
    /// in-process copies already folded into measured latency, so the
    /// charge is 0 s — but the accounting path is the simulation's,
    /// which is what the conformance tests pin). Then each member
    /// prefills, commits and decodes. A member whose prefill fails
    /// releases its own pins and reports its own error; the rest of the
    /// batch proceeds (per-request fallback).
    pub fn serve_batch(
        &mut self,
        reqs: &[BatchRequest],
        cfg: &RealConfig,
    ) -> Vec<Result<RealResponse>> {
        // Phase 1: per-member retrieval (Rust vector index — real
        // search) + the admission inputs.
        struct Prep {
            id: u64,
            t_arrive: f64,
            docs: Vec<u32>,
            docs_tokens: Vec<(u32, usize)>,
            request_tokens: usize,
        }
        let mut preps = Vec::with_capacity(reqs.len());
        for r in reqs {
            let id = self.next_id;
            self.next_id += 1;
            let t_arrive = self.driver.now();
            self.pipeline.recorder.arrival(id, t_arrive);
            let q =
                self.em
                    .query(r.target_doc, cfg.query_noise, &mut self.rng);
            let hits = self.index.search(&q, cfg.top_k);
            let docs: Vec<u32> = hits.iter().map(|h| h.1).collect();
            self.pipeline
                .recorder
                .retrieval_done(id, self.driver.now());
            let docs_tokens: Vec<(u32, usize)> = docs
                .iter()
                .map(|&d| (d, self.doc_tokens[d as usize].len()))
                .collect();
            // The separator + question form the request tail.
            let request_tokens = 1 + r.query_tokens.len(); // SEP + question
            preps.push(Prep {
                id,
                t_arrive,
                docs,
                docs_tokens,
                request_tokens,
            });
        }

        // Phase 2: shared batched admission — match → promote (with
        // GPU-prefix fallback) → pin → (α, β) per member, transfers
        // coalesced into one burst charged once through the driver.
        let base = preps.first().map(|p| p.id).unwrap_or(0);
        let batch = {
            let pipeline = &self.pipeline;
            BatchAdmission::admit_with(
                &self.driver,
                preps.iter().map(|p| p.id),
                |id| {
                    let p = &preps[(id - base) as usize];
                    Ok(pipeline.admit_one(&p.docs_tokens, p.request_tokens))
                },
            )
        };
        debug_assert!(batch.failed().is_empty(), "real admission is total");

        // Phase 3: per-member prefill → commit → decode. Members align
        // by id, never positionally: should an admission ever fail
        // mid-batch (the `admit_with` Err path), every other member
        // keeps its own admission and the failed one reports its own
        // error instead of shifting the pairing.
        let mut admissions: std::collections::HashMap<u64, Admission> =
            batch.into_members().into_iter().collect();
        preps
            .into_iter()
            .zip(reqs)
            .map(|(prep, r)| match admissions.remove(&prep.id) {
                Some(adm) => self.finish_one(
                    prep.id,
                    prep.t_arrive,
                    prep.docs,
                    adm,
                    &r.query_tokens,
                    r.max_new,
                    cfg,
                ),
                None => Err(anyhow::anyhow!(
                    "request {}: GPU admission failed mid-batch; \
                     pins released, re-submit",
                    prep.id
                )),
            })
            .collect()
    }

    /// The TCP handlers' shared wire entry point (`ragcache serve` and
    /// the e2e example drive the identical code): build the
    /// [`BatchRequest`]s from the protocol tuples — `max_new` clamped
    /// to the compiled decode budget — serve the batch, and convert
    /// each response to its wire form.
    pub fn serve_proto_batch(
        &mut self,
        batch: &[(u32, String, usize)],
        tok: &ByteTokenizer,
        cfg: &RealConfig,
    ) -> Vec<Result<crate::server::proto::QueryResult>> {
        let reqs: Vec<BatchRequest> = batch
            .iter()
            .map(|(doc, query, max_new)| BatchRequest {
                target_doc: *doc,
                query_tokens: tok.encode(query),
                max_new: (*max_new).clamp(1, 16),
            })
            .collect();
        self.serve_batch(&reqs, cfg)
            .into_iter()
            .map(|r| r.map(|resp| resp.into_query_result(tok)))
            .collect()
    }

    /// Post-admission tail of one request: prefill the non-cached
    /// tokens, commit the new document KV, decode greedily.
    #[allow(clippy::too_many_arguments)]
    fn finish_one(
        &mut self,
        id: u64,
        t_arrive: f64,
        docs: Vec<u32>,
        adm: Admission,
        query_tokens: &[i32],
        max_new: usize,
        cfg: &RealConfig,
    ) -> Result<RealResponse> {
        let mut kv = self.cache().concat_payloads(&adm);

        // Non-cached documents + separator + question.
        let mut new_tokens: Vec<i32> = Vec::new();
        let mut doc_lens = Vec::new();
        for &(d, _) in &adm.unmatched {
            let toks = &self.doc_tokens[d as usize];
            new_tokens.extend_from_slice(toks);
            doc_lens.push(toks.len());
        }
        let doc_token_total: usize = doc_lens.iter().sum();
        new_tokens.push(SEP);
        new_tokens.extend_from_slice(query_tokens);
        let beta = adm.beta;
        debug_assert_eq!(beta, new_tokens.len());

        let kv_per_tok =
            self.model.manifest().arch.kv_floats_per_token();
        let kv_before = kv.len();
        let t_prefill0 = self.driver.now();
        let logits =
            match self.chunked_prefill(&mut kv, &new_tokens, cfg.chunk) {
                Ok(l) => l,
                Err(e) => {
                    // The admission contract: a failed prefill must still
                    // return the pins, or the shared cache accumulates
                    // unevictable nodes for the life of the server.
                    self.pipeline.abort_admission(&adm);
                    return Err(e);
                }
            };
        let t_first = self.driver.now();
        self.pipeline.recorder.first_token(id, t_first);
        let prefill_secs = t_first - t_prefill0;

        // Cache the newly computed document KV (rows precede SEP+query):
        // shared commit path — policy refresh for hits, then unpin +
        // insert the new children with their payloads.
        let new_kv = &kv[kv_before..];
        let doc_rows = &new_kv[..doc_token_total * kv_per_tok];
        let payloads = if doc_lens.is_empty() {
            Vec::new()
        } else {
            KvPayload::split(doc_rows, &doc_lens)
        };
        self.pipeline.touch_hits(&adm, prefill_secs, t_first);
        self.pipeline
            .commit_prefill(&adm, prefill_secs, t_first, Some(payloads));

        // Greedy decode.
        let mut out_tokens = vec![argmax(&logits) as i32];
        for _ in 1..max_new {
            let last = *out_tokens.last().unwrap();
            let step = self.model.prefill(&kv, &[last])?;
            kv.extend_from_slice(&step.new_kv);
            out_tokens.push(argmax(&step.last_logits) as i32);
        }
        let t_done = self.driver.now();
        self.pipeline.recorder.finished(id, t_done);
        self.pipeline.record_admission(id, docs.len(), &adm);

        Ok(RealResponse {
            id,
            docs,
            cached_tokens: adm.alpha,
            computed_tokens: beta,
            docs_hit: adm.matched_docs,
            ttft: t_first - t_arrive,
            total: t_done - t_arrive,
            output_tokens: out_tokens,
        })
    }
}

/// Result of an iterative-retrieval session (paper §9: "RAGCache supports
/// iterative retrieval by treating the intermediate iterations as
/// separate requests and caching the corresponding KV cache of the
/// documents").
#[derive(Debug, Clone)]
pub struct IterativeResponse {
    pub rounds: Vec<RealResponse>,
}

impl IterativeResponse {
    pub fn total_docs_hit(&self) -> usize {
        self.rounds.iter().map(|r| r.docs_hit).sum()
    }

    pub fn total_docs(&self) -> usize {
        self.rounds.iter().map(|r| r.docs.len()).sum()
    }
}

impl RealServer {
    /// Iterative retrieval: run `targets.len()` retrieve→generate rounds,
    /// feeding each round's output tokens into the next round's query.
    /// Each round is a normal [`RealServer::serve`] request, so document
    /// KV computed in earlier rounds is reusable by later ones.
    pub fn serve_iterative(
        &mut self,
        targets: &[u32],
        initial_query: &[i32],
        max_new_per_round: usize,
        cfg: &RealConfig,
    ) -> Result<IterativeResponse> {
        let mut rounds = Vec::with_capacity(targets.len());
        let mut query = initial_query.to_vec();
        for &target in targets {
            let resp =
                self.serve(target, &query, max_new_per_round, cfg)?;
            // Next round's query: the original question refined by the
            // intermediate generation (clamped to vocab byte range).
            query = initial_query.to_vec();
            query.extend(
                resp.output_tokens.iter().map(|&t| t.clamp(0, 255)),
            );
            rounds.push(resp);
        }
        Ok(IterativeResponse { rounds })
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}
