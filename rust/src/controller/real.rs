//! Real-mode server: the same knowledge-tree / policy / scheduling stack
//! driven in real time with *actual* computation — retrieval through the
//! Rust vector index, prefill/decode through the PJRT-compiled JAX+Pallas
//! artifacts, and real KV payloads cached in the tree.
//!
//! This is the *real driver* over the shared [`pipeline`](super::pipeline)
//! core: admission (match → promote → pin → α/β), policy refresh and
//! post-prefill insertion are the exact code the simulated controller
//! runs; this file contributes wall-clock timing, real vector search and
//! PJRT execution. It is the end-to-end proof that all three layers
//! compose; the paper-scale experiments use the virtual-clock
//! [`super::sim_server`].

use super::pipeline::{Pipeline, PipelineDriver};
use super::shard::ShardedCacheService;
use crate::embed::EmbeddingModel;
use crate::kvcache::{KvPayload, PageSpec};
use crate::llm::tokenizer::SEP;
use crate::metrics::Recorder;
use crate::policy::make_policy;
use crate::runtime::PjrtModel;
use crate::sim::{Clock, RealClock};
use crate::tree::KnowledgeTree;
use crate::util::Rng;
use crate::vectordb::VectorIndex;
use anyhow::{Context, Result};

/// Real-mode server configuration.
#[derive(Debug, Clone)]
pub struct RealConfig {
    pub top_k: usize,
    /// Logical GPU-tier budget for the document cache, bytes.
    pub gpu_cache_bytes: u64,
    pub host_cache_bytes: u64,
    pub block_tokens: usize,
    pub policy: crate::config::PolicyKind,
    /// Prefill chunk size (must fit the largest compiled beta bucket).
    pub chunk: usize,
    /// Query-embedding noise (0 = queries hit their target exactly).
    pub query_noise: f64,
}

impl Default for RealConfig {
    fn default() -> Self {
        RealConfig {
            top_k: 2,
            gpu_cache_bytes: 4 * 1024 * 1024,
            host_cache_bytes: 32 * 1024 * 1024,
            block_tokens: 16,
            policy: crate::config::PolicyKind::Pgdsf,
            chunk: 64,
            query_noise: 0.02,
        }
    }
}

/// Aggregate serving metrics, cheap enough for per-poll computation.
#[derive(Debug, Clone, Copy)]
pub struct ServingStats {
    pub requests: usize,
    pub mean_ttft_s: f64,
    pub hit_rate: f64,
}

/// Response of one served request.
#[derive(Debug, Clone)]
pub struct RealResponse {
    pub id: u64,
    pub docs: Vec<u32>,
    pub cached_tokens: usize,
    pub computed_tokens: usize,
    pub docs_hit: usize,
    /// Wall-clock time to first token, seconds.
    pub ttft: f64,
    pub total: f64,
    pub output_tokens: Vec<i32>,
}

/// The real-mode [`PipelineDriver`]: wall clock; GPU↔host "transfers" are
/// in-process copies whose cost is already part of measured latency.
struct RealDriver {
    clock: RealClock,
}

impl PipelineDriver for RealDriver {
    fn now(&self) -> f64 {
        self.clock.now()
    }

    fn transfer_time(&self, _bytes: u64) -> f64 {
        0.0
    }
}

/// The real-mode serving stack.
pub struct RealServer {
    model: PjrtModel,
    pipeline: Pipeline,
    driver: RealDriver,
    index: Box<dyn VectorIndex>,
    em: EmbeddingModel,
    /// Token ids of each knowledge document.
    doc_tokens: Vec<Vec<i32>>,
    rng: Rng,
    next_id: u64,
}

impl RealServer {
    /// The page spec this server would size its cache with — exposed so
    /// callers can pre-build a shared [`ShardedCacheService`] (e.g. for
    /// the concurrent runtime's priority estimator) before the
    /// non-`Send` PJRT model exists.
    pub fn page_spec(
        kv_floats_per_token: usize,
        cfg: &RealConfig,
    ) -> PageSpec {
        PageSpec {
            block_tokens: cfg.block_tokens,
            kv_bytes_per_token: kv_floats_per_token * 4,
        }
    }

    /// Build the knowledge tree this server would construct itself.
    pub fn build_tree(
        kv_floats_per_token: usize,
        cfg: &RealConfig,
    ) -> KnowledgeTree {
        KnowledgeTree::new(
            cfg.gpu_cache_bytes,
            cfg.host_cache_bytes,
            Self::page_spec(kv_floats_per_token, cfg),
            make_policy(cfg.policy),
            true,
            0,
        )
    }

    /// Build a K-shard cache service for this model, splitting the
    /// configured tier budgets evenly across shards. Shared between the
    /// M engine replicas of a concurrent deployment (each shard has its
    /// own lock, so replicas admit in parallel).
    pub fn build_sharded_cache(
        kv_floats_per_token: usize,
        cfg: &RealConfig,
        shards: usize,
    ) -> ShardedCacheService {
        let k = shards.max(1);
        let page = Self::page_spec(kv_floats_per_token, cfg);
        ShardedCacheService::build(k, |_| {
            KnowledgeTree::new(
                cfg.gpu_cache_bytes / k as u64,
                cfg.host_cache_bytes / k as u64,
                page,
                make_policy(cfg.policy),
                true,
                0,
            )
        })
    }

    pub fn new(
        model: PjrtModel,
        index: Box<dyn VectorIndex>,
        em: EmbeddingModel,
        doc_tokens: Vec<Vec<i32>>,
        cfg: &RealConfig,
    ) -> Result<Self> {
        let kv = model.manifest().arch.kv_floats_per_token();
        let cache =
            ShardedCacheService::single(Self::build_tree(kv, cfg));
        Self::with_cache(model, index, em, doc_tokens, cache)
    }

    /// Assemble the stack around a pre-built, possibly shared cache
    /// service (its trees must have been sized with
    /// [`RealServer::page_spec`] for this model).
    pub fn with_cache(
        model: PjrtModel,
        index: Box<dyn VectorIndex>,
        em: EmbeddingModel,
        doc_tokens: Vec<Vec<i32>>,
        cache: ShardedCacheService,
    ) -> Result<Self> {
        Ok(RealServer {
            model,
            // Real-mode request ordering happens in the concurrent TCP
            // runtime's SharedReorderQueue (crate::server), not here:
            // this pipeline's own queue is unused, so it stays FIFO.
            pipeline: Pipeline::new(Some(cache), false, 1),
            driver: RealDriver {
                clock: RealClock::new(),
            },
            index,
            em,
            doc_tokens,
            rng: Rng::new(0xE2E),
            next_id: 0,
        })
    }

    /// Snapshot of the serving metrics. O(requests served) — intended
    /// for offline analysis (tests, examples), not the polling path; use
    /// [`RealServer::stats`] for that.
    pub fn recorder(&self) -> Recorder {
        self.pipeline.recorder.clone()
    }

    /// Cheap aggregates for observability polling (no record snapshot).
    pub fn stats(&self) -> ServingStats {
        let r = &self.pipeline.recorder;
        ServingStats {
            requests: r.len(),
            mean_ttft_s: r.ttft().mean(),
            hit_rate: r.hit_rate(),
        }
    }

    /// The shared, thread-safe (sharded) cache service backing this
    /// server — usable from other threads (e.g. the concurrent TCP
    /// runtime's priority estimator and sibling engine replicas) and
    /// for administration / failure injection.
    pub fn cache(&self) -> &ShardedCacheService {
        self.pipeline
            .cache
            .as_ref()
            .expect("real server always has a cache")
    }

    /// Chunked prefill through the compiled buckets: feeds `tokens` on
    /// top of `prefix_kv` in chunks, returning the final logits and all
    /// new KV rows.
    fn chunked_prefill(
        &self,
        prefix_kv: &mut Vec<f32>,
        tokens: &[i32],
        chunk: usize,
    ) -> Result<Vec<f32>> {
        let mut last_logits = Vec::new();
        for piece in tokens.chunks(chunk.max(1)) {
            let out = self
                .model
                .prefill(prefix_kv, piece)
                .context("chunked prefill")?;
            prefix_kv.extend_from_slice(&out.new_kv);
            last_logits = out.last_logits;
        }
        debug_assert!(!last_logits.is_empty());
        Ok(last_logits)
    }

    /// Serve one request: retrieve, reuse cached document KV, prefill the
    /// rest, decode `max_new` tokens greedily.
    pub fn serve(
        &mut self,
        target_doc: u32,
        query_tokens: &[i32],
        max_new: usize,
        cfg: &RealConfig,
    ) -> Result<RealResponse> {
        let id = self.next_id;
        self.next_id += 1;
        let t_arrive = self.driver.now();
        self.pipeline.recorder.arrival(id, t_arrive);

        // Retrieval (Rust vector index — real search).
        let q = self.em.query(target_doc, cfg.query_noise, &mut self.rng);
        let hits = self.index.search(&q, cfg.top_k);
        let docs: Vec<u32> = hits.iter().map(|h| h.1).collect();
        self.pipeline
            .recorder
            .retrieval_done(id, self.driver.now());

        // Shared admission: match → promote (with GPU-prefix fallback) →
        // pin → (α, β). The separator + question form the request tail.
        let docs_tokens: Vec<(u32, usize)> = docs
            .iter()
            .map(|&d| (d, self.doc_tokens[d as usize].len()))
            .collect();
        let request_tokens = 1 + query_tokens.len(); // SEP + question
        let (adm, _transfer_secs) =
            self.pipeline
                .admit(&self.driver, &docs_tokens, request_tokens);
        let mut kv = self.cache().concat_payloads(&adm);

        // Non-cached documents + separator + question.
        let mut new_tokens: Vec<i32> = Vec::new();
        let mut doc_lens = Vec::new();
        for &(d, _) in &adm.unmatched {
            let toks = &self.doc_tokens[d as usize];
            new_tokens.extend_from_slice(toks);
            doc_lens.push(toks.len());
        }
        let doc_token_total: usize = doc_lens.iter().sum();
        new_tokens.push(SEP);
        new_tokens.extend_from_slice(query_tokens);
        let beta = adm.beta;
        debug_assert_eq!(beta, new_tokens.len());

        let kv_per_tok =
            self.model.manifest().arch.kv_floats_per_token();
        let kv_before = kv.len();
        let t_prefill0 = self.driver.now();
        let logits =
            match self.chunked_prefill(&mut kv, &new_tokens, cfg.chunk) {
                Ok(l) => l,
                Err(e) => {
                    // The admission contract: a failed prefill must still
                    // return the pins, or the shared cache accumulates
                    // unevictable nodes for the life of the server.
                    self.pipeline.abort_admission(&adm);
                    return Err(e);
                }
            };
        let t_first = self.driver.now();
        self.pipeline.recorder.first_token(id, t_first);
        let prefill_secs = t_first - t_prefill0;

        // Cache the newly computed document KV (rows precede SEP+query):
        // shared commit path — policy refresh for hits, then unpin +
        // insert the new children with their payloads.
        let new_kv = &kv[kv_before..];
        let doc_rows = &new_kv[..doc_token_total * kv_per_tok];
        let payloads = if doc_lens.is_empty() {
            Vec::new()
        } else {
            KvPayload::split(doc_rows, &doc_lens)
        };
        self.pipeline.touch_hits(&adm, prefill_secs, t_first);
        self.pipeline
            .commit_prefill(&adm, prefill_secs, t_first, Some(payloads));

        // Greedy decode.
        let mut out_tokens = vec![argmax(&logits) as i32];
        for _ in 1..max_new {
            let last = *out_tokens.last().unwrap();
            let step = self.model.prefill(&kv, &[last])?;
            kv.extend_from_slice(&step.new_kv);
            out_tokens.push(argmax(&step.last_logits) as i32);
        }
        let t_done = self.driver.now();
        self.pipeline.recorder.finished(id, t_done);
        self.pipeline.record_admission(id, docs.len(), &adm);

        Ok(RealResponse {
            id,
            docs,
            cached_tokens: adm.alpha,
            computed_tokens: beta,
            docs_hit: adm.matched_docs,
            ttft: t_first - t_arrive,
            total: t_done - t_arrive,
            output_tokens: out_tokens,
        })
    }
}

/// Result of an iterative-retrieval session (paper §9: "RAGCache supports
/// iterative retrieval by treating the intermediate iterations as
/// separate requests and caching the corresponding KV cache of the
/// documents").
#[derive(Debug, Clone)]
pub struct IterativeResponse {
    pub rounds: Vec<RealResponse>,
}

impl IterativeResponse {
    pub fn total_docs_hit(&self) -> usize {
        self.rounds.iter().map(|r| r.docs_hit).sum()
    }

    pub fn total_docs(&self) -> usize {
        self.rounds.iter().map(|r| r.docs.len()).sum()
    }
}

impl RealServer {
    /// Iterative retrieval: run `targets.len()` retrieve→generate rounds,
    /// feeding each round's output tokens into the next round's query.
    /// Each round is a normal [`RealServer::serve`] request, so document
    /// KV computed in earlier rounds is reusable by later ones.
    pub fn serve_iterative(
        &mut self,
        targets: &[u32],
        initial_query: &[i32],
        max_new_per_round: usize,
        cfg: &RealConfig,
    ) -> Result<IterativeResponse> {
        let mut rounds = Vec::with_capacity(targets.len());
        let mut query = initial_query.to_vec();
        for &target in targets {
            let resp =
                self.serve(target, &query, max_new_per_round, cfg)?;
            // Next round's query: the original question refined by the
            // intermediate generation (clamped to vocab byte range).
            query = initial_query.to_vec();
            query.extend(
                resp.output_tokens.iter().map(|&t| t.clamp(0, 255)),
            );
            rounds.push(resp);
        }
        Ok(IterativeResponse { rounds })
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}
