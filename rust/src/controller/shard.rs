//! Sharded knowledge-tree service (paper §5.1 scaled out) with
//! demand-driven cross-shard tier rebalancing.
//!
//! [`ShardedCacheService`] owns K independent [`CacheService`] shards —
//! each with its own lock, tier-budget slice and counters — keyed by a
//! request's FIRST document id. Admission from N connection workers then
//! scales past one core: requests over different shards never touch the
//! same lock, while the admit → compute → commit protocol is exactly
//! [`CacheService`]'s, so [`Pipeline`](super::Pipeline), both drivers
//! and the priority estimator are untouched at their call sites.
//!
//! Routing by the first (root-child) document is sound because the
//! knowledge tree is a prefix tree: every path `[d0, d1, ...]` lives
//! entirely under the root child for `d0`, so the shard owning `d0`
//! owns the whole path and no path can span shards. Each shard carries
//! its own permanently pinned root (the system prompt S of Fig. 8),
//! mirroring a per-replica prompt prefix.
//!
//! ## Cross-shard tier rebalancing
//!
//! The paper's workloads are heavily skewed (Fig. 5/6: a few percent of
//! documents absorb most accesses), so frozen 1/K budget slices leave a
//! hot shard thrashing while cold shards strand idle GPU bytes. With
//! rebalancing enabled ([`ShardedCacheService::enable_rebalancing`]),
//! every engine loop calls [`maintenance_tick`] once per iteration;
//! every [`RebalanceConfig::interval`] ticks the rebalancer recomputes
//! demand-proportional slices and moves capacity cold → hot:
//!
//! ```text
//!   tick ──► demand_i = Δgpu_hit_bytes + Δchunk_hit_bytes
//!              │          + Δswap_out_bytes + gpu_used
//!              │            (per-shard TreeCounters deltas + gauge)
//!              ▼
//!            targets = proportional_slices(total, demand, min_share)
//!              │   Σ targets == configured budget, bit-exact
//!              ▼
//!            donors SHRINK first (evict-to-fit under the shard lock,
//!            via the replacement policy; pinned nodes refuse — a
//!            refused donor simply is not harvested), THEN receivers
//!            GROW, hottest first, from what was actually freed
//! ```
//!
//! The conservation invariant — the sum of shard capacities equals the
//! configured budget, bit-exact, after every tick — holds by
//! construction: receivers are only granted bytes a donor verifiably
//! freed. Donor swap-outs are returned as [`Transfers`] so the sim
//! driver keeps PCIe time charged; `--rebalance off` (no rebalancer
//! installed) makes [`maintenance_tick`] a no-op and the static split
//! bit-identical to the pre-rebalancing behavior.
//!
//! [`maintenance_tick`]: ShardedCacheService::maintenance_tick

use super::pipeline::{Admission, CacheService, CommitOutcome};
use crate::kvcache::{KvPayload, Tier};
use crate::tree::{
    DocId, KnowledgeTree, MatchResult, TierOccupancy, Transfers,
    TreeCounters,
};
use std::sync::{Arc, Mutex, TryLockError};

/// Split `total` bytes into `k` slices that sum to `total` EXACTLY:
/// `total / k` each, with the division remainder spread one byte per
/// shard from the front. (A bare `total / k` per shard silently drops
/// up to `k - 1` bytes of configured budget — the
/// `build_sharded_cache` truncation bug.)
pub fn split_budget(total: u64, k: usize) -> Vec<u64> {
    let k = k.max(1) as u64;
    let base = total / k;
    let rem = total % k;
    (0..k).map(|i| base + u64::from(i < rem)).collect()
}

/// Rebalancer tuning.
#[derive(Debug, Clone)]
pub struct RebalanceConfig {
    /// Maintenance ticks (engine iterations / session polls) between
    /// slice recomputations.
    pub interval: u64,
    /// Fraction of the fair 1/K share every shard always keeps, so a
    /// cold shard can warm back up without first waiting a full
    /// interval at zero capacity.
    pub min_share: f64,
    /// Dead band as a fraction of the fair share: a shard whose target
    /// differs from its current slice by less than this is left alone,
    /// so steady-state demand noise cannot churn capacity (and
    /// evictions) back and forth.
    pub hysteresis: f64,
}

impl Default for RebalanceConfig {
    fn default() -> Self {
        RebalanceConfig {
            interval: 32,
            min_share: 0.25,
            hysteresis: 1.0 / 16.0,
        }
    }
}

/// Aggregate rebalancer activity counters (observability; threaded into
/// the stats endpoint).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RebalanceStats {
    /// Slice recomputations performed (interval boundaries reached).
    pub recomputes: u64,
    /// Shard capacity adjustments applied (donor shrinks + receiver
    /// grows, both tiers).
    pub moves: u64,
    /// GPU-tier capacity bytes moved between shards, total.
    pub gpu_bytes_moved: u64,
    /// Host-tier capacity bytes moved between shards, total.
    pub host_bytes_moved: u64,
    /// Donor shrinks refused because pinned nodes kept the shard over
    /// its shrink target.
    pub refused_shrinks: u64,
}

/// Shared rebalancer state, guarded by one mutex: whichever engine's
/// tick crosses the interval runs the recompute; concurrent tickers
/// skip past a held lock instead of convoying behind an eviction sweep.
struct RebalanceState {
    cfg: RebalanceConfig,
    /// Conserved totals — the configured budgets at enable time.
    gpu_total: u64,
    host_total: u64,
    ticks: u64,
    /// Per-shard counter snapshot at the last recompute, for deltas.
    last: Vec<TreeCounters>,
    stats: RebalanceStats,
}

/// The shared rebalancer handle. `state` is held across a whole
/// recompute (including donor eviction sweeps); `published` holds a
/// copy of the counters refreshed after each recompute, so the
/// read-only stats path copies it in O(1) instead of queueing behind
/// an in-flight sweep. Lock order is state → published (the publisher
/// holds both momentarily); readers take `published` alone.
struct Rebalancer {
    state: Mutex<RebalanceState>,
    published: Mutex<RebalanceStats>,
}

/// Split `total` proportionally to `demand`, with a per-slice floor of
/// `min_share` of the fair share, summing to `total` EXACTLY (the
/// truncation remainder goes to the highest-demand slices first, ties
/// to the lower index — fully deterministic).
fn proportional_slices(
    total: u64,
    demand: &[u128],
    min_share: f64,
) -> Vec<u64> {
    let k = demand.len().max(1);
    let fair = total / k as u64;
    let floor = (fair as f64 * min_share.clamp(0.0, 1.0)) as u64;
    // floor <= fair, so k * floor <= total.
    let spread = total - floor * k as u64;
    let sum: u128 = demand.iter().sum();
    let mut out: Vec<u64> = if sum == 0 {
        split_budget(spread, k)
    } else {
        demand
            .iter()
            .map(|&d| (spread as u128 * d / sum) as u64)
            .collect()
    };
    let assigned: u64 = out.iter().sum();
    let rem = spread - assigned; // < k: each term truncates < 1 away
    if rem > 0 {
        let mut order: Vec<usize> = (0..k).collect();
        order.sort_by(|&a, &b| demand[b].cmp(&demand[a]).then(a.cmp(&b)));
        for &i in order.iter().cycle().take(rem as usize) {
            out[i] += 1;
        }
    }
    for o in out.iter_mut() {
        *o += floor;
    }
    debug_assert_eq!(out.iter().sum::<u64>(), total);
    out
}

/// K independent [`CacheService`] shards behind the same protocol.
/// Cloning shares the shards AND the rebalancer state (each
/// `CacheService` is itself a shared handle), so connection workers,
/// engine drivers and estimators all see one cache.
#[derive(Clone)]
pub struct ShardedCacheService {
    shards: Arc<[CacheService]>,
    /// Demand-driven tier rebalancer; `None` = static slices
    /// (`--rebalance off`), bit-identical to the pre-rebalancing path.
    rebalancer: Option<Arc<Rebalancer>>,
}

impl ShardedCacheService {
    pub fn new(shards: Vec<CacheService>) -> Self {
        assert!(!shards.is_empty(), "a cache needs at least one shard");
        ShardedCacheService {
            shards: shards.into(),
            rebalancer: None,
        }
    }

    /// Single-shard service over one tree — the drop-in successor of
    /// `CacheService::new` for the simulation and single-engine paths.
    pub fn single(tree: KnowledgeTree) -> Self {
        Self::new(vec![CacheService::new(tree)])
    }

    /// Build K shards from a per-shard tree builder. The builder should
    /// size each tree with its slice of the tier budgets (a K-way split
    /// of the GPU/host bytes).
    pub fn build(
        num_shards: usize,
        mut builder: impl FnMut(usize) -> KnowledgeTree,
    ) -> Self {
        let k = num_shards.max(1);
        Self::new(
            (0..k).map(|i| CacheService::new(builder(i))).collect(),
        )
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// The shard owning a document sequence: keyed by the first
    /// (root-child) document. Empty sequences go to shard 0.
    pub fn shard_of(&self, docs: &[DocId]) -> usize {
        docs.first().map_or(0, |&d| self.shard_of_doc(d))
    }

    /// The shard owning paths rooted at `doc`.
    pub fn shard_of_doc(&self, doc: DocId) -> usize {
        doc as usize % self.shards.len()
    }

    /// Direct access to one shard (administration, tests).
    pub fn shard(&self, idx: usize) -> &CacheService {
        &self.shards[idx]
    }

    /// O(h) prefix match against the owning shard (no pinning; a
    /// snapshot for priority estimates).
    pub fn lookup(&self, docs: &[DocId]) -> MatchResult {
        self.shards[self.shard_of(docs)].lookup(docs)
    }

    /// Chunk-aware non-pinning estimate on the owning shard: the prefix
    /// match plus the reused tokens the chunk cache would add for the
    /// docs past it (0 with the chunk cache off). See
    /// [`CacheService::lookup_with_chunks`].
    pub fn lookup_with_chunks(
        &self,
        docs: &[DocId],
    ) -> (MatchResult, usize) {
        self.shards[self.shard_of(docs)].lookup_with_chunks(docs)
    }

    /// Admission stage A against the owning shard. The returned
    /// [`Admission`] records its shard, so [`commit`]/[`release`]/
    /// [`touch_hits`] route back without the caller knowing about
    /// sharding at all.
    ///
    /// [`commit`]: ShardedCacheService::commit
    /// [`release`]: ShardedCacheService::release
    /// [`touch_hits`]: ShardedCacheService::touch_hits
    pub fn admit(
        &self,
        docs: &[(DocId, usize)],
        request_tokens: usize,
    ) -> Admission {
        let shard =
            docs.first().map_or(0, |&(d, _)| self.shard_of_doc(d));
        let mut adm = self.shards[shard].admit(docs, request_tokens);
        adm.shard = shard;
        adm
    }

    /// Policy refresh for an admission's hit nodes, on its shard.
    pub fn touch_hits(
        &self,
        adm: &Admission,
        estimated_time: f64,
        now: f64,
    ) {
        self.shards[adm.shard].touch_hits(adm, estimated_time, now);
    }

    /// Admission stage B on the admission's shard. See
    /// [`CacheService::commit`].
    pub fn commit(
        &self,
        adm: &Admission,
        estimated_time: f64,
        now: f64,
        payloads: Option<Vec<KvPayload>>,
    ) -> CommitOutcome {
        self.shards[adm.shard].commit(adm, estimated_time, now, payloads)
    }

    /// Abandon an admission without inserting anything.
    pub fn release(&self, adm: &Admission) {
        self.shards[adm.shard].release(adm);
    }

    /// Concatenate an admission's full reused prefix KV (real mode) —
    /// pinned path payloads plus each chunk hit's reused rows — from
    /// the shard that owns it.
    pub fn concat_payloads(&self, adm: &Admission) -> Vec<f32> {
        self.shards[adm.shard].concat_admission_payloads(adm)
    }

    /// Counters aggregated across every shard (the `Stats` endpoint and
    /// metrics read this).
    pub fn counters(&self) -> TreeCounters {
        let mut total = TreeCounters::default();
        for s in self.shards.iter() {
            total.merge(s.counters());
        }
        total
    }

    /// Validate every shard's structural invariants.
    pub fn check_invariants(&self) {
        for s in self.shards.iter() {
            s.check_invariants();
        }
    }

    /// In-flight pins summed across shards (excludes the per-shard
    /// roots' permanent pins).
    pub fn pinned_nodes(&self) -> usize {
        self.shards.iter().map(|s| s.pinned_nodes()).sum()
    }

    /// Simulate a GPU failure on every shard (§6). Returns the summed
    /// `(lost, recovered)` node counts.
    pub fn fail_gpu(&self) -> (usize, usize) {
        let mut lost = 0;
        let mut recovered = 0;
        for s in self.shards.iter() {
            let (l, r) = s.fail_gpu();
            lost += l;
            recovered += r;
        }
        (lost, recovered)
    }

    /// Drain every shard's async disk staging queue (`--disk on`): the
    /// simulator calls this once per engine iteration, the real path
    /// from its background staging thread. Returns entries written
    /// across all shards; a no-op (0) with the disk tier off.
    pub fn flush_disk_staging(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.with(|t| t.flush_disk_staging()))
            .sum()
    }

    /// Whether any shard has the NVMe disk tier enabled.
    pub fn disk_enabled(&self) -> bool {
        self.shards.iter().any(|s| s.with(|t| t.disk_enabled()))
    }

    /// CAG corpus pre-staging on the owning shard: park `doc`'s KV as a
    /// pinned disk entry (or a best-effort owned chunk entry with the
    /// disk off). See [`KnowledgeTree::prestage_corpus_doc`].
    pub fn prestage_corpus_doc(
        &self,
        doc: DocId,
        tokens: usize,
        rope_offset: usize,
        payload: Option<KvPayload>,
    ) -> bool {
        self.shards[self.shard_of_doc(doc)]
            .with(|t| t.prestage_corpus_doc(doc, tokens, rope_offset, payload))
    }

    /// Per-shard tier occupancy gauges (used/capacity, both tiers) —
    /// the rebalancer's input and the stats endpoint's per-shard view.
    pub fn shard_occupancies(&self) -> Vec<TierOccupancy> {
        self.shards.iter().map(|s| s.occupancy()).collect()
    }

    /// Install the demand-driven tier rebalancer (`--rebalance on`).
    /// The conserved totals are the shard capacities at this moment, so
    /// enable BEFORE serving mutates anything — and BEFORE taking
    /// clones: clones taken after this call share the rebalancer
    /// state, but clones taken earlier keep `None` and tick as the
    /// static path (the field lives in the handle, not behind the
    /// shared `Arc`).
    pub fn enable_rebalancing(&mut self, cfg: RebalanceConfig) {
        let occ = self.shard_occupancies();
        self.rebalancer = Some(Arc::new(Rebalancer {
            state: Mutex::new(RebalanceState {
                gpu_total: occ.iter().map(|o| o.gpu_capacity).sum(),
                host_total: occ.iter().map(|o| o.host_capacity).sum(),
                ticks: 0,
                last: self
                    .shards
                    .iter()
                    .map(|s| s.counters())
                    .collect(),
                cfg,
                stats: RebalanceStats::default(),
            }),
            published: Mutex::new(RebalanceStats::default()),
        }));
    }

    pub fn rebalancing_enabled(&self) -> bool {
        self.rebalancer.is_some()
    }

    /// Rebalancer activity counters (zeros when rebalancing is off).
    /// Reads the published copy — an O(1) lock never held across a
    /// recompute — so a stats request cannot convoy behind a sibling
    /// engine's in-flight eviction sweep.
    pub fn rebalance_stats(&self) -> RebalanceStats {
        match &self.rebalancer {
            None => RebalanceStats::default(),
            Some(rb) => match rb.published.lock() {
                Ok(g) => *g,
                Err(p) => *p.into_inner(),
            },
        }
    }

    /// One maintenance tick from an engine loop. Counts toward the
    /// recompute interval; on an interval boundary, recomputes
    /// demand-proportional slices and moves capacity cold → hot,
    /// returning the donor evictions' swap-out transfers so the caller
    /// charges link time (the sim driver delays its next iteration; the
    /// real driver's copies are already in measured latency). No-op —
    /// and lock-free — when rebalancing is off; a tick that finds the
    /// state locked skips (a sibling engine is already rebalancing)
    /// rather than convoying behind its eviction sweep.
    pub fn maintenance_tick(&self) -> Option<Transfers> {
        let rb = self.rebalancer.as_ref()?;
        let mut st = match rb.state.try_lock() {
            Ok(g) => g,
            Err(TryLockError::Poisoned(p)) => p.into_inner(),
            Err(TryLockError::WouldBlock) => return None,
        };
        st.ticks += 1;
        if st.ticks % st.cfg.interval.max(1) != 0 {
            return None;
        }
        let moved = self.rebalance_now(&mut st);
        // Refresh the stats copy the read-only path serves from.
        match rb.published.lock() {
            Ok(mut g) => *g = st.stats,
            Err(p) => *p.into_inner() = st.stats,
        }
        Some(moved)
    }

    /// Recompute slices from the per-shard demand signals and apply the
    /// moves, one tier at a time.
    fn rebalance_now(&self, st: &mut RebalanceState) -> Transfers {
        let k = self.shards.len();
        let counters: Vec<TreeCounters> =
            self.shards.iter().map(|s| s.counters()).collect();
        let occ = self.shard_occupancies();
        // Demand: bytes served from GPU since the last recompute (hot
        // traffic, prefix hits AND position-independent chunk hits) +
        // swap-out thrash (capacity shortage shows up as eviction
        // bytes) + current GPU occupancy (an idle-but-warm working set
        // is still demand; a cold empty shard is not).
        let demand: Vec<u128> = (0..k)
            .map(|i| {
                let hit = counters[i]
                    .gpu_hit_bytes
                    .saturating_sub(st.last[i].gpu_hit_bytes);
                let chunk = counters[i]
                    .chunk_hit_bytes
                    .saturating_sub(st.last[i].chunk_hit_bytes);
                let thrash = counters[i]
                    .swap_out_bytes
                    .saturating_sub(st.last[i].swap_out_bytes);
                hit as u128
                    + chunk as u128
                    + thrash as u128
                    + occ[i].gpu_used as u128
            })
            .collect();
        st.last = counters;
        st.stats.recomputes += 1;
        let mut moved = Transfers::default();
        if demand.iter().sum::<u128>() == 0 {
            return moved; // nothing observed yet: keep current slices
        }
        let gpu_targets =
            proportional_slices(st.gpu_total, &demand, st.cfg.min_share);
        let host_targets =
            proportional_slices(st.host_total, &demand, st.cfg.min_share);
        let gpu_current: Vec<u64> =
            occ.iter().map(|o| o.gpu_capacity).collect();
        let host_current: Vec<u64> =
            occ.iter().map(|o| o.host_capacity).collect();
        // Host tier first: a shard shrinking both tiers then swaps its
        // GPU evictions into the already-trimmed host slice (what does
        // not fit is dropped outright instead of paying a g2h burst
        // only to be dropped by a host pass moments later), and a
        // gpu-donor/host-receiver shard has its bigger host slice
        // ready before the swap-outs arrive.
        moved.merge(self.apply_tier(
            Tier::Host,
            &host_current,
            &host_targets,
            &demand,
            st,
        ));
        moved.merge(self.apply_tier(
            Tier::Gpu,
            &gpu_current,
            &gpu_targets,
            &demand,
            st,
        ));
        moved
    }

    /// Move one tier's capacity toward `targets`: donors shrink first
    /// (evict-to-fit under their shard lock; a refusal — pinned nodes —
    /// keeps their old slice), then receivers grow, hottest first, from
    /// the bytes actually freed. Conservation holds at every step: a
    /// byte is granted only after a donor verifiably released it.
    fn apply_tier(
        &self,
        tier: Tier,
        current: &[u64],
        targets: &[u64],
        demand: &[u128],
        st: &mut RebalanceState,
    ) -> Transfers {
        let k = self.shards.len();
        let fair = match tier {
            Tier::Gpu => st.gpu_total,
            Tier::Host => st.host_total,
        } / k as u64;
        let dead_band =
            (fair as f64 * st.cfg.hysteresis.clamp(0.0, 1.0)) as u64;
        let mut transfers = Transfers::default();
        let mut freed: u64 = 0;
        for i in 0..k {
            if current[i].saturating_sub(targets[i]) <= dead_band {
                continue; // not a donor (or within the dead band)
            }
            match self.shards[i].resize_tier(tier, targets[i]) {
                Ok(t) => {
                    transfers.merge(t);
                    freed += current[i] - targets[i];
                    st.stats.moves += 1;
                }
                // Refused (pinned nodes): the slice keeps its old size,
                // but any evictions performed before the refusal still
                // moved real bytes — keep them charged.
                Err(t) => {
                    transfers.merge(t);
                    st.stats.refused_shrinks += 1;
                }
            }
        }
        if freed == 0 {
            return transfers;
        }
        // Receivers take every freed byte, hottest first, each capped
        // at its own target. No dead band on the grant side: grows
        // never evict (hysteresis only matters for donors), and
        // capping at the target means no receiver overshoots into
        // being next tick's donor. Full distribution is guaranteed —
        // targets and current slices both sum to the conserved total,
        // so Σ receiver wants ≥ Σ donor excess ≥ freed.
        let mut order: Vec<usize> = (0..k).collect();
        order.sort_by(|&a, &b| demand[b].cmp(&demand[a]).then(a.cmp(&b)));
        for &i in &order {
            if freed == 0 {
                break;
            }
            let want = targets[i].saturating_sub(current[i]);
            if want == 0 {
                continue;
            }
            let grant = want.min(freed);
            let grown = self.shards[i]
                .resize_tier(tier, current[i] + grant)
                .is_ok();
            debug_assert!(grown, "growing a tier never fails");
            freed -= grant;
            st.stats.moves += 1;
            match tier {
                Tier::Gpu => st.stats.gpu_bytes_moved += grant,
                Tier::Host => st.stats.host_bytes_moved += grant,
            }
        }
        debug_assert_eq!(freed, 0, "every freed byte was granted");
        transfers
    }
}

impl From<CacheService> for ShardedCacheService {
    fn from(svc: CacheService) -> Self {
        ShardedCacheService::new(vec![svc])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyKind;
    use crate::kvcache::PageSpec;
    use crate::policy::make_policy;

    fn sharded(
        k: usize,
        gpu_tokens: usize,
        host_tokens: usize,
    ) -> ShardedCacheService {
        let page = PageSpec {
            block_tokens: 8,
            kv_bytes_per_token: 16,
        };
        ShardedCacheService::build(k, |_| {
            KnowledgeTree::new(
                page.bytes(gpu_tokens),
                page.bytes(host_tokens),
                page,
                make_policy(PolicyKind::Pgdsf),
                true,
                0,
            )
        })
    }

    /// The existing `CacheService` admission test, unchanged semantics,
    /// through the sharded front (acceptance: same admit/commit/release
    /// protocol per shard).
    #[test]
    fn admit_commit_roundtrip_inserts_and_unpins() {
        let svc = sharded(2, 1024, 1024);
        let docs = [(1u32, 16usize), (2, 16)];
        let adm = svc.admit(&docs, 8);
        assert_eq!(adm.shard, 1, "first doc 1 routes to shard 1 of 2");
        assert_eq!(adm.matched_docs, 0);
        assert_eq!(adm.alpha, 0);
        assert_eq!(adm.beta, 16 + 16 + 8);
        assert_eq!(adm.unmatched, vec![(1, 16), (2, 16)]);
        let out = svc.commit(&adm, 0.01, 1.0, None);
        assert_eq!(out.inserted, 2);
        svc.check_invariants();
        assert_eq!(svc.pinned_nodes(), 0, "commit released all pins");

        // Second admission fully hits and pins the path on its shard.
        let adm2 = svc.admit(&docs, 8);
        assert_eq!(adm2.matched_docs, 2);
        assert_eq!(adm2.alpha, 32);
        assert_eq!(adm2.beta, 8);
        assert_eq!(svc.pinned_nodes(), 2);
        svc.touch_hits(&adm2, 0.005, 2.0);
        svc.commit(&adm2, 0.005, 2.0, None);
        assert_eq!(svc.pinned_nodes(), 0);
        svc.check_invariants();
    }

    #[test]
    fn release_drops_pins_without_inserting() {
        let svc = sharded(2, 1024, 1024);
        let adm = svc.admit(&[(7, 16)], 4);
        svc.commit(&adm, 0.01, 1.0, None);
        let adm2 = svc.admit(&[(7, 16), (8, 16)], 4);
        assert_eq!(adm2.matched_docs, 1);
        svc.release(&adm2);
        assert_eq!(svc.pinned_nodes(), 0);
        // Doc 8 was never inserted.
        assert_eq!(svc.lookup(&[7, 8]).matched_docs, 1);
        svc.check_invariants();
    }

    #[test]
    fn requests_route_by_first_document() {
        let svc = sharded(2, 1024, 1024);
        let a = svc.admit(&[(2, 16), (3, 16)], 4); // 2 % 2 = shard 0
        let b = svc.admit(&[(3, 16), (2, 16)], 4); // 3 % 2 = shard 1
        assert_eq!(a.shard, 0);
        assert_eq!(b.shard, 1);
        svc.commit(&a, 0.01, 1.0, None);
        svc.commit(&b, 0.01, 1.0, None);
        // Order sensitivity survives sharding: each first doc owns its
        // whole path on its own shard.
        assert_eq!(svc.shard(0).lookup(&[2, 3]).matched_docs, 2);
        assert_eq!(svc.shard(1).lookup(&[3, 2]).matched_docs, 2);
        assert_eq!(svc.shard(0).lookup(&[3, 2]).matched_docs, 0);
        assert_eq!(svc.lookup(&[2, 3]).matched_docs, 2);
        assert_eq!(svc.lookup(&[3, 2]).matched_docs, 2);
        // Aggregated counters see both shards' inserts.
        assert_eq!(svc.counters().inserts, 4);
        assert_eq!(svc.pinned_nodes(), 0);
        svc.check_invariants();
    }

    /// Satellite bugfix: a K-way budget split must not drop the
    /// `total % K` remainder bytes.
    #[test]
    fn split_budget_is_exact_for_awkward_k() {
        for (total, k) in
            [(103u64, 4usize), (7, 3), (1, 5), (0, 4), (1 << 33, 7)]
        {
            let slices = split_budget(total, k);
            assert_eq!(slices.len(), k.max(1));
            assert_eq!(
                slices.iter().sum::<u64>(),
                total,
                "split of {total} over {k} drops bytes: {slices:?}"
            );
            // Slices differ by at most one byte.
            let min = *slices.iter().min().unwrap();
            let max = *slices.iter().max().unwrap();
            assert!(max - min <= 1);
        }
    }

    #[test]
    fn proportional_slices_conserve_and_floor() {
        // Skewed demand: slice 0 dominates but nobody drops below the
        // min-share floor, and the sum is bit-exact.
        let total = 1_000_003u64;
        let demand = [1_000_000u128, 10, 10, 0];
        let slices = proportional_slices(total, &demand, 0.25);
        assert_eq!(slices.iter().sum::<u64>(), total);
        let floor = (total / 4) / 4; // 25% of fair
        for (i, &s) in slices.iter().enumerate() {
            assert!(s >= floor, "slice {i} = {s} under floor {floor}");
        }
        assert!(slices[0] > slices[1]);
        // Zero demand everywhere: fair split, still exact.
        let flat = proportional_slices(total, &[0, 0, 0, 0], 0.25);
        assert_eq!(flat.iter().sum::<u64>(), total);
    }

    /// Tentpole: skewed demand moves GPU capacity to the hot shard
    /// under the conservation invariant; `--rebalance off` (no
    /// rebalancer) leaves the static slices untouched.
    #[test]
    fn rebalance_moves_capacity_to_hot_shard() {
        let mut svc = sharded(2, 64, 256); // 2 shards × 64-token GPU
        let occ0 = svc.shard_occupancies();
        let gpu_total: u64 =
            occ0.iter().map(|o| o.gpu_capacity).sum();
        svc.enable_rebalancing(RebalanceConfig {
            interval: 1,
            min_share: 0.25,
            hysteresis: 0.0,
        });
        // All traffic on shard 0 (even docs), thrashing its 64-token
        // slice: 3 docs of 32 tokens cycle through it.
        for round in 0..6 {
            for d in [0u32, 2, 4] {
                let adm = svc.admit(&[(d, 32)], 4);
                assert_eq!(adm.shard, 0);
                svc.commit(&adm, 0.01, round as f64, None);
            }
            svc.maintenance_tick();
            let occ = svc.shard_occupancies();
            assert_eq!(
                occ.iter().map(|o| o.gpu_capacity).sum::<u64>(),
                gpu_total,
                "conservation after every tick"
            );
            for (i, o) in occ.iter().enumerate() {
                assert!(
                    o.gpu_used <= o.gpu_capacity,
                    "shard {i} over capacity: {o:?}"
                );
            }
        }
        let occ = svc.shard_occupancies();
        assert!(
            occ[0].gpu_capacity > occ[1].gpu_capacity,
            "hot shard grew: {occ:?}"
        );
        assert!(svc.rebalance_stats().gpu_bytes_moved > 0);
        svc.check_invariants();

        // Static service (no rebalancer): ticks are no-ops.
        let static_svc = sharded(2, 64, 256);
        let before = static_svc.shard_occupancies();
        assert!(static_svc.maintenance_tick().is_none());
        assert_eq!(static_svc.shard_occupancies(), before);
        assert_eq!(
            static_svc.rebalance_stats(),
            RebalanceStats::default()
        );
    }

    #[test]
    fn fail_gpu_sums_across_shards() {
        let svc = sharded(3, 1024, 1024);
        for d in 0..6u32 {
            let adm = svc.admit(&[(d, 16)], 4);
            svc.commit(&adm, 0.01, 1.0, None);
        }
        let (lost, recovered) = svc.fail_gpu();
        assert_eq!(lost + recovered, 6, "every shard's nodes accounted");
        svc.check_invariants();
    }
}
