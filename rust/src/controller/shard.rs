//! Sharded knowledge-tree service (paper §5.1 scaled out).
//!
//! [`ShardedCacheService`] owns K independent [`CacheService`] shards —
//! each with its own lock, tier-budget slice and counters — keyed by a
//! request's FIRST document id. Admission from N connection workers then
//! scales past one core: requests over different shards never touch the
//! same lock, while the admit → compute → commit protocol is exactly
//! [`CacheService`]'s, so [`Pipeline`](super::Pipeline), both drivers
//! and the priority estimator are untouched at their call sites.
//!
//! Routing by the first (root-child) document is sound because the
//! knowledge tree is a prefix tree: every path `[d0, d1, ...]` lives
//! entirely under the root child for `d0`, so the shard owning `d0`
//! owns the whole path and no path can span shards. Each shard carries
//! its own permanently pinned root (the system prompt S of Fig. 8),
//! mirroring a per-replica prompt prefix.

use super::pipeline::{Admission, CacheService, CommitOutcome};
use crate::kvcache::KvPayload;
use crate::tree::{DocId, KnowledgeTree, MatchResult, TreeCounters};
use std::sync::Arc;

/// K independent [`CacheService`] shards behind the same protocol.
/// Cloning shares the shards (each `CacheService` is itself a shared
/// handle), so connection workers, engine drivers and estimators all
/// see one cache.
#[derive(Clone)]
pub struct ShardedCacheService {
    shards: Arc<[CacheService]>,
}

impl ShardedCacheService {
    pub fn new(shards: Vec<CacheService>) -> Self {
        assert!(!shards.is_empty(), "a cache needs at least one shard");
        ShardedCacheService {
            shards: shards.into(),
        }
    }

    /// Single-shard service over one tree — the drop-in successor of
    /// `CacheService::new` for the simulation and single-engine paths.
    pub fn single(tree: KnowledgeTree) -> Self {
        Self::new(vec![CacheService::new(tree)])
    }

    /// Build K shards from a per-shard tree builder. The builder should
    /// size each tree with its slice of the tier budgets (a K-way split
    /// of the GPU/host bytes).
    pub fn build(
        num_shards: usize,
        mut builder: impl FnMut(usize) -> KnowledgeTree,
    ) -> Self {
        let k = num_shards.max(1);
        Self::new(
            (0..k).map(|i| CacheService::new(builder(i))).collect(),
        )
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// The shard owning a document sequence: keyed by the first
    /// (root-child) document. Empty sequences go to shard 0.
    pub fn shard_of(&self, docs: &[DocId]) -> usize {
        docs.first().map_or(0, |&d| self.shard_of_doc(d))
    }

    /// The shard owning paths rooted at `doc`.
    pub fn shard_of_doc(&self, doc: DocId) -> usize {
        doc as usize % self.shards.len()
    }

    /// Direct access to one shard (administration, tests).
    pub fn shard(&self, idx: usize) -> &CacheService {
        &self.shards[idx]
    }

    /// O(h) prefix match against the owning shard (no pinning; a
    /// snapshot for priority estimates).
    pub fn lookup(&self, docs: &[DocId]) -> MatchResult {
        self.shards[self.shard_of(docs)].lookup(docs)
    }

    /// Admission stage A against the owning shard. The returned
    /// [`Admission`] records its shard, so [`commit`]/[`release`]/
    /// [`touch_hits`] route back without the caller knowing about
    /// sharding at all.
    ///
    /// [`commit`]: ShardedCacheService::commit
    /// [`release`]: ShardedCacheService::release
    /// [`touch_hits`]: ShardedCacheService::touch_hits
    pub fn admit(
        &self,
        docs: &[(DocId, usize)],
        request_tokens: usize,
    ) -> Admission {
        let shard =
            docs.first().map_or(0, |&(d, _)| self.shard_of_doc(d));
        let mut adm = self.shards[shard].admit(docs, request_tokens);
        adm.shard = shard;
        adm
    }

    /// Policy refresh for an admission's hit nodes, on its shard.
    pub fn touch_hits(
        &self,
        adm: &Admission,
        estimated_time: f64,
        now: f64,
    ) {
        self.shards[adm.shard].touch_hits(adm, estimated_time, now);
    }

    /// Admission stage B on the admission's shard. See
    /// [`CacheService::commit`].
    pub fn commit(
        &self,
        adm: &Admission,
        estimated_time: f64,
        now: f64,
        payloads: Option<Vec<KvPayload>>,
    ) -> CommitOutcome {
        self.shards[adm.shard].commit(adm, estimated_time, now, payloads)
    }

    /// Abandon an admission without inserting anything.
    pub fn release(&self, adm: &Admission) {
        self.shards[adm.shard].release(adm);
    }

    /// Concatenate the KV payloads along an admission's pinned path
    /// (real mode), from the shard that owns it.
    pub fn concat_payloads(&self, adm: &Admission) -> Vec<f32> {
        self.shards[adm.shard].concat_payloads(&adm.path)
    }

    /// Counters aggregated across every shard (the `Stats` endpoint and
    /// metrics read this).
    pub fn counters(&self) -> TreeCounters {
        let mut total = TreeCounters::default();
        for s in self.shards.iter() {
            total.merge(s.counters());
        }
        total
    }

    /// Validate every shard's structural invariants.
    pub fn check_invariants(&self) {
        for s in self.shards.iter() {
            s.check_invariants();
        }
    }

    /// In-flight pins summed across shards (excludes the per-shard
    /// roots' permanent pins).
    pub fn pinned_nodes(&self) -> usize {
        self.shards.iter().map(|s| s.pinned_nodes()).sum()
    }

    /// Simulate a GPU failure on every shard (§6). Returns the summed
    /// `(lost, recovered)` node counts.
    pub fn fail_gpu(&self) -> (usize, usize) {
        let mut lost = 0;
        let mut recovered = 0;
        for s in self.shards.iter() {
            let (l, r) = s.fail_gpu();
            lost += l;
            recovered += r;
        }
        (lost, recovered)
    }
}

impl From<CacheService> for ShardedCacheService {
    fn from(svc: CacheService) -> Self {
        ShardedCacheService::new(vec![svc])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::PolicyKind;
    use crate::kvcache::PageSpec;
    use crate::policy::make_policy;

    fn sharded(
        k: usize,
        gpu_tokens: usize,
        host_tokens: usize,
    ) -> ShardedCacheService {
        let page = PageSpec {
            block_tokens: 8,
            kv_bytes_per_token: 16,
        };
        ShardedCacheService::build(k, |_| {
            KnowledgeTree::new(
                page.bytes(gpu_tokens),
                page.bytes(host_tokens),
                page,
                make_policy(PolicyKind::Pgdsf),
                true,
                0,
            )
        })
    }

    /// The existing `CacheService` admission test, unchanged semantics,
    /// through the sharded front (acceptance: same admit/commit/release
    /// protocol per shard).
    #[test]
    fn admit_commit_roundtrip_inserts_and_unpins() {
        let svc = sharded(2, 1024, 1024);
        let docs = [(1u32, 16usize), (2, 16)];
        let adm = svc.admit(&docs, 8);
        assert_eq!(adm.shard, 1, "first doc 1 routes to shard 1 of 2");
        assert_eq!(adm.matched_docs, 0);
        assert_eq!(adm.alpha, 0);
        assert_eq!(adm.beta, 16 + 16 + 8);
        assert_eq!(adm.unmatched, vec![(1, 16), (2, 16)]);
        let out = svc.commit(&adm, 0.01, 1.0, None);
        assert_eq!(out.inserted, 2);
        svc.check_invariants();
        assert_eq!(svc.pinned_nodes(), 0, "commit released all pins");

        // Second admission fully hits and pins the path on its shard.
        let adm2 = svc.admit(&docs, 8);
        assert_eq!(adm2.matched_docs, 2);
        assert_eq!(adm2.alpha, 32);
        assert_eq!(adm2.beta, 8);
        assert_eq!(svc.pinned_nodes(), 2);
        svc.touch_hits(&adm2, 0.005, 2.0);
        svc.commit(&adm2, 0.005, 2.0, None);
        assert_eq!(svc.pinned_nodes(), 0);
        svc.check_invariants();
    }

    #[test]
    fn release_drops_pins_without_inserting() {
        let svc = sharded(2, 1024, 1024);
        let adm = svc.admit(&[(7, 16)], 4);
        svc.commit(&adm, 0.01, 1.0, None);
        let adm2 = svc.admit(&[(7, 16), (8, 16)], 4);
        assert_eq!(adm2.matched_docs, 1);
        svc.release(&adm2);
        assert_eq!(svc.pinned_nodes(), 0);
        // Doc 8 was never inserted.
        assert_eq!(svc.lookup(&[7, 8]).matched_docs, 1);
        svc.check_invariants();
    }

    #[test]
    fn requests_route_by_first_document() {
        let svc = sharded(2, 1024, 1024);
        let a = svc.admit(&[(2, 16), (3, 16)], 4); // 2 % 2 = shard 0
        let b = svc.admit(&[(3, 16), (2, 16)], 4); // 3 % 2 = shard 1
        assert_eq!(a.shard, 0);
        assert_eq!(b.shard, 1);
        svc.commit(&a, 0.01, 1.0, None);
        svc.commit(&b, 0.01, 1.0, None);
        // Order sensitivity survives sharding: each first doc owns its
        // whole path on its own shard.
        assert_eq!(svc.shard(0).lookup(&[2, 3]).matched_docs, 2);
        assert_eq!(svc.shard(1).lookup(&[3, 2]).matched_docs, 2);
        assert_eq!(svc.shard(0).lookup(&[3, 2]).matched_docs, 0);
        assert_eq!(svc.lookup(&[2, 3]).matched_docs, 2);
        assert_eq!(svc.lookup(&[3, 2]).matched_docs, 2);
        // Aggregated counters see both shards' inserts.
        assert_eq!(svc.counters().inserts, 4);
        assert_eq!(svc.pinned_nodes(), 0);
        svc.check_invariants();
    }

    #[test]
    fn fail_gpu_sums_across_shards() {
        let svc = sharded(3, 1024, 1024);
        for d in 0..6u32 {
            let adm = svc.admit(&[(d, 16)], 4);
            svc.commit(&adm, 0.01, 1.0, None);
        }
        let (lost, recovered) = svc.fail_gpu();
        assert_eq!(lost + recovered, 6, "every shard's nodes accounted");
        svc.check_invariants();
    }
}
