//! Event-driven request-session lifecycle — the non-blocking serving API
//! (paper §5.3 brought to the real path).
//!
//! A request no longer runs as one blocking call: it is a
//! [`RequestSession`] walking an explicit state machine,
//!
//! ```text
//!   Submitted ─► Retrieving(stage k) ─► SpeculativePrefill(gen g) ─┐
//!       │              │   ▲                    │                  │
//!       │              │   └── SpecCancelled ◄──┘ (stage changed   │
//!       │              │        pins released,    the candidates)  │
//!       │              ▼                                           ▼
//!       │          [final stage] ──────────► Admitted ─► Prefilled ─►
//!       │           fallback: PR 3 admit      promote:   (FirstToken)
//!       │           → prefill → commit        commit the spec work
//!       ▼                                                          │
//!    Failed ◄── (prefill/decode error) ◄──────────── Decoding ◄────┘
//!                                                        │
//!                                                      Done
//! ```
//!
//! driven by [`SessionEvent`]s. The [`SessionTable`] owns the per-session
//! phase, the Algorithm 2 decision state ([`SpecState`]) and the event
//! buffer; the *engine* (the real server's drive loop, the concurrent TCP
//! runtime, a test harness) owns retrieval, admission and compute, and
//! asks the table what to do after every retrieval stage tick
//! ([`SessionTable::on_stage`] → [`StageStep`]).
//!
//! The contract the table enforces (and the lifecycle tests pin):
//!
//! - **Exactly one terminal event** (`Completed` xor `Failed`) per
//!   session — terminal sessions are reaped, so a second completion is
//!   impossible by construction.
//! - **Speculative admissions pin but never commit.** A speculation's
//!   pinned admission travels inside its [`SpecWork`]; the table hands
//!   it back to the engine on cancellation (release the pins, count
//!   `wasted`) or on promotion (commit it) — it can never be dropped on
//!   the floor while live.
//! - **Every started speculation is cancelled or promoted**: on the
//!   final stage the table returns [`FinishPath::Promote`] when the live
//!   speculation covers the confirmed docs, and [`FinishPath::Fallback`]
//!   (the PR 3 blocking admit → prefill → commit path) otherwise.

use crate::spec::{SpecAction, SpecState};
use crate::tree::DocId;
use std::collections::{HashMap, VecDeque};

/// Identifies one request session (the real server reuses its request
/// ids).
pub type SessionId = u64;

/// Lifecycle phase of a session.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SessionPhase {
    /// Accepted, retrieval not yet started.
    Submitted,
    /// Staged retrieval in flight; `stage` is the last stage observed.
    Retrieving { stage: usize },
    /// A speculative prefill (generation `generation`) is live: its
    /// admission is pinned, its KV computed, awaiting confirmation.
    SpeculativePrefill { generation: u64 },
    /// Final docs confirmed and admission secured (promoted speculation
    /// or fallback admit).
    Admitted,
    /// Prefill output exists; the first token can be delivered.
    Prefilled,
    /// Decoding the remaining output tokens.
    Decoding,
    Done,
    Failed,
}

impl SessionPhase {
    pub fn is_terminal(&self) -> bool {
        matches!(self, SessionPhase::Done | SessionPhase::Failed)
    }
}

/// Notifications emitted as sessions advance; drained with
/// [`SessionTable::take_events`].
#[derive(Debug, Clone, PartialEq)]
pub enum SessionEvent {
    /// Retrieval stage `stage` delivered a candidate snapshot.
    StageReady {
        session: SessionId,
        stage: usize,
        is_final: bool,
    },
    /// A speculative prefill started on the current candidates.
    SpecStarted {
        session: SessionId,
        generation: u64,
    },
    /// A live speculation was terminated (candidates changed); its pins
    /// were handed back for release and its work counted `wasted`.
    SpecCancelled {
        session: SessionId,
        generation: u64,
    },
    /// The final docs are confirmed and the session holds a committed
    /// admission path (promoted speculation or fallback).
    AdmissionReady { session: SessionId },
    /// First output token delivered at time `at` (the TTFT milestone).
    FirstToken { session: SessionId, at: f64 },
    /// Terminal: the response is complete.
    Completed { session: SessionId },
    /// Terminal: the session errored.
    Failed {
        session: SessionId,
        error: String,
    },
}

impl SessionEvent {
    pub fn session(&self) -> SessionId {
        match *self {
            SessionEvent::StageReady { session, .. }
            | SessionEvent::SpecStarted { session, .. }
            | SessionEvent::SpecCancelled { session, .. }
            | SessionEvent::AdmissionReady { session }
            | SessionEvent::FirstToken { session, .. }
            | SessionEvent::Completed { session }
            | SessionEvent::Failed { session, .. } => session,
        }
    }

    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            SessionEvent::Completed { .. } | SessionEvent::Failed { .. }
        )
    }
}

/// A live speculative prefill: the generation tag, the candidate docs it
/// covers, and the engine's compute artifact `W` (in real mode: the
/// pinned [`Admission`](super::Admission) plus the computed KV rows and
/// logits).
#[derive(Debug)]
pub struct SpecWork<W> {
    pub generation: u64,
    pub docs: Vec<DocId>,
    pub payload: W,
}

/// One request's lifecycle state.
#[derive(Debug)]
pub struct RequestSession<W> {
    pub id: SessionId,
    pub phase: SessionPhase,
    /// Algorithm 2 decision state.
    pub spec: SpecState,
    pub submitted_at: f64,
    /// Candidates of the last observed stage.
    pub docs: Vec<DocId>,
    /// The live speculative prefill, if any.
    pub spec_work: Option<SpecWork<W>>,
}

/// How the engine must finish a session whose final stage arrived.
#[derive(Debug)]
pub enum FinishPath<W> {
    /// The live speculation covers the confirmed docs: commit its
    /// artifact and decode — retrieval latency was hidden behind the
    /// prefill (Theorem 5.1's win).
    Promote(SpecWork<W>),
    /// No usable speculation: run the blocking admit → prefill → commit
    /// path on the final docs (exactly the PR 3 batched path).
    Fallback,
}

/// What the engine must do after one retrieval stage tick.
#[derive(Debug)]
pub struct StageStep<W> {
    /// A terminated speculation whose pinned admission the engine must
    /// release (already counted `wasted`).
    pub cancelled: Option<SpecWork<W>>,
    /// Start a speculative prefill on these candidates; report the
    /// artifact via [`SessionTable::spec_started`] (or
    /// [`SessionTable::spec_aborted`] if the compute fails).
    pub start: Option<Vec<DocId>>,
    /// Set on the final stage: how this session finishes.
    pub finish: Option<FinishPath<W>>,
}

impl<W> Default for StageStep<W> {
    fn default() -> Self {
        StageStep {
            cancelled: None,
            start: None,
            finish: None,
        }
    }
}

/// Aggregated speculation counters (Fig. 19 / Table 3 ablation),
/// including sessions already reaped. Summed across engines by the
/// `stats` fan-out merge.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpecTotals {
    pub started: u64,
    pub wasted: u64,
    pub promoted: u64,
}

impl SpecTotals {
    pub fn merge(&mut self, other: SpecTotals) {
        self.started += other.started;
        self.wasted += other.wasted;
        self.promoted += other.promoted;
    }

    fn absorb(&mut self, s: &SpecState) {
        self.started += s.started;
        self.wasted += s.wasted;
        self.promoted += s.promoted;
    }
}

/// The session registry: phases, Algorithm 2 state and the event buffer
/// for every in-flight request of one engine.
pub struct SessionTable<W> {
    sessions: HashMap<SessionId, RequestSession<W>>,
    events: VecDeque<SessionEvent>,
    /// Algorithm 2's `max_prefill_bs`: the engine's prefill-pool bound.
    max_prefill: usize,
    /// Sessions currently holding a live speculative prefill.
    active_specs: usize,
    /// Counters of sessions already reaped (terminal).
    reaped: SpecTotals,
    /// Terminal events emitted — one per session ever finished.
    terminals: u64,
}

impl<W> SessionTable<W> {
    pub fn new(max_prefill: usize) -> Self {
        SessionTable {
            sessions: HashMap::new(),
            events: VecDeque::new(),
            max_prefill: max_prefill.max(1),
            active_specs: 0,
            reaped: SpecTotals::default(),
            terminals: 0,
        }
    }

    /// Live (non-terminal) sessions.
    pub fn in_flight(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    /// Sessions holding a live speculative prefill (the engine's
    /// Algorithm 2 pool occupancy).
    pub fn active_specs(&self) -> usize {
        self.active_specs
    }

    /// Terminal events ever emitted (exactly one per finished session).
    pub fn terminals(&self) -> u64 {
        self.terminals
    }

    pub fn phase(&self, id: SessionId) -> Option<&SessionPhase> {
        self.sessions.get(&id).map(|s| &s.phase)
    }

    pub fn session(&self, id: SessionId) -> Option<&RequestSession<W>> {
        self.sessions.get(&id)
    }

    /// Speculation counters over reaped and live sessions.
    pub fn totals(&self) -> SpecTotals {
        let mut t = self.reaped;
        for s in self.sessions.values() {
            t.absorb(&s.spec);
        }
        t
    }

    /// Drain the buffered lifecycle events.
    pub fn take_events(&mut self) -> Vec<SessionEvent> {
        self.events.drain(..).collect()
    }

    /// Register a new session (retrieval dispatched by the caller).
    pub fn submit(&mut self, id: SessionId, now: f64) {
        let prev = self.sessions.insert(
            id,
            RequestSession {
                id,
                phase: SessionPhase::Retrieving { stage: 0 },
                spec: SpecState::new(),
                submitted_at: now,
                docs: Vec::new(),
                spec_work: None,
            },
        );
        debug_assert!(prev.is_none(), "session id {id} reused while live");
    }

    /// One retrieval stage tick: run Algorithm 2 against the engine's
    /// current pool occupancy and tell the engine what to do. Stages for
    /// unknown (already finished) sessions are ignored — late events
    /// from a retrieval thread race nothing.
    pub fn on_stage(
        &mut self,
        id: SessionId,
        stage: usize,
        docs: &[DocId],
        is_final: bool,
    ) -> StageStep<W> {
        let mut step = StageStep::default();
        // Pool occupancy excludes this session's own speculation: its
        // slot is reusable by its own restart (terminate-then-start
        // swaps, never grows, the pool).
        let (pool, max_prefill) = {
            let Some(s) = self.sessions.get(&id) else {
                return step;
            };
            let own = usize::from(s.spec_work.is_some());
            (self.active_specs - own, self.max_prefill)
        };
        self.events.push_back(SessionEvent::StageReady {
            session: id,
            stage,
            is_final,
        });
        let s = self.sessions.get_mut(&id).expect("checked above");
        debug_assert!(
            !s.phase.is_terminal(),
            "terminal sessions are reaped"
        );
        s.docs = docs.to_vec();
        let action = s.spec.on_stage(docs, pool, max_prefill, is_final);

        // Terminating the previous speculation is common to Start and
        // Defer: hand the pinned work back for release.
        fn cancel_spec<W>(
            s: &mut RequestSession<W>,
            active_specs: &mut usize,
            events: &mut VecDeque<SessionEvent>,
        ) -> Option<SpecWork<W>> {
            let work = s.spec_work.take()?;
            *active_specs -= 1;
            events.push_back(SessionEvent::SpecCancelled {
                session: s.id,
                generation: work.generation,
            });
            Some(work)
        }

        match action {
            SpecAction::Keep => {
                if is_final {
                    s.phase = SessionPhase::Admitted;
                    self.events.push_back(
                        SessionEvent::AdmissionReady { session: id },
                    );
                    match s.spec_work.take() {
                        Some(work) => {
                            self.active_specs -= 1;
                            step.finish = Some(FinishPath::Promote(work));
                        }
                        // Defensive: Keep-on-final without a live
                        // artifact cannot happen when the engine reports
                        // failed prefills via `spec_aborted` — but a
                        // fallback always produces a correct answer.
                        None => {
                            debug_assert!(
                                false,
                                "Keep on final without live spec work"
                            );
                            step.finish = Some(FinishPath::Fallback);
                        }
                    }
                } else {
                    s.phase = SessionPhase::Retrieving { stage };
                }
            }
            SpecAction::Start { terminate_prev } => {
                if terminate_prev {
                    step.cancelled = cancel_spec(
                        s,
                        &mut self.active_specs,
                        &mut self.events,
                    );
                }
                if is_final {
                    // Final results always enter the engine — as a real
                    // generation, via the blocking PR 3 path.
                    s.phase = SessionPhase::Admitted;
                    self.events.push_back(
                        SessionEvent::AdmissionReady { session: id },
                    );
                    step.finish = Some(FinishPath::Fallback);
                } else {
                    s.phase = SessionPhase::Retrieving { stage };
                    step.start = Some(docs.to_vec());
                }
            }
            SpecAction::Defer { terminate_prev } => {
                if terminate_prev {
                    step.cancelled = cancel_spec(
                        s,
                        &mut self.active_specs,
                        &mut self.events,
                    );
                }
                debug_assert!(!is_final, "finals are always admitted");
                s.phase = SessionPhase::Retrieving { stage };
            }
        }
        step
    }

    /// The engine computed the speculative prefill requested by
    /// [`on_stage`](SessionTable::on_stage): store its artifact and mark
    /// the speculation live.
    pub fn spec_started(
        &mut self,
        id: SessionId,
        docs: Vec<DocId>,
        payload: W,
    ) {
        let Some(s) = self.sessions.get_mut(&id) else {
            debug_assert!(false, "spec_started for unknown session {id}");
            return;
        };
        debug_assert!(s.spec_work.is_none(), "speculation already live");
        let generation = s.spec.generation;
        s.spec_work = Some(SpecWork {
            generation,
            docs,
            payload,
        });
        s.phase = SessionPhase::SpeculativePrefill { generation };
        self.active_specs += 1;
        self.events
            .push_back(SessionEvent::SpecStarted {
                session: id,
                generation,
            });
    }

    /// The requested speculative prefill could not run (compute error):
    /// the speculation dies without an artifact (counted `wasted`), and
    /// Algorithm 2 may restart on a later stage.
    pub fn spec_aborted(&mut self, id: SessionId) {
        if let Some(s) = self.sessions.get_mut(&id) {
            debug_assert!(s.spec_work.is_none());
            s.spec.cancel_active();
        }
    }

    /// First-token milestone: the prefill output of the *confirmed*
    /// generation is ready at `at`.
    pub fn prefilled(&mut self, id: SessionId, at: f64) {
        if let Some(s) = self.sessions.get_mut(&id) {
            debug_assert_eq!(s.phase, SessionPhase::Admitted);
            s.phase = SessionPhase::Prefilled;
            self.events
                .push_back(SessionEvent::FirstToken { session: id, at });
        }
    }

    /// The engine is decoding the remaining output tokens.
    pub fn decoding(&mut self, id: SessionId) {
        if let Some(s) = self.sessions.get_mut(&id) {
            debug_assert_eq!(s.phase, SessionPhase::Prefilled);
            s.phase = SessionPhase::Decoding;
        }
    }

    /// Terminal success. Emits `Completed` exactly once and reaps the
    /// session; returns false if the session is unknown (already
    /// finished).
    pub fn complete(&mut self, id: SessionId) -> bool {
        self.finish(id, None)
    }

    /// Terminal failure. Emits `Failed` exactly once and reaps the
    /// session.
    pub fn fail(&mut self, id: SessionId, error: String) -> bool {
        self.finish(id, Some(error))
    }

    fn finish(&mut self, id: SessionId, error: Option<String>) -> bool {
        let Some(mut s) = self.sessions.remove(&id) else {
            return false;
        };
        debug_assert!(
            s.spec_work.is_none(),
            "finishing a session that still holds pinned spec work"
        );
        if s.spec_work.take().is_some() {
            // Release-path safety net (debug builds assert instead).
            self.active_specs -= 1;
        }
        s.phase = match error {
            None => SessionPhase::Done,
            Some(_) => SessionPhase::Failed,
        };
        self.reaped.absorb(&s.spec);
        self.terminals += 1;
        self.events.push_back(match error {
            None => SessionEvent::Completed { session: id },
            Some(e) => SessionEvent::Failed {
                session: id,
                error: e,
            },
        });
        true
    }

    /// Admission-control shedding (the real-path ladder): fail every
    /// session whose TTFT deadline (`submitted_at + slo`) has expired
    /// while it is still pre-admission — Submitted, Retrieving or
    /// SpeculativePrefill. Admitted/Prefilled/Decoding sessions are
    /// always graced, mirroring the simulator's rule that a prefill the
    /// engine already accepted is never torn down.
    ///
    /// Returns `(id, spec_work)` per shed session; the caller must
    /// release the pinned admission inside any returned work and abort
    /// the session's staged retrieval. Each shed session gets exactly
    /// one `Failed` terminal event (after a `SpecCancelled` if a
    /// speculation was live).
    pub fn shed_expired(
        &mut self,
        now: f64,
        slo: f64,
    ) -> Vec<(SessionId, Option<SpecWork<W>>)> {
        let expired: Vec<SessionId> = self
            .sessions
            .values()
            .filter(|s| {
                matches!(
                    s.phase,
                    SessionPhase::Submitted
                        | SessionPhase::Retrieving { .. }
                        | SessionPhase::SpeculativePrefill { .. }
                ) && now - s.submitted_at > slo
            })
            .map(|s| s.id)
            .collect();
        let mut shed = Vec::with_capacity(expired.len());
        for id in expired {
            let mut work = None;
            if let Some(s) = self.sessions.get_mut(&id) {
                if let Some(w) = s.spec_work.take() {
                    self.active_specs -= 1;
                    s.spec.cancel_active();
                    self.events.push_back(SessionEvent::SpecCancelled {
                        session: id,
                        generation: w.generation,
                    });
                    work = Some(w);
                }
            }
            self.fail(id, "shed: TTFT SLO expired before admission".into());
            shed.push((id, work));
        }
        shed
    }

    /// Tear down every live session (engine shutdown): hands back all
    /// live speculative work so the caller can release its pins, and
    /// emits a `Failed` terminal for each.
    pub fn abort_all(&mut self) -> Vec<SpecWork<W>> {
        let ids: Vec<SessionId> = self.sessions.keys().copied().collect();
        let mut works = Vec::new();
        for id in ids {
            if let Some(s) = self.sessions.get_mut(&id) {
                if let Some(work) = s.spec_work.take() {
                    self.active_specs -= 1;
                    s.spec.cancel_active();
                    self.events.push_back(SessionEvent::SpecCancelled {
                        session: id,
                        generation: work.generation,
                    });
                    works.push(work);
                }
            }
            self.fail(id, "session aborted at engine shutdown".into());
        }
        works
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(t: &mut SessionTable<u32>) -> Vec<SessionEvent> {
        t.take_events()
    }

    #[test]
    fn speculation_promoted_on_matching_final() {
        let mut t: SessionTable<u32> = SessionTable::new(4);
        t.submit(7, 0.0);
        let step = t.on_stage(7, 0, &[1, 2], false);
        assert!(step.cancelled.is_none());
        assert_eq!(step.start.as_deref(), Some(&[1, 2][..]));
        t.spec_started(7, vec![1, 2], 99);
        assert_eq!(t.active_specs(), 1);
        assert_eq!(
            t.phase(7),
            Some(&SessionPhase::SpeculativePrefill { generation: 1 })
        );
        // Unchanged mid-stage: keep running.
        let step = t.on_stage(7, 1, &[1, 2], false);
        assert!(step.start.is_none() && step.finish.is_none());
        // Final stage confirms: promote the artifact.
        let step = t.on_stage(7, 2, &[1, 2], true);
        let work = match step.finish {
            Some(FinishPath::Promote(w)) => w,
            other => panic!("expected promote, got {other:?}"),
        };
        assert_eq!(work.payload, 99);
        assert_eq!(t.active_specs(), 0);
        t.prefilled(7, 1.5);
        t.decoding(7);
        assert!(t.complete(7));
        assert!(!t.complete(7), "terminal is exactly-once");
        let events = drain(&mut t);
        let terminals =
            events.iter().filter(|e| e.is_terminal()).count();
        assert_eq!(terminals, 1);
        assert!(events.contains(&SessionEvent::SpecStarted {
            session: 7,
            generation: 1
        }));
        assert!(events
            .contains(&SessionEvent::FirstToken { session: 7, at: 1.5 }));
        let totals = t.totals();
        assert_eq!(
            totals,
            SpecTotals {
                started: 1,
                wasted: 0,
                promoted: 1
            }
        );
    }

    #[test]
    fn changed_candidates_cancel_and_restart() {
        let mut t: SessionTable<u32> = SessionTable::new(4);
        t.submit(3, 0.0);
        let step = t.on_stage(3, 0, &[1, 3], false);
        t.spec_started(3, step.start.unwrap(), 10);
        let step = t.on_stage(3, 1, &[1, 2], false);
        let cancelled = step.cancelled.expect("stale spec cancelled");
        assert_eq!(cancelled.payload, 10);
        assert_eq!(t.active_specs(), 0, "cancel released the pool slot");
        t.spec_started(3, step.start.unwrap(), 11);
        // Final mismatch: cancel again, fall back.
        let step = t.on_stage(3, 2, &[1, 9], true);
        assert_eq!(step.cancelled.expect("stale").payload, 11);
        assert!(matches!(step.finish, Some(FinishPath::Fallback)));
        t.prefilled(3, 2.0);
        t.decoding(3);
        t.complete(3);
        let totals = t.totals();
        assert_eq!(totals.wasted, 2);
        assert_eq!(totals.promoted, 0);
        // started: two speculations + the final re-generation.
        assert_eq!(totals.started, 3);
    }

    #[test]
    fn pool_full_defers_and_admits_final() {
        let mut t: SessionTable<u32> = SessionTable::new(1);
        t.submit(1, 0.0);
        t.submit(2, 0.0);
        // Session 1 takes the only pool slot.
        let step = t.on_stage(1, 0, &[5], false);
        t.spec_started(1, step.start.unwrap(), 1);
        // Session 2 must defer (pool full)…
        let step = t.on_stage(2, 0, &[6], false);
        assert!(step.start.is_none() && step.finish.is_none());
        // …but its final stage is always admitted (fallback).
        let step = t.on_stage(2, 1, &[6], true);
        assert!(matches!(step.finish, Some(FinishPath::Fallback)));
        t.prefilled(2, 1.0);
        t.decoding(2);
        t.complete(2);
        // Session 1's own restart reuses its own slot.
        let step = t.on_stage(1, 1, &[7], false);
        assert!(step.cancelled.is_some());
        assert!(step.start.is_some(), "own slot is reusable");
    }

    #[test]
    fn failed_spec_prefill_restarts_later() {
        let mut t: SessionTable<u32> = SessionTable::new(4);
        t.submit(4, 0.0);
        let step = t.on_stage(4, 0, &[8], false);
        assert!(step.start.is_some());
        t.spec_aborted(4); // compute failed; no artifact stored
        assert_eq!(t.active_specs(), 0);
        // Unchanged candidates restart instead of assuming coverage.
        let step = t.on_stage(4, 1, &[8], false);
        assert!(step.start.is_some());
        t.spec_started(4, vec![8], 2);
        let step = t.on_stage(4, 2, &[8], true);
        assert!(matches!(step.finish, Some(FinishPath::Promote(_))));
        t.prefilled(4, 0.5);
        t.decoding(4);
        t.complete(4);
        let totals = t.totals();
        assert_eq!(totals.started, 2);
        assert_eq!(totals.wasted, 1, "the aborted attempt counts wasted");
        assert_eq!(totals.promoted, 1);
    }

    #[test]
    fn stale_stage_events_are_ignored() {
        let mut t: SessionTable<u32> = SessionTable::new(4);
        t.submit(9, 0.0);
        let step = t.on_stage(9, 0, &[1], true);
        assert!(matches!(step.finish, Some(FinishPath::Fallback)));
        t.prefilled(9, 0.1);
        t.decoding(9);
        t.complete(9);
        let step = t.on_stage(9, 1, &[1], true);
        assert!(step.finish.is_none(), "finished session ignores stages");
        assert_eq!(t.terminals(), 1);
    }

    #[test]
    fn shed_expired_graces_admitted_and_returns_spec_work() {
        let mut t: SessionTable<u32> = SessionTable::new(4);
        // Session 1: still retrieving, expired → shed.
        t.submit(1, 0.0);
        // Session 2: live speculation, expired → shed, work handed back.
        t.submit(2, 0.0);
        let step = t.on_stage(2, 0, &[5], false);
        t.spec_started(2, step.start.unwrap(), 77);
        // Session 3: already Admitted (final stage in) → graced.
        t.submit(3, 0.0);
        let step = t.on_stage(3, 0, &[6], true);
        assert!(matches!(step.finish, Some(FinishPath::Fallback)));
        // Session 4: fresh (within SLO) → kept.
        t.submit(4, 9.9);
        let shed = t.shed_expired(10.0, 5.0);
        let ids: Vec<SessionId> = shed.iter().map(|&(id, _)| id).collect();
        assert_eq!(shed.len(), 2);
        assert!(ids.contains(&1) && ids.contains(&2));
        let work = shed
            .iter()
            .find(|&&(id, _)| id == 2)
            .and_then(|(_, w)| w.as_ref())
            .expect("session 2's spec work handed back");
        assert_eq!(work.payload, 77);
        assert_eq!(t.active_specs(), 0);
        assert_eq!(t.in_flight(), 2, "sessions 3 and 4 survive");
        assert_eq!(t.terminals(), 2);
        // Repeat at the same clock: nothing left to shed.
        assert!(t.shed_expired(10.0, 5.0).is_empty());
        let events = t.take_events();
        assert_eq!(
            events.iter().filter(|e| e.is_terminal()).count(),
            2,
            "exactly one terminal per shed session"
        );
        assert_eq!(t.totals().wasted, 1);
    }

    #[test]
    fn abort_all_returns_live_work_and_fails_sessions() {
        let mut t: SessionTable<u32> = SessionTable::new(4);
        t.submit(1, 0.0);
        t.submit(2, 0.0);
        let step = t.on_stage(1, 0, &[3], false);
        t.spec_started(1, step.start.unwrap(), 33);
        let works = t.abort_all();
        assert_eq!(works.len(), 1);
        assert_eq!(works[0].payload, 33);
        assert!(t.is_empty());
        assert_eq!(t.terminals(), 2);
        let events = t.take_events();
        assert_eq!(
            events.iter().filter(|e| e.is_terminal()).count(),
            2,
            "every live session got exactly one terminal event"
        );
        assert_eq!(t.totals().wasted, 1);
    }
}
