//! Staged-retrieval planning for the simulated pipeline.
//!
//! In real mode the controller ticks the actual vector index
//! (`vectordb::VectorIndex::staged_search`). In simulated mode the
//! final documents come from the workload trace and the *candidate
//! evolution* across stages is modelled: the paper (and our IVF staged
//! tests) observe that the final top-k usually emerges early in the
//! search, which is precisely what speculative pipelining exploits.

use crate::tree::DocId;
use crate::util::Rng;

/// Retrieval latency/staging parameters.
#[derive(Debug, Clone, Copy)]
pub struct RetrievalTiming {
    /// Full vector-search latency, seconds (scales with the searched
    /// fraction of the database — Fig. 19's x-axis).
    pub full_search_s: f64,
    /// Number of speculative stages the search is split into.
    pub stages: usize,
    /// Probability that the candidate set has converged to the final
    /// top-k by the end of stage 0 (geometrically increasing after).
    pub early_convergence: f64,
}

impl Default for RetrievalTiming {
    fn default() -> Self {
        // §3.1: retrieval executes in milliseconds per request for
        // billion-scale databases; ~50 ms ≈ the paper's Table 3 scale at
        // small search ratios.
        RetrievalTiming {
            full_search_s: 0.25,
            stages: 4,
            early_convergence: 0.55,
        }
    }
}

/// One retrieval stage: when it completes and what the candidate top-k
/// looks like at that point.
#[derive(Debug, Clone)]
pub struct StagePlan {
    /// Completion offset from retrieval start, seconds.
    pub offset: f64,
    pub docs: Vec<DocId>,
    pub is_final: bool,
}

/// A fully planned staged retrieval for one request.
#[derive(Debug, Clone)]
pub struct StagedRetrieval {
    pub stages: Vec<StagePlan>,
}

impl StagedRetrieval {
    /// Plan stage snapshots for a request whose final top-k is known
    /// (from the trace). Before the (sampled) convergence stage the
    /// candidate list differs in its last element — matching how IVF/HNSW
    /// candidate queues refine from the tail.
    pub fn plan(
        final_docs: &[DocId],
        num_docs: usize,
        timing: &RetrievalTiming,
        rng: &mut Rng,
    ) -> StagedRetrieval {
        let stages = timing.stages.max(1);
        // Sample the stage at which candidates converge: geometric with
        // p = early_convergence, capped at the final stage.
        let mut converge_at = 0usize;
        while converge_at + 1 < stages
            && !rng.chance(timing.early_convergence)
        {
            converge_at += 1;
        }
        let mut plans = Vec::with_capacity(stages);
        for s in 0..stages {
            let docs = if s >= converge_at || final_docs.len() <= 1 {
                final_docs.to_vec()
            } else {
                // Unconverged: the tail candidate is still wrong.
                let mut d = final_docs.to_vec();
                let last = d.len() - 1;
                d[last] = perturb(final_docs[last], s, num_docs);
                d
            };
            plans.push(StagePlan {
                offset: timing.full_search_s * (s + 1) as f64
                    / stages as f64,
                docs,
                is_final: s == stages - 1,
            });
        }
        StagedRetrieval { stages: plans }
    }

    /// Single-stage plan (speculation disabled): only the final result,
    /// delivered when the search completes.
    pub fn single(final_docs: &[DocId], timing: &RetrievalTiming) -> Self {
        StagedRetrieval {
            stages: vec![StagePlan {
                offset: timing.full_search_s,
                docs: final_docs.to_vec(),
                is_final: true,
            }],
        }
    }
}

fn perturb(doc: DocId, stage: usize, num_docs: usize) -> DocId {
    let x = (doc as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(stage as u64 + 1);
    let cand = ((x >> 17) % num_docs.max(2) as u64) as u32;
    if cand == doc {
        (cand + 1) % num_docs.max(2) as u32
    } else {
        cand
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn final_stage_always_correct() {
        let mut rng = Rng::new(1);
        let timing = RetrievalTiming::default();
        for _ in 0..50 {
            let plan =
                StagedRetrieval::plan(&[3, 7], 100, &timing, &mut rng);
            assert_eq!(plan.stages.len(), 4);
            let last = plan.stages.last().unwrap();
            assert!(last.is_final);
            assert_eq!(last.docs, vec![3, 7]);
            assert!((last.offset - timing.full_search_s).abs() < 1e-12);
        }
    }

    #[test]
    fn offsets_increase_linearly() {
        let mut rng = Rng::new(2);
        let timing = RetrievalTiming {
            full_search_s: 0.4,
            stages: 4,
            early_convergence: 0.5,
        };
        let plan = StagedRetrieval::plan(&[1, 2], 100, &timing, &mut rng);
        let offsets: Vec<f64> =
            plan.stages.iter().map(|s| s.offset).collect();
        for (got, want) in offsets.iter().zip([0.1, 0.2, 0.3, 0.4]) {
            assert!((got - want).abs() < 1e-12, "{got} vs {want}");
        }
    }

    #[test]
    fn early_convergence_rate_matches_parameter() {
        let mut rng = Rng::new(3);
        let timing = RetrievalTiming {
            full_search_s: 0.1,
            stages: 4,
            early_convergence: 0.6,
        };
        let trials = 2000;
        let mut converged_at_0 = 0;
        for _ in 0..trials {
            let plan =
                StagedRetrieval::plan(&[5, 9], 1000, &timing, &mut rng);
            if plan.stages[0].docs == vec![5, 9] {
                converged_at_0 += 1;
            }
        }
        let frac = converged_at_0 as f64 / trials as f64;
        assert!((0.55..0.65).contains(&frac), "{frac}");
    }

    #[test]
    fn unconverged_stage_differs_in_tail_only() {
        let mut rng = Rng::new(4);
        let timing = RetrievalTiming {
            full_search_s: 0.1,
            stages: 4,
            early_convergence: 0.0, // never converge before final
        };
        let plan =
            StagedRetrieval::plan(&[11, 22, 33], 1000, &timing, &mut rng);
        for s in &plan.stages[..3] {
            assert_eq!(s.docs[0], 11);
            assert_eq!(s.docs[1], 22);
            assert_ne!(s.docs[2], 33);
        }
        assert_eq!(plan.stages[3].docs, vec![11, 22, 33]);
    }

    #[test]
    fn single_stage_plan() {
        let timing = RetrievalTiming::default();
        let plan = StagedRetrieval::single(&[1, 2], &timing);
        assert_eq!(plan.stages.len(), 1);
        assert!(plan.stages[0].is_final);
    }
}
