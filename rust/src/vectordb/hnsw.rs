//! HNSW graph index (Malkov & Yashunin), with staged search.
//!
//! The paper adapts HNSW for pipelined search by slicing the search time
//! and reporting the current top-k after each slice (§6). Here stages
//! slice the base-layer beam expansion by node-expansion budget, which is
//! the deterministic equivalent.

use super::distance::l2_sq;
use super::{Hit, StageSnapshot, VectorIndex};
use crate::util::heap::{MinHeap, TopK};
use crate::util::Rng;

#[derive(Debug, Clone)]
struct Node {
    /// Neighbour lists per level, `0..=level`.
    neighbors: Vec<Vec<u32>>,
}

#[derive(Debug, Clone)]
pub struct HnswIndex {
    dim: usize,
    data: Vec<f32>,
    nodes: Vec<Node>,
    entry: u32,
    max_level: usize,
    /// Max connections per node per level (2M at level 0).
    m: usize,
    ef_search: usize,
}

impl HnswIndex {
    /// Build with connectivity `m` and construction/search beam `ef`.
    pub fn build(
        dim: usize,
        vectors: &[Vec<f32>],
        m: usize,
        ef: usize,
        seed: u64,
    ) -> Self {
        assert!(!vectors.is_empty());
        let mut data = Vec::with_capacity(vectors.len() * dim);
        for v in vectors {
            assert_eq!(v.len(), dim);
            data.extend_from_slice(v);
        }
        let mut index = HnswIndex {
            dim,
            data,
            nodes: Vec::with_capacity(vectors.len()),
            entry: 0,
            max_level: 0,
            m: m.max(2),
            ef_search: ef.max(8),
        };
        let mut rng = Rng::new(seed);
        let ml = 1.0 / (index.m as f64).ln();
        for id in 0..vectors.len() as u32 {
            let level = level_for(&mut rng, ml);
            index.insert(id, level, ef.max(index.m * 2));
        }
        index
    }

    #[inline]
    fn vector(&self, id: u32) -> &[f32] {
        let s = id as usize * self.dim;
        &self.data[s..s + self.dim]
    }

    fn insert(&mut self, id: u32, level: usize, ef_construction: usize) {
        let node = Node {
            neighbors: vec![Vec::new(); level + 1],
        };
        if self.nodes.is_empty() {
            self.nodes.push(node);
            self.entry = id;
            self.max_level = level;
            return;
        }
        self.nodes.push(node);

        let q = self.vector(id).to_vec();
        let mut ep = self.entry;
        // Greedy descent through levels above the new node's level.
        for l in (level + 1..=self.max_level).rev() {
            ep = self.greedy_at_level(&q, ep, l);
        }
        // Beam insert at each level from min(level, max_level) down to 0.
        for l in (0..=level.min(self.max_level)).rev() {
            let cands = self.beam_at_level(&q, ep, l, ef_construction, None);
            let cap = if l == 0 { self.m * 2 } else { self.m };
            let selected: Vec<u32> = cands
                .iter()
                .take(cap)
                .map(|&(_, n)| n)
                .collect();
            if let Some(&(_, best)) = cands.first() {
                ep = best;
            }
            for &n in &selected {
                self.nodes[id as usize].neighbors[l].push(n);
                self.nodes[n as usize].neighbors[l].push(id);
                self.prune(n, l);
            }
        }
        if level > self.max_level {
            self.max_level = level;
            self.entry = id;
        }
    }

    /// Keep only the closest `cap` neighbours of `node` at `level`.
    fn prune(&mut self, node: u32, level: usize) {
        let cap = if level == 0 { self.m * 2 } else { self.m };
        if self.nodes[node as usize].neighbors[level].len() <= cap {
            return;
        }
        let v = self.vector(node).to_vec();
        let mut scored: Vec<(f64, u32)> = self.nodes[node as usize].neighbors
            [level]
            .iter()
            .map(|&n| (l2_sq(&v, self.vector(n)), n))
            .collect();
        scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        scored.truncate(cap);
        self.nodes[node as usize].neighbors[level] =
            scored.into_iter().map(|(_, n)| n).collect();
    }

    fn greedy_at_level(&self, q: &[f32], start: u32, level: usize) -> u32 {
        let mut cur = start;
        let mut cur_d = l2_sq(q, self.vector(cur));
        loop {
            let mut improved = false;
            for &n in &self.nodes[cur as usize].neighbors[level] {
                let d = l2_sq(q, self.vector(n));
                if d < cur_d {
                    cur = n;
                    cur_d = d;
                    improved = true;
                }
            }
            if !improved {
                return cur;
            }
        }
    }

    /// Beam (ef) search at one level; returns candidates best-first.
    /// If `trace` is given, pushes the current best-k snapshot after each
    /// node expansion (used by staged search).
    fn beam_at_level(
        &self,
        q: &[f32],
        start: u32,
        level: usize,
        ef: usize,
        mut trace: Option<&mut Vec<Vec<Hit>>>,
    ) -> Vec<Hit> {
        let mut visited = vec![false; self.nodes.len()];
        visited[start as usize] = true;
        let d0 = l2_sq(q, self.vector(start));
        let mut frontier = MinHeap::new(); // by distance: expand closest
        frontier.push(d0, start);
        let mut best = TopK::new(ef);
        best.offer(d0, start);

        while let Some((d, node)) = frontier.pop() {
            if let Some(worst) = best.threshold() {
                if d > worst {
                    break;
                }
            }
            for &n in &self.nodes[node as usize].neighbors[level] {
                if visited[n as usize] {
                    continue;
                }
                visited[n as usize] = true;
                let dn = l2_sq(q, self.vector(n));
                if best.threshold().map_or(true, |t| dn < t) || best.len() < ef
                {
                    best.offer(dn, n);
                    frontier.push(dn, n);
                }
            }
            if let Some(t) = trace.as_deref_mut() {
                t.push(best.sorted());
            }
        }
        best.sorted()
    }
}

fn level_for(rng: &mut Rng, ml: f64) -> usize {
    let u = loop {
        let u = rng.f64();
        if u > 0.0 {
            break u;
        }
    };
    ((-u.ln() * ml).floor() as usize).min(16)
}

impl VectorIndex for HnswIndex {
    fn len(&self) -> usize {
        self.nodes.len()
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        let mut ep = self.entry;
        for l in (1..=self.max_level).rev() {
            ep = self.greedy_at_level(query, ep, l);
        }
        let ef = self.ef_search.max(k);
        let mut hits =
            self.beam_at_level(query, ep, 0, ef, None);
        hits.truncate(k);
        hits
    }

    fn staged_search(
        &self,
        query: &[f32],
        k: usize,
        stages: usize,
    ) -> Vec<StageSnapshot> {
        let stages = stages.max(1);
        let mut ep = self.entry;
        for l in (1..=self.max_level).rev() {
            ep = self.greedy_at_level(query, ep, l);
        }
        let ef = self.ef_search.max(k);
        let mut trace = Vec::new();
        let final_hits =
            self.beam_at_level(query, ep, 0, ef, Some(&mut trace));
        let total = trace.len().max(1);
        let mut out = Vec::with_capacity(stages);
        for s in 0..stages {
            let idx = ((total * (s + 1)) / stages).max(1) - 1;
            let mut topk = if s == stages - 1 {
                final_hits.clone()
            } else {
                trace
                    .get(idx)
                    .cloned()
                    .unwrap_or_else(|| final_hits.clone())
            };
            topk.truncate(k);
            out.push(StageSnapshot {
                frac_scanned: (s + 1) as f64 / stages as f64,
                topk,
            });
        }
        out
    }

    fn scan_cost(&self) -> usize {
        // Expected expansions: ef beam over log-degree graph.
        self.ef_search * self.m * 2 + self.max_level * self.m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus(rng: &mut Rng, n: usize, dim: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| (0..dim).map(|_| rng.f32()).collect())
            .collect()
    }

    #[test]
    fn exact_member_found() {
        let mut rng = Rng::new(31);
        let vecs = corpus(&mut rng, 500, 8);
        let idx = HnswIndex::build(8, &vecs, 12, 64, 1);
        let mut found = 0;
        for id in (0..500).step_by(17) {
            let hits = idx.search(&vecs[id], 1);
            if hits[0].1 == id as u32 {
                found += 1;
            }
        }
        assert!(found >= 25, "found {found}/30 exact members");
    }

    #[test]
    fn results_sorted_and_unique() {
        let mut rng = Rng::new(32);
        let vecs = corpus(&mut rng, 300, 6);
        let idx = HnswIndex::build(6, &vecs, 8, 32, 2);
        let q: Vec<f32> = (0..6).map(|_| rng.f32()).collect();
        let hits = idx.search(&q, 10);
        assert!(hits.windows(2).all(|w| w[0].0 <= w[1].0));
        let mut ids: Vec<u32> = hits.iter().map(|h| h.1).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), hits.len());
    }

    #[test]
    fn graph_degrees_bounded() {
        let mut rng = Rng::new(33);
        let vecs = corpus(&mut rng, 400, 6);
        let m = 8;
        let idx = HnswIndex::build(6, &vecs, m, 32, 3);
        for node in &idx.nodes {
            for (l, nbrs) in node.neighbors.iter().enumerate() {
                let cap = if l == 0 { m * 2 } else { m };
                assert!(nbrs.len() <= cap + 1, "level {l}: {}", nbrs.len());
            }
        }
    }

    #[test]
    fn single_vector_index() {
        let idx = HnswIndex::build(4, &[vec![1.0, 2.0, 3.0, 4.0]], 4, 16, 4);
        let hits = idx.search(&[1.0, 2.0, 3.0, 4.0], 3);
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].1, 0);
    }
}
