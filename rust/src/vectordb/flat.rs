//! Exact brute-force L2 index (the paper's FlatL2 baseline, §3.2).

use super::distance::l2_sq;
use super::{Hit, StageSnapshot, VectorIndex};
use crate::util::heap::TopK;

/// Row-major dense storage; ids are row indices.
#[derive(Debug, Clone)]
pub struct FlatIndex {
    dim: usize,
    data: Vec<f32>,
}

impl FlatIndex {
    pub fn build(dim: usize, vectors: &[Vec<f32>]) -> Self {
        let mut data = Vec::with_capacity(vectors.len() * dim);
        for v in vectors {
            assert_eq!(v.len(), dim, "vector dim mismatch");
            data.extend_from_slice(v);
        }
        FlatIndex { dim, data }
    }

    #[inline]
    pub fn vector(&self, id: u32) -> &[f32] {
        let s = id as usize * self.dim;
        &self.data[s..s + self.dim]
    }

    fn scan_range(
        &self,
        query: &[f32],
        range: std::ops::Range<usize>,
        topk: &mut TopK<u32>,
    ) {
        for id in range {
            let d = l2_sq(query, self.vector(id as u32));
            // Prune: TopK::offer is cheap, but the threshold check avoids
            // the heap touch for the common far-away case.
            if topk.threshold().map_or(true, |t| d < t) {
                topk.offer(d, id as u32);
            }
        }
    }
}

impl VectorIndex for FlatIndex {
    fn len(&self) -> usize {
        self.data.len() / self.dim.max(1)
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        let mut topk = TopK::new(k);
        self.scan_range(query, 0..self.len(), &mut topk);
        topk.sorted()
    }

    fn staged_search(
        &self,
        query: &[f32],
        k: usize,
        stages: usize,
    ) -> Vec<StageSnapshot> {
        let stages = stages.max(1);
        let n = self.len();
        let mut topk = TopK::new(k);
        let mut out = Vec::with_capacity(stages);
        let mut start = 0;
        for s in 0..stages {
            let end = (n * (s + 1)) / stages;
            self.scan_range(query, start..end, &mut topk);
            start = end;
            out.push(StageSnapshot {
                frac_scanned: if n == 0 {
                    1.0
                } else {
                    end as f64 / n as f64
                },
                topk: topk.sorted(),
            });
        }
        out
    }

    fn scan_cost(&self) -> usize {
        self.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::testing::check;
    use crate::util::Rng;

    fn random_vectors(rng: &mut Rng, n: usize, dim: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| (0..dim).map(|_| rng.f32()).collect())
            .collect()
    }

    #[test]
    fn finds_exact_nearest() {
        let mut rng = Rng::new(1);
        let vecs = random_vectors(&mut rng, 500, 8);
        let idx = FlatIndex::build(8, &vecs);
        // Query exactly equal to vector 123.
        let hits = idx.search(&vecs[123], 3);
        assert_eq!(hits[0].1, 123);
        assert_eq!(hits[0].0, 0.0);
        assert!(hits.windows(2).all(|w| w[0].0 <= w[1].0));
    }

    #[test]
    fn search_matches_naive_property() {
        check("flat_matches_naive", |rng| {
            let n = 1 + rng.index(200);
            let dim = 1 + rng.index(16);
            let vecs = random_vectors(rng, n, dim);
            let idx = FlatIndex::build(dim, &vecs);
            let q: Vec<f32> = (0..dim).map(|_| rng.f32()).collect();
            let k = 1 + rng.index(8);
            let got = idx.search(&q, k);

            let mut naive: Vec<Hit> = vecs
                .iter()
                .enumerate()
                .map(|(i, v)| {
                    (super::super::distance::l2_sq(&q, v), i as u32)
                })
                .collect();
            naive.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            naive.truncate(k);
            let got_ids: Vec<u32> = got.iter().map(|h| h.1).collect();
            let want_ids: Vec<u32> = naive.iter().map(|h| h.1).collect();
            prop_assert!(
                got_ids == want_ids,
                "got {got_ids:?} want {want_ids:?}"
            );
            Ok(())
        });
    }

    #[test]
    fn k_larger_than_n() {
        let mut rng = Rng::new(2);
        let vecs = random_vectors(&mut rng, 3, 4);
        let idx = FlatIndex::build(4, &vecs);
        let hits = idx.search(&vecs[0], 10);
        assert_eq!(hits.len(), 3);
    }

    #[test]
    fn staged_progress_monotone_improvement() {
        let mut rng = Rng::new(3);
        let vecs = random_vectors(&mut rng, 300, 8);
        let idx = FlatIndex::build(8, &vecs);
        let q: Vec<f32> = (0..8).map(|_| rng.f32()).collect();
        let stages = idx.staged_search(&q, 4, 5);
        assert_eq!(stages.len(), 5);
        // Best distance never gets worse as stages progress.
        let mut best = f64::INFINITY;
        for s in &stages {
            if let Some(h) = s.topk.first() {
                assert!(h.0 <= best + 1e-12);
                best = h.0;
            }
        }
    }
}
