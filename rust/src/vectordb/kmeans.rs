//! Lloyd's k-means — the clustering behind the IVF index.

use super::distance::l2_sq;
use crate::util::Rng;

/// Result of a k-means run: row-major centroids plus assignments.
#[derive(Debug, Clone)]
pub struct KMeans {
    pub dim: usize,
    pub k: usize,
    /// `k * dim` row-major centroid matrix.
    pub centroids: Vec<f32>,
    /// Cluster id per input vector.
    pub assignments: Vec<u32>,
}

impl KMeans {
    #[inline]
    pub fn centroid(&self, c: usize) -> &[f32] {
        &self.centroids[c * self.dim..(c + 1) * self.dim]
    }

    /// Index of the nearest centroid to `v`.
    pub fn nearest(&self, v: &[f32]) -> usize {
        let mut best = 0;
        let mut best_d = f64::INFINITY;
        for c in 0..self.k {
            let d = l2_sq(v, self.centroid(c));
            if d < best_d {
                best_d = d;
                best = c;
            }
        }
        best
    }

    /// Centroid indices ordered by distance to `v`, nearest first.
    pub fn ranked(&self, v: &[f32]) -> Vec<(f64, usize)> {
        let mut ds: Vec<(f64, usize)> = (0..self.k)
            .map(|c| (l2_sq(v, self.centroid(c)), c))
            .collect();
        ds.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        ds
    }
}

/// Run Lloyd's algorithm with k-means++-style seeding.
pub fn kmeans(
    dim: usize,
    vectors: &[Vec<f32>],
    k: usize,
    iters: usize,
    seed: u64,
) -> KMeans {
    assert!(!vectors.is_empty(), "kmeans over empty set");
    let k = k.min(vectors.len());
    let mut rng = Rng::new(seed);

    // Seeding: first uniform, then weighted by distance-squared.
    let mut centroids: Vec<f32> = Vec::with_capacity(k * dim);
    let first = rng.index(vectors.len());
    centroids.extend_from_slice(&vectors[first]);
    let mut min_d: Vec<f64> = vectors
        .iter()
        .map(|v| l2_sq(v, &vectors[first]))
        .collect();
    for _ in 1..k {
        let total: f64 = min_d.iter().sum();
        let pick = if total <= 0.0 {
            rng.index(vectors.len())
        } else {
            let mut target = rng.f64() * total;
            let mut chosen = vectors.len() - 1;
            for (i, &d) in min_d.iter().enumerate() {
                target -= d;
                if target <= 0.0 {
                    chosen = i;
                    break;
                }
            }
            chosen
        };
        centroids.extend_from_slice(&vectors[pick]);
        let c = &centroids[centroids.len() - dim..];
        for (i, v) in vectors.iter().enumerate() {
            let d = l2_sq(v, c);
            if d < min_d[i] {
                min_d[i] = d;
            }
        }
    }

    let k_actual = centroids.len() / dim;
    let mut assignments = vec![0u32; vectors.len()];
    for _ in 0..iters {
        // Assign.
        let mut changed = false;
        for (i, v) in vectors.iter().enumerate() {
            let mut best = 0usize;
            let mut best_d = f64::INFINITY;
            for c in 0..k_actual {
                let d = l2_sq(v, &centroids[c * dim..(c + 1) * dim]);
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            if assignments[i] != best as u32 {
                assignments[i] = best as u32;
                changed = true;
            }
        }
        // Update.
        let mut sums = vec![0f64; k_actual * dim];
        let mut counts = vec![0usize; k_actual];
        for (i, v) in vectors.iter().enumerate() {
            let c = assignments[i] as usize;
            counts[c] += 1;
            for (j, &x) in v.iter().enumerate() {
                sums[c * dim + j] += x as f64;
            }
        }
        for c in 0..k_actual {
            if counts[c] == 0 {
                // Re-seed empty cluster at a random vector.
                let pick = rng.index(vectors.len());
                centroids[c * dim..(c + 1) * dim]
                    .copy_from_slice(&vectors[pick]);
                continue;
            }
            for j in 0..dim {
                centroids[c * dim + j] =
                    (sums[c * dim + j] / counts[c] as f64) as f32;
            }
        }
        if !changed {
            break;
        }
    }

    KMeans {
        dim,
        k: k_actual,
        centroids,
        assignments,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(rng: &mut Rng, centers: usize, per: usize, dim: usize) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        for c in 0..centers {
            let center: Vec<f32> =
                (0..dim).map(|_| c as f32 * 10.0 + rng.f32()).collect();
            for _ in 0..per {
                out.push(
                    center
                        .iter()
                        .map(|&x| x + rng.f32() * 0.1)
                        .collect::<Vec<f32>>(),
                );
            }
        }
        out
    }

    #[test]
    fn separates_well_separated_blobs() {
        let mut rng = Rng::new(9);
        let vecs = blobs(&mut rng, 4, 50, 6);
        let km = kmeans(6, &vecs, 4, 20, 1);
        assert_eq!(km.k, 4);
        // All members of one blob share an assignment.
        for b in 0..4 {
            let first = km.assignments[b * 50];
            for i in 0..50 {
                assert_eq!(km.assignments[b * 50 + i], first, "blob {b}");
            }
        }
    }

    #[test]
    fn nearest_agrees_with_assignment() {
        let mut rng = Rng::new(10);
        let vecs = blobs(&mut rng, 3, 30, 4);
        let km = kmeans(4, &vecs, 3, 20, 2);
        for (i, v) in vecs.iter().enumerate() {
            assert_eq!(km.nearest(v) as u32, km.assignments[i]);
        }
    }

    #[test]
    fn ranked_is_sorted_and_complete() {
        let mut rng = Rng::new(11);
        let vecs = blobs(&mut rng, 5, 10, 4);
        let km = kmeans(4, &vecs, 5, 10, 3);
        let r = km.ranked(&vecs[0]);
        assert_eq!(r.len(), km.k);
        assert!(r.windows(2).all(|w| w[0].0 <= w[1].0));
    }

    #[test]
    fn k_clamped_to_n() {
        let vecs = vec![vec![1f32, 2f32], vec![3f32, 4f32]];
        let km = kmeans(2, &vecs, 10, 5, 4);
        assert!(km.k <= 2);
    }
}
