//! IVF (inverted file) index: k-means clusters + probed search.
//!
//! The paper's default retrieval index (§7: IVF with 1024 clusters).
//! Staged search probes cluster batches in centroid-distance order and
//! snapshots the candidate queue after each batch — exactly the hook the
//! dynamic speculative pipeline consumes (§6 "Pipelined vector search").

use super::distance::l2_sq;
use super::kmeans::{kmeans, KMeans};
use super::{Hit, StageSnapshot, VectorIndex};
use crate::util::heap::TopK;

#[derive(Debug, Clone)]
pub struct IvfIndex {
    dim: usize,
    km: KMeans,
    /// Per cluster: member ids.
    clusters: Vec<Vec<u32>>,
    /// Dense vector storage (row-major by id).
    data: Vec<f32>,
    /// Clusters probed per query.
    nprobe: usize,
}

impl IvfIndex {
    /// Build with `nlist` clusters, probing `nprobe` at query time.
    pub fn build(
        dim: usize,
        vectors: &[Vec<f32>],
        nlist: usize,
        nprobe: usize,
        seed: u64,
    ) -> Self {
        assert!(!vectors.is_empty());
        let km = kmeans(dim, vectors, nlist, 15, seed);
        let mut clusters = vec![Vec::new(); km.k];
        for (i, &c) in km.assignments.iter().enumerate() {
            clusters[c as usize].push(i as u32);
        }
        let mut data = Vec::with_capacity(vectors.len() * dim);
        for v in vectors {
            data.extend_from_slice(v);
        }
        IvfIndex {
            dim,
            km,
            clusters,
            data,
            nprobe: nprobe.max(1),
        }
    }

    #[inline]
    fn vector(&self, id: u32) -> &[f32] {
        let s = id as usize * self.dim;
        &self.data[s..s + self.dim]
    }

    pub fn nprobe(&self) -> usize {
        self.nprobe
    }

    pub fn nlist(&self) -> usize {
        self.km.k
    }

    fn probe_order(&self, query: &[f32]) -> Vec<usize> {
        self.km
            .ranked(query)
            .into_iter()
            .take(self.nprobe)
            .map(|(_, c)| c)
            .collect()
    }

    fn scan_cluster(&self, query: &[f32], c: usize, topk: &mut TopK<u32>) {
        for &id in &self.clusters[c] {
            let d = l2_sq(query, self.vector(id));
            if topk.threshold().map_or(true, |t| d < t) {
                topk.offer(d, id);
            }
        }
    }
}

impl VectorIndex for IvfIndex {
    fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    fn dim(&self) -> usize {
        self.dim
    }

    fn search(&self, query: &[f32], k: usize) -> Vec<Hit> {
        let mut topk = TopK::new(k);
        for c in self.probe_order(query) {
            self.scan_cluster(query, c, &mut topk);
        }
        topk.sorted()
    }

    fn staged_search(
        &self,
        query: &[f32],
        k: usize,
        stages: usize,
    ) -> Vec<StageSnapshot> {
        let stages = stages.max(1);
        let order = self.probe_order(query);
        let total_vecs: usize = order
            .iter()
            .map(|&c| self.clusters[c].len())
            .sum::<usize>()
            .max(1);
        let mut topk = TopK::new(k);
        let mut out = Vec::with_capacity(stages);
        let mut scanned = 0usize;
        let mut next_cluster = 0usize;
        for s in 0..stages {
            let end = (order.len() * (s + 1)) / stages;
            while next_cluster < end {
                let c = order[next_cluster];
                self.scan_cluster(query, c, &mut topk);
                scanned += self.clusters[c].len();
                next_cluster += 1;
            }
            out.push(StageSnapshot {
                frac_scanned: if s == stages - 1 {
                    1.0
                } else {
                    scanned as f64 / total_vecs as f64
                },
                topk: topk.sorted(),
            });
        }
        out
    }

    fn scan_cost(&self) -> usize {
        // Centroid ranking + expected probed fraction of the data.
        self.km.k + (self.len() * self.nprobe) / self.km.k.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn corpus(rng: &mut Rng, n: usize, dim: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| (0..dim).map(|_| rng.f32()).collect())
            .collect()
    }

    #[test]
    fn full_probe_equals_flat() {
        // nprobe == nlist makes IVF exhaustive => identical to flat.
        let mut rng = Rng::new(21);
        let vecs = corpus(&mut rng, 400, 8);
        let ivf = IvfIndex::build(8, &vecs, 16, 16, 5);
        let flat = super::super::FlatIndex::build(8, &vecs);
        for _ in 0..20 {
            let q: Vec<f32> = (0..8).map(|_| rng.f32()).collect();
            let a: Vec<u32> = ivf.search(&q, 5).iter().map(|h| h.1).collect();
            let b: Vec<u32> =
                flat.search(&q, 5).iter().map(|h| h.1).collect();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn probes_own_cluster_first() {
        let mut rng = Rng::new(22);
        let vecs = corpus(&mut rng, 300, 8);
        let ivf = IvfIndex::build(8, &vecs, 10, 1, 6);
        // An exact member query must find itself even with nprobe=1.
        for id in [0u32, 50, 299] {
            let hits = ivf.search(&vecs[id as usize], 1);
            assert_eq!(hits[0].1, id);
        }
    }

    #[test]
    fn staged_candidates_stabilise_early() {
        // The paper's DSP premise: final top-k usually emerges before the
        // probe completes. With clusters ordered by centroid distance the
        // first-stage winner should very often survive.
        let mut rng = Rng::new(23);
        let vecs = corpus(&mut rng, 1000, 8);
        let ivf = IvfIndex::build(8, &vecs, 32, 16, 7);
        let mut stable = 0;
        let trials = 50;
        for _ in 0..trials {
            let q: Vec<f32> = (0..8).map(|_| rng.f32()).collect();
            let st = ivf.staged_search(&q, 2, 4);
            let first: Vec<u32> = st[0].topk.iter().map(|h| h.1).collect();
            let last: Vec<u32> =
                st.last().unwrap().topk.iter().map(|h| h.1).collect();
            if first == last {
                stable += 1;
            }
        }
        assert!(stable > trials / 2, "only {stable}/{trials} stabilised early");
    }

    #[test]
    fn scan_cost_scales_with_nprobe() {
        let mut rng = Rng::new(24);
        let vecs = corpus(&mut rng, 500, 8);
        let a = IvfIndex::build(8, &vecs, 25, 2, 8);
        let b = IvfIndex::build(8, &vecs, 25, 20, 8);
        assert!(a.scan_cost() < b.scan_cost());
    }
}
