//! Distance kernels — the innermost loop of every retrieval.
//!
//! Written as 4-wide unrolled f32 loops the compiler auto-vectorises;
//! this is the hot path the §Perf pass profiles.

/// Squared L2 distance between two equal-length vectors.
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc0 = 0f32;
    let mut acc1 = 0f32;
    let mut acc2 = 0f32;
    let mut acc3 = 0f32;
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        let d0 = a[j] - b[j];
        let d1 = a[j + 1] - b[j + 1];
        let d2 = a[j + 2] - b[j + 2];
        let d3 = a[j + 3] - b[j + 3];
        acc0 += d0 * d0;
        acc1 += d1 * d1;
        acc2 += d2 * d2;
        acc3 += d3 * d3;
    }
    for j in chunks * 4..a.len() {
        let d = a[j] - b[j];
        acc0 += d * d;
    }
    (acc0 + acc1 + acc2 + acc3) as f64
}

/// Dot product (used by k-means updates).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0f32;
    for (x, y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc as f64
}

/// Squared L2 norm.
#[inline]
pub fn norm_sq(a: &[f32]) -> f64 {
    dot(a, a)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l2_matches_naive() {
        let a: Vec<f32> = (0..19).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..19).map(|i| 10.0 - i as f32).collect();
        let naive: f64 = a
            .iter()
            .zip(&b)
            .map(|(x, y)| ((x - y) * (x - y)) as f64)
            .sum();
        assert!((l2_sq(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn l2_zero_for_identical() {
        let a = vec![1.5f32; 33];
        assert_eq!(l2_sq(&a, &a), 0.0);
    }

    #[test]
    fn dot_and_norm() {
        let a = vec![3f32, 4f32];
        assert_eq!(norm_sq(&a), 25.0);
        assert_eq!(dot(&a, &[1f32, 1f32]), 7.0);
    }
}
