//! Vector database substrate (Faiss replacement).
//!
//! The paper's retrieval step runs on Faiss with FlatL2, IVF and HNSW
//! indexes (§3.2, §6). All three are implemented here, each supporting
//! *staged* search — the property dynamic speculative pipelining (§5.3)
//! exploits: intermediate top-k snapshots are exposed while the search is
//! still refining, and the final snapshot equals the non-staged result.
//!
//! - [`flat`] — exact brute-force L2 (the paper's FlatL2 baseline).
//! - [`ivf`] — inverted-file index over [`kmeans`] clusters; stages probe
//!   cluster batches in centroid-distance order (paper §6: "split the IVF
//!   search into multiple stages, each searching some clusters").
//! - [`hnsw`] — hierarchical navigable small-world graph; stages slice the
//!   base-layer beam expansion by hop budget (paper §6: time slices).

pub mod distance;
pub mod flat;
pub mod hnsw;
pub mod ivf;
pub mod kmeans;

pub use flat::FlatIndex;
pub use hnsw::HnswIndex;
pub use ivf::IvfIndex;

/// A scored hit: (squared L2 distance, document id).
pub type Hit = (f64, u32);

/// One intermediate state of a staged search.
#[derive(Debug, Clone)]
pub struct StageSnapshot {
    /// Fraction of the index's scan work completed after this stage.
    pub frac_scanned: f64,
    /// Current top-k candidates, best first.
    pub topk: Vec<Hit>,
}

/// Common interface over the three index kinds.
pub trait VectorIndex: Send + Sync {
    fn len(&self) -> usize;
    fn dim(&self) -> usize;
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Exact or approximate top-k search, best first.
    fn search(&self, query: &[f32], k: usize) -> Vec<Hit>;

    /// Search in `stages` increments, returning an intermediate top-k
    /// snapshot after each. The final snapshot's `topk` must equal
    /// `search(query, k)`.
    fn staged_search(
        &self,
        query: &[f32],
        k: usize,
        stages: usize,
    ) -> Vec<StageSnapshot>;

    /// Number of vector-distance evaluations a full search performs —
    /// the work unit the simulation's retrieval-latency model scales.
    fn scan_cost(&self) -> usize;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embed::EmbeddingModel;
    use crate::util::Rng;

    fn build_corpus(n: usize, dim: usize) -> (EmbeddingModel, Vec<Vec<f32>>) {
        let em = EmbeddingModel::new(dim, 7);
        let vecs = (0..n as u32).map(|i| em.document(i)).collect();
        (em, vecs)
    }

    fn recall_at_1(
        idx: &dyn VectorIndex,
        em: &EmbeddingModel,
        n: usize,
        queries: usize,
    ) -> f64 {
        let mut rng = Rng::new(3);
        let mut hits = 0;
        for _ in 0..queries {
            let target = rng.below(n as u64) as u32;
            let q = em.query(target, 0.05, &mut rng);
            let got = idx.search(&q, 1);
            if got.first().map(|h| h.1) == Some(target) {
                hits += 1;
            }
        }
        hits as f64 / queries as f64
    }

    #[test]
    fn ivf_recall_close_to_flat() {
        let (em, vecs) = build_corpus(2000, 16);
        let flat = FlatIndex::build(16, &vecs);
        let ivf = IvfIndex::build(16, &vecs, 32, 8, 11);
        let r_flat = recall_at_1(&flat, &em, 2000, 100);
        let r_ivf = recall_at_1(&ivf, &em, 2000, 100);
        assert!(r_flat > 0.95, "flat recall {r_flat}");
        assert!(r_ivf > 0.80, "ivf recall {r_ivf}");
    }

    #[test]
    fn hnsw_recall_close_to_flat() {
        let (em, vecs) = build_corpus(2000, 16);
        let hnsw = HnswIndex::build(16, &vecs, 12, 64, 13);
        let r = recall_at_1(&hnsw, &em, 2000, 100);
        assert!(r > 0.85, "hnsw recall {r}");
    }

    #[test]
    fn staged_final_equals_search_all_indexes() {
        let (_, vecs) = build_corpus(800, 12);
        let mut rng = Rng::new(5);
        let q: Vec<f32> = (0..12).map(|_| rng.f32()).collect();
        let indexes: Vec<Box<dyn VectorIndex>> = vec![
            Box::new(FlatIndex::build(12, &vecs)),
            Box::new(IvfIndex::build(12, &vecs, 16, 16, 1)),
            Box::new(HnswIndex::build(12, &vecs, 12, 48, 2)),
        ];
        for idx in &indexes {
            let direct = idx.search(&q, 5);
            let stages = idx.staged_search(&q, 5, 4);
            assert!(!stages.is_empty());
            let last = stages.last().unwrap();
            assert!((last.frac_scanned - 1.0).abs() < 1e-9);
            let ids: Vec<u32> = last.topk.iter().map(|h| h.1).collect();
            let direct_ids: Vec<u32> = direct.iter().map(|h| h.1).collect();
            assert_eq!(ids, direct_ids);
            // Monotone progress.
            for w in stages.windows(2) {
                assert!(w[0].frac_scanned <= w[1].frac_scanned + 1e-12);
            }
        }
    }
}
