//! Lightweight command-line argument parser (clap replacement).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with generated usage text.

use std::collections::BTreeMap;

/// Parsed arguments: flags, key-value options and positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub flags: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse raw arguments. `known_flags` distinguishes valueless flags
    /// from options that consume the next token.
    pub fn parse(raw: &[String], known_flags: &[&str]) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = raw.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some(eq) = name.find('=') {
                    let (k, v) = name.split_at(eq);
                    args.options.insert(k.to_string(), v[1..].to_string());
                } else if known_flags.contains(&name) {
                    args.flags.push(name.to_string());
                } else {
                    let val = it
                        .next()
                        .ok_or_else(|| format!("--{name} expects a value"))?;
                    args.options.insert(name.to_string(), val.clone());
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(
        &self,
        name: &str,
    ) -> Result<Option<T>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| format!("--{name}: cannot parse '{s}'")),
        }
    }

    pub fn get_parse_or<T: std::str::FromStr>(
        &self,
        name: &str,
        default: T,
    ) -> Result<T, String> {
        Ok(self.get_parse(name)?.unwrap_or(default))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed_args() {
        let a = Args::parse(
            &raw(&[
                "serve", "--port", "8080", "--verbose", "--rate=1.5", "extra",
            ]),
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.positional, vec!["serve", "extra"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get_parse_or::<f64>("rate", 0.0).unwrap(), 1.5);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&raw(&["--port"]), &[]).is_err());
    }

    #[test]
    fn parse_error_reported() {
        let a = Args::parse(&raw(&["--rate", "abc"]), &[]).unwrap();
        assert!(a.get_parse::<f64>("rate").is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&raw(&[]), &[]).unwrap();
        assert_eq!(a.get_or("model", "mistral-7b"), "mistral-7b");
        assert_eq!(a.get_parse_or::<usize>("batch", 4).unwrap(), 4);
    }
}
