//! Wire protocol: newline-delimited JSON messages.
//!
//! The `stats` response body is schema-driven: the field set, wire
//! names, parse defaults and merge semantics all come from the metric
//! registry ([`crate::metrics::registry`]), so this module only defines
//! the structs and delegates their encode/parse.

use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// Client → server.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Query {
        target_doc: u32,
        query: String,
        max_new: usize,
    },
    Stats,
    Shutdown,
}

/// One query's result.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryResult {
    pub id: u64,
    pub docs: Vec<u32>,
    pub docs_hit: usize,
    pub cached_tokens: usize,
    pub computed_tokens: usize,
    pub ttft_ms: f64,
    pub total_ms: f64,
    pub text: String,
}

/// One tenant's slice of the aggregate serving metrics, with its CAG
/// admission mode. The fan-out merge combines lines element-wise by
/// tenant id: counts sum, `mean_ttft_ms` is request-weighted (with a
/// NaN/zero-served guard, like the top-level mean).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TenantLine {
    pub tenant: u32,
    pub requests: u64,
    pub completed: u64,
    pub shed: u64,
    pub downgraded: u64,
    /// Requests whose TTFT met the SLO (meaningful only with
    /// `slo_enabled` on the enclosing stats).
    pub slo_ok: u64,
    /// Mean TTFT over this tenant's served requests, milliseconds
    /// (0 when none served — never NaN on the wire).
    pub mean_ttft_ms: f64,
    /// CAG admission mode wire code: 0 = cold-RAG, 1 = cached-RAG,
    /// 2 = CAG (corpus pinned, retrieval-free).
    pub mode: u8,
}

/// Aggregate stats. Tree counters aggregate every shard of the (shared)
/// sharded cache; `engines` reports how many engine replicas answered
/// the merged `stats` request.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct StatsResult {
    pub requests: usize,
    pub mean_ttft_ms: f64,
    pub hit_rate: f64,
    /// Engine replicas merged into this answer (1 for a single engine).
    pub engines: usize,
    /// Knowledge-tree insertions, aggregated across shards.
    pub tree_inserts: u64,
    /// GPU-tier evictions, aggregated across shards.
    pub tree_gpu_evictions: u64,
    /// Host-tier evictions, aggregated across shards.
    pub tree_host_evictions: u64,
    /// Speculative generations started (§5.3); per-engine, summed by
    /// the fan-out merge.
    pub spec_started: u64,
    /// Speculations terminated with their work discarded.
    pub spec_wasted: u64,
    /// Speculations confirmed by the final retrieval stage.
    pub spec_promoted: u64,
    /// KV bytes admissions served from GPU-resident prefixes,
    /// aggregated across shards (shared cache: max-merged across
    /// engines, like the tree counters).
    pub tree_gpu_hit_bytes: u64,
    /// Position-independent chunk-cache hits (`--chunk-cache on`;
    /// 0 when off), aggregated across shards and max-merged across
    /// engines like the tree counters.
    pub chunk_hits: u64,
    /// KV bytes chunk hits reused (the hit span minus the boundary).
    pub chunk_hit_bytes: u64,
    /// Boundary tokens re-prefilled across all chunk hits.
    pub boundary_recompute_tokens: u64,
    /// Cross-shard rebalancer slice recomputations (shared rebalancer
    /// state: max-merged).
    pub rebalance_recomputes: u64,
    /// Tier-capacity bytes the rebalancer moved between shards, GPU +
    /// host (max-merged).
    pub rebalance_moved_bytes: u64,
    /// Per-shard GPU bytes in use — the occupancy gauge that makes
    /// skew (and rebalancing) observable. The fan-out merge takes both
    /// shard arrays from ONE engine's snapshot (the freshest by
    /// rebalance progress) so they stay self-consistent — mixing
    /// snapshots taken across a capacity move could report more total
    /// capacity than the conserved budget.
    pub shard_gpu_used: Vec<u64>,
    /// Per-shard GPU capacity slice (static 1/K split, or wherever the
    /// rebalancer moved it); Σ == the configured budget. Merged from
    /// the same snapshot as `shard_gpu_used`.
    pub shard_gpu_capacity: Vec<u64>,
    /// Goodput under the configured TTFT SLO, requests/second over the
    /// full trace horizon (0 when no SLO accounting is active; summed
    /// across engines — each serves its own request stream).
    pub goodput_rps: f64,
    /// p99.9 TTFT, milliseconds, nearest-rank (max of the merge).
    pub ttft_p999_ms: f64,
    /// Requests shed by admission control; summed across engines.
    pub shed_requests: u64,
    /// Arrivals downgraded (speculation disabled); summed.
    pub downgraded_requests: u64,
    /// Fraction of all requests meeting the TTFT SLO
    /// (request-weighted in the merge, over engines that measured one).
    pub slo_attainment: f64,
    /// Whether this engine ran SLO admission control (`--shed on`).
    /// Distinguishes "no SLO measured" from "0% attained" — zeros in
    /// the fields above are only meaningful when this is true. The
    /// fan-out merge ORs it across engines.
    pub slo_enabled: bool,
    /// Disk-tier spills (host→disk demotions staged), aggregated
    /// across shards; 0 with `--disk off`. Shared-tree counter:
    /// max-merged across engines.
    pub disk_spills: u64,
    /// KV bytes those spills staged (async writes — counted, never
    /// charged).
    pub disk_spill_bytes: u64,
    /// Disk→host restages that served admissions (max-merged).
    pub disk_restage_hits: u64,
    /// KV bytes those restages read — the per-batch NVMe read-burst
    /// charge (max-merged).
    pub disk_restage_bytes: u64,
    /// Disk bytes in use across shards (gauge, from the same snapshot
    /// as the shard arrays; both zero with `--disk off`).
    pub disk_used: u64,
    /// Disk capacity across shards (same snapshot).
    pub disk_capacity: u64,
    /// Per-tenant SLO/mode breakdown, ascending tenant id (a single
    /// line for tenant 0 on legacy single-tenant deployments). The
    /// fan-out merge combines lines element-wise by tenant id.
    pub tenants: Vec<TenantLine>,
    /// Extension counters registered beyond the standard schema
    /// ([`crate::metrics::registry::Registry::with_counter`]): present
    /// entries travel the wire and merge under their registered
    /// semantics; the standard registry leaves this empty.
    pub ext: Vec<(&'static str, u64)>,
}

/// Server → client.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    Query(QueryResult),
    Stats(StatsResult),
    Ok,
    Error { message: String },
}

pub fn encode_request(req: &Request) -> String {
    let v = match req {
        Request::Query {
            target_doc,
            query,
            max_new,
        } => Json::obj(vec![
            ("op", Json::str("query")),
            ("target_doc", Json::num(*target_doc as f64)),
            ("query", Json::str(query.clone())),
            ("max_new", Json::num(*max_new as f64)),
        ]),
        Request::Stats => Json::obj(vec![("op", Json::str("stats"))]),
        Request::Shutdown => Json::obj(vec![("op", Json::str("shutdown"))]),
    };
    v.to_string()
}

pub fn parse_request(line: &str) -> Result<Request> {
    let v = Json::parse(line).map_err(|e| anyhow!("{e}"))?;
    let op = v
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing op"))?;
    match op {
        "query" => Ok(Request::Query {
            target_doc: v
                .get("target_doc")
                .and_then(Json::as_u64)
                .ok_or_else(|| anyhow!("target_doc"))?
                as u32,
            query: v
                .get("query")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            max_new: v
                .get("max_new")
                .and_then(Json::as_usize)
                .unwrap_or(4),
        }),
        "stats" => Ok(Request::Stats),
        "shutdown" => Ok(Request::Shutdown),
        other => Err(anyhow!("unknown op '{other}'")),
    }
}

pub fn encode_response(resp: &Response) -> String {
    let v = match resp {
        Response::Query(q) => Json::obj(vec![
            ("type", Json::str("query")),
            ("id", Json::num(q.id as f64)),
            (
                "docs",
                Json::Arr(
                    q.docs.iter().map(|&d| Json::num(d as f64)).collect(),
                ),
            ),
            ("docs_hit", Json::num(q.docs_hit as f64)),
            ("cached_tokens", Json::num(q.cached_tokens as f64)),
            ("computed_tokens", Json::num(q.computed_tokens as f64)),
            ("ttft_ms", Json::num(q.ttft_ms)),
            ("total_ms", Json::num(q.total_ms)),
            ("text", Json::str(q.text.clone())),
        ]),
        Response::Stats(s) => {
            crate::metrics::registry::Registry::standard().encode_stats(s)
        }
        Response::Ok => Json::obj(vec![("type", Json::str("ok"))]),
        Response::Error { message } => Json::obj(vec![
            ("type", Json::str("error")),
            ("message", Json::str(message.clone())),
        ]),
    };
    v.to_string()
}

pub fn parse_response(line: &str) -> Result<Response> {
    let v = Json::parse(line).map_err(|e| anyhow!("{e}"))?;
    let ty = v
        .get("type")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing type"))?;
    match ty {
        "query" => Ok(Response::Query(QueryResult {
            id: v.get("id").and_then(Json::as_u64).unwrap_or(0),
            docs: v
                .get("docs")
                .and_then(Json::as_arr)
                .map(|a| {
                    a.iter()
                        .filter_map(|x| x.as_u64().map(|d| d as u32))
                        .collect()
                })
                .unwrap_or_default(),
            docs_hit: v
                .get("docs_hit")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            cached_tokens: v
                .get("cached_tokens")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            computed_tokens: v
                .get("computed_tokens")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            ttft_ms: v.get("ttft_ms").and_then(Json::as_f64).unwrap_or(0.0),
            total_ms: v
                .get("total_ms")
                .and_then(Json::as_f64)
                .unwrap_or(0.0),
            text: v
                .get("text")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
        })),
        "stats" => Ok(Response::Stats(
            crate::metrics::registry::Registry::standard().parse_stats(&v),
        )),
        "ok" => Ok(Response::Ok),
        "error" => Ok(Response::Error {
            message: v
                .get("message")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
        }),
        other => Err(anyhow!("unknown response type '{other}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let reqs = [
            Request::Query {
                target_doc: 42,
                query: "what is RAG?".to_string(),
                max_new: 8,
            },
            Request::Stats,
            Request::Shutdown,
        ];
        for r in reqs {
            let enc = encode_request(&r);
            assert_eq!(parse_request(&enc).unwrap(), r);
        }
    }

    #[test]
    fn response_roundtrip() {
        let resps = [
            Response::Query(QueryResult {
                id: 7,
                docs: vec![1, 2],
                docs_hit: 1,
                cached_tokens: 64,
                computed_tokens: 40,
                ttft_ms: 12.5,
                total_ms: 30.0,
                text: "answer".to_string(),
            }),
            Response::Stats(StatsResult {
                requests: 10,
                mean_ttft_ms: 5.5,
                hit_rate: 0.75,
                engines: 2,
                tree_inserts: 40,
                tree_gpu_evictions: 7,
                tree_host_evictions: 3,
                spec_started: 9,
                spec_wasted: 2,
                spec_promoted: 5,
                tree_gpu_hit_bytes: 4096,
                chunk_hits: 6,
                chunk_hit_bytes: 768,
                boundary_recompute_tokens: 48,
                rebalance_recomputes: 3,
                rebalance_moved_bytes: 1024,
                shard_gpu_used: vec![512, 0, 256, 128],
                shard_gpu_capacity: vec![2048, 512, 768, 768],
                goodput_rps: 1.25,
                ttft_p999_ms: 87.5,
                shed_requests: 4,
                downgraded_requests: 2,
                slo_attainment: 0.9,
                slo_enabled: true,
                disk_spills: 11,
                disk_spill_bytes: 5632,
                disk_restage_hits: 8,
                disk_restage_bytes: 4096,
                disk_used: 9216,
                disk_capacity: 65536,
                tenants: vec![
                    TenantLine {
                        tenant: 0,
                        requests: 6,
                        completed: 5,
                        shed: 1,
                        downgraded: 1,
                        slo_ok: 4,
                        mean_ttft_ms: 7.25,
                        mode: 2,
                    },
                    TenantLine {
                        tenant: 1,
                        requests: 4,
                        completed: 4,
                        shed: 0,
                        downgraded: 0,
                        slo_ok: 3,
                        mean_ttft_ms: 11.5,
                        mode: 1,
                    },
                ],
                ext: Vec::new(),
            }),
            Response::Ok,
            Response::Error {
                message: "nope".to_string(),
            },
        ];
        for r in resps {
            let enc = encode_response(&r);
            assert_eq!(parse_response(&enc).unwrap(), r);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_request("{}").is_err());
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"op":"fly"}"#).is_err());
        assert!(parse_response(r#"{"type":"wat"}"#).is_err());
    }
}
