//! TCP JSON-lines serving front-end — concurrent runtime.
//!
//! The paper's prototype exposes retrieval + generation behind a RESTful
//! API; here the transport is a newline-delimited JSON protocol over TCP
//! (std-only — no HTTP stack offline). The runtime is multi-worker:
//!
//! ```text
//!   acceptor thread ──► connection channel ──► N connection workers
//!                                                   │ parse + estimate
//!                                                   ▼
//!                                  SharedReorderQueue (§5.2 ordering)
//!                                                   │
//!                                                   ▼
//!                         engine-driver thread (owns the QueryHandler;
//!                         PJRT handles are not `Send`, so the handler is
//!                         constructed *inside* this thread)
//! ```
//!
//! Connection workers block on their own sockets only, so up to
//! `workers` clients progress fully independently (a connection holds
//! its worker for its lifetime; an idle-timeout reclaims workers from
//! silent keep-alive clients). The single engine thread drains the
//! shared queue in cache-aware priority order. Shutdown is graceful: the
//! queue is sealed against new work, queued requests are drained and
//! answered, then every thread exits. An optional
//! [`ServerOptions::estimator`] supplies
//! cached/compute token estimates (e.g. from a shared
//! [`crate::controller::CacheService`]) so the queue can reorder by the
//! paper's `CachedLength / ComputationLength` priority.

pub mod proto;

use anyhow::Result;
use crate::sched::{PendingRequest, SharedReorderQueue};
use proto::{Request, Response};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Application hook: execute one query.
pub trait QueryHandler {
    fn query(
        &mut self,
        target_doc: u32,
        query: &str,
        max_new: usize,
    ) -> Result<proto::QueryResult>;

    /// Aggregate stats line.
    fn stats(&self) -> proto::StatsResult;
}

/// Cached/compute token estimate for a request, used as the reorder
/// priority. Must be callable from any connection worker.
pub type PriorityEstimator =
    Arc<dyn Fn(&Request) -> (usize, usize) + Send + Sync>;

/// Concurrency configuration of a server.
#[derive(Clone)]
pub struct ServerOptions {
    /// Connection-handler threads (how many clients progress at once).
    pub workers: usize,
    /// Cache-aware reordering of queued requests (§5.2). Takes effect
    /// only when an `estimator` is supplied; otherwise the queue is
    /// strict FIFO (equal priorities would reorder arbitrarily).
    pub reorder: bool,
    /// Starvation window for the reorder queue.
    pub window: usize,
    /// Optional cached/compute estimator feeding the reorder priority.
    pub estimator: Option<PriorityEstimator>,
    /// Close a connection that completes no request for this long. Each
    /// open connection occupies a worker thread, so without a bound,
    /// `workers` idle keep-alive clients would starve everyone else.
    pub idle_timeout: Duration,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            workers: 4,
            reorder: true,
            window: 16,
            estimator: None,
            idle_timeout: Duration::from_secs(60),
        }
    }
}

/// One queued query: the parsed request plus the channel its connection
/// worker is blocked on.
struct Job {
    req: Request,
    resp: mpsc::Sender<Response>,
}

/// A running server bound to a local port.
pub struct Server {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    jobs: Arc<SharedReorderQueue<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind to `127.0.0.1:port` (0 = ephemeral) with default options.
    /// `factory` builds the handler on the engine-driver thread, so the
    /// handler type need not be `Send` (PJRT state is thread-local).
    pub fn spawn<H, F>(port: u16, factory: F) -> Result<Server>
    where
        H: QueryHandler,
        F: FnOnce() -> Result<H> + Send + 'static,
    {
        Self::spawn_with(port, ServerOptions::default(), factory)
    }

    /// Bind and start the full runtime: acceptor + `opts.workers`
    /// connection handlers + one engine-driver thread.
    pub fn spawn_with<H, F>(
        port: u16,
        opts: ServerOptions,
        factory: F,
    ) -> Result<Server>
    where
        H: QueryHandler,
        F: FnOnce() -> Result<H> + Send + 'static,
    {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let shutdown = Arc::new(AtomicBool::new(false));
        // Without an estimator every request gets the same priority, and
        // "reordering" equal priorities is just unfair scrambling — fall
        // back to strict FIFO until a cache-aware signal exists.
        let reorder = opts.reorder && opts.estimator.is_some();
        let jobs: Arc<SharedReorderQueue<Job>> =
            Arc::new(SharedReorderQueue::new(reorder, opts.window));
        let started = Instant::now();
        let next_job = Arc::new(AtomicU64::new(0));
        let (conn_tx, conn_rx) = mpsc::channel::<TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let mut handles = Vec::new();

        // Acceptor: hand accepted connections to the worker pool.
        {
            let shutdown = Arc::clone(&shutdown);
            handles.push(std::thread::spawn(move || {
                accept_loop(listener, conn_tx, &shutdown);
            }));
        }

        // Connection workers.
        for _ in 0..opts.workers.max(1) {
            let conn_rx = Arc::clone(&conn_rx);
            let jobs = Arc::clone(&jobs);
            let shutdown = Arc::clone(&shutdown);
            let estimator = opts.estimator.clone();
            let next_job = Arc::clone(&next_job);
            let idle_timeout = opts.idle_timeout;
            handles.push(std::thread::spawn(move || loop {
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
                let stream = {
                    let rx = match conn_rx.lock() {
                        Ok(g) => g,
                        Err(p) => p.into_inner(),
                    };
                    rx.recv_timeout(Duration::from_millis(10))
                };
                match stream {
                    Ok(s) => {
                        if let Err(e) = serve_conn(
                            s,
                            &jobs,
                            &shutdown,
                            estimator.as_ref(),
                            &next_job,
                            started,
                            idle_timeout,
                        ) {
                            log::warn!("connection error: {e}");
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => continue,
                    Err(mpsc::RecvTimeoutError::Disconnected) => return,
                }
            }));
        }

        // Engine driver: owns the handler, drains the shared queue.
        {
            let jobs = Arc::clone(&jobs);
            let shutdown = Arc::clone(&shutdown);
            handles.push(std::thread::spawn(move || {
                engine_loop(factory, &jobs, &shutdown);
            }));
        }

        Ok(Server {
            addr,
            shutdown,
            jobs,
            handles,
        })
    }

    /// Block until every runtime thread exits (after a shutdown op).
    pub fn join(mut self) {
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }

    /// Request shutdown (draining queued work) and wait.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Wake anything blocked on the queue so joins cannot hang.
        self.jobs.close();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    conn_tx: mpsc::Sender<TcpStream>,
    shutdown: &AtomicBool,
) {
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                if conn_tx.send(stream).is_err() {
                    break; // workers gone
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                log::warn!("accept error: {e}");
                break;
            }
        }
    }
    // However this loop ends — shutdown op, fatal accept error, workers
    // gone — the rest of the runtime must wind down too, or the engine
    // thread would poll a forever-empty queue and join() would hang.
    shutdown.store(true, Ordering::SeqCst);
}

fn engine_loop<H, F>(
    factory: F,
    jobs: &SharedReorderQueue<Job>,
    shutdown: &AtomicBool,
) where
    H: QueryHandler,
    F: FnOnce() -> Result<H>,
{
    // Close the queue however this thread exits — normal shutdown,
    // factory failure, or a panicking handler. Dropping pending jobs
    // disconnects their response channels; without this, connection
    // workers blocked in `submit` would wait forever and
    // `Server::stop`/`join` would deadlock on joining them.
    struct CloseGuard<'a> {
        jobs: &'a SharedReorderQueue<Job>,
        shutdown: &'a AtomicBool,
    }
    impl Drop for CloseGuard<'_> {
        fn drop(&mut self) {
            self.shutdown.store(true, Ordering::SeqCst);
            self.jobs.close();
        }
    }
    let _guard = CloseGuard { jobs, shutdown };

    let mut handler = match factory() {
        Ok(h) => h,
        Err(e) => {
            log::error!("handler construction failed: {e:#}");
            return;
        }
    };
    loop {
        match jobs.pop_timeout(Duration::from_millis(20)) {
            Some((_pending, job)) => {
                let response = match job.req {
                    Request::Query {
                        target_doc,
                        query,
                        max_new,
                    } => match handler.query(target_doc, &query, max_new) {
                        Ok(result) => Response::Query(result),
                        Err(e) => Response::Error {
                            message: format!("query failed: {e}"),
                        },
                    },
                    Request::Stats => Response::Stats(handler.stats()),
                    // Shutdown never reaches the queue; answered inline
                    // by the connection worker.
                    Request::Shutdown => Response::Ok,
                };
                // A worker that gave up (connection died) is fine.
                let _ = job.resp.send(response);
            }
            None => {
                if shutdown.load(Ordering::SeqCst) {
                    // Two-phase graceful drain: seal first so no push
                    // can slip in behind the emptiness check (a refused
                    // push is answered "server shutting down" by its
                    // worker), then finish everything already accepted.
                    jobs.seal();
                    if jobs.is_empty() {
                        break;
                    }
                }
            }
        }
    }
}

fn serve_conn(
    stream: TcpStream,
    jobs: &SharedReorderQueue<Job>,
    shutdown: &AtomicBool,
    estimator: Option<&PriorityEstimator>,
    next_job: &AtomicU64,
    started: Instant,
    idle_timeout: Duration,
) -> Result<()> {
    // Bounded reads so an idle connection cannot wedge its worker past a
    // shutdown request.
    stream.set_read_timeout(Some(Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    // Persistent line buffer: a timeout mid-line must not drop the
    // partial request (read_line appends). Bounded so a newline-free
    // byte stream cannot grow it without limit.
    const MAX_LINE_BYTES: usize = 1 << 20;
    let mut line = String::new();
    let mut last_activity = Instant::now();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) if line.ends_with('\n') => {}
            Ok(_) => {
                // Partial line: keep accumulating. Deliberately NOT
                // activity — only a completed request earns the worker;
                // a byte-dripping client is reclaimed by the idle bound.
                if line.len() > MAX_LINE_BYTES {
                    anyhow::bail!("request line exceeds {MAX_LINE_BYTES} bytes");
                }
                continue;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Idle keep-alive bound: this connection owns a worker
                // thread, so a client that completes no requests must
                // eventually yield it.
                if last_activity.elapsed() >= idle_timeout {
                    return Ok(());
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        if line.len() > MAX_LINE_BYTES {
            anyhow::bail!("request line exceeds {MAX_LINE_BYTES} bytes");
        }
        if line.trim().is_empty() {
            line.clear();
            continue;
        }
        last_activity = Instant::now();
        let response = match proto::parse_request(&line) {
            Err(e) => Response::Error {
                message: format!("bad request: {e}"),
            },
            Ok(Request::Shutdown) => {
                shutdown.store(true, Ordering::SeqCst);
                writeln!(
                    writer,
                    "{}",
                    proto::encode_response(&Response::Ok)
                )?;
                return Ok(());
            }
            Ok(req) => submit(req, jobs, estimator, next_job, started),
        };
        writeln!(writer, "{}", proto::encode_response(&response))?;
        // Re-stamp after answering: queue wait + engine service time must
        // not count against the client's idle budget.
        last_activity = Instant::now();
        line.clear();
    }
}

/// Enqueue one request on the shared queue and wait for the engine's
/// answer. Stats requests get infinite priority (zero compute) so
/// observability is never starved by a deep prefill backlog.
fn submit(
    req: Request,
    jobs: &SharedReorderQueue<Job>,
    estimator: Option<&PriorityEstimator>,
    next_job: &AtomicU64,
    started: Instant,
) -> Response {
    let (cached, compute) = match (&req, estimator) {
        (Request::Stats, _) => (0, 0),
        (r, Some(f)) => f(r),
        (_, None) => (0, 1),
    };
    let (tx, rx) = mpsc::channel();
    let pending = PendingRequest {
        id: next_job.fetch_add(1, Ordering::SeqCst),
        arrival: started.elapsed().as_secs_f64(),
        cached_tokens: cached,
        compute_tokens: compute,
        bypassed: 0,
    };
    if !jobs.push(pending, Job { req, resp: tx }) {
        return Response::Error {
            message: "server shutting down".to_string(),
        };
    }
    match rx.recv() {
        Ok(response) => response,
        // Engine thread gone (construction failure or shutdown close):
        // the job was dropped, not silently lost.
        Err(_) => Response::Error {
            message: "engine unavailable".to_string(),
        },
    }
}

/// Blocking client for the JSON-lines protocol.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client {
            reader: BufReader::new(stream),
            writer,
        })
    }

    pub fn call(&mut self, req: &Request) -> Result<Response> {
        writeln!(self.writer, "{}", proto::encode_request(req))?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        proto::parse_response(&line)
    }
}
