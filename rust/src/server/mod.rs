//! TCP JSON-lines serving front-end — concurrent runtime.
//!
//! The paper's prototype exposes retrieval + generation behind a RESTful
//! API; here the transport is a newline-delimited JSON protocol over TCP
//! (std-only — no HTTP stack offline). The runtime is multi-worker and
//! multi-engine:
//!
//! ```text
//!   acceptor thread ──► connection channel ──► N connection workers
//!                                                │ parse + estimate
//!                                                │ shard-affinity route
//!                                 ┌──────────────┼──────────────┐
//!                                 ▼              ▼              ▼
//!                             queue 0        queue 1  …     queue M-1
//!                        (SharedReorderQueue each: §5.2 ordering and
//!                         starvation bound hold per engine)
//!                                 │              │              │
//!                                 ▼ pop_batch    ▼ pop_batch    ▼
//!                       ┌─ admission-control ladder (`--shed on`) ─┐
//!                       │ each pop stamps the member's queue wait  │
//!                       │ (pop wall clock − arrival) and hands it  │
//!                       │ to the handler via query_batch_timed /   │
//!                       │ submit_session_timed; the RealServer     │
//!                       │ ladder (controller::pipeline::ShedLadder)│
//!                       │ EWMAs the waits, downgrades new          │
//!                       │ admissions to single-stage retrieval,    │
//!                       │ sheds members queued past the TTFT SLO.  │
//!                       │ `--shed off`: waits ignored, bit-exact   │
//!                       └───────────────────────────────────────────┘
//!                             engine 0       engine 1  …    engine M-1
//!                        (each engine-driver thread owns its own
//!                         QueryHandler. Blocking mode — `--speculate
//!                         off` — admits a BATCH per iteration: up to
//!                         `max_batch` compatible requests popped
//!                         together in §5.2 order — one bypass event,
//!                         ≤ `batch_tokens` summed compute — answered
//!                         through QueryHandler::query_batch, whose
//!                         admissions coalesce into one H2D burst and
//!                         whose commits into one write-back burst
//!                         (controller::batch::BatchAdmission).
//!                         Event-driven mode — `--speculate on` — is a
//!                         MULTIPLEXER instead: queries enter the
//!                         handler's session lifecycle (submit_session)
//!                         so staged retrieval on the handler's thread
//!                         pool overlaps speculative prefill (§5.3);
//!                         the loop drains the queue non-blockingly
//!                         (try_pop_batch) while ≤ `max_batch` sessions
//!                         are parked in Retrieving, and completions
//!                         stream back via poll_sessions. PJRT handles
//!                         are not `Send`, so each handler is
//!                         constructed *inside* its engine thread)
//! ```
//!
//! Connection workers block on their own sockets only, so up to
//! `workers` clients progress fully independently (a connection holds
//! its worker for its lifetime; an idle-timeout reclaims workers from
//! silent keep-alive clients). Each engine thread drains its own queue
//! in cache-aware priority order, a batch per iteration; requests are
//! routed to engines by knowledge-tree shard
//! ([`ServerOptions::router`], folded through
//! [`crate::sched::ShardRouter`]), so a shard's working set stays with
//! one engine. `stats` requests fan out to every engine and the replies
//! are merged by the metric registry's table-driven merge (see
//! [`crate::metrics::registry`] and the diagram on `merge_stats`).
//! Shutdown is graceful: every queue is sealed against new
//! work, queued requests are drained and answered, then every thread
//! exits. An optional [`ServerOptions::estimator`] supplies
//! cached/compute token estimates (e.g. from a shared
//! [`crate::controller::ShardedCacheService`]) so each queue can
//! reorder by the paper's `CachedLength / ComputationLength` priority.

pub mod proto;

use anyhow::Result;
use crate::sched::{PendingRequest, ShardRouter, SharedReorderQueue};
use proto::{Request, Response};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A completed (or failed) non-blocking session, surfaced by
/// [`QueryHandler::poll_sessions`]. `ticket` echoes the id the engine
/// passed to [`QueryHandler::submit_session`].
pub struct SessionDone {
    pub ticket: u64,
    pub result: Result<proto::QueryResult>,
}

/// Application hook: execute queries — blocking (`query`/`query_batch`)
/// or as non-blocking sessions (`submit_session`/`poll_sessions`, used
/// by the `--speculate on` event-multiplexing engine loop).
pub trait QueryHandler {
    fn query(
        &mut self,
        target_doc: u32,
        query: &str,
        max_new: usize,
    ) -> Result<proto::QueryResult>;

    /// Submit one query into the handler's non-blocking session
    /// lifecycle; the result arrives later through
    /// [`QueryHandler::poll_sessions`] tagged with `ticket`. The
    /// default — for handlers without a staged retrieval path — serves
    /// synchronously and returns the result immediately (`Some`).
    fn submit_session(
        &mut self,
        ticket: u64,
        target_doc: u32,
        query: &str,
        max_new: usize,
    ) -> Option<Result<proto::QueryResult>> {
        let _ = ticket;
        Some(self.query(target_doc, query, max_new))
    }

    /// Drain completed sessions, blocking at most `timeout` for
    /// progress. Default: no session lifecycle, nothing to drain.
    fn poll_sessions(&mut self, timeout: Duration) -> Vec<SessionDone> {
        let _ = timeout;
        Vec::new()
    }

    /// Sessions submitted and not yet completed; the engine loop bounds
    /// admission by `max_batch - sessions_in_flight()`.
    fn sessions_in_flight(&self) -> usize {
        0
    }

    /// Execute the queries of one admission batch (popped together by
    /// the engine driver, `(target_doc, query, max_new)` each),
    /// returning exactly one result per member in order. The default
    /// runs members sequentially through [`QueryHandler::query`];
    /// batched handlers override it to admit every member first and
    /// coalesce their cache-hit transfers into one H2D burst
    /// ([`crate::controller::BatchAdmission`], e.g. via
    /// [`crate::controller::real::RealServer::serve_batch`]).
    fn query_batch(
        &mut self,
        batch: &[(u32, String, usize)],
    ) -> Vec<Result<proto::QueryResult>> {
        batch
            .iter()
            .map(|(doc, query, max_new)| {
                self.query(*doc, query, *max_new)
            })
            .collect()
    }

    /// [`QueryHandler::query_batch`] plus each member's reorder-queue
    /// wait (seconds between queue entry and this pop). Handlers with
    /// SLO admission control override this to feed the waits into their
    /// shed ladder (e.g.
    /// [`crate::controller::real::RealServer::serve_batch_timed`]); the
    /// default ignores the waits, so plain handlers are unaffected.
    fn query_batch_timed(
        &mut self,
        batch: &[(u32, String, usize)],
        waits: &[f64],
    ) -> Vec<Result<proto::QueryResult>> {
        let _ = waits;
        self.query_batch(batch)
    }

    /// [`QueryHandler::submit_session`] plus the request's reorder-queue
    /// wait, for the session multiplexer. Default ignores the wait.
    fn submit_session_timed(
        &mut self,
        ticket: u64,
        target_doc: u32,
        query: &str,
        max_new: usize,
        wait: f64,
    ) -> Option<Result<proto::QueryResult>> {
        let _ = wait;
        self.submit_session(ticket, target_doc, query, max_new)
    }

    /// Aggregate stats line. Contract for multi-engine deployments
    /// ([`Server::spawn_sharded`]): `requests`/`mean_ttft_ms`/`hit_rate`
    /// must cover only THIS handler's work (they are summed /
    /// request-weighted across engines), while the `tree_*` counters
    /// must snapshot the SHARED sharded cache (they merge by maximum —
    /// per-engine private caches would be under-reported).
    fn stats(&self) -> proto::StatsResult;
}

/// Cached/compute token estimate for a request, used as the reorder
/// priority. Must be callable from any connection worker.
pub type PriorityEstimator =
    Arc<dyn Fn(&Request) -> (usize, usize) + Send + Sync>;

/// Maps a request to its knowledge-tree shard (cache affinity); the
/// runtime folds the shard onto an engine with [`ShardRouter`]. Must be
/// callable from any connection worker.
pub type ShardFn = Arc<dyn Fn(&Request) -> usize + Send + Sync>;

/// Concurrency configuration of a server.
#[derive(Clone)]
pub struct ServerOptions {
    /// Connection-handler threads (how many clients progress at once).
    pub workers: usize,
    /// Engine-driver threads (one per GPU/replica), each draining its
    /// own reorder queue. Requests route to engines by shard affinity.
    pub engines: usize,
    /// Requests admitted per engine iteration (one batched queue pop,
    /// counted as ONE §5.2 bypass event): the batch whose cache-hit
    /// transfers coalesce into a single H2D burst. 1 reproduces the
    /// one-request-per-iteration behavior bit-for-bit.
    pub max_batch: usize,
    /// Summed compute-token budget (the members' β estimates) of one
    /// admitted batch; the first pick is always taken.
    pub batch_tokens: usize,
    /// Event-driven serving (`--speculate on`): the engine loop becomes
    /// a multiplexer over queue pops and session events, driving
    /// requests through [`QueryHandler::submit_session`] /
    /// [`QueryHandler::poll_sessions`] so staged retrieval overlaps
    /// speculative prefill (§5.3). `false` keeps the blocking batched
    /// loop, bit for bit.
    pub speculate: bool,
    /// Cache-aware reordering of queued requests (§5.2). Takes effect
    /// only when an `estimator` is supplied; otherwise each queue is
    /// strict FIFO (equal priorities would reorder arbitrarily).
    pub reorder: bool,
    /// Starvation window for each reorder queue.
    pub window: usize,
    /// Optional cached/compute estimator feeding the reorder priority.
    pub estimator: Option<PriorityEstimator>,
    /// Optional request → shard mapping for engine affinity. Without
    /// one, queries route by `target_doc` and everything else goes to
    /// engine 0.
    pub router: Option<ShardFn>,
    /// Close a connection that completes no request for this long. Each
    /// open connection occupies a worker thread, so without a bound,
    /// `workers` idle keep-alive clients would starve everyone else.
    pub idle_timeout: Duration,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            workers: 4,
            engines: 1,
            max_batch: 8,
            batch_tokens: 16384,
            speculate: false,
            reorder: true,
            window: 16,
            estimator: None,
            router: None,
            idle_timeout: Duration::from_secs(60),
        }
    }
}

/// One queued query: the parsed request plus the channel its connection
/// worker is blocked on.
struct Job {
    req: Request,
    resp: mpsc::Sender<Response>,
}

/// A running server bound to a local port.
pub struct Server {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    queues: Arc<Vec<Arc<SharedReorderQueue<Job>>>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind to `127.0.0.1:port` (0 = ephemeral) with default options.
    /// `factory` builds the handler on the engine-driver thread, so the
    /// handler type need not be `Send` (PJRT state is thread-local).
    pub fn spawn<H, F>(port: u16, factory: F) -> Result<Server>
    where
        H: QueryHandler,
        F: FnOnce() -> Result<H> + Send + 'static,
    {
        Self::spawn_with(port, ServerOptions::default(), factory)
    }

    /// Single-engine runtime over a one-shot handler factory. For
    /// `opts.engines > 1` use [`Server::spawn_sharded`], whose factory
    /// can build one handler per engine.
    pub fn spawn_with<H, F>(
        port: u16,
        mut opts: ServerOptions,
        factory: F,
    ) -> Result<Server>
    where
        H: QueryHandler,
        F: FnOnce() -> Result<H> + Send + 'static,
    {
        opts.engines = 1;
        let cell = Mutex::new(Some(factory));
        Self::spawn_sharded(port, opts, move |_engine| {
            let taken = match cell.lock() {
                Ok(mut g) => g.take(),
                Err(p) => p.into_inner().take(),
            };
            match taken {
                Some(build) => build(),
                None => Err(anyhow::anyhow!(
                    "single-engine factory already consumed"
                )),
            }
        })
    }

    /// Bind and start the full runtime: acceptor + `opts.workers`
    /// connection handlers + `opts.engines` engine-driver threads, each
    /// draining its own shard-affine reorder queue. `factory(i)` runs
    /// inside engine thread `i`, so handlers need not be `Send`.
    pub fn spawn_sharded<H, F>(
        port: u16,
        opts: ServerOptions,
        factory: F,
    ) -> Result<Server>
    where
        H: QueryHandler,
        F: Fn(usize) -> Result<H> + Send + Sync + 'static,
    {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let shutdown = Arc::new(AtomicBool::new(false));
        // Without an estimator every request gets the same priority, and
        // "reordering" equal priorities is just unfair scrambling — fall
        // back to strict FIFO until a cache-aware signal exists.
        let reorder = opts.reorder && opts.estimator.is_some();
        let engines = opts.engines.max(1);
        let queues: Arc<Vec<Arc<SharedReorderQueue<Job>>>> = Arc::new(
            (0..engines)
                .map(|_| {
                    Arc::new(SharedReorderQueue::new(reorder, opts.window))
                })
                .collect(),
        );
        let started = Instant::now();
        let next_job = Arc::new(AtomicU64::new(0));
        let (conn_tx, conn_rx) = mpsc::channel::<TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let mut handles = Vec::new();

        // Acceptor: hand accepted connections to the worker pool.
        {
            let shutdown = Arc::clone(&shutdown);
            handles.push(std::thread::spawn(move || {
                accept_loop(listener, conn_tx, &shutdown);
            }));
        }

        // Connection workers.
        for _ in 0..opts.workers.max(1) {
            let conn_rx = Arc::clone(&conn_rx);
            let queues = Arc::clone(&queues);
            let shutdown = Arc::clone(&shutdown);
            let estimator = opts.estimator.clone();
            let router = opts.router.clone();
            let next_job = Arc::clone(&next_job);
            let idle_timeout = opts.idle_timeout;
            handles.push(std::thread::spawn(move || loop {
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
                let stream = {
                    let rx = match conn_rx.lock() {
                        Ok(g) => g,
                        Err(p) => p.into_inner(),
                    };
                    rx.recv_timeout(Duration::from_millis(10))
                };
                match stream {
                    Ok(s) => {
                        if let Err(e) = serve_conn(
                            s,
                            &queues,
                            &shutdown,
                            estimator.as_ref(),
                            router.as_ref(),
                            &next_job,
                            started,
                            idle_timeout,
                        ) {
                            log::warn!("connection error: {e}");
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Timeout) => continue,
                    Err(mpsc::RecvTimeoutError::Disconnected) => return,
                }
            }));
        }

        // Engine drivers: each owns its handler and drains its queue a
        // batch per iteration.
        let factory = Arc::new(factory);
        let max_batch = opts.max_batch.max(1);
        let batch_tokens = opts.batch_tokens.max(1);
        let speculate = opts.speculate;
        for engine in 0..engines {
            let queue = Arc::clone(&queues[engine]);
            let shutdown = Arc::clone(&shutdown);
            let factory = Arc::clone(&factory);
            handles.push(std::thread::spawn(move || {
                engine_loop(
                    engine,
                    factory.as_ref(),
                    &queue,
                    &shutdown,
                    max_batch,
                    batch_tokens,
                    speculate,
                    started,
                );
            }));
        }

        Ok(Server {
            addr,
            shutdown,
            queues,
            handles,
        })
    }

    /// Block until every runtime thread exits (after a shutdown op).
    pub fn join(mut self) {
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }

    /// Request shutdown (draining queued work) and wait.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Wake anything blocked on any queue so joins cannot hang.
        for q in self.queues.iter() {
            q.close();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    conn_tx: mpsc::Sender<TcpStream>,
    shutdown: &AtomicBool,
) {
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                if conn_tx.send(stream).is_err() {
                    break; // workers gone
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                log::warn!("accept error: {e}");
                break;
            }
        }
    }
    // However this loop ends — shutdown op, fatal accept error, workers
    // gone — the rest of the runtime must wind down too, or the engine
    // thread would poll a forever-empty queue and join() would hang.
    shutdown.store(true, Ordering::SeqCst);
}

#[allow(clippy::too_many_arguments)]
fn engine_loop<H, F>(
    engine: usize,
    factory: &F,
    jobs: &SharedReorderQueue<Job>,
    shutdown: &AtomicBool,
    max_batch: usize,
    batch_tokens: usize,
    speculate: bool,
    started: Instant,
) where
    H: QueryHandler,
    F: Fn(usize) -> Result<H>,
{
    // Close THIS engine's queue however its thread exits — normal
    // shutdown, factory failure, or a panicking handler. Dropping its
    // pending jobs disconnects their response channels; without this,
    // connection workers blocked in `submit` would wait forever and
    // `Server::stop`/`join` would deadlock on joining them. Setting the
    // shutdown op tells the sibling engines to seal + drain their own
    // queues gracefully (a guard must never close a sibling's queue —
    // that would drop jobs the sibling is still draining).
    struct CloseGuard<'a> {
        jobs: &'a SharedReorderQueue<Job>,
        shutdown: &'a AtomicBool,
    }
    impl Drop for CloseGuard<'_> {
        fn drop(&mut self) {
            self.shutdown.store(true, Ordering::SeqCst);
            self.jobs.close();
        }
    }
    let _guard = CloseGuard { jobs, shutdown };

    let mut handler = match factory(engine) {
        Ok(h) => h,
        Err(e) => {
            log::error!("engine {engine}: handler construction failed: {e:#}");
            return;
        }
    };
    if speculate {
        // Event-driven serving: the loop multiplexes queue pops with
        // the handler's session events instead of blocking per batch.
        engine_loop_sessions(
            &mut handler,
            jobs,
            shutdown,
            max_batch,
            batch_tokens,
            started,
        );
        return;
    }
    // Answer a contiguous run of queries through the handler's batched
    // entry point, pairing each response channel by position. The
    // members' measured reorder-queue waits travel alongside so an
    // SLO-aware handler can feed its admission-control ladder.
    fn flush_queries<H: QueryHandler>(
        handler: &mut H,
        queries: &mut Vec<(u32, String, usize)>,
        waits: &mut Vec<f64>,
        resps: &mut Vec<mpsc::Sender<Response>>,
    ) {
        if queries.is_empty() {
            return;
        }
        let results = handler.query_batch_timed(queries, waits);
        debug_assert_eq!(
            results.len(),
            queries.len(),
            "query_batch answers every member"
        );
        for (resp, result) in resps.drain(..).zip(results) {
            let response = match result {
                Ok(r) => Response::Query(r),
                Err(e) => Response::Error {
                    message: format!("query failed: {e}"),
                },
            };
            // A worker that gave up (connection died) is fine.
            let _ = resp.send(response);
        }
        queries.clear();
        waits.clear();
    }
    loop {
        let popped = jobs.pop_batch_timeout(
            Duration::from_millis(20),
            max_batch,
            batch_tokens,
        );
        if popped.is_empty() {
            if shutdown.load(Ordering::SeqCst) {
                // Two-phase graceful drain: seal first so no push
                // can slip in behind the emptiness check (a refused
                // push is answered "server shutting down" by its
                // worker), then finish everything already accepted.
                jobs.seal();
                if jobs.is_empty() {
                    break;
                }
            }
            continue;
        }
        // One engine iteration: contiguous runs of queries batch
        // through the handler's batched entry point (whose admissions
        // coalesce into one H2D burst); stats snapshots and shutdown
        // acks answer in their popped position, so within a batch the
        // §5.2 pop order stays the observable answer order (under
        // reordering, a stats job's infinite priority pops it at the
        // batch front anyway).
        let mut queries: Vec<(u32, String, usize)> = Vec::new();
        let mut waits: Vec<f64> = Vec::new();
        let mut query_resp: Vec<mpsc::Sender<Response>> = Vec::new();
        for (pending, job) in popped {
            match job.req {
                Request::Query {
                    target_doc,
                    query,
                    max_new,
                } => {
                    // Queue wait measured at pop time: pop wall clock
                    // minus the arrival stamp the connection worker
                    // recorded at push (both on the server's `started`
                    // clock).
                    let wait = (started.elapsed().as_secs_f64()
                        - pending.arrival)
                        .max(0.0);
                    queries.push((target_doc, query, max_new));
                    waits.push(wait);
                    query_resp.push(job.resp);
                }
                Request::Stats => {
                    flush_queries(
                        &mut handler,
                        &mut queries,
                        &mut waits,
                        &mut query_resp,
                    );
                    let _ = job.resp.send(Response::Stats(handler.stats()));
                }
                // Shutdown never reaches the queue; answered inline
                // by the connection worker.
                Request::Shutdown => {
                    let _ = job.resp.send(Response::Ok);
                }
            }
        }
        flush_queries(&mut handler, &mut queries, &mut waits, &mut query_resp);
    }
}

/// Wire form of one query result (shared by both engine loops).
fn query_response(result: Result<proto::QueryResult>) -> Response {
    match result {
        Ok(r) => Response::Query(r),
        Err(e) => Response::Error {
            message: format!("query failed: {e}"),
        },
    }
}

/// The `--speculate on` engine loop: an event multiplexer. Queries
/// enter the handler's non-blocking session lifecycle
/// ([`QueryHandler::submit_session`]) — their staged retrievals run on
/// the handler's thread pool while this loop keeps draining the queue —
/// and completions stream back through [`QueryHandler::poll_sessions`].
/// Admission stays bounded by `max_batch` in-flight sessions, and the
/// queue is drained NON-blockingly while sessions are parked in
/// Retrieving ([`SharedReorderQueue::try_pop_batch`]), so neither side
/// can starve the other. Responses may complete out of §5.2 pop order —
/// that reordering is the point of overlapping retrieval.
fn engine_loop_sessions<H: QueryHandler>(
    handler: &mut H,
    jobs: &SharedReorderQueue<Job>,
    shutdown: &AtomicBool,
    max_batch: usize,
    batch_tokens: usize,
    started: Instant,
) {
    let mut waiters: HashMap<u64, mpsc::Sender<Response>> = HashMap::new();
    let mut next_ticket = 0u64;
    let mut sealed_at: Option<Instant> = None;
    loop {
        let in_flight = handler.sessions_in_flight();
        let slots = max_batch.saturating_sub(in_flight);
        let popped = if in_flight > 0 {
            // Sessions in flight: never block on the queue — their
            // stage events are the thing to wait on below.
            jobs.try_pop_batch(slots, batch_tokens)
        } else {
            jobs.pop_batch_timeout(
                Duration::from_millis(20),
                slots.max(1),
                batch_tokens,
            )
        };
        let drained_empty = popped.is_empty();
        for (pending, job) in popped {
            match job.req {
                Request::Query {
                    target_doc,
                    query,
                    max_new,
                } => {
                    let ticket = next_ticket;
                    next_ticket += 1;
                    // Same pop-time queue-wait measurement as the
                    // blocking loop; SLO-aware handlers shed or
                    // downgrade the submit based on it.
                    let wait = (started.elapsed().as_secs_f64()
                        - pending.arrival)
                        .max(0.0);
                    match handler.submit_session_timed(
                        ticket,
                        target_doc,
                        &query,
                        max_new,
                        wait,
                    ) {
                        Some(result) => {
                            let _ =
                                job.resp.send(query_response(result));
                        }
                        None => {
                            waiters.insert(ticket, job.resp);
                        }
                    }
                }
                // Stats answer in pop position; with speculation on,
                // responses are not globally ordered anyway.
                Request::Stats => {
                    let _ = job.resp.send(Response::Stats(handler.stats()));
                }
                Request::Shutdown => {
                    let _ = job.resp.send(Response::Ok);
                }
            }
        }
        // Poll while ANY waiter is outstanding, not only while sessions
        // are live: a session that died at submit time (refused
        // retrieval task) is reaped immediately — in_flight drops to 0
        // — yet its error still has to reach the stored waiter.
        if handler.sessions_in_flight() > 0 || !waiters.is_empty() {
            for done in handler.poll_sessions(Duration::from_millis(5)) {
                if let Some(resp) = waiters.remove(&done.ticket) {
                    let _ = resp.send(query_response(done.result));
                }
            }
        }
        if shutdown.load(Ordering::SeqCst) && drained_empty {
            // Two-phase drain, session flavor: seal first, then finish
            // accepted work — queued jobs AND in-flight sessions.
            jobs.seal();
            let sealed = *sealed_at.get_or_insert_with(Instant::now);
            if jobs.is_empty()
                && handler.sessions_in_flight() == 0
                && waiters.is_empty()
            {
                break;
            }
            // A wedged session (dead retrieval pool) must not hang
            // shutdown forever: after a generous drain window the
            // remaining waiters' channels drop, which their connection
            // workers observe as "engine unavailable".
            if sealed.elapsed() > Duration::from_secs(10) {
                log::warn!(
                    "engine: abandoning {} unfinished session(s) at \
                     shutdown",
                    waiters.len().max(handler.sessions_in_flight())
                );
                break;
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn serve_conn(
    stream: TcpStream,
    queues: &[Arc<SharedReorderQueue<Job>>],
    shutdown: &AtomicBool,
    estimator: Option<&PriorityEstimator>,
    router: Option<&ShardFn>,
    next_job: &AtomicU64,
    started: Instant,
    idle_timeout: Duration,
) -> Result<()> {
    // Bounded reads so an idle connection cannot wedge its worker past a
    // shutdown request.
    stream.set_read_timeout(Some(Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    // Persistent line buffer: a timeout mid-line must not drop the
    // partial request (read_line appends). Bounded so a newline-free
    // byte stream cannot grow it without limit.
    const MAX_LINE_BYTES: usize = 1 << 20;
    let mut line = String::new();
    let mut last_activity = Instant::now();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) if line.ends_with('\n') => {}
            Ok(_) => {
                // Partial line: keep accumulating. Deliberately NOT
                // activity — only a completed request earns the worker;
                // a byte-dripping client is reclaimed by the idle bound.
                if line.len() > MAX_LINE_BYTES {
                    anyhow::bail!("request line exceeds {MAX_LINE_BYTES} bytes");
                }
                continue;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Idle keep-alive bound: this connection owns a worker
                // thread, so a client that completes no requests must
                // eventually yield it.
                if last_activity.elapsed() >= idle_timeout {
                    return Ok(());
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        if line.len() > MAX_LINE_BYTES {
            anyhow::bail!("request line exceeds {MAX_LINE_BYTES} bytes");
        }
        if line.trim().is_empty() {
            line.clear();
            continue;
        }
        last_activity = Instant::now();
        let response = match proto::parse_request(&line) {
            Err(e) => Response::Error {
                message: format!("bad request: {e}"),
            },
            Ok(Request::Shutdown) => {
                shutdown.store(true, Ordering::SeqCst);
                writeln!(
                    writer,
                    "{}",
                    proto::encode_response(&Response::Ok)
                )?;
                return Ok(());
            }
            Ok(req) => {
                submit(req, queues, estimator, router, next_job, started)
            }
        };
        writeln!(writer, "{}", proto::encode_response(&response))?;
        // Re-stamp after answering: queue wait + engine service time must
        // not count against the client's idle budget.
        last_activity = Instant::now();
        line.clear();
    }
}

/// The engine queue that owns a request: the app-supplied shard mapping
/// (or `target_doc` when absent), folded onto the engine count by the
/// stable [`ShardRouter`] assignment.
fn route_engine(
    req: &Request,
    router: Option<&ShardFn>,
    engines: usize,
) -> usize {
    let shard = match router {
        Some(f) => f(req),
        None => match req {
            Request::Query { target_doc, .. } => *target_doc as usize,
            _ => 0,
        },
    };
    ShardRouter::new(engines).route(shard)
}

/// Merge the per-engine answers to one `stats` request by delegating to
/// the metric registry's table-driven merge: every field combines under
/// the [`MergeKind`](crate::metrics::registry::MergeKind) it was
/// registered with, so the per-engine/shared-state distinctions live in
/// ONE schema instead of a field-by-field function here.
///
/// ```text
///   submit_stats ──► engine 0 ┐
///                    engine 1 ├─ StatsResult parts (one per engine)
///                    engine M ┘        │
///                                      ▼
///            metrics::registry::Registry::standard().merge(parts)
///               │  per descriptor: Sum | Max | Or | EngineCount
///               │  RequestWeightedMean / SloGatedMean (NaN-skip)
///               │  SnapshotConsistentGroup (ONE freshest snapshot)
///               │  ByKey (tenant lines, request-weighted mean)
///               ▼
///            one merged StatsResult ──► proto::encode_response
///               (field set + wire names from the same registry)
/// ```
///
/// See the merge-semantics vocabulary in [`crate::metrics`] for why
/// each kind exists (shared-tree counters max-merge, gauges come from
/// one self-consistent snapshot, means skip NaN parts without diluting
/// weights, attainment only counts SLO-enabled engines).
fn merge_stats(parts: &[proto::StatsResult]) -> proto::StatsResult {
    crate::metrics::registry::Registry::standard().merge(parts)
}

/// Fan one `stats` request out to every engine and merge the answers,
/// so observability covers all replicas in one round trip. Stats jobs
/// carry infinite priority (zero compute) so a deep prefill backlog
/// never starves them.
fn submit_stats(
    queues: &[Arc<SharedReorderQueue<Job>>],
    next_job: &AtomicU64,
    started: Instant,
) -> Response {
    let (tx, rx) = mpsc::channel();
    let mut accepted = 0usize;
    for q in queues {
        let pending = PendingRequest {
            id: next_job.fetch_add(1, Ordering::SeqCst),
            arrival: started.elapsed().as_secs_f64(),
            cached_tokens: 0,
            compute_tokens: 0,
            bypassed: 0,
        };
        let job = Job {
            req: Request::Stats,
            resp: tx.clone(),
        };
        if q.push(pending, job) {
            accepted += 1;
        }
    }
    // Only the queued jobs may keep the channel open: if an engine dies,
    // its job's sender drops and `recv` below observes the disconnect
    // instead of blocking on this (never-used) original sender forever.
    drop(tx);
    if accepted == 0 {
        return Response::Error {
            message: "server shutting down".to_string(),
        };
    }
    let mut parts = Vec::with_capacity(accepted);
    for _ in 0..accepted {
        match rx.recv() {
            Ok(Response::Stats(s)) => parts.push(s),
            Ok(other) => return other,
            // An engine died mid-request; merge what did answer.
            Err(_) => break,
        }
    }
    if parts.is_empty() {
        return Response::Error {
            message: "engine unavailable".to_string(),
        };
    }
    Response::Stats(merge_stats(&parts))
}

/// Enqueue one request on its affinity engine's queue and wait for the
/// answer; `stats` fans out to every engine instead.
fn submit(
    req: Request,
    queues: &[Arc<SharedReorderQueue<Job>>],
    estimator: Option<&PriorityEstimator>,
    router: Option<&ShardFn>,
    next_job: &AtomicU64,
    started: Instant,
) -> Response {
    if matches!(req, Request::Stats) {
        return submit_stats(queues, next_job, started);
    }
    let (cached, compute) = match estimator {
        Some(f) => f(&req),
        None => (0, 1),
    };
    let engine = route_engine(&req, router, queues.len());
    let (tx, rx) = mpsc::channel();
    let pending = PendingRequest {
        id: next_job.fetch_add(1, Ordering::SeqCst),
        arrival: started.elapsed().as_secs_f64(),
        cached_tokens: cached,
        compute_tokens: compute,
        bypassed: 0,
    };
    if !queues[engine].push(pending, Job { req, resp: tx }) {
        return Response::Error {
            message: "server shutting down".to_string(),
        };
    }
    match rx.recv() {
        Ok(response) => response,
        // Engine thread gone (construction failure or shutdown close):
        // the job was dropped, not silently lost.
        Err(_) => Response::Error {
            message: "engine unavailable".to_string(),
        },
    }
}

/// Blocking client for the JSON-lines protocol.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client {
            reader: BufReader::new(stream),
            writer,
        })
    }

    pub fn call(&mut self, req: &Request) -> Result<Response> {
        writeln!(self.writer, "{}", proto::encode_request(req))?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        proto::parse_response(&line)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn part(requests: usize) -> proto::StatsResult {
        proto::StatsResult {
            requests,
            engines: 1,
            ..Default::default()
        }
    }

    #[test]
    fn merge_skips_nan_parts_without_diluting_weights() {
        // Engine 0 measured nothing finishable: its recorder mean is
        // NaN. Engine 1 measured 10ms over 10 requests. The merge must
        // report 10ms — not NaN, and not 10ms diluted by engine 0's
        // request count.
        let mut a = part(30);
        a.mean_ttft_ms = f64::NAN;
        a.hit_rate = f64::NAN;
        let mut b = part(10);
        b.mean_ttft_ms = 10.0;
        b.hit_rate = 0.5;
        let m = merge_stats(&[a, b]);
        assert_eq!(m.requests, 40);
        assert_eq!(m.mean_ttft_ms, 10.0);
        assert_eq!(m.hit_rate, 0.5);
        assert!(!m.slo_enabled);
    }

    #[test]
    fn merge_weights_attainment_only_over_slo_engines() {
        // Engine a ran --shed off (slo_enabled false, attainment 0.0 is
        // "not measured", not "0% attained"); engines b and c measured.
        let mut a = part(1000);
        a.slo_attainment = 0.0;
        let mut b = part(10);
        b.slo_enabled = true;
        b.slo_attainment = 0.9;
        let mut c = part(30);
        c.slo_enabled = true;
        c.slo_attainment = 0.5;
        let m = merge_stats(&[a, b, c]);
        assert!(m.slo_enabled);
        let want = (0.9 * 10.0 + 0.5 * 30.0) / 40.0;
        assert!((m.slo_attainment - want).abs() < 1e-12);
    }

    #[test]
    fn merge_of_empty_and_zero_request_parts_is_zeroed() {
        let m = merge_stats(&[part(0), part(0)]);
        assert_eq!(m.requests, 0);
        assert_eq!(m.mean_ttft_ms, 0.0);
        assert_eq!(m.slo_attainment, 0.0);
        assert!(!m.slo_enabled);
        let empty = merge_stats(&[]);
        assert_eq!(empty.requests, 0);
        assert_eq!(empty.engines, 0);
    }

    #[test]
    fn merge_combines_tenant_lines_and_disk_counters() {
        let line = |tenant, completed, ttft, mode| proto::TenantLine {
            tenant,
            requests: completed + 1,
            completed,
            shed: 1,
            slo_ok: completed,
            mean_ttft_ms: ttft,
            mode,
            ..Default::default()
        };
        let mut a = part(8);
        a.disk_spills = 5;
        a.disk_restage_bytes = 4096;
        a.disk_used = 100;
        a.disk_capacity = 1 << 20;
        a.tenants = vec![line(0, 4, 10.0, 2), line(1, 2, 30.0, 0)];
        let mut b = part(8);
        b.disk_spills = 7;
        b.disk_restage_bytes = 2048;
        b.disk_used = 900;
        b.disk_capacity = 1 << 20;
        // b is the fresher snapshot: more shard gauges reported.
        b.shard_gpu_capacity = vec![1];
        // Tenant 1 completed nothing on b: NaN mean must contribute
        // neither value nor weight; the cold→cached flip (code 1)
        // must still win the mode max.
        b.tenants = vec![line(0, 2, 4.0, 2), line(1, 0, f64::NAN, 1)];
        let m = merge_stats(&[a, b]);
        // Shared-tree counters max-merge; gauges follow the freshest
        // snapshot (b, which reported shard arrays).
        assert_eq!(m.disk_spills, 7);
        assert_eq!(m.disk_restage_bytes, 4096);
        assert_eq!(m.disk_used, 900);
        assert_eq!(m.disk_capacity, 1 << 20);
        assert_eq!(m.tenants.len(), 2);
        let t0 = &m.tenants[0];
        assert_eq!(t0.tenant, 0);
        assert_eq!(t0.requests, 8);
        assert_eq!(t0.completed, 6);
        assert_eq!(t0.shed, 2);
        assert_eq!(t0.mode, 2);
        // Request-weighted: a served tenant 0 at 10ms over 5 requests,
        // b at 4ms over 3.
        let want = (10.0 * 5.0 + 4.0 * 3.0) / 8.0;
        assert!((t0.mean_ttft_ms - want).abs() < 1e-12);
        let t1 = &m.tenants[1];
        assert_eq!(t1.tenant, 1);
        assert_eq!(t1.completed, 2);
        assert_eq!(t1.mean_ttft_ms, 30.0);
        assert_eq!(t1.mode, 1);
    }

    #[test]
    fn merge_weights_tenant_mean_by_requests() {
        // Regression: the by-tenant mean used to merge completed-
        // weighted (and unguarded against zero-request lines). It must
        // weight by the tenant's request count on each engine, with the
        // same NaN/zero-served skip rule as the top-level mean.
        let line = |requests, completed, ttft| proto::TenantLine {
            tenant: 0,
            requests,
            completed,
            mean_ttft_ms: ttft,
            ..Default::default()
        };
        let mut a = part(10);
        a.tenants = vec![line(9, 3, 12.0)];
        let mut b = part(10);
        b.tenants = vec![line(1, 1, 2.0)];
        // An engine that admitted requests but completed none reports a
        // non-finite mean: no value, no weight.
        let mut c = part(10);
        c.tenants = vec![line(5, 0, f64::NAN)];
        let m = merge_stats(&[a, b, c]);
        let t0 = &m.tenants[0];
        assert_eq!(t0.requests, 15);
        assert_eq!(t0.completed, 4);
        // Request-weighted: (12*9 + 2*1) / (9 + 1), NOT the completed-
        // weighted (12*3 + 2*1) / 4 = 9.5.
        let want = (12.0 * 9.0 + 2.0 * 1.0) / 10.0;
        assert!((t0.mean_ttft_ms - want).abs() < 1e-12);
    }
}
