//! TCP JSON-lines serving front-end.
//!
//! The paper's prototype exposes retrieval + generation behind a RESTful
//! API; here the transport is a newline-delimited JSON protocol over TCP
//! (std-only — no HTTP stack offline). The handler is constructed *inside*
//! the server thread (PJRT handles are not `Send`), and connections are
//! served sequentially — the single-engine setup the paper also uses.

pub mod proto;

use anyhow::Result;
use proto::{Request, Response};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Application hook: execute one query.
pub trait QueryHandler {
    fn query(
        &mut self,
        target_doc: u32,
        query: &str,
        max_new: usize,
    ) -> Result<proto::QueryResult>;

    /// Aggregate stats line.
    fn stats(&self) -> proto::StatsResult;
}

/// A running server bound to a local port.
pub struct Server {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind to `127.0.0.1:port` (0 = ephemeral). `factory` builds the
    /// handler on the server thread, so the handler type need not be
    /// `Send` (PJRT state is thread-local).
    pub fn spawn<H, F>(port: u16, factory: F) -> Result<Server>
    where
        H: QueryHandler,
        F: FnOnce() -> Result<H> + Send + 'static,
    {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&shutdown);
        let handle = std::thread::spawn(move || {
            let mut handler = match factory() {
                Ok(h) => h,
                Err(e) => {
                    log::error!("handler construction failed: {e:#}");
                    flag.store(true, Ordering::SeqCst);
                    return;
                }
            };
            while !flag.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        if let Err(e) =
                            serve_conn(stream, &mut handler, &flag)
                        {
                            log::warn!("connection error: {e}");
                        }
                    }
                    Err(ref e)
                        if e.kind() == std::io::ErrorKind::WouldBlock =>
                    {
                        std::thread::sleep(
                            std::time::Duration::from_millis(5),
                        );
                    }
                    Err(e) => {
                        log::warn!("accept error: {e}");
                        break;
                    }
                }
            }
        });
        Ok(Server {
            addr,
            shutdown,
            handle: Some(handle),
        })
    }

    /// Block until the server thread exits (shutdown op received).
    pub fn join(mut self) {
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }

    /// Request shutdown and wait.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve_conn<H: QueryHandler>(
    stream: TcpStream,
    handler: &mut H,
    shutdown: &AtomicBool,
) -> Result<()> {
    // Bounded reads so an idle connection cannot wedge the accept loop
    // past a shutdown request.
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    // Persistent line buffer: a timeout mid-line must not drop the
    // partial request (read_line appends).
    let mut line = String::new();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // client closed
            Ok(_) if line.ends_with('\n') => {}
            Ok(_) => continue, // partial line, keep accumulating
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(e) => return Err(e.into()),
        }
        if line.trim().is_empty() {
            line.clear();
            continue;
        }
        let response = match proto::parse_request(&line) {
            Err(e) => Response::Error {
                message: format!("bad request: {e}"),
            },
            Ok(Request::Query {
                target_doc,
                query,
                max_new,
            }) => match handler.query(target_doc, &query, max_new) {
                Ok(result) => Response::Query(result),
                Err(e) => Response::Error {
                    message: format!("query failed: {e}"),
                },
            },
            Ok(Request::Stats) => Response::Stats(handler.stats()),
            Ok(Request::Shutdown) => {
                shutdown.store(true, Ordering::SeqCst);
                writeln!(
                    writer,
                    "{}",
                    proto::encode_response(&Response::Ok)
                )?;
                return Ok(());
            }
        };
        writeln!(writer, "{}", proto::encode_response(&response))?;
        line.clear();
    }
}

/// Blocking client for the JSON-lines protocol.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let writer = stream.try_clone()?;
        Ok(Client {
            reader: BufReader::new(stream),
            writer,
        })
    }

    pub fn call(&mut self, req: &Request) -> Result<Response> {
        writeln!(self.writer, "{}", proto::encode_request(req))?;
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        proto::parse_response(&line)
    }
}
