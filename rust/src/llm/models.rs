//! Model and GPU specification registry (paper Table 1 and §7 Testbed).

use anyhow::{bail, Result};

const MIB: f64 = 1024.0 * 1024.0;
const GIB: u64 = 1024 * 1024 * 1024;

/// Transformer parameters needed by the cost model — the paper's Table 1
/// rows plus the tiny PJRT-backed variants.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: &'static str,
    pub n_layers: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub d_model: usize,
    pub d_ff: usize,
    /// Mixture-of-experts: (active experts, total experts); None = dense.
    pub moe: Option<(usize, usize)>,
    /// Total parameter storage, bytes (fp16 unless tiny).
    pub params_bytes: u64,
    /// KV-cache bytes per token (Table 1 "KV Size").
    pub kv_bytes_per_token: usize,
}

impl ModelSpec {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_q_heads
    }

    /// Parameters touched per token (MoE activates a subset).
    pub fn active_params_bytes(&self) -> u64 {
        match self.moe {
            None => self.params_bytes,
            Some((active, total)) => {
                // Attention is shared; FFN experts dominate, scale by the
                // active fraction.
                let ffn_fraction = 0.75; // FFN share of a dense block
                let shared =
                    self.params_bytes as f64 * (1.0 - ffn_fraction);
                let experts = self.params_bytes as f64 * ffn_fraction
                    * active as f64
                    / total as f64;
                (shared + experts) as u64
            }
        }
    }

    pub fn lookup(name: &str) -> Result<ModelSpec> {
        for &m in ALL_MODELS {
            if m.name == name {
                return Ok(m.clone());
            }
        }
        bail!("unknown model '{name}'")
    }
}

/// Paper Table 1.
pub const MISTRAL_7B: ModelSpec = ModelSpec {
    name: "mistral-7b",
    n_layers: 32,
    n_q_heads: 32,
    n_kv_heads: 8,
    d_model: 4096,
    d_ff: 14336,
    moe: None,
    params_bytes: 14 * GIB,
    kv_bytes_per_token: (0.125 * MIB) as usize,
};

pub const LLAMA2_7B: ModelSpec = ModelSpec {
    name: "llama2-7b",
    n_layers: 32,
    n_q_heads: 32,
    n_kv_heads: 32,
    d_model: 4096,
    d_ff: 11008,
    moe: None,
    params_bytes: 14 * GIB,
    kv_bytes_per_token: (0.5 * MIB) as usize,
};

pub const MIXTRAL_8X7B: ModelSpec = ModelSpec {
    name: "mixtral-8x7b",
    n_layers: 32,
    n_q_heads: 32,
    n_kv_heads: 8,
    d_model: 4096,
    d_ff: 14336,
    moe: Some((2, 8)),
    params_bytes: (96.8 * GIB as f64) as u64,
    kv_bytes_per_token: (0.125 * MIB) as usize,
};

pub const LLAMA2_70B: ModelSpec = ModelSpec {
    name: "llama2-70b",
    n_layers: 80,
    n_q_heads: 64,
    n_kv_heads: 8,
    d_model: 8192,
    d_ff: 28672,
    moe: None,
    params_bytes: 140 * GIB,
    kv_bytes_per_token: (0.3125 * MIB) as usize,
};

/// The PJRT-backed tiny models (see python/compile/model.py); KV stored
/// as f32.
pub const TINY_MHA: ModelSpec = ModelSpec {
    name: "tiny-mha",
    n_layers: 4,
    n_q_heads: 8,
    n_kv_heads: 8,
    d_model: 128,
    d_ff: 512,
    moe: None,
    params_bytes: 3_674_624,
    kv_bytes_per_token: 4 * 2 * 8 * 16 * 4,
};

pub const TINY_GQA: ModelSpec = ModelSpec {
    name: "tiny-gqa",
    n_layers: 4,
    n_q_heads: 8,
    n_kv_heads: 2,
    d_model: 128,
    d_ff: 512,
    moe: None,
    params_bytes: 3_281_408,
    kv_bytes_per_token: 4 * 2 * 2 * 16 * 4,
};

pub const ALL_MODELS: &[&ModelSpec] = &[
    &MISTRAL_7B,
    &LLAMA2_7B,
    &MIXTRAL_8X7B,
    &LLAMA2_70B,
    &TINY_MHA,
    &TINY_GQA,
];

/// GPU capability model (§7 Testbed).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Peak dense fp16/bf16 FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bps: f64,
    /// Device memory, bytes.
    pub memory_bytes: u64,
    /// Achievable fraction of peak in prefill GEMMs.
    pub mfu: f64,
    /// Fixed per-iteration launch/framework overhead, seconds.
    pub iter_overhead_s: f64,
    /// Fraction of HBM bandwidth achieved when the prefix-caching prefill
    /// kernel gathers paged KV blocks (block-granular gather is far below
    /// streaming bandwidth; calibrated to the paper's Fig. 4 ratios).
    pub paged_kv_read_frac: f64,
}

impl GpuSpec {
    pub fn lookup(name: &str) -> Result<GpuSpec> {
        for &g in ALL_GPUS {
            if g.name == name {
                return Ok(g.clone());
            }
        }
        bail!("unknown gpu '{name}'")
    }
}

/// NVIDIA A10G (g5.16xlarge): 125 TFLOPS fp16, 600 GB/s, 24 GiB.
pub const A10G: GpuSpec = GpuSpec {
    name: "a10g",
    peak_flops: 125e12,
    hbm_bps: 600e9,
    memory_bytes: 24 * GIB,
    mfu: 0.45,
    iter_overhead_s: 4e-3,
    paged_kv_read_frac: 0.06,
};

/// Two NVLinked H800s with tensor/expert parallelism (§7.2): aggregate
/// compute and bandwidth at 85% parallel efficiency.
pub const H800X2: GpuSpec = GpuSpec {
    name: "h800x2",
    peak_flops: 2.0 * 989e12 * 0.85,
    hbm_bps: 2.0 * 3350e9 * 0.85,
    memory_bytes: 160 * GIB,
    mfu: 0.40,
    iter_overhead_s: 6e-3,
    paged_kv_read_frac: 0.06,
};

/// The CPU PJRT path for the tiny models (rate-limited by interpretation,
/// so the numbers are only used for smoke sims).
pub const CPU: GpuSpec = GpuSpec {
    name: "cpu",
    peak_flops: 5e10,
    hbm_bps: 2e10,
    memory_bytes: 8 * GIB,
    mfu: 0.5,
    iter_overhead_s: 1e-4,
    paged_kv_read_frac: 1.0,
};

pub const ALL_GPUS: &[&GpuSpec] = &[&A10G, &H800X2, &CPU];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_kv_sizes() {
        // Table 1: Mistral 0.125 MiB/token, LLaMA2-7B 0.5 MiB/token,
        // LLaMA2-70B 0.3125 MiB/token.
        assert_eq!(MISTRAL_7B.kv_bytes_per_token, 131072);
        assert_eq!(LLAMA2_7B.kv_bytes_per_token, 524288);
        assert_eq!(LLAMA2_70B.kv_bytes_per_token, 327680);
        // LLaMA2-7B KV is 4x Mistral's (drives the Fig. 13/14 gap).
        assert_eq!(
            LLAMA2_7B.kv_bytes_per_token,
            4 * MISTRAL_7B.kv_bytes_per_token
        );
    }

    #[test]
    fn kv_bytes_consistent_with_arch() {
        // bytes/token = layers * 2 * kv_heads * d_head * 2 (fp16).
        for m in [&MISTRAL_7B, &LLAMA2_7B, &LLAMA2_70B] {
            let derived =
                m.n_layers * 2 * m.n_kv_heads * m.d_head() * 2;
            assert_eq!(m.kv_bytes_per_token, derived, "{}", m.name);
        }
    }

    #[test]
    fn moe_activates_fewer_params() {
        let active = MIXTRAL_8X7B.active_params_bytes();
        assert!(active < MIXTRAL_8X7B.params_bytes / 2);
        assert!(active > MIXTRAL_8X7B.params_bytes / 8);
        assert_eq!(LLAMA2_7B.active_params_bytes(), LLAMA2_7B.params_bytes);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(ModelSpec::lookup("mistral-7b").unwrap(), MISTRAL_7B);
        assert!(ModelSpec::lookup("gpt-5").is_err());
        assert_eq!(GpuSpec::lookup("a10g").unwrap(), A10G);
        assert!(GpuSpec::lookup("tpu").is_err());
    }

    #[test]
    fn tiny_kv_matches_python_layout() {
        // (layers * 2 * kv_heads * d_head) f32 per token.
        assert_eq!(TINY_GQA.kv_bytes_per_token, 4 * 2 * 2 * 16 * 4);
        assert_eq!(TINY_MHA.kv_bytes_per_token, 4 * 2 * 8 * 16 * 4);
    }
}
