//! Analytic GPU cost model — the documented substitute for the paper's
//! A10G/H800 testbeds (DESIGN.md §4).
//!
//! Roofline style: an iteration costs
//! `max(FLOPs / (peak · MFU), bytes / HBM-bw) + overhead`.
//! Prefill over β new tokens with α cached tokens is compute-bound for
//! large β (weights GEMMs ∝ β·params, attention ∝ β·(α+β)); small-β
//! prefills and decodes are memory-bound on the weight read — which is
//! exactly the asymmetry that makes document-KV caching pay off (paper
//! §3.2, Fig. 4: up to 11.5× prefill reduction).

use super::models::{GpuSpec, ModelSpec};
use crate::util::stats::BilinearGrid;

/// Cost model for one (model, GPU) pair.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub model: ModelSpec,
    pub gpu: GpuSpec,
}

impl CostModel {
    pub fn new(model: ModelSpec, gpu: GpuSpec) -> Self {
        CostModel { model, gpu }
    }

    /// FLOPs to prefill `beta` new tokens attending to `alpha` cached
    /// tokens (single sequence).
    pub fn prefill_flops(&self, alpha: usize, beta: usize) -> f64 {
        let m = &self.model;
        // GEMMs: 2 FLOPs per param per token over the active parameters.
        let dense = 2.0
            * (m.active_params_bytes() as f64 / 2.0) // params (fp16 bytes→count)
            * beta as f64;
        // Attention: QK^T + PV, new tokens attend to alpha + causal half
        // of beta. 2 matmuls * 2 FLOPs.
        let attended = alpha as f64 * beta as f64
            + 0.5 * beta as f64 * beta as f64;
        let attn = 4.0 * m.n_layers as f64 * attended * m.d_model as f64;
        dense + attn
    }

    /// Memory time (seconds) of a prefill iteration: streaming weight and
    /// activation reads at full bandwidth, cached-prefix KV gathered at
    /// the (much lower) paged-gather bandwidth — the inefficiency that
    /// bounds the paper's Fig. 4 speedup at 11.5×.
    pub fn prefill_memory_time(&self, alpha: usize, beta: usize) -> f64 {
        let weights = self.model.active_params_bytes() as f64;
        let activations =
            beta as f64 * self.model.d_model as f64 * 2.0 * 8.0;
        let kv_read = (alpha + beta) as f64
            * self.model.kv_bytes_per_token as f64;
        (weights + activations) / self.gpu.hbm_bps
            + kv_read / (self.gpu.hbm_bps * self.gpu.paged_kv_read_frac)
    }

    /// Seconds to prefill one sequence: `beta` new tokens on `alpha`
    /// cached tokens.
    pub fn prefill_time(&self, alpha: usize, beta: usize) -> f64 {
        if beta == 0 {
            return 0.0;
        }
        let compute = self.prefill_flops(alpha, beta)
            / (self.gpu.peak_flops * self.gpu.mfu);
        let memory = self.prefill_memory_time(alpha, beta);
        compute.max(memory) + self.gpu.iter_overhead_s
    }

    /// Seconds to prefill a *batch* of `(alpha, beta)` jobs in one
    /// iteration: compute adds up, the weight read is shared.
    pub fn prefill_batch_time(&self, jobs: &[(usize, usize)]) -> f64 {
        if jobs.is_empty() {
            return 0.0;
        }
        let compute: f64 = jobs
            .iter()
            .map(|&(a, b)| self.prefill_flops(a, b))
            .sum::<f64>()
            / (self.gpu.peak_flops * self.gpu.mfu);
        // Weights are read once for the whole batch; per-sequence KV and
        // activation traffic adds up.
        let shared_weights =
            self.model.active_params_bytes() as f64 / self.gpu.hbm_bps;
        let per_seq: f64 = jobs
            .iter()
            .map(|&(a, b)| {
                self.prefill_memory_time(a, b)
                    - self.model.active_params_bytes() as f64
                        / self.gpu.hbm_bps
            })
            .sum();
        let memory = shared_weights + per_seq;
        compute.max(memory) + self.gpu.iter_overhead_s
    }

    /// Seconds for one decode iteration over a batch with the given
    /// context lengths (memory-bound: weights once + everyone's KV).
    pub fn decode_step_time(&self, context_lens: &[usize]) -> f64 {
        if context_lens.is_empty() {
            return 0.0;
        }
        let weights = self.model.active_params_bytes() as f64;
        let kv: f64 = context_lens
            .iter()
            .map(|&c| c as f64 * self.model.kv_bytes_per_token as f64)
            .sum();
        let memory = (weights + kv) / self.gpu.hbm_bps;
        let compute = context_lens.len() as f64 * 2.0
            * (self.model.active_params_bytes() as f64 / 2.0)
            / (self.gpu.peak_flops * self.gpu.mfu);
        memory.max(compute) + self.gpu.iter_overhead_s
    }

    /// Build the offline `(alpha, beta) → seconds` profile PGDSF
    /// interpolates (Algorithm 1 lines 6–9). Grid points are exponential
    /// in both axes, matching how the paper profiles "varying cached and
    /// non-cached token lengths offline".
    pub fn profile(&self, max_alpha: usize, max_beta: usize) -> CostProfile {
        let alphas = grid_points(max_alpha);
        let betas = grid_points(max_beta);
        let z: Vec<Vec<f64>> = alphas
            .iter()
            .map(|&a| {
                betas
                    .iter()
                    .map(|&b| self.prefill_time(a as usize, b as usize))
                    .collect()
            })
            .collect();
        CostProfile {
            grid: BilinearGrid::new(alphas, betas, z),
        }
    }
}

fn grid_points(max: usize) -> Vec<f64> {
    let mut pts = vec![0.0];
    let mut v = 32usize;
    while v < max {
        pts.push(v as f64);
        v *= 2;
    }
    pts.push(max as f64);
    pts
}

/// The profiled `(alpha, beta)` surface, consumed by PGDSF.
#[derive(Debug, Clone)]
pub struct CostProfile {
    grid: BilinearGrid,
}

impl CostProfile {
    /// Construct from explicit measurements (real-mode profiling).
    pub fn from_samples(
        alphas: Vec<f64>,
        betas: Vec<f64>,
        times: Vec<Vec<f64>>,
    ) -> Self {
        CostProfile {
            grid: BilinearGrid::new(alphas, betas, times),
        }
    }

    /// Estimated prefill seconds for (alpha cached, beta new) — Algorithm
    /// 1's `T(alpha, beta)`.
    pub fn estimate(&self, alpha: usize, beta: usize) -> f64 {
        self.grid.at(alpha as f64, beta as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::models::{A10G, LLAMA2_7B, MISTRAL_7B, MIXTRAL_8X7B, H800X2};

    fn llama_a10g() -> CostModel {
        CostModel::new(LLAMA2_7B, A10G)
    }

    #[test]
    fn fig2_shape_prefill_4k_about_a_second() {
        // Paper Fig. 2: LLaMA2-7B on A10G reaches ~1 s at 4000 input
        // tokens. Order of magnitude must match.
        let t = llama_a10g().prefill_time(0, 4000);
        assert!((0.5..2.0).contains(&t), "prefill(4000) = {t}s");
    }

    #[test]
    fn fig2_monotone_in_length() {
        let cm = llama_a10g();
        let mut prev = 0.0;
        for len in [128, 512, 1024, 2048, 4096, 8192] {
            let t = cm.prefill_time(0, len);
            assert!(t > prev);
            prev = t;
        }
    }

    #[test]
    fn fig4_cached_prefix_speedup() {
        // Paper Fig. 4: full prefill up to 11.5x slower than prefilling
        // just the 32 request tokens on a 4096-token cached prefix.
        let cm = llama_a10g();
        let full = cm.prefill_time(0, 4096 + 32);
        let cached = cm.prefill_time(4096, 32);
        let speedup = full / cached;
        assert!(
            (8.0..16.0).contains(&speedup),
            "speedup {speedup} vs paper's up-to-11.5x"
        );
    }

    #[test]
    fn fig4_cache_hit_with_transfer_still_wins() {
        // Paper Fig. 4: cache-hit latency including host→GPU KV
        // transmission is up to 3.9× lower than full prefill.
        let cm = llama_a10g();
        let transfer = crate::kvcache::TransferModel::pcie4();
        let kv_bytes = 4096u64 * cm.model.kv_bytes_per_token as u64;
        let hit = cm.prefill_time(4096, 32)
            + transfer.transfer_time(kv_bytes);
        let full = cm.prefill_time(0, 4096 + 32);
        let ratio = full / hit;
        assert!(
            (2.5..6.0).contains(&ratio),
            "hit-with-transfer ratio {ratio} vs paper's up-to-3.9x"
        );
    }

    #[test]
    fn small_prefill_is_memory_bound() {
        let cm = llama_a10g();
        // 1 token: dominated by the weight read (~14 GiB / 600 GB/s ≈
        // 25 ms), far above pure compute.
        let t = cm.prefill_time(0, 1);
        assert!(t > 0.02, "{t}");
        assert!(t < 0.06, "{t}");
    }

    #[test]
    fn decode_scales_with_context_and_batch() {
        let cm = llama_a10g();
        let short = cm.decode_step_time(&[100]);
        let long = cm.decode_step_time(&[8000]);
        assert!(long > short);
        let b1 = cm.decode_step_time(&[1000]);
        let b4 = cm.decode_step_time(&[1000; 4]);
        assert!(b4 > b1);
        // But far from 4x: weights are shared.
        assert!(b4 < 2.0 * b1);
    }

    #[test]
    fn batched_prefill_shares_weight_read() {
        let cm = llama_a10g();
        let single = cm.prefill_time(0, 32);
        let batch4 = cm.prefill_batch_time(&[(0, 32); 4]);
        assert!(batch4 < 4.0 * single);
        assert!(batch4 > single);
    }

    /// Conformance-suite anchor: for every batch size ≥ 2, one batched
    /// iteration over B cache-miss jobs is strictly cheaper than B
    /// serialized singleton iterations (shared weight read + one
    /// iteration overhead) — the engine-side half of the batched
    /// admission win; the link-side half is
    /// `kvcache::TransferModel`'s coalesced burst.
    #[test]
    fn batch_prefill_strictly_beats_serial_singletons() {
        let cm = llama_a10g();
        for b in [2usize, 4, 8] {
            let jobs = vec![(0usize, 256usize); b];
            let batched = cm.prefill_batch_time(&jobs);
            let serial = b as f64 * cm.prefill_time(0, 256);
            assert!(
                batched < serial,
                "batch {b}: {batched} !< serial {serial}"
            );
        }
    }

    #[test]
    fn mistral_prefill_cheaper_kv_equal_compute() {
        // Same dense size => similar big-prefill time; Mistral's GQA KV
        // makes the *memory-bound* small-β prefill slightly cheaper.
        let llama = llama_a10g();
        let mistral = CostModel::new(MISTRAL_7B, A10G);
        let l = llama.prefill_time(4096, 32);
        let m = mistral.prefill_time(4096, 32);
        assert!(m < l, "mistral {m} vs llama {l}");
    }

    #[test]
    fn h800_faster_than_a10g() {
        let a = CostModel::new(MIXTRAL_8X7B, H800X2).prefill_time(0, 2048);
        let b = CostModel::new(MIXTRAL_8X7B, A10G).prefill_time(0, 2048);
        assert!(a < b);
    }

    #[test]
    fn profile_interpolates_model() {
        let cm = llama_a10g();
        let profile = cm.profile(8192, 8192);
        for (a, b) in [(0, 100), (1000, 32), (4096, 4096), (123, 457)] {
            let direct = cm.prefill_time(a, b);
            let interp = profile.estimate(a, b);
            let rel = (direct - interp).abs() / direct;
            assert!(rel < 0.25, "({a},{b}): direct {direct} interp {interp}");
        }
    }

    #[test]
    fn profile_clamps_beyond_grid() {
        let cm = llama_a10g();
        let profile = cm.profile(1024, 1024);
        assert!(profile.estimate(10_000, 10_000) >= profile.estimate(1024, 1024));
    }
}
