//! Byte-level tokenizer for the PJRT-backed end-to-end path.
//!
//! The tiny models have a 512-token vocabulary: 256 byte values, a few
//! specials, and the rest reserved. Deterministic, lossless for ASCII/UTF-8
//! text, no external vocabulary files.

/// Special token ids.
pub const BOS: i32 = 256;
pub const EOS: i32 = 257;
pub const SEP: i32 = 258;
pub const PAD: i32 = 0;

/// Byte-level tokenizer (vocab 512).
#[derive(Debug, Clone, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn new() -> Self {
        ByteTokenizer
    }

    pub fn vocab_size(&self) -> usize {
        512
    }

    /// Encode text as raw bytes (no specials).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes().map(|b| b as i32).collect()
    }

    /// Encode with BOS prefix.
    pub fn encode_with_bos(&self, text: &str) -> Vec<i32> {
        let mut v = vec![BOS];
        v.extend(self.encode(text));
        v
    }

    /// Decode token ids back to text; specials are dropped, invalid UTF-8
    /// is replaced.
    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .filter(|&&t| (0..256).contains(&t))
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer::new();
        let s = "RAGCache caches knowledge!";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn roundtrip_utf8() {
        let t = ByteTokenizer::new();
        let s = "héllo 世界";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn specials_dropped_on_decode() {
        let t = ByteTokenizer::new();
        let mut toks = t.encode_with_bos("hi");
        toks.push(EOS);
        assert_eq!(t.decode(&toks), "hi");
        assert_eq!(toks[0], BOS);
    }

    #[test]
    fn all_ids_in_vocab() {
        let t = ByteTokenizer::new();
        for tok in t.encode_with_bos("any ütf8 ẗext") {
            assert!((0..512).contains(&tok));
        }
    }
}
