//! LLM engine layer: model/GPU specifications, the analytic cost model
//! standing in for the paper's A10G/H800 testbeds, the offline
//! `(alpha, beta)` profiler PGDSF interpolates over, a byte-level
//! tokenizer, and the iteration-level batching engine.

pub mod models;
pub mod cost_model;
pub mod tokenizer;
pub mod engine;

pub use cost_model::{CostModel, CostProfile};
pub use engine::{Engine, IterKind, IterationPlan, SeqEvent, SeqSpec};
pub use models::{GpuSpec, ModelSpec};
pub use tokenizer::ByteTokenizer;
