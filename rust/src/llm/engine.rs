//! Iteration-level batching engine (vLLM/Orca-style continuous batching).
//!
//! The engine is *stepped* by the controller: `plan()` yields the next
//! iteration (a prefill batch or a decode batch) with its duration from
//! the cost model; the controller advances its clock (virtual or real)
//! and calls `complete()` to collect sequence events. One iteration is
//! either prefill or decode, matching vLLM v0.3's scheduler that the
//! paper builds on; aborts (from speculative pipelining) take effect at
//! iteration boundaries — Algorithm 2 "terminate after the current
//! iteration".

use super::cost_model::CostModel;
use std::collections::VecDeque;

/// A sequence admitted for prefill.
#[derive(Debug, Clone)]
pub struct SeqSpec {
    pub id: u64,
    /// Cached tokens (skipped in prefill).
    pub alpha: usize,
    /// Tokens to prefill (documents not cached + question).
    pub beta: usize,
    /// Total output tokens (>= 1; the first comes out of prefill).
    pub output_tokens: usize,
    /// Extra time charged to this sequence's prefill iteration, seconds —
    /// host→GPU KV loading for cache hits (§3.2 cache-hit latency).
    pub extra_time: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IterKind {
    Prefill,
    Decode,
}

/// One engine iteration, planned but not yet completed.
#[derive(Debug, Clone)]
pub struct IterationPlan {
    pub kind: IterKind,
    pub seq_ids: Vec<u64>,
    /// Modelled duration, seconds.
    pub duration: f64,
}

/// Sequence lifecycle events emitted at iteration completion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SeqEvent {
    /// First token produced (end of prefill) — the TTFT point. Also
    /// emitted for sequences aborted mid-prefill: the iteration ran to
    /// completion, so their KV exists and the controller may cache it
    /// (the paper's Theorem 5.1 case 4 — wrong speculation still only
    /// used otherwise-idle resources, and its document KV is valid).
    FirstToken { id: u64 },
    /// All output tokens produced.
    Finished { id: u64 },
}

/// Result of an abort request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AbortOutcome {
    /// Removed before doing (more) work; no further events.
    Removed,
    /// The sequence is inside the in-flight prefill iteration; it will
    /// finish that iteration (emitting `FirstToken`) and then stop —
    /// Algorithm 2's "terminate after the current iteration".
    Deferred,
    NotFound,
}

#[derive(Debug, Clone)]
struct DecodeState {
    id: u64,
    context: usize,
    generated: usize,
    output_tokens: usize,
}

/// The batching engine.
pub struct Engine {
    cost: CostModel,
    max_batch: usize,
    max_prefill_tokens: usize,
    waiting: VecDeque<SeqSpec>,
    decoding: Vec<DecodeState>,
    in_flight: Option<IterationPlan>,
    /// Sequences to drop when the in-flight iteration completes.
    kill_after_iter: Vec<u64>,
}

impl Engine {
    pub fn new(
        cost: CostModel,
        max_batch: usize,
        max_prefill_tokens: usize,
    ) -> Self {
        Engine {
            cost,
            max_batch: max_batch.max(1),
            max_prefill_tokens: max_prefill_tokens.max(1),
            waiting: VecDeque::new(),
            decoding: Vec::new(),
            in_flight: None,
            kill_after_iter: Vec::new(),
        }
    }

    pub fn cost_model(&self) -> &CostModel {
        &self.cost
    }

    /// Admit a sequence for prefill (the controller's scheduler decides
    /// admission order — see `sched::ReorderQueue`). A batched
    /// admission ([`crate::controller::batch::BatchAdmission`]) puts
    /// its one coalesced H2D burst on its first member's `extra_time`
    /// and zero on the rest, so the per-iteration sum below charges
    /// each burst exactly once.
    pub fn admit(&mut self, seq: SeqSpec) {
        self.waiting.push_back(seq);
    }

    /// Sequences waiting for prefill (Algorithm 2's "pool").
    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn decoding_len(&self) -> usize {
        self.decoding.len()
    }

    /// Whether the engine has nothing to do and nothing in flight.
    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty()
            && self.decoding.is_empty()
            && self.in_flight.is_none()
    }

    /// Abort a sequence wherever it is. A sequence inside the in-flight
    /// *prefill* iteration finishes that iteration first (Algorithm 2:
    /// "terminate the incorrect speculative generation after the current
    /// LLM iteration, which does not affect other requests in the
    /// batch") — its `FirstToken` still fires so the computed KV can be
    /// cached; it is then dropped before decoding.
    pub fn abort(&mut self, id: u64) -> AbortOutcome {
        if let Some(pos) = self.waiting.iter().position(|s| s.id == id) {
            self.waiting.remove(pos);
            return AbortOutcome::Removed;
        }
        if let Some(plan) = &self.in_flight {
            if plan.kind == IterKind::Prefill && plan.seq_ids.contains(&id)
            {
                self.kill_after_iter.push(id);
                return AbortOutcome::Deferred;
            }
        }
        if let Some(pos) = self.decoding.iter().position(|s| s.id == id) {
            self.decoding.remove(pos);
            return AbortOutcome::Removed;
        }
        AbortOutcome::NotFound
    }

    /// True when the in-flight iteration consists solely of aborted
    /// sequences — §5.3's batch-size-one case, where the paper terminates
    /// the incorrect speculation *immediately* rather than letting the
    /// iteration finish ("we can immediately terminate any incorrect
    /// speculative generation request").
    pub fn in_flight_fully_killed(&self) -> bool {
        match &self.in_flight {
            Some(p) if p.kind == IterKind::Prefill => p
                .seq_ids
                .iter()
                .all(|id| self.kill_after_iter.contains(id)),
            _ => false,
        }
    }

    /// Cancel the in-flight iteration outright (only meaningful when
    /// [`Engine::in_flight_fully_killed`]): partial work is discarded, the
    /// sequences are dropped, and the engine is immediately free. Returns
    /// the cancelled sequence ids.
    pub fn cancel_in_flight(&mut self) -> Vec<u64> {
        let Some(plan) = self.in_flight.take() else {
            return Vec::new();
        };
        for id in &plan.seq_ids {
            self.decoding.retain(|d| d.id != *id);
        }
        self.kill_after_iter.clear();
        plan.seq_ids
    }

    /// Plan the next iteration. Returns None if idle or an iteration is
    /// already in flight.
    pub fn plan(&mut self) -> Option<IterationPlan> {
        if self.in_flight.is_some() {
            return None;
        }
        // Prefill takes precedence when batch slots are free (vLLM v0.3
        // prioritises waiting prefills to keep the batch full).
        let free_slots = self.max_batch.saturating_sub(self.decoding.len());
        if !self.waiting.is_empty() && free_slots > 0 {
            let mut jobs = Vec::new();
            let mut ids = Vec::new();
            let mut tokens = 0usize;
            let mut extra = 0.0f64;
            while jobs.len() < free_slots {
                let Some(front) = self.waiting.front() else {
                    break;
                };
                if !jobs.is_empty()
                    && tokens + front.beta > self.max_prefill_tokens
                {
                    break;
                }
                let seq = self.waiting.pop_front().unwrap();
                tokens += seq.beta;
                extra += seq.extra_time;
                jobs.push((seq.alpha, seq.beta));
                ids.push(seq.id);
                self.decoding.push(DecodeState {
                    id: seq.id,
                    context: seq.alpha + seq.beta,
                    generated: 0,
                    output_tokens: seq.output_tokens,
                });
            }
            let duration = self.cost.prefill_batch_time(&jobs) + extra;
            let plan = IterationPlan {
                kind: IterKind::Prefill,
                seq_ids: ids,
                duration,
            };
            self.in_flight = Some(plan.clone());
            return Some(plan);
        }
        if !self.decoding.is_empty() {
            let ctxs: Vec<usize> =
                self.decoding.iter().map(|d| d.context).collect();
            let duration = self.cost.decode_step_time(&ctxs);
            let plan = IterationPlan {
                kind: IterKind::Decode,
                seq_ids: self.decoding.iter().map(|d| d.id).collect(),
                duration,
            };
            self.in_flight = Some(plan.clone());
            return Some(plan);
        }
        None
    }

    /// Complete the in-flight iteration, emitting sequence events.
    pub fn complete(&mut self) -> Vec<SeqEvent> {
        let Some(plan) = self.in_flight.take() else {
            return Vec::new();
        };
        let mut events = Vec::new();
        match plan.kind {
            IterKind::Prefill => {
                for &id in &plan.seq_ids {
                    let Some(d) =
                        self.decoding.iter_mut().find(|d| d.id == id)
                    else {
                        continue;
                    };
                    d.generated = 1;
                    // FirstToken fires even for kill-after-iteration
                    // sequences: the prefill ran, the KV is real.
                    events.push(SeqEvent::FirstToken { id });
                }
                let killed = std::mem::take(&mut self.kill_after_iter);
                // Drop killed sequences (no Finished event), then finish
                // single-token outputs (MMLU) at prefill.
                self.decoding.retain(|d| {
                    if killed.contains(&d.id) {
                        return false;
                    }
                    if plan.seq_ids.contains(&d.id)
                        && d.generated >= d.output_tokens
                    {
                        events.push(SeqEvent::Finished { id: d.id });
                        false
                    } else {
                        true
                    }
                });
            }
            IterKind::Decode => {
                for d in self.decoding.iter_mut() {
                    d.generated += 1;
                    d.context += 1;
                }
                self.decoding.retain(|d| {
                    if d.generated >= d.output_tokens {
                        events.push(SeqEvent::Finished { id: d.id });
                        false
                    } else {
                        true
                    }
                });
            }
        }
        events
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::llm::models::{A10G, MISTRAL_7B};

    fn engine(max_batch: usize) -> Engine {
        Engine::new(
            CostModel::new(MISTRAL_7B, A10G),
            max_batch,
            16384,
        )
    }

    fn seq(id: u64, beta: usize, out: usize) -> SeqSpec {
        SeqSpec {
            id,
            alpha: 0,
            beta,
            output_tokens: out,
            extra_time: 0.0,
        }
    }

    #[test]
    fn single_token_output_finishes_at_prefill() {
        let mut e = engine(4);
        e.admit(seq(1, 100, 1));
        let plan = e.plan().unwrap();
        assert_eq!(plan.kind, IterKind::Prefill);
        assert!(plan.duration > 0.0);
        let events = e.complete();
        assert!(events.contains(&SeqEvent::FirstToken { id: 1 }));
        assert!(events.contains(&SeqEvent::Finished { id: 1 }));
        assert!(e.is_idle());
    }

    #[test]
    fn multi_token_output_decodes() {
        let mut e = engine(4);
        e.admit(seq(1, 50, 3));
        e.plan().unwrap();
        let ev = e.complete();
        assert_eq!(ev, vec![SeqEvent::FirstToken { id: 1 }]);
        // Two more decode iterations to finish.
        let p = e.plan().unwrap();
        assert_eq!(p.kind, IterKind::Decode);
        assert!(e.complete().is_empty());
        e.plan().unwrap();
        let ev = e.complete();
        assert_eq!(ev, vec![SeqEvent::Finished { id: 1 }]);
        assert!(e.is_idle());
    }

    #[test]
    fn batch_cap_respected() {
        let mut e = engine(2);
        for i in 0..5 {
            e.admit(seq(i, 10, 2));
        }
        let p = e.plan().unwrap();
        assert_eq!(p.seq_ids.len(), 2, "prefill batch capped");
        e.complete();
        // Batch is now full of decoders; next iteration must be decode.
        let p2 = e.plan().unwrap();
        assert_eq!(p2.kind, IterKind::Decode);
        e.complete(); // both finish (out=2)
        let p3 = e.plan().unwrap();
        assert_eq!(p3.kind, IterKind::Prefill);
        assert_eq!(p3.seq_ids.len(), 2);
    }

    #[test]
    fn prefill_token_budget_limits_batch() {
        let mut e = Engine::new(
            CostModel::new(MISTRAL_7B, A10G),
            8,
            1000,
        );
        e.admit(seq(1, 800, 1));
        e.admit(seq(2, 800, 1));
        let p = e.plan().unwrap();
        assert_eq!(p.seq_ids, vec![1], "token budget splits prefills");
        e.complete();
        let p2 = e.plan().unwrap();
        assert_eq!(p2.seq_ids, vec![2]);
    }

    #[test]
    fn abort_waiting_and_decoding() {
        let mut e = engine(4);
        e.admit(seq(1, 10, 5));
        e.admit(seq(2, 10, 5));
        assert_eq!(e.abort(2), AbortOutcome::Removed, "from waiting");
        e.plan().unwrap();
        e.complete();
        assert_eq!(e.abort(1), AbortOutcome::Removed, "from decoding");
        assert!(e.is_idle());
        assert_eq!(e.abort(99), AbortOutcome::NotFound);
    }

    #[test]
    fn abort_in_flight_prefill_is_deferred_and_caches() {
        let mut e = engine(4);
        e.admit(seq(1, 10, 5));
        e.plan().unwrap();
        assert_eq!(e.abort(1), AbortOutcome::Deferred);
        assert!(e.in_flight_fully_killed());
        // Completing the iteration still emits FirstToken (KV is real),
        // then the sequence is gone.
        let ev = e.complete();
        assert_eq!(ev, vec![SeqEvent::FirstToken { id: 1 }]);
        assert!(e.is_idle());
    }

    #[test]
    fn cancel_in_flight_discards_work() {
        let mut e = engine(4);
        e.admit(seq(1, 10, 5));
        e.plan().unwrap();
        assert_eq!(e.abort(1), AbortOutcome::Deferred);
        let cancelled = e.cancel_in_flight();
        assert_eq!(cancelled, vec![1]);
        assert!(e.is_idle());
        assert!(e.complete().is_empty(), "no residue events");
    }

    #[test]
    fn shared_batch_not_fully_killed() {
        let mut e = engine(4);
        e.admit(seq(1, 10, 5));
        e.admit(seq(2, 10, 5));
        e.plan().unwrap();
        assert_eq!(e.abort(1), AbortOutcome::Deferred);
        assert!(
            !e.in_flight_fully_killed(),
            "seq 2 still needs the iteration"
        );
        let ev = e.complete();
        assert!(ev.contains(&SeqEvent::FirstToken { id: 1 }));
        assert!(ev.contains(&SeqEvent::FirstToken { id: 2 }));
        assert_eq!(e.decoding_len(), 1, "killed seq dropped, other stays");
    }

    #[test]
    fn plan_none_while_in_flight() {
        let mut e = engine(4);
        e.admit(seq(1, 10, 2));
        assert!(e.plan().is_some());
        assert!(e.plan().is_none(), "no overlapping iterations");
        e.complete();
        assert!(e.plan().is_some());
    }

    /// A batched admission's coalesced burst rides on the first
    /// member's `extra_time`: the iteration containing that member is
    /// billed the burst exactly once, and zero-extra members add
    /// nothing — so one charge per burst, never one per member.
    #[test]
    fn first_member_extra_charges_burst_once_per_iteration() {
        let burst = 0.0371;
        let mut plain = engine(4);
        plain.admit(seq(1, 100, 1));
        plain.admit(seq(2, 100, 1));
        let base = plain.plan().unwrap().duration;

        let mut charged = engine(4);
        charged.admit(SeqSpec {
            extra_time: burst,
            ..seq(1, 100, 1)
        });
        charged.admit(seq(2, 100, 1));
        let with_burst = charged.plan().unwrap().duration;
        assert_eq!(
            with_burst,
            base + burst,
            "burst billed exactly once for the whole batch"
        );

        // Later iterations carry no residue of the burst.
        charged.complete();
        charged.admit(seq(3, 100, 1));
        charged.admit(seq(4, 100, 1));
        let later = charged.plan().unwrap().duration;
        assert_eq!(later, base, "burst not re-billed: {later} vs {base}");
    }

    #[test]
    fn cached_alpha_shortens_prefill() {
        let mut e = engine(4);
        e.admit(SeqSpec {
            id: 1,
            alpha: 4000,
            beta: 32,
            output_tokens: 1,
            extra_time: 0.0,
        });
        let cached = e.plan().unwrap().duration;
        e.complete();
        e.admit(seq(2, 4032, 1));
        let full = e.plan().unwrap().duration;
        assert!(
            full / cached > 3.0,
            "caching speedup: full {full} vs cached {cached}"
        );
    }
}
