//! TOML-subset parser lowering to [`Json`] values.
//!
//! Supported grammar (sufficient for serving configs):
//! `[table]` / `[table.sub]` headers, `key = value` with dotted keys,
//! basic strings, integers, floats, booleans, homogeneous inline arrays,
//! `#` comments. Unsupported (rejected, not silently ignored): array
//! tables `[[x]]`, multi-line strings, datetimes, inline tables.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// TOML parse error with line information.
#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

fn err(line: usize, msg: impl Into<String>) -> TomlError {
    TomlError {
        line,
        msg: msg.into(),
    }
}

/// Parse a TOML document into a JSON object tree.
pub fn parse(input: &str) -> Result<Json, TomlError> {
    let mut root: BTreeMap<String, Json> = BTreeMap::new();
    // Current table path from the most recent [header].
    let mut prefix: Vec<String> = Vec::new();

    for (lineno, raw) in input.lines().enumerate() {
        let line = lineno + 1;
        let text = strip_comment(raw).trim();
        if text.is_empty() {
            continue;
        }
        if let Some(inner) = text.strip_prefix('[') {
            if text.starts_with("[[") {
                return Err(err(line, "array-of-tables is not supported"));
            }
            let inner = inner
                .strip_suffix(']')
                .ok_or_else(|| err(line, "unterminated table header"))?;
            prefix = parse_key_path(inner, line)?;
            // Materialise the table so empty tables exist.
            ensure_table(&mut root, &prefix, line)?;
        } else {
            let eq = text
                .find('=')
                .ok_or_else(|| err(line, "expected 'key = value'"))?;
            let keypart = &text[..eq];
            let valpart = text[eq + 1..].trim();
            let mut path = prefix.clone();
            path.extend(parse_key_path(keypart, line)?);
            let value = parse_value(valpart, line)?;
            insert(&mut root, &path, value, line)?;
        }
    }
    Ok(Json::Obj(root))
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside of quotes begins a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_key_path(s: &str, line: usize) -> Result<Vec<String>, TomlError> {
    let mut parts = Vec::new();
    for part in s.split('.') {
        let p = part.trim();
        let p = p
            .strip_prefix('"')
            .and_then(|x| x.strip_suffix('"'))
            .unwrap_or(p);
        if p.is_empty() {
            return Err(err(line, "empty key component"));
        }
        if !p
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
        {
            return Err(err(line, format!("invalid key '{}'", p)));
        }
        parts.push(p.to_string());
    }
    Ok(parts)
}

fn ensure_table<'a>(
    root: &'a mut BTreeMap<String, Json>,
    path: &[String],
    line: usize,
) -> Result<&'a mut BTreeMap<String, Json>, TomlError> {
    let mut cur = root;
    for key in path {
        let entry = cur
            .entry(key.clone())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        cur = match entry {
            Json::Obj(m) => m,
            _ => return Err(err(line, format!("'{}' is not a table", key))),
        };
    }
    Ok(cur)
}

fn insert(
    root: &mut BTreeMap<String, Json>,
    path: &[String],
    value: Json,
    line: usize,
) -> Result<(), TomlError> {
    let (last, dirs) = path.split_last().expect("non-empty path");
    let table = ensure_table(root, dirs, line)?;
    if table.contains_key(last) {
        return Err(err(line, format!("duplicate key '{}'", last)));
    }
    table.insert(last.clone(), value);
    Ok(())
}

fn parse_value(s: &str, line: usize) -> Result<Json, TomlError> {
    let s = s.trim();
    if s.is_empty() {
        return Err(err(line, "missing value"));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err(line, "unterminated string"))?;
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    _ => return Err(err(line, "bad string escape")),
                }
            } else if c == '"' {
                return Err(err(line, "unescaped quote in string"));
            } else {
                out.push(c);
            }
        }
        return Ok(Json::Str(out));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| err(line, "unterminated array"))?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim(), line)?);
            }
        }
        return Ok(Json::Arr(items));
    }
    match s {
        "true" => return Ok(Json::Bool(true)),
        "false" => return Ok(Json::Bool(false)),
        _ => {}
    }
    let cleaned: String = s.chars().filter(|&c| c != '_').collect();
    if let Ok(n) = cleaned.parse::<f64>() {
        return Ok(Json::Num(n));
    }
    Err(err(line, format!("cannot parse value '{}'", s)))
}

/// Split array contents on commas that are not inside strings or nested
/// arrays.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    if start < s.len() {
        parts.push(&s[start..]);
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_document() {
        let doc = r#"
# serving config
name = "ragcache"
max_batch = 4
rate = 0.8

[cache]
gpu_gib = 24
host_gib = 192.0
policy = "pgdsf"

[cache.transfer]
pcie_gbps = 25.6
"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("ragcache"));
        assert_eq!(v.get("max_batch").unwrap().as_u64(), Some(4));
        let cache = v.get("cache").unwrap();
        assert_eq!(cache.get("policy").unwrap().as_str(), Some("pgdsf"));
        assert_eq!(
            cache
                .get("transfer")
                .unwrap()
                .get("pcie_gbps")
                .unwrap()
                .as_f64(),
            Some(25.6)
        );
    }

    #[test]
    fn arrays_and_dotted_keys() {
        let doc = r#"
topk = [1, 3, 5]
workload.dataset = "mmlu"
workload.rates = [0.5, 1.0, 1.5]
"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("topk").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("workload").unwrap().get("dataset").unwrap().as_str(),
            Some("mmlu")
        );
    }

    #[test]
    fn comments_inside_strings_survive() {
        let v = parse("s = \"a # not comment\" # real comment").unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("a # not comment"));
    }

    #[test]
    fn rejects_duplicates_and_garbage() {
        assert!(parse("a = 1\na = 2").is_err());
        assert!(parse("[[x]]").is_err());
        assert!(parse("a =").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("a = \"unterminated").is_err());
    }

    #[test]
    fn underscored_numbers() {
        let v = parse("n = 1_000_000").unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(1_000_000));
    }
}
