//! Configuration system.
//!
//! A real deployment needs declarative configuration; since `serde`/`toml`
//! are unavailable offline, [`toml`] implements a TOML-subset parser
//! (tables, dotted keys, strings, numbers, booleans, arrays, comments)
//! that lowers into the crate's [`crate::util::json::Json`] value model,
//! and [`schema`] defines the typed `SystemConfig` consumed by the
//! controller, with named presets matching the paper's testbeds.

pub mod toml;
pub mod schema;

pub use schema::{
    CacheConfig, EngineConfig, IndexKind, PolicyKind, RetrievalConfig,
    SchedConfig, ShedConfig, SpecConfig, SystemConfig, SystemKind,
    SystemKindField, WorkloadConfig,
};
