//! Typed system configuration and paper-testbed presets.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};

const GIB: u64 = 1024 * 1024 * 1024;

/// Which serving system variant to assemble (§7 baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SystemKind {
    /// Full RAGCache: multilevel cache + PGDSF + reordering + DSP.
    RagCache,
    /// vLLM-like baseline: paged KV within a request, no cross-request
    /// document cache.
    VllmLike,
    /// SGLang-like baseline: GPU-only prefix cache with LRU.
    SglangLike,
}

impl SystemKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "ragcache" => SystemKind::RagCache,
            "vllm" => SystemKind::VllmLike,
            "sglang" => SystemKind::SglangLike,
            _ => bail!("unknown system kind '{s}'"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            SystemKind::RagCache => "ragcache",
            SystemKind::VllmLike => "vllm",
            SystemKind::SglangLike => "sglang",
        }
    }
}

/// Cache replacement policy selection (§7.3 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    Pgdsf,
    Gdsf,
    Lru,
    Lfu,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "pgdsf" => PolicyKind::Pgdsf,
            "gdsf" => PolicyKind::Gdsf,
            "lru" => PolicyKind::Lru,
            "lfu" => PolicyKind::Lfu,
            _ => bail!("unknown policy '{s}'"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::Pgdsf => "pgdsf",
            PolicyKind::Gdsf => "gdsf",
            PolicyKind::Lru => "lru",
            PolicyKind::Lfu => "lfu",
        }
    }
}

/// Multilevel KV-cache parameters.
#[derive(Debug, Clone)]
pub struct CacheConfig {
    /// GPU-tier capacity available for document KV caching, bytes.
    pub gpu_bytes: u64,
    /// Host-tier capacity for caching, bytes (paper: 192 GiB on g5.16xlarge).
    pub host_bytes: u64,
    /// Tokens per KV block (vLLM-style paging).
    pub block_tokens: usize,
    pub policy: PolicyKind,
    /// §5.1 swap-out-only-once: host copy retained after first eviction.
    pub swap_out_only_once: bool,
    /// §6 fault tolerance: replicate hot upper-level nodes in host memory.
    pub replicate_hot_nodes: bool,
    /// Knowledge-tree shards the tier budgets are split across (the
    /// RAGCache system only; baselines stay single-tree).
    pub shards: usize,
    /// Demand-driven cross-shard tier rebalancing: periodically move
    /// budget slices from cold shards to hot ones. `false` keeps the
    /// static 1/K split, bit-identical to the pre-rebalancing path.
    pub rebalance: bool,
    /// Engine iterations between rebalance recomputations.
    pub rebalance_interval: usize,
    /// Chunk-level position-independent KV reuse beside the prefix tree
    /// (`--chunk-cache on`): docs that miss the prefix walk can reuse a
    /// cached chunk at any position, re-prefilling only the first
    /// `boundary_tokens` tokens. `false` is bit-identical to the
    /// tree-only path.
    pub chunk_cache: bool,
    /// `r`: boundary tokens re-prefilled per cross-position chunk hit.
    pub boundary_tokens: usize,
    /// NVMe-backed third cache tier (`--disk on`): host evictions
    /// demote to a slotted backing store instead of dropping, and
    /// disk-resident KV is restaged host-ward on demand. `false` is
    /// bit-identical to the two-tier path.
    pub disk: bool,
    /// Disk-tier capacity for KV caching, bytes.
    pub disk_bytes: u64,
    /// Fixed NVMe read latency per staged-read burst, seconds.
    pub disk_latency_s: f64,
    /// CAG-style per-tenant corpus pinning (`--cag auto`): tenants
    /// whose whole corpus KV fits `cag_pin_bytes` are served
    /// retrieval-free from pre-staged pinned chunk entries. Requires
    /// the chunk cache.
    pub cag: bool,
    /// Pin budget the CAG admission greedily fills, bytes.
    pub cag_pin_bytes: u64,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            // A10G: 24 GiB total; roughly half is weights/activations, the
            // rest KV. The sim-mode engine budget is set per ModelSpec; this
            // is the document-cache share.
            gpu_bytes: 8 * GIB,
            host_bytes: 192 * GIB,
            block_tokens: 16,
            policy: PolicyKind::Pgdsf,
            swap_out_only_once: true,
            replicate_hot_nodes: true,
            shards: 1,
            rebalance: false,
            rebalance_interval: 32,
            chunk_cache: false,
            boundary_tokens: 8,
            disk: false,
            // Paper-testbed-scale NVMe: a 1 TiB datacenter SSD share.
            disk_bytes: 1024 * GIB,
            disk_latency_s: 100e-6,
            cag: false,
            // Half the default GPU tier: pins stay a minority share.
            cag_pin_bytes: 4 * GIB,
        }
    }
}

/// LLM engine parameters.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Model name resolved against [`crate::llm::models`] (paper Table 1).
    pub model: String,
    /// GPU name resolved against [`crate::llm::models::GpuSpec`] registry.
    pub gpu: String,
    /// Maximum batch size (paper §7.1: 4 for 7B models).
    pub max_batch: usize,
    /// Maximum tokens admitted to one prefill iteration
    /// (`max_prefill_bs` of Algorithm 2, in tokens).
    pub max_prefill_tokens: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            model: "mistral-7b".to_string(),
            gpu: "a10g".to_string(),
            max_batch: 4,
            max_prefill_tokens: 16384,
        }
    }
}

/// Vector index kind for the retrieval step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IndexKind {
    Flat,
    Ivf,
    Hnsw,
}

impl IndexKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "flat" => IndexKind::Flat,
            "ivf" => IndexKind::Ivf,
            "hnsw" => IndexKind::Hnsw,
            _ => bail!("unknown index kind '{s}'"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            IndexKind::Flat => "flat",
            IndexKind::Ivf => "ivf",
            IndexKind::Hnsw => "hnsw",
        }
    }
}

/// Retrieval (vector database) parameters.
#[derive(Debug, Clone)]
pub struct RetrievalConfig {
    pub index: IndexKind,
    /// Documents injected per request (paper default: top-2).
    pub top_k: usize,
    /// IVF cluster count (paper §7: 1024).
    pub nlist: usize,
    /// IVF clusters probed per query.
    pub nprobe: usize,
    /// Stages the staged search is divided into (DSP granularity).
    pub stages: usize,
    /// Embedding dimensionality.
    pub dim: usize,
}

impl Default for RetrievalConfig {
    fn default() -> Self {
        RetrievalConfig {
            index: IndexKind::Ivf,
            top_k: 2,
            nlist: 1024,
            nprobe: 64,
            stages: 4,
            dim: 64,
        }
    }
}

/// Scheduler parameters (§5.2).
#[derive(Debug, Clone)]
pub struct SchedConfig {
    /// Enable cache-aware reordering.
    pub reorder: bool,
    /// Starvation window: a request is never passed over more than this
    /// many times (paper §7.3 uses 32).
    pub window: usize,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            reorder: true,
            window: 32,
        }
    }
}

/// Dynamic speculative pipelining parameters (§5.3).
#[derive(Debug, Clone)]
pub struct SpecConfig {
    pub enabled: bool,
}

impl Default for SpecConfig {
    fn default() -> Self {
        SpecConfig { enabled: true }
    }
}

/// Admission-control (overload shedding) parameters for open-loop
/// serving. Off by default: the `--shed off` path is conformance-tested
/// bit-identical to the pre-admission-control simulator.
#[derive(Debug, Clone)]
pub struct ShedConfig {
    pub enabled: bool,
    /// TTFT service-level objective, seconds. Requests that cannot
    /// produce a first token within this deadline are shed.
    pub ttft_slo_s: f64,
    /// Downgrade threshold as a fraction of the SLO: when the EWMA of
    /// admission queueing delay exceeds `downgrade_frac × ttft_slo_s`,
    /// new arrivals are downgraded (speculation disabled, single-stage
    /// retrieval) before any request is shed outright.
    pub downgrade_frac: f64,
}

impl Default for ShedConfig {
    fn default() -> Self {
        ShedConfig {
            enabled: false,
            ttft_slo_s: 5.0,
            downgrade_frac: 0.5,
        }
    }
}

/// Workload generation parameters (§7 Workloads).
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    /// Dataset profile: "mmlu", "nq", "hotpotqa", "triviaqa".
    pub dataset: String,
    /// Average arrival rate, requests/second.
    pub rate: f64,
    /// Number of requests to generate.
    pub num_requests: usize,
    /// Corpus size in documents (paper: ~0.3 M Wikipedia pages).
    pub num_docs: usize,
    pub seed: u64,
    /// Arrival process: "poisson" (default), "bursty", "diurnal".
    pub arrivals: String,
    /// Tenants sharing the trace (1 = legacy single-tenant stream).
    pub tenants: usize,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            dataset: "mmlu".to_string(),
            rate: 0.8,
            num_requests: 2000,
            num_docs: 300_000,
            seed: 42,
            arrivals: "poisson".to_string(),
            tenants: 1,
        }
    }
}

/// Top-level system configuration.
#[derive(Debug, Clone, Default)]
pub struct SystemConfig {
    pub kind: SystemKindField,
    pub cache: CacheConfig,
    pub engine: EngineConfig,
    pub retrieval: RetrievalConfig,
    pub sched: SchedConfig,
    pub spec: SpecConfig,
    pub shed: ShedConfig,
    pub workload: WorkloadConfig,
}

/// Newtype wrapper so `SystemConfig` can derive Default.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SystemKindField(pub SystemKind);

impl Default for SystemKindField {
    fn default() -> Self {
        SystemKindField(SystemKind::RagCache)
    }
}

impl std::ops::Deref for SystemKindField {
    type Target = SystemKind;
    fn deref(&self) -> &SystemKind {
        &self.0
    }
}

impl SystemConfig {
    /// Parse from a TOML document.
    pub fn from_toml_str(s: &str) -> Result<Self> {
        let v = super::toml::parse(s).map_err(|e| anyhow!("{e}"))?;
        Self::from_json(&v)
    }

    /// Load from a TOML file.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_toml_str(&text)
    }

    /// Build from the JSON object tree produced by the TOML parser.
    /// Unknown sections/keys are rejected so typos fail loudly.
    pub fn from_json(v: &Json) -> Result<Self> {
        let mut cfg = SystemConfig::default();
        let obj = v.as_obj().ok_or_else(|| anyhow!("config not a table"))?;
        for (key, val) in obj {
            match key.as_str() {
                "system" => {
                    cfg.kind = SystemKindField(SystemKind::parse(
                        val.as_str().ok_or_else(|| anyhow!("system: string"))?,
                    )?)
                }
                "cache" => apply_cache(&mut cfg.cache, val)?,
                "engine" => apply_engine(&mut cfg.engine, val)?,
                "retrieval" => apply_retrieval(&mut cfg.retrieval, val)?,
                "sched" => apply_sched(&mut cfg.sched, val)?,
                "spec" => apply_spec(&mut cfg.spec, val)?,
                "shed" => apply_shed(&mut cfg.shed, val)?,
                "workload" => apply_workload(&mut cfg.workload, val)?,
                other => bail!("unknown config section '{other}'"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.engine.max_batch == 0 {
            bail!("engine.max_batch must be > 0");
        }
        if self.retrieval.top_k == 0 {
            bail!("retrieval.top_k must be > 0");
        }
        if self.cache.block_tokens == 0 {
            bail!("cache.block_tokens must be > 0");
        }
        if self.cache.shards == 0 {
            bail!("cache.shards must be > 0");
        }
        if self.cache.rebalance_interval == 0 {
            bail!("cache.rebalance_interval must be > 0");
        }
        if self.cache.chunk_cache && self.cache.boundary_tokens == 0 {
            bail!(
                "cache.boundary_tokens must be > 0 with chunk_cache on \
                 (cross-position reuse always re-prefills a boundary)"
            );
        }
        if self.cache.disk && self.cache.disk_bytes == 0 {
            bail!("cache.disk_gib must be > 0 with the disk tier on");
        }
        if self.cache.disk && self.cache.disk_latency_s < 0.0 {
            bail!("cache.disk_latency_s must be >= 0");
        }
        if self.cache.cag && !self.cache.chunk_cache {
            bail!(
                "cache.cag requires cache.chunk_cache (corpus pins are \
                 position-independent chunk entries)"
            );
        }
        if self.workload.rate <= 0.0 {
            bail!("workload.rate must be > 0");
        }
        if self.workload.tenants == 0 {
            bail!("workload.tenants must be > 0");
        }
        crate::workload::ArrivalProcess::parse(&self.workload.arrivals)
            .map_err(|e| anyhow!("workload.arrivals: {e}"))?;
        if self.shed.ttft_slo_s <= 0.0 {
            bail!("shed.ttft_slo_s must be > 0");
        }
        if !(self.shed.downgrade_frac > 0.0
            && self.shed.downgrade_frac <= 1.0)
        {
            bail!("shed.downgrade_frac must be in (0, 1]");
        }
        Ok(())
    }

    /// Named presets matching the paper's testbeds.
    ///
    /// - `"a10g-7b"`: g5.16xlarge — one A10G (24 GiB), 192 GiB host cache,
    ///   Mistral-7B, batch 4 (§7 Testbed).
    /// - `"h800-large"`: 2×H800 — LLaMA2-70B, 384 GiB host cache (§7.2).
    /// - `"smoke"`: tiny everything, for tests and the quickstart.
    pub fn preset(name: &str) -> Result<Self> {
        let mut cfg = SystemConfig::default();
        match name {
            "a10g-7b" => {}
            "h800-large" => {
                cfg.engine.model = "llama2-70b".to_string();
                cfg.engine.gpu = "h800x2".to_string();
                cfg.engine.max_batch = 4;
                cfg.cache.gpu_bytes = 60 * GIB;
                cfg.cache.host_bytes = 384 * GIB;
            }
            "smoke" => {
                cfg.engine.model = "tiny-mha".to_string();
                cfg.engine.gpu = "cpu".to_string();
                cfg.engine.max_batch = 2;
                cfg.cache.gpu_bytes = 8 * 1024 * 1024;
                cfg.cache.host_bytes = 64 * 1024 * 1024;
                cfg.retrieval.index = IndexKind::Flat;
                cfg.retrieval.dim = 16;
                cfg.retrieval.nlist = 16;
                cfg.retrieval.nprobe = 4;
                cfg.workload.num_docs = 256;
                cfg.workload.num_requests = 64;
                cfg.workload.rate = 10.0;
            }
            _ => bail!("unknown preset '{name}'"),
        }
        Ok(cfg)
    }
}

fn get_f64(v: &Json, key: &str) -> Result<f64> {
    v.as_f64().ok_or_else(|| anyhow!("{key}: expected number"))
}

fn get_usize(v: &Json, key: &str) -> Result<usize> {
    v.as_usize()
        .ok_or_else(|| anyhow!("{key}: expected non-negative integer"))
}

fn get_bool(v: &Json, key: &str) -> Result<bool> {
    v.as_bool().ok_or_else(|| anyhow!("{key}: expected bool"))
}

fn get_str(v: &Json, key: &str) -> Result<String> {
    Ok(v.as_str()
        .ok_or_else(|| anyhow!("{key}: expected string"))?
        .to_string())
}

fn apply_cache(c: &mut CacheConfig, v: &Json) -> Result<()> {
    for (k, val) in v.as_obj().ok_or_else(|| anyhow!("cache: table"))? {
        match k.as_str() {
            "gpu_gib" => c.gpu_bytes = (get_f64(val, k)? * GIB as f64) as u64,
            "host_gib" => c.host_bytes = (get_f64(val, k)? * GIB as f64) as u64,
            "block_tokens" => c.block_tokens = get_usize(val, k)?,
            "policy" => c.policy = PolicyKind::parse(&get_str(val, k)?)?,
            "swap_out_only_once" => c.swap_out_only_once = get_bool(val, k)?,
            "replicate_hot_nodes" => c.replicate_hot_nodes = get_bool(val, k)?,
            "shards" => c.shards = get_usize(val, k)?,
            "rebalance" => c.rebalance = get_bool(val, k)?,
            "rebalance_interval" => {
                c.rebalance_interval = get_usize(val, k)?
            }
            "chunk_cache" => c.chunk_cache = get_bool(val, k)?,
            "boundary_tokens" => c.boundary_tokens = get_usize(val, k)?,
            "disk" => c.disk = get_bool(val, k)?,
            "disk_gib" => {
                c.disk_bytes = (get_f64(val, k)? * GIB as f64) as u64
            }
            "disk_latency_s" => c.disk_latency_s = get_f64(val, k)?,
            "cag" => c.cag = get_bool(val, k)?,
            "cag_pin_gib" => {
                c.cag_pin_bytes = (get_f64(val, k)? * GIB as f64) as u64
            }
            other => bail!("unknown cache key '{other}'"),
        }
    }
    Ok(())
}

fn apply_engine(c: &mut EngineConfig, v: &Json) -> Result<()> {
    for (k, val) in v.as_obj().ok_or_else(|| anyhow!("engine: table"))? {
        match k.as_str() {
            "model" => c.model = get_str(val, k)?,
            "gpu" => c.gpu = get_str(val, k)?,
            "max_batch" => c.max_batch = get_usize(val, k)?,
            "max_prefill_tokens" => c.max_prefill_tokens = get_usize(val, k)?,
            other => bail!("unknown engine key '{other}'"),
        }
    }
    Ok(())
}

fn apply_retrieval(c: &mut RetrievalConfig, v: &Json) -> Result<()> {
    for (k, val) in v.as_obj().ok_or_else(|| anyhow!("retrieval: table"))? {
        match k.as_str() {
            "index" => c.index = IndexKind::parse(&get_str(val, k)?)?,
            "top_k" => c.top_k = get_usize(val, k)?,
            "nlist" => c.nlist = get_usize(val, k)?,
            "nprobe" => c.nprobe = get_usize(val, k)?,
            "stages" => c.stages = get_usize(val, k)?,
            "dim" => c.dim = get_usize(val, k)?,
            other => bail!("unknown retrieval key '{other}'"),
        }
    }
    Ok(())
}

fn apply_sched(c: &mut SchedConfig, v: &Json) -> Result<()> {
    for (k, val) in v.as_obj().ok_or_else(|| anyhow!("sched: table"))? {
        match k.as_str() {
            "reorder" => c.reorder = get_bool(val, k)?,
            "window" => c.window = get_usize(val, k)?,
            other => bail!("unknown sched key '{other}'"),
        }
    }
    Ok(())
}

fn apply_spec(c: &mut SpecConfig, v: &Json) -> Result<()> {
    for (k, val) in v.as_obj().ok_or_else(|| anyhow!("spec: table"))? {
        match k.as_str() {
            "enabled" => c.enabled = get_bool(val, k)?,
            other => bail!("unknown spec key '{other}'"),
        }
    }
    Ok(())
}

fn apply_shed(c: &mut ShedConfig, v: &Json) -> Result<()> {
    for (k, val) in v.as_obj().ok_or_else(|| anyhow!("shed: table"))? {
        match k.as_str() {
            "enabled" => c.enabled = get_bool(val, k)?,
            "ttft_slo_s" => c.ttft_slo_s = get_f64(val, k)?,
            "downgrade_frac" => c.downgrade_frac = get_f64(val, k)?,
            other => bail!("unknown shed key '{other}'"),
        }
    }
    Ok(())
}

fn apply_workload(c: &mut WorkloadConfig, v: &Json) -> Result<()> {
    for (k, val) in v.as_obj().ok_or_else(|| anyhow!("workload: table"))? {
        match k.as_str() {
            "dataset" => c.dataset = get_str(val, k)?,
            "rate" => c.rate = get_f64(val, k)?,
            "num_requests" => c.num_requests = get_usize(val, k)?,
            "num_docs" => c.num_docs = get_usize(val, k)?,
            "seed" => {
                c.seed = val.as_u64().ok_or_else(|| anyhow!("seed: u64"))?
            }
            "arrivals" => c.arrivals = get_str(val, k)?,
            "tenants" => c.tenants = get_usize(val, k)?,
            other => bail!("unknown workload key '{other}'"),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_testbed() {
        let c = SystemConfig::default();
        assert_eq!(*c.kind, SystemKind::RagCache);
        assert_eq!(c.engine.max_batch, 4);
        assert_eq!(c.retrieval.top_k, 2);
        assert_eq!(c.retrieval.nlist, 1024);
        assert_eq!(c.cache.host_bytes, 192 * GIB);
        assert_eq!(c.sched.window, 32);
    }

    #[test]
    fn parse_full_toml() {
        let doc = r#"
system = "sglang"

[cache]
gpu_gib = 4
host_gib = 0.5
policy = "lru"

[engine]
model = "llama2-7b"
max_batch = 8

[retrieval]
index = "hnsw"
top_k = 5

[sched]
reorder = false

[workload]
dataset = "nq"
rate = 1.4
"#;
        let c = SystemConfig::from_toml_str(doc).unwrap();
        assert_eq!(*c.kind, SystemKind::SglangLike);
        assert_eq!(c.cache.policy, PolicyKind::Lru);
        assert_eq!(c.cache.gpu_bytes, 4 * GIB);
        assert_eq!(c.cache.host_bytes, GIB / 2);
        assert_eq!(c.engine.model, "llama2-7b");
        assert_eq!(c.retrieval.index, IndexKind::Hnsw);
        assert_eq!(c.retrieval.top_k, 5);
        assert!(!c.sched.reorder);
        assert_eq!(c.workload.dataset, "nq");
    }

    #[test]
    fn chunk_cache_keys_parse() {
        let doc = "[cache]\nchunk_cache = true\nboundary_tokens = 4";
        let c = SystemConfig::from_toml_str(doc).unwrap();
        assert!(c.cache.chunk_cache);
        assert_eq!(c.cache.boundary_tokens, 4);
        assert!(!SystemConfig::default().cache.chunk_cache, "off by default");
        assert!(SystemConfig::from_toml_str(
            "[cache]\nchunk_cache = true\nboundary_tokens = 0"
        )
        .is_err());
    }

    #[test]
    fn sharding_and_rebalance_keys_parse() {
        let doc = "[cache]\nshards = 4\nrebalance = true\n\
                   rebalance_interval = 16";
        let c = SystemConfig::from_toml_str(doc).unwrap();
        assert_eq!(c.cache.shards, 4);
        assert!(c.cache.rebalance);
        assert_eq!(c.cache.rebalance_interval, 16);
        assert!(SystemConfig::from_toml_str("[cache]\nshards = 0").is_err());
        assert!(SystemConfig::from_toml_str(
            "[cache]\nrebalance_interval = 0"
        )
        .is_err());
    }

    #[test]
    fn shed_and_open_loop_keys_parse() {
        let doc = "[shed]\nenabled = true\nttft_slo_s = 2.5\n\
                   downgrade_frac = 0.4\n\n\
                   [workload]\narrivals = \"bursty\"\ntenants = 4";
        let c = SystemConfig::from_toml_str(doc).unwrap();
        assert!(c.shed.enabled);
        assert_eq!(c.shed.ttft_slo_s, 2.5);
        assert_eq!(c.shed.downgrade_frac, 0.4);
        assert_eq!(c.workload.arrivals, "bursty");
        assert_eq!(c.workload.tenants, 4);
        let d = SystemConfig::default();
        assert!(!d.shed.enabled, "shedding off by default");
        assert_eq!(d.workload.arrivals, "poisson");
        assert_eq!(d.workload.tenants, 1);
        assert!(SystemConfig::from_toml_str(
            "[workload]\narrivals = \"weibull\""
        )
        .is_err());
        assert!(
            SystemConfig::from_toml_str("[workload]\ntenants = 0").is_err()
        );
        assert!(SystemConfig::from_toml_str("[shed]\nttft_slo_s = 0.0")
            .is_err());
        assert!(
            SystemConfig::from_toml_str("[shed]\ndowngrade_frac = 1.5")
                .is_err()
        );
    }

    #[test]
    fn disk_and_cag_keys_parse() {
        let doc = "[cache]\ndisk = true\ndisk_gib = 2\n\
                   disk_latency_s = 0.0002\ncag = true\n\
                   chunk_cache = true\ncag_pin_gib = 0.5";
        let c = SystemConfig::from_toml_str(doc).unwrap();
        assert!(c.cache.disk);
        assert_eq!(c.cache.disk_bytes, 2 * GIB);
        assert_eq!(c.cache.disk_latency_s, 0.0002);
        assert!(c.cache.cag);
        assert_eq!(c.cache.cag_pin_bytes, GIB / 2);
        let d = SystemConfig::default();
        assert!(!d.cache.disk, "disk tier off by default");
        assert!(!d.cache.cag, "cag off by default");
        // CAG without the chunk cache is rejected (corpus pins are
        // chunk entries), as is an empty disk tier.
        assert!(SystemConfig::from_toml_str("[cache]\ncag = true").is_err());
        assert!(SystemConfig::from_toml_str(
            "[cache]\ndisk = true\ndisk_gib = 0"
        )
        .is_err());
    }

    #[test]
    fn unknown_keys_rejected() {
        assert!(SystemConfig::from_toml_str("[cache]\nbogus = 1").is_err());
        assert!(SystemConfig::from_toml_str("[nonsense]\na = 1").is_err());
    }

    #[test]
    fn invalid_values_rejected() {
        assert!(SystemConfig::from_toml_str("[engine]\nmax_batch = 0").is_err());
        assert!(
            SystemConfig::from_toml_str("[cache]\npolicy = \"mru\"").is_err()
        );
    }

    #[test]
    fn presets_load() {
        for p in ["a10g-7b", "h800-large", "smoke"] {
            SystemConfig::preset(p).unwrap();
        }
        assert!(SystemConfig::preset("nope").is_err());
    }
}
