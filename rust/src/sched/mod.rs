//! Cache-aware request reordering (paper §5.2).
//!
//! Pending requests are prioritised by `OrderPriority = CachedLength /
//! ComputationLength` — prefer requests with a large cached context
//! relative to what they must recompute (the paper's two scenarios: big
//! cached contexts first, short recomputations first). A starvation
//! window bounds how many times any request can be bypassed.

/// A request waiting for engine admission.
#[derive(Debug, Clone)]
pub struct PendingRequest {
    pub id: u64,
    pub arrival: f64,
    /// Cached tokens (α) at enqueue time.
    pub cached_tokens: usize,
    /// Tokens to compute (β).
    pub compute_tokens: usize,
    /// Times a newer request has been served ahead of this one.
    pub bypassed: usize,
}

impl PendingRequest {
    /// §5.2 OrderPriority. A zero compute length (fully cached) gets the
    /// highest priority.
    pub fn order_priority(&self) -> f64 {
        if self.compute_tokens == 0 {
            f64::INFINITY
        } else {
            self.cached_tokens as f64 / self.compute_tokens as f64
        }
    }
}

/// The reordering queue. With `reorder = false` it degrades to FIFO
/// (the vLLM/SGLang baseline behaviour).
#[derive(Debug)]
pub struct ReorderQueue {
    items: Vec<PendingRequest>,
    /// Global pop counter; `bypassed` of an item is derived from the
    /// counter value at its enqueue.
    pops: usize,
    reorder: bool,
    window: usize,
}

impl ReorderQueue {
    pub fn new(reorder: bool, window: usize) -> Self {
        ReorderQueue {
            items: Vec::new(),
            pops: 0,
            reorder,
            window: window.max(1),
        }
    }

    pub fn push(&mut self, req: PendingRequest) {
        self.items.push(req);
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Remove a request (e.g. aborted speculation).
    pub fn remove(&mut self, id: u64) -> Option<PendingRequest> {
        let pos = self.items.iter().position(|r| r.id == id)?;
        Some(self.items.swap_remove(pos))
    }

    /// Refresh a queued request's cached/compute lengths (cache contents
    /// change while it waits).
    pub fn update_lengths(
        &mut self,
        id: u64,
        cached: usize,
        compute: usize,
    ) -> bool {
        if let Some(r) = self.items.iter_mut().find(|r| r.id == id) {
            r.cached_tokens = cached;
            r.compute_tokens = compute;
            true
        } else {
            false
        }
    }

    /// Pop the next request to admit.
    ///
    /// FIFO when reordering is off. Otherwise: if the oldest request has
    /// been bypassed `window` times it goes first (starvation guard);
    /// else the max-OrderPriority request goes (FIFO tie-break), and all
    /// older requests it bypassed get their counters bumped.
    pub fn pop(&mut self) -> Option<PendingRequest> {
        if self.items.is_empty() {
            return None;
        }
        if !self.reorder {
            // FIFO = strictly oldest first. Item order in `items` is not
            // significant (swap_remove below), so scan for the minimum.
            let oldest = self
                .items
                .iter()
                .enumerate()
                .min_by(|a, b| {
                    a.1.arrival.partial_cmp(&b.1.arrival).unwrap()
                })
                .map(|(i, _)| i)
                .unwrap();
            self.pops += 1;
            let mut r = self.items.swap_remove(oldest);
            r.bypassed = 0;
            return Some(r);
        }
        // Single pass: find the oldest entry (starvation guard) and the
        // max-OrderPriority entry together (§Perf: this queue grows to
        // thousands at saturation).
        let mut oldest = 0usize;
        let mut best = 0usize;
        let mut best_pri = self.items[0].order_priority();
        for (i, r) in self.items.iter().enumerate().skip(1) {
            if r.arrival < self.items[oldest].arrival {
                oldest = i;
            }
            let p = r.order_priority();
            if p > best_pri {
                best_pri = p;
                best = i;
            }
        }
        self.pops += 1;
        if self.items[oldest].bypassed >= self.window {
            // Starvation guard: the oldest request has been overtaken
            // `window` times — serve it now (§5.2).
            return Some(self.items.swap_remove(oldest));
        }
        // Overtake accounting: every request older than the chosen one
        // was bypassed once. (§Perf: single pass, swap_remove — exact
        // semantics kept; the O(n) sweep only costs under deep backlog,
        // where the system is past SLO anyway.)
        let chosen_arrival = self.items[best].arrival;
        for r in self.items.iter_mut() {
            if r.arrival < chosen_arrival {
                r.bypassed += 1;
            }
        }
        Some(self.items.swap_remove(best))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: f64, cached: usize, compute: usize) -> PendingRequest {
        PendingRequest {
            id,
            arrival,
            cached_tokens: cached,
            compute_tokens: compute,
            bypassed: 0,
        }
    }

    #[test]
    fn fifo_when_disabled() {
        let mut q = ReorderQueue::new(false, 32);
        q.push(req(1, 0.0, 0, 100));
        q.push(req(2, 1.0, 1000, 1));
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.pop().unwrap().id, 2);
    }

    #[test]
    fn prefers_larger_cached_context() {
        // §5.2 scenario 1: same compute, larger cached first.
        let mut q = ReorderQueue::new(true, 32);
        q.push(req(1, 0.0, 100, 50));
        q.push(req(2, 1.0, 400, 50));
        assert_eq!(q.pop().unwrap().id, 2);
    }

    #[test]
    fn prefers_shorter_recompute() {
        // §5.2 scenario 2: same cached, shorter recompute first.
        let mut q = ReorderQueue::new(true, 32);
        q.push(req(1, 0.0, 200, 400));
        q.push(req(2, 1.0, 200, 40));
        assert_eq!(q.pop().unwrap().id, 2);
    }

    #[test]
    fn fully_cached_wins() {
        let mut q = ReorderQueue::new(true, 32);
        q.push(req(1, 0.0, 500, 100));
        q.push(req(2, 1.0, 100, 0));
        assert_eq!(q.pop().unwrap().id, 2);
    }

    #[test]
    fn starvation_window_bounds_bypasses() {
        let window = 3;
        let mut q = ReorderQueue::new(true, window);
        // Request 1: terrible priority, arrives first.
        q.push(req(1, 0.0, 0, 10_000));
        // Feed better requests; request 1 must pop by the (window+1)-th.
        let mut popped_1_at = None;
        for round in 0..10u64 {
            q.push(req(100 + round, 1.0 + round as f64, 1000, 10));
            let got = q.pop().unwrap();
            if got.id == 1 {
                popped_1_at = Some(round);
                break;
            }
        }
        let at = popped_1_at.expect("request 1 eventually served");
        assert!(
            at as usize <= window,
            "served after {at} bypasses (window {window})"
        );
    }

    #[test]
    fn update_lengths_changes_order() {
        let mut q = ReorderQueue::new(true, 32);
        q.push(req(1, 0.0, 0, 100));
        q.push(req(2, 1.0, 50, 100));
        assert!(q.update_lengths(1, 500, 100));
        assert_eq!(q.pop().unwrap().id, 1);
        assert!(!q.update_lengths(99, 0, 0));
    }

    #[test]
    fn remove_works() {
        let mut q = ReorderQueue::new(true, 32);
        q.push(req(1, 0.0, 0, 10));
        q.push(req(2, 1.0, 0, 10));
        assert_eq!(q.remove(1).unwrap().id, 1);
        assert!(q.remove(1).is_none());
        assert_eq!(q.len(), 1);
    }
}
