//! Cache-aware request reordering (paper §5.2).
//!
//! Pending requests are prioritised by `OrderPriority = CachedLength /
//! ComputationLength` — prefer requests with a large cached context
//! relative to what they must recompute (the paper's two scenarios: big
//! cached contexts first, short recomputations first). A starvation
//! window bounds how many times any request can be bypassed.
//!
//! [`ReorderQueue`] is the single-owner queue the simulated controller
//! drives; [`SharedReorderQueue`] wraps the identical ordering semantics
//! behind a mutex + condvar so the concurrent TCP runtime's connection
//! handlers can feed it from many threads while one engine-driver thread
//! drains it.

use std::collections::HashMap;
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// A request waiting for engine admission.
#[derive(Debug, Clone)]
pub struct PendingRequest {
    pub id: u64,
    pub arrival: f64,
    /// Cached tokens (α) at enqueue time.
    pub cached_tokens: usize,
    /// Tokens to compute (β).
    pub compute_tokens: usize,
    /// Times a newer request has been served ahead of this one.
    pub bypassed: usize,
}

impl PendingRequest {
    /// §5.2 OrderPriority. A zero compute length (fully cached) gets the
    /// highest priority.
    pub fn order_priority(&self) -> f64 {
        if self.compute_tokens == 0 {
            f64::INFINITY
        } else {
            self.cached_tokens as f64 / self.compute_tokens as f64
        }
    }
}

/// The reordering queue. With `reorder = false` it degrades to FIFO
/// (the vLLM/SGLang baseline behaviour).
#[derive(Debug)]
pub struct ReorderQueue {
    items: Vec<PendingRequest>,
    reorder: bool,
    window: usize,
}

impl ReorderQueue {
    pub fn new(reorder: bool, window: usize) -> Self {
        ReorderQueue {
            items: Vec::new(),
            reorder,
            window: window.max(1),
        }
    }

    pub fn push(&mut self, req: PendingRequest) {
        self.items.push(req);
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Remove a request (e.g. aborted speculation).
    pub fn remove(&mut self, id: u64) -> Option<PendingRequest> {
        let pos = self.items.iter().position(|r| r.id == id)?;
        Some(self.items.swap_remove(pos))
    }

    /// Refresh a queued request's cached/compute lengths (cache contents
    /// change while it waits).
    pub fn update_lengths(
        &mut self,
        id: u64,
        cached: usize,
        compute: usize,
    ) -> bool {
        if let Some(r) = self.items.iter_mut().find(|r| r.id == id) {
            r.cached_tokens = cached;
            r.compute_tokens = compute;
            true
        } else {
            false
        }
    }

    /// Whether `a` is older than `b` under the total `(arrival, id)`
    /// order. Two connection workers can stamp the same arrival instant,
    /// so raw arrival comparison is only a partial order — ties would
    /// never bump each other's starvation counters and would tie-break
    /// nondeterministically. Ids are handed out monotonically, so the
    /// id completes the order in submission sequence.
    fn arrives_before(a: &PendingRequest, b: &PendingRequest) -> bool {
        (a.arrival, a.id) < (b.arrival, b.id)
    }

    /// Index of the oldest item under the total order. Item order in
    /// `items` is not significant (`swap_remove` in `pop`), so scan.
    fn oldest_index(&self) -> usize {
        let mut oldest = 0usize;
        for (i, r) in self.items.iter().enumerate().skip(1) {
            if Self::arrives_before(r, &self.items[oldest]) {
                oldest = i;
            }
        }
        oldest
    }

    /// Index of the next request to serve under the §5.2 single-pick
    /// rules — FIFO when reordering is off; otherwise the starvation
    /// guard (oldest request bypassed `window` times goes first), else
    /// the max-OrderPriority request (FIFO tie-break). No counter is
    /// mutated here; [`pop`](ReorderQueue::pop) and
    /// [`pop_batch`](ReorderQueue::pop_batch) layer the bypass
    /// accounting on top.
    fn select_index(&self) -> Option<usize> {
        if self.items.is_empty() {
            return None;
        }
        if !self.reorder {
            // FIFO = strictly oldest first.
            return Some(self.oldest_index());
        }
        // Single pass: find the oldest entry (starvation guard) and the
        // max-OrderPriority entry together (§Perf: this queue grows to
        // thousands at saturation).
        let mut oldest = 0usize;
        let mut best = 0usize;
        let mut best_pri = self.items[0].order_priority();
        for (i, r) in self.items.iter().enumerate().skip(1) {
            if Self::arrives_before(r, &self.items[oldest]) {
                oldest = i;
            }
            let p = r.order_priority();
            if p > best_pri {
                best_pri = p;
                best = i;
            }
        }
        if self.items[oldest].bypassed >= self.window {
            // Starvation guard: the oldest request has been overtaken
            // `window` times — serve it now (§5.2).
            Some(oldest)
        } else {
            Some(best)
        }
    }

    /// Pop the next request to admit.
    ///
    /// FIFO when reordering is off. Otherwise: if the oldest request has
    /// been bypassed `window` times it goes first (starvation guard);
    /// else the max-OrderPriority request goes (FIFO tie-break), and all
    /// older requests it bypassed get their counters bumped. "Oldest"
    /// and "older" are the total `(arrival, id)` order throughout, and
    /// every pop — FIFO, starvation guard, or priority — returns the
    /// request with its bypass counter reset, so a re-enqueued id
    /// starts a fresh starvation window.
    ///
    /// Exactly a batch of one: the bypass bump over requests older than
    /// the single member reproduces the historical per-pop accounting
    /// (the starvation and FIFO paths serve the oldest, so for them the
    /// bump is vacuous), which is what keeps `--max-batch 1` deployments
    /// bit-identical to the unbatched scheduler.
    pub fn pop(&mut self) -> Option<PendingRequest> {
        self.pop_batch(1, usize::MAX).pop()
    }

    /// Pop up to `max_batch` requests as ONE admission batch, in §5.2
    /// order: each pick follows the exact single-pop rules (starvation
    /// guard, then max-OrderPriority; FIFO when reordering is off), and
    /// selection stops early once adding the next pick would push the
    /// batch's summed `compute_tokens` past `token_budget` — the first
    /// pick is always taken, so an oversized request cannot wedge the
    /// queue.
    ///
    /// Starvation accounting treats the whole batch as ONE bypass
    /// event: a request left behind is bumped at most once — iff some
    /// batch member is newer than it under the total `(arrival, id)`
    /// order — however many members overtook it. The §5.2 bound then
    /// holds per batch event: every batch either serves the oldest
    /// request or bumps it exactly once, so it is served within
    /// `window + 1` batch pops.
    pub fn pop_batch(
        &mut self,
        max_batch: usize,
        token_budget: usize,
    ) -> Vec<PendingRequest> {
        let max_batch = max_batch.max(1);
        let mut batch: Vec<PendingRequest> = Vec::new();
        let mut tokens = 0usize;
        while batch.len() < max_batch {
            let Some(idx) = self.select_index() else { break };
            let next = &self.items[idx];
            if !batch.is_empty()
                && tokens.saturating_add(next.compute_tokens) > token_budget
            {
                break;
            }
            tokens = tokens.saturating_add(next.compute_tokens);
            let mut r = self.items.swap_remove(idx);
            r.bypassed = 0;
            batch.push(r);
        }
        // Overtake accounting, once per batch: everything still queued
        // that is older than the newest member was bypassed by this
        // admission event. (§Perf: single sweep, and only under deep
        // backlog is it over many items — where the system is past SLO
        // anyway.)
        if self.reorder && !batch.is_empty() {
            let newest = batch
                .iter()
                .map(|r| (r.arrival, r.id))
                .fold((f64::NEG_INFINITY, 0u64), |a, b| {
                    if b > a {
                        b
                    } else {
                        a
                    }
                });
            for r in self.items.iter_mut() {
                if (r.arrival, r.id) < newest {
                    r.bypassed += 1;
                }
            }
        }
        batch
    }
}

/// Stable shard → engine assignment for multi-engine dispatch: requests
/// that hit the same knowledge-tree shard always drain through the same
/// engine queue, so a shard's working set stays coherent with one
/// engine's admissions (cache affinity) and the §5.2 ordering plus
/// starvation bound hold per engine.
#[derive(Debug, Clone, Copy)]
pub struct ShardRouter {
    engines: usize,
}

impl ShardRouter {
    pub fn new(engines: usize) -> Self {
        ShardRouter {
            engines: engines.max(1),
        }
    }

    pub fn engines(&self) -> usize {
        self.engines
    }

    /// Engine index that owns `shard`.
    pub fn route(&self, shard: usize) -> usize {
        shard % self.engines
    }
}

/// Thread-safe reorder queue carrying an opaque job payload per pending
/// request (the concurrent server attaches the parsed request + its
/// response channel). Many producers push; one (or more) consumers pop in
/// §5.2 priority order with the same starvation bound as
/// [`ReorderQueue`].
///
/// `close()` makes the queue refuse further pushes and drops every
/// pending job — producers blocked on a job's response channel observe
/// the disconnect instead of hanging, which is what makes engine-thread
/// failure and shutdown deadlock-free.
pub struct SharedReorderQueue<T> {
    inner: Mutex<SharedState<T>>,
    ready: Condvar,
}

struct SharedState<T> {
    queue: ReorderQueue,
    jobs: HashMap<u64, T>,
    closed: bool,
}

impl<T> SharedReorderQueue<T> {
    pub fn new(reorder: bool, window: usize) -> Self {
        SharedReorderQueue {
            inner: Mutex::new(SharedState {
                queue: ReorderQueue::new(reorder, window),
                jobs: HashMap::new(),
                closed: false,
            }),
            ready: Condvar::new(),
        }
    }

    fn lock(&self) -> MutexGuard<'_, SharedState<T>> {
        match self.inner.lock() {
            Ok(g) => g,
            // A producer/consumer panicking mid-push must not wedge the
            // whole runtime; the state itself stays coherent (each
            // operation completes its queue+jobs updates together).
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    /// Enqueue a request with its job payload. Returns false (dropping
    /// the job) if the queue is closed.
    pub fn push(&self, req: PendingRequest, job: T) -> bool {
        let mut s = self.lock();
        if s.closed {
            return false;
        }
        s.jobs.insert(req.id, job);
        s.queue.push(req);
        drop(s);
        self.ready.notify_one();
        true
    }

    /// Pop the highest-priority request, blocking up to `timeout` for one
    /// to arrive. Returns None on timeout, spurious wakeup, or when the
    /// queue is closed and empty — callers loop. A batch of one: see
    /// [`SharedReorderQueue::pop_batch_timeout`].
    pub fn pop_timeout(
        &self,
        timeout: Duration,
    ) -> Option<(PendingRequest, T)> {
        self.pop_batch_timeout(timeout, 1, usize::MAX).pop()
    }

    /// Non-blocking batch pop: [`SharedReorderQueue::pop_batch_timeout`]
    /// that never waits. The event-multiplexing engine loop uses it to
    /// drain admissible work between session events while requests are
    /// parked in `Retrieving`: the drain must not block behind an empty
    /// queue when stage events may already be pending, and sessions
    /// outside the queue must not starve those inside it — an empty (or
    /// skipped, `max_batch == 0`) drain pops nothing and therefore
    /// bumps no bypass counter, so the §5.2 bound keeps counting only
    /// real admission events.
    pub fn try_pop_batch(
        &self,
        max_batch: usize,
        token_budget: usize,
    ) -> Vec<(PendingRequest, T)> {
        if max_batch == 0 {
            return Vec::new();
        }
        let mut s = self.lock();
        let batch = s.queue.pop_batch(max_batch, token_budget);
        batch
            .into_iter()
            .map(|req| {
                let job =
                    s.jobs.remove(&req.id).expect("job for queued request");
                (req, job)
            })
            .collect()
    }

    /// Pop up to `max_batch` requests (bounded by `token_budget` summed
    /// compute tokens) as one admission batch, blocking up to `timeout`
    /// for the first to arrive. Returns an empty vec on timeout,
    /// spurious wakeup, or when the queue is closed and empty — callers
    /// loop. Batch selection and the batch-as-one-bypass-event
    /// starvation semantics are [`ReorderQueue::pop_batch`]'s; the lock
    /// is held across the whole drain, so the batch is a consistent
    /// §5.2 prefix of the queue even with producers racing.
    pub fn pop_batch_timeout(
        &self,
        timeout: Duration,
        max_batch: usize,
        token_budget: usize,
    ) -> Vec<(PendingRequest, T)> {
        let mut s = self.lock();
        if s.queue.is_empty() && !s.closed {
            s = match self.ready.wait_timeout(s, timeout) {
                Ok((guard, _)) => guard,
                Err(poisoned) => poisoned.into_inner().0,
            };
        }
        let batch = s.queue.pop_batch(max_batch, token_budget);
        batch
            .into_iter()
            .map(|req| {
                let job =
                    s.jobs.remove(&req.id).expect("job for queued request");
                (req, job)
            })
            .collect()
    }

    pub fn len(&self) -> usize {
        self.lock().queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lock().queue.is_empty()
    }

    pub fn is_closed(&self) -> bool {
        self.lock().closed
    }

    /// Refuse further pushes but keep already-accepted jobs poppable —
    /// the first phase of a graceful drain. Once sealed, a consumer can
    /// finish everything that was accepted with no producer able to
    /// slip a job in behind its final emptiness check.
    pub fn seal(&self) {
        let mut s = self.lock();
        s.closed = true;
        drop(s);
        self.ready.notify_all();
    }

    /// Refuse further pushes and drop all pending jobs, waking every
    /// waiter.
    pub fn close(&self) {
        let mut s = self.lock();
        s.closed = true;
        while s.queue.pop().is_some() {}
        s.jobs.clear();
        drop(s);
        self.ready.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: f64, cached: usize, compute: usize) -> PendingRequest {
        PendingRequest {
            id,
            arrival,
            cached_tokens: cached,
            compute_tokens: compute,
            bypassed: 0,
        }
    }

    #[test]
    fn fifo_when_disabled() {
        let mut q = ReorderQueue::new(false, 32);
        q.push(req(1, 0.0, 0, 100));
        q.push(req(2, 1.0, 1000, 1));
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.pop().unwrap().id, 2);
    }

    #[test]
    fn prefers_larger_cached_context() {
        // §5.2 scenario 1: same compute, larger cached first.
        let mut q = ReorderQueue::new(true, 32);
        q.push(req(1, 0.0, 100, 50));
        q.push(req(2, 1.0, 400, 50));
        assert_eq!(q.pop().unwrap().id, 2);
    }

    #[test]
    fn prefers_shorter_recompute() {
        // §5.2 scenario 2: same cached, shorter recompute first.
        let mut q = ReorderQueue::new(true, 32);
        q.push(req(1, 0.0, 200, 400));
        q.push(req(2, 1.0, 200, 40));
        assert_eq!(q.pop().unwrap().id, 2);
    }

    #[test]
    fn fully_cached_wins() {
        let mut q = ReorderQueue::new(true, 32);
        q.push(req(1, 0.0, 500, 100));
        q.push(req(2, 1.0, 100, 0));
        assert_eq!(q.pop().unwrap().id, 2);
    }

    #[test]
    fn starvation_window_bounds_bypasses() {
        let window = 3;
        let mut q = ReorderQueue::new(true, window);
        // Request 1: terrible priority, arrives first.
        q.push(req(1, 0.0, 0, 10_000));
        // Feed better requests; request 1 must pop by the (window+1)-th.
        let mut popped_1_at = None;
        for round in 0..10u64 {
            q.push(req(100 + round, 1.0 + round as f64, 1000, 10));
            let got = q.pop().unwrap();
            if got.id == 1 {
                popped_1_at = Some(round);
                break;
            }
        }
        let at = popped_1_at.expect("request 1 eventually served");
        assert!(
            at as usize <= window,
            "served after {at} bypasses (window {window})"
        );
    }

    /// Regression: the starvation-guard path used to return the oldest
    /// request with its stale `bypassed` counter — a re-enqueued id
    /// inherited a spent starvation window (the FIFO path did reset).
    #[test]
    fn starvation_pop_resets_bypass_counter() {
        let mut q = ReorderQueue::new(true, 1);
        q.push(req(1, 0.0, 0, 1_000_000)); // oldest, worst priority
        q.push(req(2, 1.0, 10_000, 1));
        assert_eq!(q.pop().unwrap().id, 2, "priority wins round one");
        q.push(req(3, 2.0, 10_000, 1));
        let starved = q.pop().unwrap();
        assert_eq!(starved.id, 1, "starvation guard fires");
        assert_eq!(starved.bypassed, 0, "counter reset on pop");
    }

    /// Regression: bypass bumping used `arrival < chosen_arrival`, so
    /// equal-arrival requests — possible when two connection workers
    /// stamp the same instant — never bumped each other and the oldest
    /// pick tie-broke nondeterministically. Under the total
    /// `(arrival, id)` order the starvation bound holds regardless.
    #[test]
    fn equal_arrivals_keep_the_starvation_bound() {
        let window = 2;
        let mut q = ReorderQueue::new(true, window);
        // The victim: same arrival stamp as everything else, lowest id.
        q.push(req(0, 0.0, 0, 1_000_000));
        let mut served_at = None;
        for round in 0..10u64 {
            q.push(req(1 + round, 0.0, 10_000, 1));
            let got = q.pop().unwrap();
            if got.id == 0 {
                served_at = Some(round as usize);
                break;
            }
        }
        let at = served_at.expect("equal-arrival victim served");
        assert!(
            at <= window,
            "served after {at} bypasses (window {window})"
        );
    }

    /// Equal arrivals pop in id (submission) order under FIFO.
    #[test]
    fn fifo_ties_break_by_id() {
        let mut q = ReorderQueue::new(false, 4);
        q.push(req(7, 0.0, 0, 10));
        q.push(req(3, 0.0, 0, 10));
        q.push(req(5, 0.0, 0, 10));
        assert_eq!(q.pop().unwrap().id, 3);
        assert_eq!(q.pop().unwrap().id, 5);
        assert_eq!(q.pop().unwrap().id, 7);
    }

    #[test]
    fn pop_batch_respects_cap_and_token_budget() {
        let mut q = ReorderQueue::new(true, 32);
        q.push(req(1, 0.0, 100, 40));
        q.push(req(2, 1.0, 100, 40));
        q.push(req(3, 2.0, 100, 40));
        q.push(req(4, 3.0, 100, 40));
        // Cap of 3 leaves the fourth queued.
        let b = q.pop_batch(3, usize::MAX);
        assert_eq!(b.len(), 3);
        assert_eq!(q.len(), 1);
        // Budget of 50 tokens fits only the (mandatory) first pick.
        q.push(req(5, 4.0, 100, 40));
        let b = q.pop_batch(8, 50);
        assert_eq!(b.len(), 1);
        assert_eq!(q.len(), 1);
        // An oversized first pick is still taken (never wedges).
        let mut q = ReorderQueue::new(true, 32);
        q.push(req(9, 0.0, 0, 10_000));
        assert_eq!(q.pop_batch(4, 100).len(), 1);
    }

    /// Tentpole semantics: however many members a batch pops, a request
    /// left behind is bumped exactly once — the batch is ONE bypass
    /// event.
    #[test]
    fn pop_batch_is_one_bypass_event() {
        let window = 100; // never fires; isolate the bump accounting
        let mut q = ReorderQueue::new(true, window);
        q.push(req(1, 0.0, 0, 1_000_000)); // victim: oldest, worst
        for i in 0..3u64 {
            q.push(req(10 + i, 1.0 + i as f64, 10_000, 1));
        }
        let b = q.pop_batch(3, usize::MAX);
        assert_eq!(b.len(), 3, "three hot members pop");
        assert!(b.iter().all(|r| r.id != 1));
        let victim = q.remove(1).unwrap();
        assert_eq!(
            victim.bypassed, 1,
            "three members overtook, one batch event counted"
        );
    }

    /// The §5.2 bound per batch event: the victim is served within
    /// `window + 1` batch pops, because each batch either contains it or
    /// bumps it once.
    #[test]
    fn pop_batch_preserves_starvation_bound_per_batch() {
        let window = 2;
        let mut q = ReorderQueue::new(true, window);
        q.push(req(1, 0.0, 0, 1_000_000));
        let mut served_at = None;
        for event in 0..8usize {
            // Keep the queue saturated with hot requests.
            for j in 0..4u64 {
                let id = 100 + (event as u64) * 10 + j;
                q.push(req(id, 1.0 + id as f64, 10_000, 1));
            }
            let batch = q.pop_batch(4, usize::MAX);
            if batch.iter().any(|r| r.id == 1) {
                served_at = Some(event);
                break;
            }
        }
        let at = served_at.expect("victim eventually served");
        assert!(
            at <= window,
            "victim served at batch event {at}, window {window}"
        );
    }

    /// Delegation guard: `pop()` is defined as `pop_batch(1, ∞)` today,
    /// so this randomized interleaving over two identically fed queues
    /// holds by construction — it exists to catch a future change that
    /// re-splits the two implementations and lets them drift. The
    /// non-tautological conformance proof against a literal copy of the
    /// pre-batching pop lives in `tests/batched_admission.rs`
    /// (`batch_of_one_is_bit_identical_to_unbatched_reference`).
    #[test]
    fn pop_batch_of_one_matches_pop_exactly() {
        let mut rng = crate::util::Rng::new(0xBA7C);
        for _round in 0..50 {
            let reorder = rng.chance(0.8);
            let window = 1 + rng.index(4);
            let mut a = ReorderQueue::new(reorder, window);
            let mut b = ReorderQueue::new(reorder, window);
            let mut next_id = 0u64;
            for _op in 0..60 {
                if rng.chance(0.6) {
                    let r = req(
                        next_id,
                        rng.index(8) as f64, // deliberate arrival ties
                        rng.index(500),
                        rng.index(500),
                    );
                    next_id += 1;
                    a.push(r.clone());
                    b.push(r);
                } else {
                    let x = a.pop();
                    let y = b.pop_batch(1, usize::MAX).pop();
                    match (x, y) {
                        (None, None) => {}
                        (Some(x), Some(y)) => {
                            assert_eq!(x.id, y.id);
                            assert_eq!(x.bypassed, y.bypassed);
                        }
                        (x, y) => panic!("diverged: {x:?} vs {y:?}"),
                    }
                }
            }
            // Drain both; the tails must agree too.
            loop {
                match (a.pop(), b.pop_batch(1, usize::MAX).pop()) {
                    (None, None) => break,
                    (Some(x), Some(y)) => assert_eq!(x.id, y.id),
                    (x, y) => panic!("tail diverged: {x:?} vs {y:?}"),
                }
            }
        }
    }

    #[test]
    fn pop_batch_fifo_drains_in_arrival_order() {
        let mut q = ReorderQueue::new(false, 4);
        q.push(req(3, 2.0, 0, 10));
        q.push(req(1, 0.0, 0, 10));
        q.push(req(2, 1.0, 0, 10));
        let ids: Vec<u64> =
            q.pop_batch(3, usize::MAX).iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
    }

    #[test]
    fn shard_router_is_stable_and_total() {
        let r = ShardRouter::new(3);
        assert_eq!(r.engines(), 3);
        for shard in 0..32usize {
            let e = r.route(shard);
            assert!(e < 3);
            assert_eq!(e, r.route(shard), "routing is deterministic");
        }
        // Zero engines degrades to one, never a division by zero.
        assert_eq!(ShardRouter::new(0).route(5), 0);
    }

    /// Satellite property test: across randomized configurations, every
    /// shard routes to a valid engine, and with S ≥ E shards the shard
    /// count per engine is balanced within ±1 (no engine starves while
    /// a sibling owns two more shards than it).
    #[test]
    fn shard_router_routes_valid_and_balanced() {
        let mut rng = crate::util::Rng::new(0x5A4D);
        for _ in 0..256 {
            let engines = 1 + rng.index(8);
            let shards = engines + rng.index(25);
            let r = ShardRouter::new(engines);
            let mut counts = vec![0usize; engines];
            for shard in 0..shards {
                let e = r.route(shard);
                assert!(
                    e < engines,
                    "shard {shard} routed to engine {e} of {engines}"
                );
                counts[e] += 1;
            }
            let min = counts.iter().min().unwrap();
            let max = counts.iter().max().unwrap();
            assert!(
                max - min <= 1,
                "{shards} shards over {engines} engines unbalanced: \
                 {counts:?}"
            );
        }
    }

    #[test]
    fn update_lengths_changes_order() {
        let mut q = ReorderQueue::new(true, 32);
        q.push(req(1, 0.0, 0, 100));
        q.push(req(2, 1.0, 50, 100));
        assert!(q.update_lengths(1, 500, 100));
        assert_eq!(q.pop().unwrap().id, 1);
        assert!(!q.update_lengths(99, 0, 0));
    }

    #[test]
    fn remove_works() {
        let mut q = ReorderQueue::new(true, 32);
        q.push(req(1, 0.0, 0, 10));
        q.push(req(2, 1.0, 0, 10));
        assert_eq!(q.remove(1).unwrap().id, 1);
        assert!(q.remove(1).is_none());
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn shared_queue_push_pop_roundtrip() {
        let q: SharedReorderQueue<&'static str> =
            SharedReorderQueue::new(true, 8);
        assert!(q.push(req(1, 0.0, 0, 100), "low"));
        assert!(q.push(req(2, 1.0, 1000, 1), "high"));
        let (r, job) = q.pop_timeout(Duration::from_millis(10)).unwrap();
        assert_eq!((r.id, job), (2, "high"));
        let (r, job) = q.pop_timeout(Duration::from_millis(10)).unwrap();
        assert_eq!((r.id, job), (1, "low"));
        assert!(q.pop_timeout(Duration::from_millis(1)).is_none());
    }

    #[test]
    fn shared_queue_pop_batch_drains_priority_order_with_jobs() {
        let q: SharedReorderQueue<&'static str> =
            SharedReorderQueue::new(true, 8);
        assert!(q.push(req(1, 0.0, 0, 100), "low"));
        assert!(q.push(req(2, 1.0, 1000, 1), "high"));
        assert!(q.push(req(3, 2.0, 500, 2), "mid"));
        let batch = q.pop_batch_timeout(Duration::from_millis(10), 2, usize::MAX);
        let got: Vec<(u64, &str)> =
            batch.iter().map(|(r, j)| (r.id, *j)).collect();
        assert_eq!(got, vec![(2, "high"), (3, "mid")]);
        assert_eq!(q.len(), 1, "cap left the low-priority request queued");
        let rest = q.pop_batch_timeout(Duration::from_millis(10), 4, usize::MAX);
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].1, "low");
        assert!(q
            .pop_batch_timeout(Duration::from_millis(1), 4, usize::MAX)
            .is_empty());
    }

    /// The non-blocking drain behaves exactly like the blocking one on
    /// content, never waits, and an empty/skipped drain leaves bypass
    /// state untouched — sessions parked in Retrieving (outside the
    /// queue) cost queued requests nothing.
    #[test]
    fn shared_queue_try_pop_matches_and_never_bumps_on_empty() {
        let q: SharedReorderQueue<u32> = SharedReorderQueue::new(true, 2);
        // Never waits: an empty queue answers immediately.
        let t0 = std::time::Instant::now();
        assert!(q.try_pop_batch(4, usize::MAX).is_empty());
        assert!(t0.elapsed() < Duration::from_millis(50));

        // Victim with terrible priority, then hot requests.
        assert!(q.push(req(1, 0.0, 0, 1_000_000), 1));
        assert!(q.push(req(2, 1.0, 10_000, 1), 2));
        // A zero-slot drain (engine full of parked sessions) is a no-op
        // admission event: nothing popped, nobody bumped.
        assert!(q.try_pop_batch(0, usize::MAX).is_empty());
        assert_eq!(q.len(), 2);
        // First real drain: priority order, victim bumped once.
        let got = q.try_pop_batch(1, usize::MAX);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].0.id, 2);
        // Window 2: one more bypass event before the guard fires.
        assert!(q.push(req(3, 2.0, 10_000, 1), 3));
        let got = q.try_pop_batch(1, usize::MAX);
        assert_eq!(got[0].0.id, 3);
        assert!(q.push(req(4, 3.0, 10_000, 1), 4));
        let got = q.try_pop_batch(1, usize::MAX);
        assert_eq!(
            got[0].0.id, 1,
            "starvation guard fires after `window` real drains — \
             empty/zero-slot drains did not count against the victim"
        );
    }

    #[test]
    fn shared_queue_close_refuses_and_drops() {
        let q: SharedReorderQueue<u32> = SharedReorderQueue::new(true, 8);
        assert!(q.push(req(1, 0.0, 0, 1), 10));
        q.close();
        assert!(!q.push(req(2, 1.0, 0, 1), 20), "closed queue refuses");
        assert!(q.pop_timeout(Duration::from_millis(1)).is_none());
        assert!(q.is_empty());
    }

    #[test]
    fn shared_queue_seal_refuses_but_drains() {
        let q: SharedReorderQueue<u32> = SharedReorderQueue::new(true, 8);
        assert!(q.push(req(1, 0.0, 0, 1), 10));
        assert!(q.push(req(2, 1.0, 0, 1), 20));
        q.seal();
        assert!(!q.push(req(3, 2.0, 0, 1), 30), "sealed queue refuses");
        // Accepted jobs remain drainable after sealing.
        assert!(q.pop_timeout(Duration::from_millis(1)).is_some());
        assert!(q.pop_timeout(Duration::from_millis(1)).is_some());
        assert!(q.pop_timeout(Duration::from_millis(1)).is_none());
    }

    /// Satellite coverage: the §5.2 bypass window bounds starvation even
    /// when the queue is fed and drained from different threads. The
    /// victim is always the oldest entry, so every pop either serves it
    /// or bumps its bypass counter — its position in the drain order can
    /// never exceed `window + 1`, under any interleaving.
    #[test]
    fn shared_queue_starvation_bound_across_threads() {
        use std::sync::Arc;
        let window = 4usize;
        let hot = 4 * window as u64;
        let q: Arc<SharedReorderQueue<u64>> =
            Arc::new(SharedReorderQueue::new(true, window));
        // The victim: oldest arrival, worst possible priority.
        assert!(q.push(req(1, 0.0, 0, 1_000_000), 1));

        let feeder = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                for i in 0..hot {
                    // Newer, very high priority requests.
                    assert!(q.push(
                        req(100 + i, 1.0 + i as f64, 10_000, 1),
                        100 + i
                    ));
                    if i % 3 == 0 {
                        std::thread::yield_now();
                    }
                }
            })
        };

        let drainer = {
            let q = Arc::clone(&q);
            std::thread::spawn(move || {
                let mut order = Vec::new();
                while order.len() < (hot as usize) + 1 {
                    if let Some((r, _)) =
                        q.pop_timeout(Duration::from_millis(50))
                    {
                        order.push(r.id);
                    }
                }
                order
            })
        };

        feeder.join().unwrap();
        let order = drainer.join().unwrap();
        let victim_pos = order
            .iter()
            .position(|&id| id == 1)
            .expect("victim eventually served");
        assert!(
            victim_pos <= window + 1,
            "victim served at position {victim_pos}, window {window}"
        );
        assert_eq!(order.len(), hot as usize + 1, "nothing lost");
    }
}
