//! Cache replacement policies: PGDSF (the paper's contribution, §5.1,
//! Eq. 1–3, Algorithm 1) and the ablation baselines GDSF, LRU, LFU
//! (§7.3, Fig. 17 / Table 2).
//!
//! A policy owns per-node statistics updates and the priority function;
//! the knowledge tree owns the per-tier logical clocks and the leaf-only
//! eviction mechanics. With the NVMe tier enabled (`--disk on`) the same
//! priority order drives the full GPU → host → disk → drop cascade: the
//! policy only ever names the victim, the tree decides (by room below)
//! whether that victim demotes one level or drops — see
//! `crate::kvcache` for the cascade and burst-charging contract.
//!
//! The same [`NodeStats`] + priority machinery also scores owned
//! chunk-cache entries (`--chunk-cache on`): chunk entries compete with
//! leaf-frontier tree nodes for tier residency under one policy, anchored
//! at the clock of the tier each candidate resides in — an eviction takes
//! the chunk victim only when it scores STRICTLY below the node victim.

use crate::config::PolicyKind;

/// Per-node statistics a policy reads/writes. Stored inside each
/// knowledge-tree node.
#[derive(Debug, Clone, Default)]
pub struct NodeStats {
    /// Retrieval count within the current window (Algorithm 1 line 3).
    pub frequency: u64,
    /// Σ T(α,β)/β over requests that found this node uncached
    /// (Algorithm 1 line 10).
    pub total_cost: f64,
    /// Count of such requests (line 11).
    pub num_computed: u64,
    /// total_cost / num_computed (line 12) — cost per non-cached token.
    pub avg_cost: f64,
    /// Wall/virtual time of the last access (for LRU).
    pub last_access: f64,
    /// Cached priority (recomputed on access; the clock component is
    /// frozen at access time, as in GDSF).
    pub priority: f64,
}

/// Context of one access, assembled by the controller.
#[derive(Debug, Clone, Copy)]
pub struct AccessCtx {
    /// Cached tokens of the request at the time of access (α).
    pub alpha: usize,
    /// Non-cached tokens the request had to compute (β).
    pub beta: usize,
    /// Estimated compute time for (α, β) from the offline profile,
    /// seconds (Algorithm 1 lines 6–9 bilinear interpolation).
    pub estimated_time: f64,
    /// Whether this node's KV was already cached when accessed.
    pub was_cached: bool,
    /// Access timestamp.
    pub now: f64,
    /// Node size in tokens.
    pub tokens: usize,
}

/// A replacement policy: stat updates + priority.
pub trait ReplacementPolicy: Send + Sync {
    fn kind(&self) -> PolicyKind;

    /// Update `stats` for an access; `clock` is the current logical clock
    /// of the tier the node resides in (0 for uncached nodes — they are
    /// about to be inserted into GPU).
    fn on_access(&self, stats: &mut NodeStats, ctx: &AccessCtx, clock: f64);

    /// Priority used for eviction ordering (lower evicts first).
    fn priority(&self, stats: &NodeStats) -> f64 {
        stats.priority
    }
}

/// Build a policy from config.
pub fn make_policy(kind: PolicyKind) -> Box<dyn ReplacementPolicy> {
    match kind {
        PolicyKind::Pgdsf => Box::new(Pgdsf),
        PolicyKind::Gdsf => Box::new(Gdsf),
        PolicyKind::Lru => Box::new(Lru),
        PolicyKind::Lfu => Box::new(Lfu),
    }
}

/// Prefix-aware GDSF (the paper's policy).
///
/// `Priority = Clock + Frequency × AvgCost` where `AvgCost` amortises the
/// *measured* prefill time over the non-cached tokens of each request
/// that computed this node (Eq. 3) — so a document deep in a shared
/// prefix, whose recomputation is cheap per token, is valued accordingly.
pub struct Pgdsf;

impl ReplacementPolicy for Pgdsf {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Pgdsf
    }

    fn on_access(&self, s: &mut NodeStats, ctx: &AccessCtx, clock: f64) {
        s.frequency += 1;
        s.last_access = ctx.now;
        if !ctx.was_cached && ctx.beta > 0 {
            s.total_cost += ctx.estimated_time / ctx.beta as f64;
            s.num_computed += 1;
            s.avg_cost = s.total_cost / s.num_computed as f64;
        }
        s.priority = clock + s.avg_cost * s.frequency as f64;
    }
}

/// Classic GDSF: cost taken as proportional to document size, which makes
/// `Cost/Size` a constant — the paper's §7.3 baseline configuration.
pub struct Gdsf;

/// The per-token cost constant for GDSF. Any positive constant gives the
/// same eviction order; we use 1.0.
const GDSF_COST_PER_TOKEN: f64 = 1.0;

impl ReplacementPolicy for Gdsf {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Gdsf
    }

    fn on_access(&self, s: &mut NodeStats, ctx: &AccessCtx, clock: f64) {
        s.frequency += 1;
        s.last_access = ctx.now;
        s.priority = clock + GDSF_COST_PER_TOKEN * s.frequency as f64;
    }
}

/// Least-recently-used.
pub struct Lru;

impl ReplacementPolicy for Lru {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Lru
    }

    fn on_access(&self, s: &mut NodeStats, ctx: &AccessCtx, _clock: f64) {
        s.frequency += 1;
        s.last_access = ctx.now;
        s.priority = ctx.now;
    }
}

/// Least-frequently-used.
pub struct Lfu;

impl ReplacementPolicy for Lfu {
    fn kind(&self) -> PolicyKind {
        PolicyKind::Lfu
    }

    fn on_access(&self, s: &mut NodeStats, ctx: &AccessCtx, _clock: f64) {
        s.frequency += 1;
        s.last_access = ctx.now;
        s.priority = s.frequency as f64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(beta: usize, time: f64, cached: bool, now: f64) -> AccessCtx {
        AccessCtx {
            alpha: 0,
            beta,
            estimated_time: time,
            was_cached: cached,
            now,
            tokens: beta,
        }
    }

    #[test]
    fn pgdsf_amortises_cost_over_new_tokens() {
        let p = Pgdsf;
        let mut s = NodeStats::default();
        // First access: 100 new tokens took 1s => 0.01 s/token.
        p.on_access(&mut s, &ctx(100, 1.0, false, 0.0), 0.0);
        assert!((s.avg_cost - 0.01).abs() < 1e-12);
        assert_eq!(s.frequency, 1);
        // Second access, cached: cost unchanged, frequency up.
        p.on_access(&mut s, &ctx(100, 9.0, true, 1.0), 0.0);
        assert!((s.avg_cost - 0.01).abs() < 1e-12);
        assert_eq!(s.frequency, 2);
        assert!((s.priority - 0.02).abs() < 1e-12);
    }

    #[test]
    fn pgdsf_prefix_awareness() {
        // A node always recomputed behind a long cached prefix (small β)
        // is more expensive *per new token* only if its measured time per
        // token says so — two different prefix situations give different
        // avg costs.
        let p = Pgdsf;
        let mut shallow = NodeStats::default();
        // 1000 new tokens, 2s => 0.002 s/token.
        p.on_access(&mut shallow, &ctx(1000, 2.0, false, 0.0), 0.0);
        let mut deep = NodeStats::default();
        // Same doc behind cached prefix: only 100 new tokens, 0.5s =>
        // 0.005 s/token (attention over the prefix makes per-token cost
        // higher).
        p.on_access(&mut deep, &ctx(100, 0.5, false, 0.0), 0.0);
        assert!(deep.avg_cost > shallow.avg_cost);
    }

    #[test]
    fn pgdsf_clock_lifts_priority() {
        let p = Pgdsf;
        let mut s = NodeStats::default();
        p.on_access(&mut s, &ctx(10, 0.1, false, 0.0), 5.0);
        assert!(s.priority > 5.0);
    }

    #[test]
    fn gdsf_ignores_measured_cost() {
        let p = Gdsf;
        let mut a = NodeStats::default();
        let mut b = NodeStats::default();
        p.on_access(&mut a, &ctx(100, 5.0, false, 0.0), 0.0);
        p.on_access(&mut b, &ctx(100, 0.001, false, 0.0), 0.0);
        assert_eq!(a.priority, b.priority);
    }

    #[test]
    fn lru_orders_by_recency() {
        let p = Lru;
        let mut old = NodeStats::default();
        let mut new = NodeStats::default();
        p.on_access(&mut old, &ctx(1, 0.0, true, 1.0), 0.0);
        p.on_access(&mut new, &ctx(1, 0.0, true, 2.0), 0.0);
        assert!(p.priority(&old) < p.priority(&new));
    }

    #[test]
    fn lfu_orders_by_frequency() {
        let p = Lfu;
        let mut hot = NodeStats::default();
        let mut cold = NodeStats::default();
        for t in 0..5 {
            p.on_access(&mut hot, &ctx(1, 0.0, true, t as f64), 0.0);
        }
        p.on_access(&mut cold, &ctx(1, 0.0, true, 9.0), 0.0);
        assert!(p.priority(&cold) < p.priority(&hot));
    }

    #[test]
    fn factory_returns_right_kinds() {
        for kind in [
            PolicyKind::Pgdsf,
            PolicyKind::Gdsf,
            PolicyKind::Lru,
            PolicyKind::Lfu,
        ] {
            assert_eq!(make_policy(kind).kind(), kind);
        }
    }
}
