//! Synthetic document corpus.
//!
//! Stands in for the paper's Wikipedia knowledge base (~0.3 M documents,
//! mean length 3718 tokens, long-tailed — Fig. 3). Lengths are lognormal,
//! clipped to a plausible range, deterministic per (seed, doc id).

use crate::util::Rng;

/// A corpus: token length per document (content is irrelevant to cache
/// behaviour; the PJRT path generates token ids separately).
#[derive(Debug, Clone)]
pub struct Corpus {
    doc_tokens: Vec<usize>,
}

impl Corpus {
    /// Wikipedia-like corpus (paper Fig. 3): lognormal with mean ≈ 3718
    /// tokens, clipped to [64, 16384].
    pub fn wikipedia_like(num_docs: usize, seed: u64) -> Self {
        // mean = exp(mu + sigma^2/2) = 3718 with sigma = 0.9
        // => mu = ln(3718) - 0.405 = 7.82.
        Self::lognormal(num_docs, 7.82, 0.9, 64, 16384, seed)
    }

    /// Tiny corpus for the PJRT-backed path: short docs that fit the
    /// compiled buckets (16–96 tokens).
    pub fn tiny(num_docs: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let doc_tokens = (0..num_docs)
            .map(|_| 16 + rng.index(6) * 16)
            .collect();
        Corpus { doc_tokens }
    }

    pub fn lognormal(
        num_docs: usize,
        mu: f64,
        sigma: f64,
        min: usize,
        max: usize,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed);
        let doc_tokens = (0..num_docs)
            .map(|_| {
                (rng.lognormal(mu, sigma).round() as usize).clamp(min, max)
            })
            .collect();
        Corpus { doc_tokens }
    }

    pub fn len(&self) -> usize {
        self.doc_tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.doc_tokens.is_empty()
    }

    pub fn tokens(&self, doc: u32) -> usize {
        self.doc_tokens[doc as usize]
    }

    pub fn mean_tokens(&self) -> f64 {
        self.doc_tokens.iter().sum::<usize>() as f64
            / self.doc_tokens.len().max(1) as f64
    }

    pub fn all_tokens(&self) -> &[usize] {
        &self.doc_tokens
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wikipedia_mean_matches_fig3() {
        let c = Corpus::wikipedia_like(50_000, 1);
        let mean = c.mean_tokens();
        // Paper: average document length 3718 tokens.
        assert!(
            (3000.0..4500.0).contains(&mean),
            "mean {mean} should be near 3718"
        );
    }

    #[test]
    fn wikipedia_is_long_tailed() {
        let c = Corpus::wikipedia_like(50_000, 2);
        let mut v = c.all_tokens().to_vec();
        v.sort_unstable();
        let median = v[v.len() / 2] as f64;
        let mean = c.mean_tokens();
        assert!(mean > median, "long tail: mean {mean} > median {median}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Corpus::wikipedia_like(100, 7);
        let b = Corpus::wikipedia_like(100, 7);
        assert_eq!(a.all_tokens(), b.all_tokens());
        let c = Corpus::wikipedia_like(100, 8);
        assert_ne!(a.all_tokens(), c.all_tokens());
    }

    #[test]
    fn tiny_fits_buckets() {
        let c = Corpus::tiny(100, 3);
        assert!(c.all_tokens().iter().all(|&t| (16..=96).contains(&t)));
    }
}
