//! Request traces: Poisson arrivals over a dataset profile + corpus.

use super::corpus::Corpus;
use super::datasets::DatasetProfile;
use crate::util::json::Json;
use crate::util::Rng;

/// One request in a trace.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    pub id: u64,
    /// Arrival time, seconds from trace start.
    pub arrival: f64,
    /// Retrieved document sequence (most relevant first) — what the
    /// vector search *will* return for this request.
    pub docs: Vec<u32>,
    /// Token count of each document.
    pub doc_tokens: Vec<usize>,
    /// Question length in tokens.
    pub request_tokens: usize,
    /// Output tokens to generate (>= 1).
    pub output_tokens: usize,
}

impl TraceRequest {
    /// Total injected-prompt tokens (documents + question).
    pub fn prompt_tokens(&self) -> usize {
        self.doc_tokens.iter().sum::<usize>() + self.request_tokens
    }
}

/// A generated workload trace.
#[derive(Debug, Clone)]
pub struct Trace {
    pub dataset: String,
    pub rate: f64,
    pub requests: Vec<TraceRequest>,
}

impl Trace {
    /// Generate `num_requests` Poisson arrivals at `rate` req/s over the
    /// dataset's popularity profile (§7 Workloads: questions sampled per
    /// the §3.2 distribution, shuffled, Poisson arrival times).
    ///
    /// Uses the paper's default prompt budget (4096 tokens — the LLaMA2
    /// context window, which also bounds batch-4 KV on a 24 GiB A10G).
    pub fn generate(
        profile: &DatasetProfile,
        corpus: &Corpus,
        rate: f64,
        num_requests: usize,
        top_k: usize,
        seed: u64,
    ) -> Trace {
        Self::generate_with_budget(
            profile,
            corpus,
            rate,
            num_requests,
            top_k,
            4096,
            seed,
        )
    }

    /// As [`Trace::generate`] with an explicit prompt-token budget:
    /// injected documents are truncated evenly so the prompt fits the
    /// model context (the paper truncates documents "to fit within GPU
    /// capacity limits", §7.2).
    pub fn generate_with_budget(
        profile: &DatasetProfile,
        corpus: &Corpus,
        rate: f64,
        num_requests: usize,
        top_k: usize,
        max_prompt_tokens: usize,
        seed: u64,
    ) -> Trace {
        let mut rng = Rng::new(seed);
        let sampler = profile.popularity(corpus.len());
        let mut t = 0.0;
        let mut requests = Vec::with_capacity(num_requests);
        for id in 0..num_requests as u64 {
            t += rng.exponential(rate);
            let primary = sampler.sample(&mut rng);
            let docs = sampler.doc_sequence(primary, top_k);
            let request_tokens = profile.sample_request_tokens(&mut rng);
            // Even per-document truncation to fit the budget, with a
            // fixed question reserve. The cap is a function of
            // (budget, k) only — NOT of this request's question length —
            // so a document's truncated length (and thus its KV) is
            // identical across requests, preserving reusability.
            const QUESTION_RESERVE: usize = 256;
            let per_doc_cap = max_prompt_tokens
                .saturating_sub(QUESTION_RESERVE)
                .checked_div(top_k)
                .unwrap_or(usize::MAX)
                .max(32);
            let doc_tokens = docs
                .iter()
                .map(|&d| corpus.tokens(d).min(per_doc_cap))
                .collect();
            requests.push(TraceRequest {
                id,
                arrival: t,
                docs,
                doc_tokens,
                request_tokens,
                output_tokens: profile.sample_output_tokens(&mut rng),
            });
        }
        Trace {
            dataset: profile.name.to_string(),
            rate,
            requests,
        }
    }

    pub fn duration(&self) -> f64 {
        self.requests.last().map_or(0.0, |r| r.arrival)
    }

    /// Serialise for the record/replay tooling and the server protocol.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::str(self.dataset.clone())),
            ("rate", Json::num(self.rate)),
            (
                "requests",
                Json::Arr(
                    self.requests
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("id", Json::num(r.id as f64)),
                                ("arrival", Json::num(r.arrival)),
                                (
                                    "docs",
                                    Json::Arr(
                                        r.docs
                                            .iter()
                                            .map(|&d| Json::num(d as f64))
                                            .collect(),
                                    ),
                                ),
                                (
                                    "doc_tokens",
                                    Json::Arr(
                                        r.doc_tokens
                                            .iter()
                                            .map(|&t| Json::num(t as f64))
                                            .collect(),
                                    ),
                                ),
                                (
                                    "request_tokens",
                                    Json::num(r.request_tokens as f64),
                                ),
                                (
                                    "output_tokens",
                                    Json::num(r.output_tokens as f64),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Trace> {
        use anyhow::anyhow;
        let dataset = v
            .get("dataset")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("trace: dataset"))?
            .to_string();
        let rate = v
            .get("rate")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("trace: rate"))?;
        let mut requests = Vec::new();
        for r in v
            .get("requests")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("trace: requests"))?
        {
            let nums = |key: &str| -> anyhow::Result<Vec<usize>> {
                r.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("trace: {key}"))?
                    .iter()
                    .map(|x| {
                        x.as_usize().ok_or_else(|| anyhow!("trace: {key}"))
                    })
                    .collect()
            };
            requests.push(TraceRequest {
                id: r
                    .get("id")
                    .and_then(Json::as_u64)
                    .ok_or_else(|| anyhow!("trace: id"))?,
                arrival: r
                    .get("arrival")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("trace: arrival"))?,
                docs: nums("docs")?.into_iter().map(|d| d as u32).collect(),
                doc_tokens: nums("doc_tokens")?,
                request_tokens: r
                    .get("request_tokens")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("trace: request_tokens"))?,
                output_tokens: r
                    .get("output_tokens")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("trace: output_tokens"))?,
            });
        }
        Ok(Trace {
            dataset,
            rate,
            requests,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::datasets::MMLU;

    fn small_trace() -> Trace {
        let corpus = Corpus::tiny(64, 1);
        Trace::generate(&MMLU, &corpus, 2.0, 100, 2, 7)
    }

    #[test]
    fn arrivals_increasing_and_rate_plausible() {
        let t = small_trace();
        assert_eq!(t.requests.len(), 100);
        for w in t.requests.windows(2) {
            assert!(w[0].arrival < w[1].arrival);
        }
        // 100 requests at 2/s should span roughly 50s.
        assert!((25.0..100.0).contains(&t.duration()), "{}", t.duration());
    }

    #[test]
    fn docs_match_corpus_tokens() {
        let corpus = Corpus::tiny(64, 1);
        let t = Trace::generate(&MMLU, &corpus, 1.0, 50, 3, 8);
        for r in &t.requests {
            assert_eq!(r.docs.len(), 3);
            for (i, &d) in r.docs.iter().enumerate() {
                assert_eq!(r.doc_tokens[i], corpus.tokens(d));
            }
            assert!(r.output_tokens >= 1);
            assert!(r.prompt_tokens() > r.request_tokens);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let corpus = Corpus::tiny(64, 1);
        let a = Trace::generate(&MMLU, &corpus, 1.0, 20, 2, 9);
        let b = Trace::generate(&MMLU, &corpus, 1.0, 20, 2, 9);
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.docs, y.docs);
            assert_eq!(x.arrival, y.arrival);
        }
    }

    #[test]
    fn json_roundtrip() {
        let t = small_trace();
        let j = t.to_json();
        let back = Trace::from_json(&j).unwrap();
        assert_eq!(back.requests.len(), t.requests.len());
        assert_eq!(back.requests[5].docs, t.requests[5].docs);
        assert_eq!(back.requests[5].arrival, t.requests[5].arrival);
    }
}
