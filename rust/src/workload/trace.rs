//! Request traces: open-loop arrival processes (Poisson, bursty MMPP,
//! diurnal) over a dataset profile + corpus, optionally multi-tenant.
//!
//! Traces are *open-loop*: arrival timestamps are generated up front,
//! independent of service capacity, so replaying one against a saturated
//! simulator builds real queues (the overload regime admission control
//! is tested in). Multi-tenant traces slice the corpus into contiguous
//! per-tenant document ranges, each with its own calibrated Zipf skew.

use super::corpus::Corpus;
use super::datasets::{DatasetProfile, DocSampler};
use crate::util::json::Json;
use crate::util::Rng;

/// One request in a trace.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    pub id: u64,
    /// Arrival time, seconds from trace start.
    pub arrival: f64,
    /// Owning tenant (0 for single-tenant traces). Tenants own disjoint
    /// contiguous corpus slices, so the tenant id also determines which
    /// shard range this request's documents route to.
    pub tenant: u32,
    /// Retrieved document sequence (most relevant first) — what the
    /// vector search *will* return for this request.
    pub docs: Vec<u32>,
    /// Token count of each document.
    pub doc_tokens: Vec<usize>,
    /// Question length in tokens.
    pub request_tokens: usize,
    /// Output tokens to generate (>= 1).
    pub output_tokens: usize,
}

impl TraceRequest {
    /// Total injected-prompt tokens (documents + question).
    pub fn prompt_tokens(&self) -> usize {
        self.doc_tokens.iter().sum::<usize>() + self.request_tokens
    }
}

/// Arrival-process selection for open-loop trace generation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Homogeneous Poisson at the configured rate (the §7 default).
    Poisson,
    /// Markov-modulated on/off bursts: exponential dwell in an "on"
    /// phase (arrivals at `rate · (on_s + off_s) / on_s`, so the
    /// long-run average stays `rate`) alternating with a silent "off"
    /// phase of mean `off_s`.
    Bursty { on_s: f64, off_s: f64 },
    /// Non-homogeneous Poisson with a sinusoidal rate —
    /// `λ(t) = rate · (1 + amplitude · sin(2πt / period_s))` — sampled
    /// by Lewis–Shedler thinning against `λmax = rate · (1 + amplitude)`.
    Diurnal { period_s: f64, amplitude: f64 },
}

impl ArrivalProcess {
    /// Parse a CLI name with the default shape parameters: bursts dwell
    /// 10 s on / 30 s off (4× rate inside a burst); the diurnal cycle
    /// spans 300 s at ±80 % modulation.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "poisson" => ArrivalProcess::Poisson,
            "bursty" => ArrivalProcess::Bursty {
                on_s: 10.0,
                off_s: 30.0,
            },
            "diurnal" => ArrivalProcess::Diurnal {
                period_s: 300.0,
                amplitude: 0.8,
            },
            other => anyhow::bail!(
                "unknown arrival process '{other}' \
                 (expected poisson|bursty|diurnal)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalProcess::Poisson => "poisson",
            ArrivalProcess::Bursty { .. } => "bursty",
            ArrivalProcess::Diurnal { .. } => "diurnal",
        }
    }
}

/// Knobs of [`Trace::generate_open_loop`] beyond the legacy positional
/// arguments.
#[derive(Debug, Clone)]
pub struct TraceOptions {
    pub top_k: usize,
    /// Prompt budget documents are truncated into (see
    /// [`Trace::generate_with_budget`]).
    pub max_prompt_tokens: usize,
    pub arrivals: ArrivalProcess,
    /// Tenants sharing the trace; each owns a contiguous corpus slice
    /// with its own Zipf skew. 1 = the legacy single-tenant stream.
    pub tenants: usize,
}

impl Default for TraceOptions {
    fn default() -> Self {
        TraceOptions {
            top_k: 2,
            max_prompt_tokens: 4096,
            arrivals: ArrivalProcess::Poisson,
            tenants: 1,
        }
    }
}

/// Stateful arrival-time generator: one `next()` per request, strictly
/// increasing timestamps for every process.
struct ArrivalGen {
    process: ArrivalProcess,
    rate: f64,
    t: f64,
    /// Bursty state: currently in the "on" phase, and when it flips.
    in_on: bool,
    switch_at: f64,
}

impl ArrivalGen {
    fn new(process: ArrivalProcess, rate: f64, rng: &mut Rng) -> Self {
        let switch_at = match process {
            ArrivalProcess::Bursty { on_s, .. } => {
                rng.exponential(1.0 / on_s)
            }
            _ => f64::INFINITY,
        };
        ArrivalGen {
            process,
            rate,
            t: 0.0,
            in_on: true,
            switch_at,
        }
    }

    fn next(&mut self, rng: &mut Rng) -> f64 {
        match self.process {
            ArrivalProcess::Poisson => {
                self.t += rng.exponential(self.rate);
            }
            ArrivalProcess::Bursty { on_s, off_s } => {
                let rate_on = self.rate * (on_s + off_s) / on_s;
                loop {
                    if self.in_on {
                        // Memorylessness lets us discard the partial
                        // inter-arrival draw at a phase switch.
                        let dt = rng.exponential(rate_on);
                        if self.t + dt <= self.switch_at {
                            self.t += dt;
                            break;
                        }
                        self.t = self.switch_at;
                        self.in_on = false;
                        self.switch_at =
                            self.t + rng.exponential(1.0 / off_s);
                    } else {
                        self.t = self.switch_at;
                        self.in_on = true;
                        self.switch_at =
                            self.t + rng.exponential(1.0 / on_s);
                    }
                }
            }
            ArrivalProcess::Diurnal {
                period_s,
                amplitude,
            } => {
                let lambda_max = self.rate * (1.0 + amplitude);
                loop {
                    self.t += rng.exponential(lambda_max);
                    let phase = 2.0 * std::f64::consts::PI * self.t
                        / period_s;
                    let lam =
                        self.rate * (1.0 + amplitude * phase.sin());
                    if lam > 0.0 && rng.chance(lam / lambda_max) {
                        break;
                    }
                }
            }
        }
        self.t
    }
}

/// One tenant's view of the corpus: a popularity sampler over its slice
/// plus the slice's base document id.
struct TenantPlan {
    sampler: DocSampler,
    doc_base: u32,
}

/// Contiguous `(start, len)` corpus slices for `tenants` tenants:
/// `n / tenants` docs each with the remainder spread from the front —
/// the single source of truth shared by the trace sampler
/// ([`tenant_plans`]) and the CAG corpus-fit metadata
/// ([`tenant_corpora`]), so the two views can never disagree on who
/// owns a document.
fn tenant_slices(n: usize, tenants: usize) -> Vec<(usize, usize)> {
    let base = n / tenants;
    let rem = n % tenants;
    let mut start = 0usize;
    (0..tenants)
        .map(|t| {
            let len = base + usize::from(t < rem);
            let s = start;
            start += len;
            (s, len)
        })
        .collect()
}

/// Even per-document truncation cap: a function of `(budget, top_k)`
/// only — NOT of a request's question length — so a document's
/// truncated length (and thus its KV) is identical across requests AND
/// across the trace / corpus-metadata views of the same options.
fn per_doc_cap(opts: &TraceOptions) -> usize {
    const QUESTION_RESERVE: usize = 256;
    opts.max_prompt_tokens
        .saturating_sub(QUESTION_RESERVE)
        .checked_div(opts.top_k)
        .unwrap_or(usize::MAX)
        .max(32)
}

/// Per-tenant corpus-fit metadata for the CAG admission policy
/// (`--cag auto`): the tenant's contiguous corpus slice with each
/// document's TRUNCATED token count — the same per-doc cap the trace
/// generator applies, so the corpus KV sized from this is exactly the
/// KV the tenant's requests would carry.
#[derive(Debug, Clone)]
pub struct TenantCorpus {
    pub tenant: u32,
    /// First document id of the slice.
    pub doc_base: u32,
    /// Truncated token count of each slice document, in doc-id order
    /// (`doc_base + i`).
    pub doc_tokens: Vec<usize>,
}

impl TenantCorpus {
    /// Total corpus tokens after truncation.
    pub fn total_tokens(&self) -> usize {
        self.doc_tokens.iter().sum()
    }

    /// Page-rounded KV bytes of the whole slice — the corpus-fit number
    /// the CAG pin budget is checked against.
    pub fn kv_bytes(&self, page: crate::kvcache::PageSpec) -> u64 {
        self.doc_tokens.iter().map(|&t| page.bytes(t)).sum()
    }
}

/// The per-tenant corpus slices a trace with these options draws from
/// (single tenant: one slice covering the whole corpus).
pub fn tenant_corpora(
    corpus: &Corpus,
    opts: &TraceOptions,
) -> Vec<TenantCorpus> {
    let tenants = opts.tenants.max(1);
    let cap = per_doc_cap(opts);
    tenant_slices(corpus.len(), tenants)
        .into_iter()
        .enumerate()
        .map(|(t, (start, len))| TenantCorpus {
            tenant: t as u32,
            doc_base: start as u32,
            doc_tokens: (start..start + len)
                .map(|d| corpus.tokens(d as u32).min(cap))
                .collect(),
        })
        .collect()
}

fn tenant_plans(
    profile: &DatasetProfile,
    corpus: &Corpus,
    tenants: usize,
    top_k: usize,
) -> Vec<TenantPlan> {
    if tenants <= 1 {
        // Exactly the legacy sampler: single-tenant traces must be
        // bit-identical to what `generate` always produced.
        return vec![TenantPlan {
            sampler: profile.popularity(corpus.len()),
            doc_base: 0,
        }];
    }
    let n = corpus.len();
    assert!(
        n >= tenants * top_k,
        "corpus of {n} docs cannot give {tenants} tenants top-{top_k} \
         sequences from disjoint slices"
    );
    tenant_slices(n, tenants)
        .into_iter()
        .enumerate()
        .map(|(t, (start, len))| {
            // Deterministic per-tenant skew spread around the dataset's
            // calibrated mass: tenants t ≡ 0..3 (mod 4) get offsets
            // −0.12, −0.04, +0.04, +0.12 — hot and cool tenants coexist
            // in one trace, which is what per-tenant SLO breakdowns
            // (and the cross-shard rebalancer) are exercised by.
            let off = 0.08 * ((t % 4) as f64 - 1.5);
            let mass = (profile.skew_mass + off).clamp(0.2, 0.85);
            TenantPlan {
                sampler: profile.popularity_with_skew(len, mass),
                doc_base: start as u32,
            }
        })
        .collect()
}

/// A generated workload trace.
#[derive(Debug, Clone)]
pub struct Trace {
    pub dataset: String,
    pub rate: f64,
    pub requests: Vec<TraceRequest>,
}

impl Trace {
    /// Generate `num_requests` Poisson arrivals at `rate` req/s over the
    /// dataset's popularity profile (§7 Workloads: questions sampled per
    /// the §3.2 distribution, shuffled, Poisson arrival times).
    ///
    /// Uses the paper's default prompt budget (4096 tokens — the LLaMA2
    /// context window, which also bounds batch-4 KV on a 24 GiB A10G).
    pub fn generate(
        profile: &DatasetProfile,
        corpus: &Corpus,
        rate: f64,
        num_requests: usize,
        top_k: usize,
        seed: u64,
    ) -> Trace {
        Self::generate_with_budget(
            profile,
            corpus,
            rate,
            num_requests,
            top_k,
            4096,
            seed,
        )
    }

    /// As [`Trace::generate`] with an explicit prompt-token budget:
    /// injected documents are truncated evenly so the prompt fits the
    /// model context (the paper truncates documents "to fit within GPU
    /// capacity limits", §7.2).
    pub fn generate_with_budget(
        profile: &DatasetProfile,
        corpus: &Corpus,
        rate: f64,
        num_requests: usize,
        top_k: usize,
        max_prompt_tokens: usize,
        seed: u64,
    ) -> Trace {
        Self::generate_open_loop(
            profile,
            corpus,
            rate,
            num_requests,
            &TraceOptions {
                top_k,
                max_prompt_tokens,
                ..TraceOptions::default()
            },
            seed,
        )
    }

    /// The full open-loop generator: any [`ArrivalProcess`], any tenant
    /// count. With `{poisson, 1 tenant}` the RNG consumption sequence is
    /// exactly the historical [`Trace::generate`] one — per request:
    /// inter-arrival, primary doc, question length, output length — so
    /// legacy traces stay bit-identical under the same seed (pinned by
    /// this module's tests).
    pub fn generate_open_loop(
        profile: &DatasetProfile,
        corpus: &Corpus,
        rate: f64,
        num_requests: usize,
        opts: &TraceOptions,
        seed: u64,
    ) -> Trace {
        let mut rng = Rng::new(seed);
        let tenants = opts.tenants.max(1);
        let plans = tenant_plans(profile, corpus, tenants, opts.top_k);
        let mut arrivals = ArrivalGen::new(opts.arrivals, rate, &mut rng);
        let mut requests = Vec::with_capacity(num_requests);
        for id in 0..num_requests as u64 {
            let t = arrivals.next(&mut rng);
            // Tenant selection consumes randomness ONLY in multi-tenant
            // traces (single-tenant must keep the legacy RNG stream).
            let tenant = if tenants > 1 {
                rng.index(tenants) as u32
            } else {
                0
            };
            let plan = &plans[tenant as usize];
            let primary = plan.sampler.sample(&mut rng);
            let docs: Vec<u32> = plan
                .sampler
                .doc_sequence(primary, opts.top_k)
                .into_iter()
                .map(|d| plan.doc_base + d)
                .collect();
            let request_tokens = profile.sample_request_tokens(&mut rng);
            // Even per-document truncation to fit the budget, with a
            // fixed question reserve (see [`per_doc_cap`] — shared with
            // the CAG corpus-fit metadata so both size the same KV).
            let cap = per_doc_cap(opts);
            let doc_tokens = docs
                .iter()
                .map(|&d| corpus.tokens(d).min(cap))
                .collect();
            requests.push(TraceRequest {
                id,
                arrival: t,
                tenant,
                docs,
                doc_tokens,
                request_tokens,
                output_tokens: profile.sample_output_tokens(&mut rng),
            });
        }
        Trace {
            dataset: profile.name.to_string(),
            rate,
            requests,
        }
    }

    /// Tenants present in this trace (max id + 1); 1 when empty.
    pub fn num_tenants(&self) -> usize {
        self.requests
            .iter()
            .map(|r| r.tenant as usize + 1)
            .max()
            .unwrap_or(1)
    }

    /// Trace horizon: the last arrival. Open-loop audit: requests are
    /// generated in increasing time, but replay/merge tooling may
    /// reorder them — take the max rather than trusting the tail.
    pub fn duration(&self) -> f64 {
        self.requests
            .iter()
            .map(|r| r.arrival)
            .fold(0.0, f64::max)
    }

    /// Serialise for the record/replay tooling and the server protocol.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::str(self.dataset.clone())),
            ("rate", Json::num(self.rate)),
            (
                "requests",
                Json::Arr(
                    self.requests
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("id", Json::num(r.id as f64)),
                                ("arrival", Json::num(r.arrival)),
                                ("tenant", Json::num(r.tenant as f64)),
                                (
                                    "docs",
                                    Json::Arr(
                                        r.docs
                                            .iter()
                                            .map(|&d| Json::num(d as f64))
                                            .collect(),
                                    ),
                                ),
                                (
                                    "doc_tokens",
                                    Json::Arr(
                                        r.doc_tokens
                                            .iter()
                                            .map(|&t| Json::num(t as f64))
                                            .collect(),
                                    ),
                                ),
                                (
                                    "request_tokens",
                                    Json::num(r.request_tokens as f64),
                                ),
                                (
                                    "output_tokens",
                                    Json::num(r.output_tokens as f64),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> anyhow::Result<Trace> {
        use anyhow::anyhow;
        let dataset = v
            .get("dataset")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("trace: dataset"))?
            .to_string();
        let rate = v
            .get("rate")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow!("trace: rate"))?;
        let mut requests = Vec::new();
        for r in v
            .get("requests")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("trace: requests"))?
        {
            let nums = |key: &str| -> anyhow::Result<Vec<usize>> {
                r.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("trace: {key}"))?
                    .iter()
                    .map(|x| {
                        x.as_usize().ok_or_else(|| anyhow!("trace: {key}"))
                    })
                    .collect()
            };
            requests.push(TraceRequest {
                id: r
                    .get("id")
                    .and_then(Json::as_u64)
                    .ok_or_else(|| anyhow!("trace: id"))?,
                arrival: r
                    .get("arrival")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("trace: arrival"))?,
                // Absent in traces recorded before multi-tenancy.
                tenant: r
                    .get("tenant")
                    .and_then(Json::as_u64)
                    .unwrap_or(0) as u32,
                docs: nums("docs")?.into_iter().map(|d| d as u32).collect(),
                doc_tokens: nums("doc_tokens")?,
                request_tokens: r
                    .get("request_tokens")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("trace: request_tokens"))?,
                output_tokens: r
                    .get("output_tokens")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("trace: output_tokens"))?,
            });
        }
        Ok(Trace {
            dataset,
            rate,
            requests,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::datasets::MMLU;

    fn small_trace() -> Trace {
        let corpus = Corpus::tiny(64, 1);
        Trace::generate(&MMLU, &corpus, 2.0, 100, 2, 7)
    }

    #[test]
    fn arrivals_increasing_and_rate_plausible() {
        let t = small_trace();
        assert_eq!(t.requests.len(), 100);
        for w in t.requests.windows(2) {
            assert!(w[0].arrival < w[1].arrival);
        }
        // 100 requests at 2/s should span roughly 50s.
        assert!((25.0..100.0).contains(&t.duration()), "{}", t.duration());
    }

    #[test]
    fn docs_match_corpus_tokens() {
        let corpus = Corpus::tiny(64, 1);
        let t = Trace::generate(&MMLU, &corpus, 1.0, 50, 3, 8);
        for r in &t.requests {
            assert_eq!(r.docs.len(), 3);
            for (i, &d) in r.docs.iter().enumerate() {
                assert_eq!(r.doc_tokens[i], corpus.tokens(d));
            }
            assert!(r.output_tokens >= 1);
            assert!(r.prompt_tokens() > r.request_tokens);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let corpus = Corpus::tiny(64, 1);
        let a = Trace::generate(&MMLU, &corpus, 1.0, 20, 2, 9);
        let b = Trace::generate(&MMLU, &corpus, 1.0, 20, 2, 9);
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.docs, y.docs);
            assert_eq!(x.arrival, y.arrival);
        }
    }

    #[test]
    fn json_roundtrip() {
        let t = small_trace();
        let j = t.to_json();
        let back = Trace::from_json(&j).unwrap();
        assert_eq!(back.requests.len(), t.requests.len());
        assert_eq!(back.requests[5].docs, t.requests[5].docs);
        assert_eq!(back.requests[5].arrival, t.requests[5].arrival);
    }

    fn open_loop(arrivals: ArrivalProcess, tenants: usize) -> Trace {
        let corpus = Corpus::tiny(64, 1);
        Trace::generate_open_loop(
            &MMLU,
            &corpus,
            2.0,
            120,
            &TraceOptions {
                arrivals,
                tenants,
                ..TraceOptions::default()
            },
            21,
        )
    }

    /// `--shed off` conformance rests on this: the generalized open-loop
    /// generator with {poisson, 1 tenant} must reproduce the historical
    /// `generate` stream bit for bit.
    #[test]
    fn open_loop_poisson_matches_legacy_generate() {
        let corpus = Corpus::tiny(64, 1);
        let legacy = Trace::generate(&MMLU, &corpus, 2.0, 80, 2, 5);
        let open = Trace::generate_open_loop(
            &MMLU,
            &corpus,
            2.0,
            80,
            &TraceOptions::default(),
            5,
        );
        assert_eq!(legacy.requests.len(), open.requests.len());
        for (a, b) in legacy.requests.iter().zip(&open.requests) {
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
            assert_eq!(a.docs, b.docs);
            assert_eq!(a.doc_tokens, b.doc_tokens);
            assert_eq!(a.request_tokens, b.request_tokens);
            assert_eq!(a.output_tokens, b.output_tokens);
            assert_eq!(b.tenant, 0);
        }
    }

    /// Satellite: same seed → bit-identical trace for the new arrival
    /// generators, surviving a JSON round trip.
    #[test]
    fn bursty_and_diurnal_deterministic_per_seed() {
        for arrivals in [
            ArrivalProcess::parse("bursty").unwrap(),
            ArrivalProcess::parse("diurnal").unwrap(),
        ] {
            let a = open_loop(arrivals, 4);
            let b = open_loop(arrivals, 4);
            let back = Trace::from_json(&a.to_json()).unwrap();
            for ((x, y), z) in
                a.requests.iter().zip(&b.requests).zip(&back.requests)
            {
                assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
                assert_eq!(x.arrival.to_bits(), z.arrival.to_bits());
                assert_eq!(x.docs, y.docs);
                assert_eq!(x.docs, z.docs);
                assert_eq!(x.tenant, y.tenant);
                assert_eq!(x.tenant, z.tenant);
            }
            // And the serialised form itself is identical.
            assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        }
    }

    #[test]
    fn arrivals_strictly_increase_for_all_processes() {
        for name in ["poisson", "bursty", "diurnal"] {
            let t =
                open_loop(ArrivalProcess::parse(name).unwrap(), 1);
            assert_eq!(t.requests.len(), 120, "{name}");
            for w in t.requests.windows(2) {
                assert!(w[0].arrival < w[1].arrival, "{name}");
            }
            assert!(t.duration() > 0.0);
        }
        assert!(ArrivalProcess::parse("weibull").is_err());
    }

    #[test]
    fn bursty_bunches_arrivals() {
        // MMPP must produce more short gaps AND more long gaps than the
        // flat Poisson stream — dispersion, the point of burstiness.
        let gaps = |t: &Trace| -> Vec<f64> {
            t.requests
                .windows(2)
                .map(|w| w[1].arrival - w[0].arrival)
                .collect()
        };
        let p = gaps(&open_loop(ArrivalProcess::Poisson, 1));
        let b = gaps(&open_loop(
            ArrivalProcess::parse("bursty").unwrap(),
            1,
        ));
        let var = |xs: &[f64]| {
            let m = xs.iter().sum::<f64>() / xs.len() as f64;
            xs.iter().map(|x| (x - m).powi(2)).sum::<f64>()
                / xs.len() as f64
        };
        assert!(
            var(&b) > var(&p) * 1.5,
            "bursty inter-arrival variance {} !> poisson {}",
            var(&b),
            var(&p)
        );
    }

    #[test]
    fn tenants_own_disjoint_corpus_slices() {
        let corpus = Corpus::tiny(64, 1);
        let t = open_loop(ArrivalProcess::Poisson, 4);
        // 64 docs / 4 tenants → 16-doc slices.
        let mut seen = [false; 4];
        for r in &t.requests {
            assert!((r.tenant as usize) < 4);
            seen[r.tenant as usize] = true;
            assert_eq!(r.doc_tokens.len(), r.docs.len());
            for &d in &r.docs {
                let slice = d / 16;
                assert_eq!(
                    slice, r.tenant,
                    "doc {d} outside tenant {} slice",
                    r.tenant
                );
                assert!(corpus.tokens(d) > 0);
            }
        }
        assert!(seen.iter().all(|&s| s), "all tenants drew traffic");
        assert_eq!(t.num_tenants(), 4);
        assert_eq!(small_trace().num_tenants(), 1);
    }
}
