//! QA-dataset access profiles.
//!
//! The paper characterises four datasets (§3.2, Fig. 5): the retrieval
//! pattern is skewed — for MMLU the top 3% of documents serve ~60% of
//! requests (20× denser than uniform). Each profile here calibrates a
//! Zipf exponent to the paper's reported skew and carries the §7 request
//! and output length distributions (MMLU answers are a single token; NQ
//! answers average 6 tokens with p99 ≤ 32).

use crate::util::rng::Zipf;
use crate::util::Rng;

/// Access-pattern profile of one QA dataset.
#[derive(Debug, Clone)]
pub struct DatasetProfile {
    pub name: &'static str,
    /// Fraction of documents (`skew_frac`) that receive `skew_mass` of
    /// the requests — the paper's skew statement.
    pub skew_frac: f64,
    pub skew_mass: f64,
    /// Mean request (question) length in tokens.
    pub request_tokens_mean: f64,
    /// Output-length distribution: (mean, max).
    pub output_mean: f64,
    pub output_max: usize,
}

pub const MMLU: DatasetProfile = DatasetProfile {
    name: "mmlu",
    skew_frac: 0.03,
    skew_mass: 0.60,
    request_tokens_mean: 72.0,
    output_mean: 1.0,
    output_max: 1,
};

pub const NATURAL_QUESTIONS: DatasetProfile = DatasetProfile {
    name: "nq",
    skew_frac: 0.03,
    skew_mass: 0.42,
    request_tokens_mean: 16.0,
    output_mean: 6.0,
    output_max: 32,
};

pub const HOTPOTQA: DatasetProfile = DatasetProfile {
    name: "hotpotqa",
    skew_frac: 0.03,
    skew_mass: 0.50,
    request_tokens_mean: 28.0,
    output_mean: 4.0,
    output_max: 24,
};

pub const TRIVIAQA: DatasetProfile = DatasetProfile {
    name: "triviaqa",
    skew_frac: 0.03,
    skew_mass: 0.55,
    request_tokens_mean: 20.0,
    output_mean: 3.0,
    output_max: 16,
};

pub const ALL_DATASETS: &[&DatasetProfile] =
    &[&MMLU, &NATURAL_QUESTIONS, &HOTPOTQA, &TRIVIAQA];

impl DatasetProfile {
    pub fn lookup(name: &str) -> anyhow::Result<&'static DatasetProfile> {
        for &d in ALL_DATASETS {
            if d.name == name {
                return Ok(d);
            }
        }
        anyhow::bail!("unknown dataset '{name}'")
    }

    /// Build the calibrated document-popularity sampler over `num_docs`.
    /// Rank r is mapped to a pseudo-random document id so popular docs are
    /// spread across the id space (as embedding-based retrieval would).
    ///
    /// Calibration is O(num_docs × bisection-steps) worth of `powf`, so
    /// samplers are memoised per (dataset, num_docs) — benches build many
    /// traces over the same corpus (§Perf).
    pub fn popularity(&self, num_docs: usize) -> DocSampler {
        use std::collections::HashMap;
        use std::sync::{Arc, Mutex, OnceLock};
        static CACHE: OnceLock<
            Mutex<HashMap<(&'static str, usize), Arc<Zipf>>>,
        > = OnceLock::new();
        let key = (self.name, num_docs);
        let zipf = {
            let mut cache = CACHE
                .get_or_init(|| Mutex::new(HashMap::new()))
                .lock()
                .expect("zipf cache");
            if let Some(z) = cache.get(&key) {
                Arc::clone(z)
            } else {
                let s = Zipf::calibrate(
                    num_docs,
                    self.skew_frac,
                    self.skew_mass,
                );
                let z = Arc::new(Zipf::new(num_docs, s));
                cache.insert(key, Arc::clone(&z));
                z
            }
        };
        DocSampler { zipf, num_docs }
    }

    /// Per-tenant variant of [`DatasetProfile::popularity`]: a sampler
    /// over `num_docs` documents with an explicit skew mass (fraction of
    /// requests landing on the top `skew_frac` documents) instead of the
    /// dataset's. Multi-tenant traces give each tenant its own corpus
    /// slice and its own skew, so tenants stress the cache unevenly —
    /// the regime per-tenant SLO breakdowns exist to expose.
    ///
    /// Memoised like `popularity` (keyed by the mass bits as well):
    /// per-tenant calibration re-runs the Zipf bisection otherwise.
    pub fn popularity_with_skew(
        &self,
        num_docs: usize,
        skew_mass: f64,
    ) -> DocSampler {
        use std::collections::HashMap;
        use std::sync::{Arc, Mutex, OnceLock};
        static CACHE: OnceLock<
            Mutex<HashMap<(&'static str, usize, u64), Arc<Zipf>>>,
        > = OnceLock::new();
        let key = (self.name, num_docs, skew_mass.to_bits());
        let zipf = {
            let mut cache = CACHE
                .get_or_init(|| Mutex::new(HashMap::new()))
                .lock()
                .expect("zipf skew cache");
            if let Some(z) = cache.get(&key) {
                Arc::clone(z)
            } else {
                let s =
                    Zipf::calibrate(num_docs, self.skew_frac, skew_mass);
                let z = Arc::new(Zipf::new(num_docs, s));
                cache.insert(key, Arc::clone(&z));
                z
            }
        };
        DocSampler { zipf, num_docs }
    }

    /// Sample a question length (tokens), >= 8.
    pub fn sample_request_tokens(&self, rng: &mut Rng) -> usize {
        let t = rng.normal(self.request_tokens_mean, self.request_tokens_mean * 0.3);
        (t.round() as isize).max(8) as usize
    }

    /// Sample an output length per the §7 distribution.
    pub fn sample_output_tokens(&self, rng: &mut Rng) -> usize {
        if self.output_max <= 1 {
            return 1;
        }
        // Lognormal with the profile mean, clipped to output_max.
        let sigma = 0.8;
        let mu = self.output_mean.ln() - sigma * sigma / 2.0;
        (rng.lognormal(mu, sigma).round() as usize)
            .clamp(1, self.output_max)
    }
}

/// Popularity-ranked document sampler.
#[derive(Debug, Clone)]
pub struct DocSampler {
    zipf: std::sync::Arc<Zipf>,
    num_docs: usize,
}

impl DocSampler {
    /// Sample a primary document id.
    pub fn sample(&self, rng: &mut Rng) -> u32 {
        let rank = self.zipf.sample(rng);
        self.rank_to_doc(rank)
    }

    /// Deterministic rank→doc shuffling (splitmix-style hash).
    pub fn rank_to_doc(&self, rank: usize) -> u32 {
        let mut x = rank as u64 ^ 0x5851_F42D_4C95_7F2D;
        x ^= x >> 30;
        x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        x ^= x >> 27;
        x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
        x ^= x >> 31;
        (x % self.num_docs as u64) as u32
    }

    /// The deterministic retrieved-document sequence for a request whose
    /// top document is `primary`: the paper's top-k injection. Related
    /// documents are a pure function of the primary, so requests hitting
    /// the same topic share the whole ordered sequence (which is what
    /// knowledge-tree paths cache).
    pub fn doc_sequence(&self, primary: u32, k: usize) -> Vec<u32> {
        let mut docs = Vec::with_capacity(k);
        docs.push(primary);
        let mut x = primary as u64;
        while docs.len() < k {
            x = x
                .wrapping_mul(0xD129_0D3B_3E62_394B)
                .wrapping_add(0x9E37_79B9_7F4A_7C15);
            let cand = ((x >> 16) % self.num_docs as u64) as u32;
            if !docs.contains(&cand) {
                docs.push(cand);
            }
        }
        docs
    }

    pub fn num_docs(&self) -> usize {
        self.num_docs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::{access_cdf, cdf_at};

    #[test]
    fn mmlu_skew_matches_paper() {
        // Fig. 5: top 3% of docs referred to by ~60% of MMLU requests.
        let sampler = MMLU.popularity(10_000);
        let mut rng = Rng::new(1);
        let mut counts = vec![0u64; 10_000];
        for _ in 0..200_000 {
            counts[sampler.sample(&mut rng) as usize] += 1;
        }
        let cdf = access_cdf(&counts);
        let top3 = cdf_at(&cdf, 0.03);
        assert!(
            (0.55..0.65).contains(&top3),
            "top-3% mass {top3}, paper says ~0.60"
        );
    }

    #[test]
    fn datasets_ordered_by_skew() {
        // MMLU most skewed, NQ least (drives the Fig. 13 vs 14 gap).
        let mut rng = Rng::new(2);
        let masses: Vec<f64> = [&MMLU, &TRIVIAQA, &HOTPOTQA, &NATURAL_QUESTIONS]
            .iter()
            .map(|d| {
                let s = d.popularity(5_000);
                let mut counts = vec![0u64; 5_000];
                for _ in 0..50_000 {
                    counts[s.sample(&mut rng) as usize] += 1;
                }
                cdf_at(&access_cdf(&counts), 0.03)
            })
            .collect();
        assert!(masses[0] > masses[1]);
        assert!(masses[1] > masses[2]);
        assert!(masses[2] > masses[3]);
    }

    #[test]
    fn doc_sequence_deterministic_and_distinct() {
        let s = MMLU.popularity(1000);
        let a = s.doc_sequence(42, 5);
        let b = s.doc_sequence(42, 5);
        assert_eq!(a, b);
        assert_eq!(a.len(), 5);
        let mut uniq = a.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 5, "no duplicate docs in sequence");
        assert_ne!(a, s.doc_sequence(43, 5));
    }

    #[test]
    fn output_lengths_respect_caps() {
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            assert_eq!(MMLU.sample_output_tokens(&mut rng), 1);
            let nq = NATURAL_QUESTIONS.sample_output_tokens(&mut rng);
            assert!((1..=32).contains(&nq));
        }
        // NQ mean close to 6 (paper §7).
        let mean: f64 = (0..20_000)
            .map(|_| NATURAL_QUESTIONS.sample_output_tokens(&mut rng) as f64)
            .sum::<f64>()
            / 20_000.0;
        assert!((4.0..8.0).contains(&mean), "NQ output mean {mean}");
    }

    #[test]
    fn per_tenant_skew_sampler_varies_mass() {
        // Multi-tenant traces calibrate one sampler per tenant with its
        // own skew mass; more mass must measurably concentrate access.
        let hot = MMLU.popularity_with_skew(5_000, 0.75);
        let cool = MMLU.popularity_with_skew(5_000, 0.35);
        let mut rng = Rng::new(4);
        let mass = |s: &DocSampler, rng: &mut Rng| {
            let mut counts = vec![0u64; 5_000];
            for _ in 0..50_000 {
                counts[s.sample(rng) as usize] += 1;
            }
            cdf_at(&access_cdf(&counts), 0.03)
        };
        let hot_mass = mass(&hot, &mut rng);
        let cool_mass = mass(&cool, &mut rng);
        assert!(
            hot_mass > cool_mass + 0.1,
            "top-3% mass {hot_mass} should exceed {cool_mass}"
        );
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(DatasetProfile::lookup("mmlu").unwrap().name, "mmlu");
        assert!(DatasetProfile::lookup("squad").is_err());
    }
}
