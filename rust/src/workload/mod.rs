//! Workload generation: synthetic corpus, QA-dataset access profiles and
//! open-loop arrival traces — Poisson, bursty (MMPP), diurnal — with
//! optional multi-tenant corpus slicing (paper §3.2 characterization and
//! §7 workloads).

pub mod corpus;
pub mod datasets;
pub mod trace;

pub use corpus::Corpus;
pub use datasets::DatasetProfile;
pub use trace::{
    tenant_corpora, ArrivalProcess, TenantCorpus, Trace, TraceOptions,
    TraceRequest,
};
