//! PJRT executor: compile HLO-text buckets once, run prefills on the
//! request path.
//!
//! Follows the reference wiring in /opt/xla-example/load_hlo:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`.

use super::manifest::{Bucket, ModelManifest};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;

/// Result of one prefill execution.
#[derive(Debug, Clone)]
pub struct PrefillOutput {
    /// Logits of the last valid token, `(vocab,)`.
    pub last_logits: Vec<f32>,
    /// New KV rows, token-major `(beta_len, L, 2, Hkv, dh)` flattened —
    /// already truncated to the valid `beta_len` rows.
    pub new_kv: Vec<f32>,
}

/// A loaded model: parameters resident as device buffers, one compiled
/// PJRT executable per shape bucket.
pub struct PjrtModel {
    client: xla::PjRtClient,
    manifest: ModelManifest,
    /// Parameter device buffers in ABI order: staged once at load so the
    /// request path never re-transfers weights.
    params: Vec<xla::PjRtBuffer>,
    /// Compiled executables keyed by `(alpha_max, beta)`.
    executables: HashMap<(usize, usize), xla::PjRtLoadedExecutable>,
}

impl PjrtModel {
    /// Load parameters and compile every bucket of `model_name` from the
    /// artifact directory. Compilation happens once here, never on the
    /// request path.
    pub fn load(manifest: &ModelManifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let params = load_params(&client, manifest)?;
        let mut executables = HashMap::new();
        for bucket in &manifest.buckets {
            let exe = compile_bucket(&client, bucket)?;
            executables.insert((bucket.alpha_max, bucket.beta), exe);
        }
        Ok(PjrtModel {
            client,
            manifest: manifest.clone(),
            params,
            executables,
        })
    }

    pub fn manifest(&self) -> &ModelManifest {
        &self.manifest
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Run one prefill: `prefix_kv` is the token-major cached prefix
    /// (`alpha` tokens × kv_floats_per_token f32), `tokens` the new token
    /// ids. Returns last-token logits and the new KV rows.
    pub fn prefill(
        &self,
        prefix_kv: &[f32],
        tokens: &[i32],
    ) -> Result<PrefillOutput> {
        let arch = &self.manifest.arch;
        let kv_per_tok = arch.kv_floats_per_token();
        if prefix_kv.len() % kv_per_tok != 0 {
            bail!(
                "prefix_kv length {} not a multiple of kv/token {}",
                prefix_kv.len(),
                kv_per_tok
            );
        }
        let alpha = prefix_kv.len() / kv_per_tok;
        let beta_len = tokens.len();
        if beta_len == 0 {
            bail!("prefill with no tokens");
        }
        let bucket = self
            .manifest
            .pick_bucket(alpha, beta_len)
            .ok_or_else(|| {
                anyhow!(
                    "no bucket fits alpha={alpha}, beta={beta_len} \
                     (max {}x{})",
                    self.manifest.max_alpha(),
                    self.manifest.max_beta()
                )
            })?;
        let exe = &self.executables[&(bucket.alpha_max, bucket.beta)];

        // Assemble inputs: params..., prefix_kv, alpha_len, tokens, beta_len.
        let mut kv_padded = vec![0f32; bucket.alpha_max * kv_per_tok];
        kv_padded[..prefix_kv.len()].copy_from_slice(prefix_kv);
        let kv_buf = self
            .client
            .buffer_from_host_buffer(
                &kv_padded,
                &[
                    bucket.alpha_max,
                    arch.n_layers,
                    2,
                    arch.n_kv_heads,
                    arch.d_head,
                ],
                None,
            )
            .map_err(|e| anyhow!("kv buffer: {e:?}"))?;

        let mut toks_padded = vec![0i32; bucket.beta];
        toks_padded[..beta_len].copy_from_slice(tokens);
        let toks_buf = self
            .client
            .buffer_from_host_buffer(&toks_padded, &[bucket.beta], None)
            .map_err(|e| anyhow!("tokens buffer: {e:?}"))?;

        let alpha_buf = self
            .client
            .buffer_from_host_buffer(&[alpha as i32], &[], None)
            .map_err(|e| anyhow!("alpha buffer: {e:?}"))?;
        let beta_buf = self
            .client
            .buffer_from_host_buffer(&[beta_len as i32], &[], None)
            .map_err(|e| anyhow!("beta buffer: {e:?}"))?;

        let mut inputs: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
        let owned = [kv_buf, alpha_buf, toks_buf, beta_buf];
        inputs.extend(owned.iter());

        let result = exe
            .execute_b(&inputs)
            .map_err(|e| anyhow!("pjrt execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let (logits_lit, kv_lit) = lit
            .to_tuple2()
            .map_err(|e| anyhow!("expected 2-tuple output: {e:?}"))?;

        let last_logits = logits_lit
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits: {e:?}"))?;
        let new_kv_full = kv_lit
            .to_vec::<f32>()
            .map_err(|e| anyhow!("new_kv: {e:?}"))?;
        debug_assert_eq!(new_kv_full.len(), bucket.beta * kv_per_tok);
        let new_kv = new_kv_full[..beta_len * kv_per_tok].to_vec();

        Ok(PrefillOutput {
            last_logits,
            new_kv,
        })
    }

    /// Greedy-decode `steps` tokens starting from `prompt`, reusing the
    /// prefix KV across steps (the same code path the serving example
    /// uses).
    pub fn generate(
        &self,
        prompt: &[i32],
        steps: usize,
    ) -> Result<(Vec<i32>, Vec<f32>)> {
        let kv_per_tok = self.manifest.arch.kv_floats_per_token();
        let mut kv: Vec<f32> = Vec::new();
        let mut out = Vec::new();
        let first = self.prefill(&kv, prompt)?;
        kv.extend_from_slice(&first.new_kv);
        let mut next = argmax(&first.last_logits) as i32;
        out.push(next);
        for _ in 1..steps {
            let step = self.prefill(&kv, &[next])?;
            kv.extend_from_slice(&step.new_kv);
            next = argmax(&step.last_logits) as i32;
            out.push(next);
            if kv.len() / kv_per_tok >= self.manifest.max_alpha() {
                break;
            }
        }
        Ok((out, kv))
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

fn compile_bucket(
    client: &xla::PjRtClient,
    bucket: &Bucket,
) -> Result<xla::PjRtLoadedExecutable> {
    let path = bucket
        .hlo_path
        .to_str()
        .ok_or_else(|| anyhow!("non-utf8 artifact path"))?;
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("parsing {}: {e:?}", path))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compiling {}: {e:?}", path))
}

fn load_params(
    client: &xla::PjRtClient,
    manifest: &ModelManifest,
) -> Result<Vec<xla::PjRtBuffer>> {
    let bytes = std::fs::read(&manifest.params_path).with_context(|| {
        format!("reading {}", manifest.params_path.display())
    })?;
    let want = manifest.param_floats() * 4;
    if bytes.len() != want {
        bail!(
            "param file {} is {} bytes, ABI wants {}",
            manifest.params_path.display(),
            bytes.len(),
            want
        );
    }
    let floats: Vec<f32> = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let mut params = Vec::with_capacity(manifest.param_specs.len());
    let mut offset = 0usize;
    for (_, shape) in &manifest.param_specs {
        let n: usize = shape.iter().product();
        let slice = &floats[offset..offset + n];
        offset += n;
        let buf = client
            .buffer_from_host_buffer(slice, shape, None)
            .map_err(|e| anyhow!("staging param: {e:?}"))?;
        params.push(buf);
    }
    Ok(params)
}

// PJRT-backed tests live in rust/tests/runtime_pjrt.rs (they need the
// artifacts built by `make artifacts`); manifest parsing is covered in
// manifest.rs.
