//! Artifact manifest: the ABI contract between `python/compile/aot.py`
//! and the Rust runtime.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Transformer architecture parameters (mirrors `model.ModelConfig`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelArch {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_q_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
}

impl ModelArch {
    /// f32 elements of KV cache per token:
    /// `layers * 2 * kv_heads * d_head`.
    pub fn kv_floats_per_token(&self) -> usize {
        self.n_layers * 2 * self.n_kv_heads * self.d_head
    }

    /// Bytes of KV cache per token (f32 storage).
    pub fn kv_bytes_per_token(&self) -> usize {
        self.kv_floats_per_token() * 4
    }
}

/// One compiled `(alpha_max, beta)` shape bucket.
#[derive(Debug, Clone)]
pub struct Bucket {
    pub alpha_max: usize,
    pub beta: usize,
    pub hlo_path: PathBuf,
}

/// Everything the runtime needs to load one model.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub name: String,
    pub arch: ModelArch,
    pub params_path: PathBuf,
    /// Ordered `(name, shape)` — the flat parameter ABI.
    pub param_specs: Vec<(String, Vec<usize>)>,
    pub buckets: Vec<Bucket>,
}

impl ModelManifest {
    /// Total parameter element count.
    pub fn param_floats(&self) -> usize {
        self.param_specs
            .iter()
            .map(|(_, s)| s.iter().product::<usize>())
            .sum()
    }

    /// Smallest bucket that fits `(alpha, beta)` tokens, preferring the
    /// least padding waste. None if no bucket is large enough.
    pub fn pick_bucket(&self, alpha: usize, beta: usize) -> Option<&Bucket> {
        self.buckets
            .iter()
            .filter(|b| b.alpha_max >= alpha && b.beta >= beta)
            .min_by_key(|b| (b.alpha_max, b.beta))
    }

    /// Largest prefix capacity across buckets.
    pub fn max_alpha(&self) -> usize {
        self.buckets.iter().map(|b| b.alpha_max).max().unwrap_or(0)
    }

    /// Largest new-token capacity across buckets.
    pub fn max_beta(&self) -> usize {
        self.buckets.iter().map(|b| b.beta).max().unwrap_or(0)
    }
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelManifest>,
}

impl ArtifactManifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        Self::from_json(dir, &v)
    }

    pub fn from_json(dir: &Path, v: &Json) -> Result<Self> {
        let models_json = v
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest: missing 'models'"))?;
        let mut models = BTreeMap::new();
        for (name, entry) in models_json {
            models.insert(name.clone(), parse_model(dir, name, entry)?);
        }
        if models.is_empty() {
            bail!("manifest lists no models");
        }
        Ok(ArtifactManifest {
            dir: dir.to_path_buf(),
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model '{name}' not in manifest"))
    }
}

fn parse_model(dir: &Path, name: &str, v: &Json) -> Result<ModelManifest> {
    let cfg = v
        .get("config")
        .ok_or_else(|| anyhow!("{name}: missing config"))?;
    let num = |key: &str| -> Result<usize> {
        cfg.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("{name}: config.{key}"))
    };
    let arch = ModelArch {
        vocab: num("vocab")?,
        d_model: num("d_model")?,
        n_layers: num("n_layers")?,
        n_q_heads: num("n_q_heads")?,
        n_kv_heads: num("n_kv_heads")?,
        d_head: num("d_head")?,
        d_ff: num("d_ff")?,
    };
    if arch.n_q_heads % arch.n_kv_heads != 0 {
        bail!("{name}: q heads not a multiple of kv heads");
    }

    let params_file = v
        .get("params_file")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("{name}: params_file"))?;
    let params_path = dir.join(params_file);

    let mut param_specs = Vec::new();
    for spec in v
        .get("param_specs")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("{name}: param_specs"))?
    {
        let pair = spec.as_arr().ok_or_else(|| anyhow!("bad spec"))?;
        let pname = pair[0]
            .as_str()
            .ok_or_else(|| anyhow!("bad spec name"))?
            .to_string();
        let shape: Vec<usize> = pair[1]
            .as_arr()
            .ok_or_else(|| anyhow!("bad spec shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<_>>()?;
        param_specs.push((pname, shape));
    }
    if param_specs.is_empty() {
        bail!("{name}: empty param_specs");
    }

    let mut buckets = Vec::new();
    for b in v
        .get("buckets")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("{name}: buckets"))?
    {
        let alpha_max = b
            .get("alpha_max")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("bucket alpha_max"))?;
        let beta = b
            .get("beta")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("bucket beta"))?;
        let hlo = b
            .get("hlo")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("bucket hlo"))?;
        buckets.push(Bucket {
            alpha_max,
            beta,
            hlo_path: dir.join(hlo),
        });
    }
    if buckets.is_empty() {
        bail!("{name}: no buckets");
    }

    Ok(ModelManifest {
        name: name.to_string(),
        arch,
        params_path,
        param_specs,
        buckets,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> Json {
        Json::parse(
            r#"{
              "version": 1,
              "models": {
                "tiny-x": {
                  "config": {"vocab": 512, "d_model": 128, "n_layers": 4,
                             "n_q_heads": 8, "n_kv_heads": 2, "d_head": 16,
                             "d_ff": 512},
                  "param_seed": 0,
                  "params_file": "params_tiny-x.bin",
                  "param_specs": [["tok_emb", [512, 128]],
                                  ["final_norm", [128]]],
                  "buckets": [
                    {"alpha_max": 128, "beta": 16, "hlo": "a.hlo.txt"},
                    {"alpha_max": 512, "beta": 64, "hlo": "b.hlo.txt"}
                  ]
                }
              }
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_sample() {
        let m =
            ArtifactManifest::from_json(Path::new("/tmp/art"), &sample_json())
                .unwrap();
        let model = m.model("tiny-x").unwrap();
        assert_eq!(model.arch.vocab, 512);
        assert_eq!(model.arch.kv_floats_per_token(), 4 * 2 * 2 * 16);
        assert_eq!(model.param_floats(), 512 * 128 + 128);
        assert_eq!(model.buckets.len(), 2);
        assert!(m.model("absent").is_err());
    }

    #[test]
    fn bucket_selection_prefers_tightest() {
        let m =
            ArtifactManifest::from_json(Path::new("/tmp/art"), &sample_json())
                .unwrap();
        let model = m.model("tiny-x").unwrap();
        assert_eq!(model.pick_bucket(100, 10).unwrap().alpha_max, 128);
        assert_eq!(model.pick_bucket(128, 16).unwrap().alpha_max, 128);
        assert_eq!(model.pick_bucket(129, 16).unwrap().alpha_max, 512);
        assert_eq!(model.pick_bucket(200, 32).unwrap().beta, 64);
        assert!(model.pick_bucket(1000, 16).is_none());
        assert!(model.pick_bucket(16, 100).is_none());
        assert_eq!(model.max_alpha(), 512);
        assert_eq!(model.max_beta(), 64);
    }

    #[test]
    fn rejects_malformed() {
        let bad = Json::parse(r#"{"models": {}}"#).unwrap();
        assert!(ArtifactManifest::from_json(Path::new("/x"), &bad).is_err());
        let bad2 = Json::parse(r#"{"nope": 1}"#).unwrap();
        assert!(ArtifactManifest::from_json(Path::new("/x"), &bad2).is_err());
    }
}
