//! Layer-3 ↔ Layer-2 bridge: load and execute AOT artifacts via PJRT.
//!
//! The Python compile path (`python/compile/aot.py`) lowers the JAX model
//! (with its Pallas kernel) to HLO *text* per `(alpha_max, beta)` shape
//! bucket and records the ABI in `artifacts/manifest.json`. This module
//! parses the manifest ([`manifest`]) and wraps the `xla` crate's PJRT CPU
//! client ([`pjrt`]) so the coordinator can run real prefills on the
//! request path with Python nowhere in sight.

pub mod manifest;
pub mod pjrt;

pub use manifest::{ArtifactManifest, Bucket, ModelArch, ModelManifest};
pub use pjrt::{PjrtModel, PrefillOutput};
