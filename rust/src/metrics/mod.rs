//! Serving metrics: TTFT, throughput, hit rate (§7 Metrics), plus the
//! SLO-attainment family for open-loop overload studies: goodput under a
//! TTFT SLO, p99.9 tails, shed/downgrade counters and per-tenant
//! breakdowns whose counts sum exactly to the aggregate.
//!
//! # Merge-semantics vocabulary
//!
//! Every aggregate stat the serving stack reports is declared once in
//! [`registry`], and its cross-engine merge rule is picked from a small
//! closed vocabulary ([`registry::MergeKind`]) instead of being
//! hand-written per field:
//!
//! - **Sum** — per-engine counters over disjoint work (requests served,
//!   speculations, shed/downgraded, goodput). Engines never see each
//!   other's requests, so totals add.
//! - **Max** — shared monotonic counters snapshotted by every engine
//!   (the tree, the rebalancer, the disk tier): each engine reports the
//!   SAME counter, so summing would multiply it by the engine count;
//!   the freshest (largest) snapshot is the truth. Also worst-case
//!   tails (`ttft_p999_ms`), where the fleet tail is the max of the
//!   per-engine tails under disjoint request sets.
//! - **Or** — capability flags (`slo_enabled`): the merged answer ran
//!   SLO admission control iff any engine did.
//! - **RequestWeightedMean** — means and rates (`mean_ttft_ms`,
//!   `hit_rate`) weighted by each engine's request count, with the
//!   NaN-skip rule: a part with zero requests or a non-finite value
//!   contributes neither value nor weight, so one idle engine's NaN
//!   neither poisons nor dilutes the engines that measured.
//! - **SloGatedMean** — `RequestWeightedMean` restricted to engines
//!   with `slo_enabled`: attainment is only defined where an SLO was
//!   enforced.
//! - **EngineCount** — the merged value is the part count itself.
//! - **SnapshotConsistentGroup** — point-in-time gauges that are only
//!   self-consistent within ONE engine's snapshot (per-shard
//!   used/capacity arrays, disk occupancy): taken verbatim from the
//!   freshest part, never mixed across parts, so a capacity move can't
//!   report phantom bytes.
//! - **ByKey** — the per-tenant sub-table: lines merge element-wise by
//!   tenant id, each sub-field by its own kind (counts Sum, mode Max,
//!   the mean request-weighted with a NaN/zero-served guard).
//!
//! The registry drives the wire encoder/decoder, the fan-out merge,
//! the BENCH column set, the bench_diff tolerance bands and the CI
//! schema snapshot from this one table — see [`registry`].

use crate::util::Summary;
use std::collections::BTreeMap;

pub mod registry;

/// Per-request lifecycle timestamps.
#[derive(Debug, Clone, Default)]
pub struct RequestRecord {
    pub arrival: f64,
    pub retrieval_done: Option<f64>,
    pub first_token: Option<f64>,
    pub finished: Option<f64>,
    /// Owning tenant (0 in single-tenant runs).
    pub tenant: u32,
    /// Set (to the shed time) when admission control rejected the
    /// request instead of serving it. Mutually exclusive with
    /// `first_token` — a shed request never produced a token.
    pub shed: Option<f64>,
    /// Admission control downgraded this request (speculation disabled,
    /// single-stage retrieval) to relieve queueing pressure.
    pub downgraded: bool,
    /// Retrieved / hit document counts for the §7.3 hit-rate definition.
    pub docs_retrieved: usize,
    pub docs_hit: usize,
    /// Tokens cached (α) vs computed (β) at prefill.
    pub cached_tokens: usize,
    pub computed_tokens: usize,
    /// Non-overlapping vector-search time (Table 3): retrieval time not
    /// hidden behind LLM work.
    pub non_overlapped_search: f64,
    /// Output tokens generated (for TPOT, paper §8).
    pub output_tokens: usize,
}

/// Collects per-request records and derives the paper's metrics.
/// `Clone` supports cheap snapshots out of a live (locked or owned)
/// pipeline without freezing the serving path.
#[derive(Debug, Clone, Default)]
pub struct Recorder {
    records: BTreeMap<u64, RequestRecord>,
}

impl Recorder {
    pub fn new() -> Self {
        Recorder::default()
    }

    pub fn arrival(&mut self, id: u64, t: f64) {
        self.records.entry(id).or_default().arrival = t;
    }

    pub fn retrieval_done(&mut self, id: u64, t: f64) {
        self.records.entry(id).or_default().retrieval_done = Some(t);
    }

    pub fn first_token(&mut self, id: u64, t: f64) {
        let r = self.records.entry(id).or_default();
        if r.first_token.is_none() {
            r.first_token = Some(t);
        }
    }

    pub fn finished(&mut self, id: u64, t: f64) {
        self.records.entry(id).or_default().finished = Some(t);
    }

    pub fn output_tokens(&mut self, id: u64, n: usize) {
        self.records.entry(id).or_default().output_tokens = n;
    }

    pub fn docs(&mut self, id: u64, retrieved: usize, hit: usize) {
        let r = self.records.entry(id).or_default();
        r.docs_retrieved = retrieved;
        r.docs_hit = hit;
    }

    pub fn tokens(&mut self, id: u64, cached: usize, computed: usize) {
        let r = self.records.entry(id).or_default();
        r.cached_tokens = cached;
        r.computed_tokens = computed;
    }

    pub fn non_overlapped_search(&mut self, id: u64, secs: f64) {
        self.records.entry(id).or_default().non_overlapped_search = secs;
    }

    pub fn tenant(&mut self, id: u64, tenant: u32) {
        self.records.entry(id).or_default().tenant = tenant;
    }

    /// Mark a request shed by admission control at time `t`.
    pub fn shed(&mut self, id: u64, t: f64) {
        self.records.entry(id).or_default().shed = Some(t);
    }

    pub fn downgraded(&mut self, id: u64) {
        self.records.entry(id).or_default().downgraded = true;
    }

    pub fn shed_count(&self) -> usize {
        self.records.values().filter(|r| r.shed.is_some()).count()
    }

    pub fn downgrade_count(&self) -> usize {
        self.records.values().filter(|r| r.downgraded).count()
    }

    pub fn record(&self, id: u64) -> Option<&RequestRecord> {
        self.records.get(&id)
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// TTFT summary over completed requests (seconds).
    pub fn ttft(&self) -> Summary {
        let mut s = Summary::new();
        for r in self.records.values() {
            if let Some(ft) = r.first_token {
                s.add(ft - r.arrival);
            }
        }
        s
    }

    /// Time per output token (paper §8): (finish − first token) /
    /// (output tokens − 1), over requests with ≥ 2 output tokens.
    pub fn tpot(&self) -> Summary {
        let mut s = Summary::new();
        for r in self.records.values() {
            if let (Some(ft), Some(fin)) = (r.first_token, r.finished) {
                if r.output_tokens >= 2 {
                    s.add((fin - ft) / (r.output_tokens - 1) as f64);
                }
            }
        }
        s
    }

    /// §7.3 hit rate: hit documents / retrieved documents.
    pub fn hit_rate(&self) -> f64 {
        let (mut hit, mut total) = (0usize, 0usize);
        for r in self.records.values() {
            hit += r.docs_hit;
            total += r.docs_retrieved;
        }
        if total == 0 {
            0.0
        } else {
            hit as f64 / total as f64
        }
    }

    /// Token-level hit rate: cached / (cached + computed).
    pub fn token_hit_rate(&self) -> f64 {
        let (mut cached, mut total) = (0usize, 0usize);
        for r in self.records.values() {
            cached += r.cached_tokens;
            total += r.cached_tokens + r.computed_tokens;
        }
        if total == 0 {
            0.0
        } else {
            cached as f64 / total as f64
        }
    }

    /// Mean non-overlapping vector-search time (Table 3), seconds.
    pub fn mean_non_overlapped_search(&self) -> f64 {
        let mut s = Summary::new();
        for r in self.records.values() {
            s.add(r.non_overlapped_search);
        }
        s.mean()
    }

    /// Observed span of the whole trace: first arrival to the last event
    /// of any kind (finish, shed, or — for still-queued requests under
    /// overload — the arrival itself). Rates divide by this horizon, not
    /// by the completed-only span: an overloaded run that completes 10
    /// of 100 requests must not report the throughput of the lucky 10.
    pub fn horizon(&self) -> f64 {
        let mut first = f64::INFINITY;
        let mut last = f64::NEG_INFINITY;
        for r in self.records.values() {
            first = first.min(r.arrival);
            last = last
                .max(r.arrival)
                .max(r.finished.unwrap_or(f64::NEG_INFINITY))
                .max(r.shed.unwrap_or(f64::NEG_INFINITY));
        }
        if last > first {
            last - first
        } else {
            0.0
        }
    }

    /// Completed-request throughput over the full trace horizon, req/s.
    pub fn throughput(&self) -> f64 {
        let completed =
            self.records.values().filter(|r| r.finished.is_some()).count();
        if completed < 2 {
            return 0.0;
        }
        let span = self.horizon();
        if span <= 0.0 {
            0.0
        } else {
            completed as f64 / span
        }
    }

    /// Goodput under a TTFT SLO: requests whose first token arrived
    /// within `ttft_slo` seconds of arrival, per second of trace
    /// horizon. Shed and still-queued requests count in the denominator
    /// time but contribute nothing — the metric admission control is
    /// judged by (serve fewer requests well > serve all of them late).
    pub fn goodput(&self, ttft_slo: f64) -> f64 {
        let good = self.slo_ok_count(ttft_slo);
        if good == 0 {
            return 0.0;
        }
        let span = self.horizon();
        if span <= 0.0 {
            0.0
        } else {
            good as f64 / span
        }
    }

    /// Fraction of ALL requests (including shed / never-served) meeting
    /// the TTFT SLO.
    pub fn slo_attainment(&self, ttft_slo: f64) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.slo_ok_count(ttft_slo) as f64 / self.records.len() as f64
    }

    fn slo_ok_count(&self, ttft_slo: f64) -> usize {
        self.records
            .values()
            .filter(|r| {
                r.first_token
                    .map_or(false, |ft| ft - r.arrival <= ttft_slo)
            })
            .count()
    }

    /// Per-tenant breakdown under a TTFT SLO. Tenants are listed in
    /// ascending id order and every request belongs to exactly one
    /// tenant, so the columns sum exactly to the aggregate counters.
    pub fn per_tenant(&self, ttft_slo: f64) -> Vec<TenantStats> {
        let mut by: BTreeMap<u32, TenantStats> = BTreeMap::new();
        for r in self.records.values() {
            let s = by.entry(r.tenant).or_insert_with(|| TenantStats {
                tenant: r.tenant,
                ..TenantStats::default()
            });
            s.requests += 1;
            if r.finished.is_some() {
                s.completed += 1;
            }
            if r.shed.is_some() {
                s.shed += 1;
            }
            if r.downgraded {
                s.downgraded += 1;
            }
            if let Some(ft) = r.first_token {
                let ttft = ft - r.arrival;
                if ttft <= ttft_slo {
                    s.slo_ok += 1;
                }
                s.ttft_sum += ttft;
                s.ttft_n += 1;
            }
        }
        by.into_values().collect()
    }
}

/// One tenant's share of the aggregate metrics (see
/// [`Recorder::per_tenant`]).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TenantStats {
    pub tenant: u32,
    pub requests: usize,
    pub completed: usize,
    pub shed: usize,
    pub downgraded: usize,
    /// Requests whose TTFT met the SLO.
    pub slo_ok: usize,
    ttft_sum: f64,
    ttft_n: usize,
}

impl TenantStats {
    /// Mean TTFT over this tenant's served requests (NaN if none).
    pub fn mean_ttft(&self) -> f64 {
        if self.ttft_n == 0 {
            f64::NAN
        } else {
            self.ttft_sum / self.ttft_n as f64
        }
    }
}

/// The paper's throughput definition: the highest request rate whose
/// average TTFT stays below `slo_factor ×` the TTFT at the lowest rate
/// (§7 Metrics). Input: (rate, mean TTFT) pairs sorted by rate.
pub fn slo_throughput(points: &[(f64, f64)], slo_factor: f64) -> f64 {
    if points.is_empty() {
        return 0.0;
    }
    let baseline = points[0].1;
    let slo = baseline * slo_factor;
    let mut best = 0.0;
    for &(rate, ttft) in points {
        if ttft <= slo {
            best = rate;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ttft_and_hit_rate() {
        let mut r = Recorder::new();
        r.arrival(1, 0.0);
        r.first_token(1, 0.5);
        r.finished(1, 0.6);
        r.docs(1, 2, 1);
        r.arrival(2, 1.0);
        r.first_token(2, 2.5);
        r.finished(2, 2.6);
        r.docs(2, 2, 2);
        let mut ttft = r.ttft();
        assert_eq!(ttft.len(), 2);
        assert!((ttft.mean() - 1.0).abs() < 1e-9);
        assert!((ttft.percentile(100.0) - 1.5).abs() < 1e-9);
        assert!((r.hit_rate() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn first_token_recorded_once() {
        let mut r = Recorder::new();
        r.arrival(1, 0.0);
        r.first_token(1, 1.0);
        r.first_token(1, 99.0); // speculative re-delivery ignored
        assert_eq!(r.record(1).unwrap().first_token, Some(1.0));
    }

    #[test]
    fn tpot_over_decode_tokens() {
        let mut r = Recorder::new();
        r.arrival(1, 0.0);
        r.first_token(1, 1.0);
        r.finished(1, 1.5);
        r.output_tokens(1, 6); // 5 decode steps over 0.5 s => 0.1 s each
        r.arrival(2, 0.0);
        r.first_token(2, 1.0);
        r.finished(2, 1.0);
        r.output_tokens(2, 1); // single-token output excluded
        let mut t = r.tpot();
        assert_eq!(t.len(), 1);
        assert!((t.mean() - 0.1).abs() < 1e-9);
    }

    #[test]
    fn token_hit_rate() {
        let mut r = Recorder::new();
        r.arrival(1, 0.0);
        r.tokens(1, 300, 100);
        assert!((r.token_hit_rate() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn throughput_span() {
        let mut r = Recorder::new();
        for i in 0..10u64 {
            r.arrival(i, i as f64);
            r.finished(i, i as f64 + 1.0);
        }
        // 10 requests finishing between t=1 and t=10, first arrival 0.
        assert!((r.throughput() - 1.0).abs() < 0.01);
    }

    #[test]
    fn horizon_counts_shed_and_queued_requests() {
        let mut r = Recorder::new();
        r.arrival(0, 0.0);
        r.first_token(0, 1.0);
        r.finished(0, 2.0);
        r.arrival(1, 5.0);
        r.shed(1, 9.0); // shed extends the horizon past the last finish
        r.arrival(2, 12.0); // still queued at end of run
        assert!((r.horizon() - 12.0).abs() < 1e-9);
        assert_eq!(r.shed_count(), 1);
        // Throughput needs >= 2 completions; with one it reports 0.
        assert_eq!(r.throughput(), 0.0);
    }

    #[test]
    fn goodput_and_attainment_under_slo() {
        let mut r = Recorder::new();
        // 4 requests over a 10 s horizon: one fast, one slow (misses the
        // 1 s SLO), one shed, one never served.
        r.arrival(0, 0.0);
        r.first_token(0, 0.5);
        r.finished(0, 1.0);
        r.arrival(1, 1.0);
        r.first_token(1, 4.0);
        r.finished(1, 5.0);
        r.arrival(2, 2.0);
        r.shed(2, 3.5);
        r.arrival(3, 10.0);
        assert!((r.horizon() - 10.0).abs() < 1e-9);
        assert!((r.goodput(1.0) - 0.1).abs() < 1e-9); // 1 good / 10 s
        assert!((r.slo_attainment(1.0) - 0.25).abs() < 1e-9);
        // Loose SLO admits the slow one too.
        assert!((r.slo_attainment(5.0) - 0.5).abs() < 1e-9);
        assert!((r.goodput(5.0) - 0.2).abs() < 1e-9);
        assert_eq!(r.goodput(0.0), 0.0);
    }

    /// Degenerate-input regression: the wire stats divide by the trace
    /// horizon, so an empty trace and a zero-span trace (every event at
    /// one instant — horizon 0) must both report 0.0, never inf or NaN.
    /// The TCP `stats` op serves these shapes routinely (stats polled
    /// before any request, or after exactly one instantaneous one).
    #[test]
    fn goodput_degenerate_traces_report_zero() {
        let empty = Recorder::new();
        assert_eq!(empty.goodput(1.0), 0.0);
        assert_eq!(empty.slo_attainment(1.0), 0.0);
        assert_eq!(empty.horizon(), 0.0);
        assert_eq!(empty.throughput(), 0.0);

        // One request arriving, serving and finishing at t=0: a "good"
        // completion exists but the horizon is zero — good/span would
        // be 1/0 = inf without the guard.
        let mut r = Recorder::new();
        r.arrival(0, 0.0);
        r.first_token(0, 0.0);
        r.finished(0, 0.0);
        assert_eq!(r.goodput(1.0), 0.0);
        assert!(r.goodput(1.0).is_finite());
        assert!((r.slo_attainment(1.0) - 1.0).abs() < 1e-12);

        // Shed-only trace at one instant: zero horizon again, and the
        // attainment denominator counts the shed request.
        let mut s = Recorder::new();
        s.arrival(0, 3.0);
        s.shed(0, 3.0);
        assert_eq!(s.goodput(1.0), 0.0);
        assert_eq!(s.slo_attainment(1.0), 0.0);
    }

    #[test]
    fn per_tenant_sums_to_aggregate() {
        let mut r = Recorder::new();
        for i in 0..12u64 {
            r.arrival(i, i as f64);
            r.tenant(i, (i % 3) as u32);
            match i % 4 {
                0 => r.shed(i, i as f64 + 2.0),
                1 => {
                    r.first_token(i, i as f64 + 0.2);
                    r.finished(i, i as f64 + 0.4);
                    r.downgraded(i);
                }
                _ => {
                    r.first_token(i, i as f64 + 3.0);
                    r.finished(i, i as f64 + 4.0);
                }
            }
        }
        let slo = 1.0;
        let per = r.per_tenant(slo);
        assert_eq!(per.len(), 3);
        assert_eq!(per.iter().map(|t| t.requests).sum::<usize>(), r.len());
        assert_eq!(
            per.iter().map(|t| t.shed).sum::<usize>(),
            r.shed_count()
        );
        assert_eq!(
            per.iter().map(|t| t.downgraded).sum::<usize>(),
            r.downgrade_count()
        );
        assert_eq!(
            per.iter().map(|t| t.completed).sum::<usize>(),
            r.records.values().filter(|x| x.finished.is_some()).count()
        );
        let agg_ok = (r.slo_attainment(slo) * r.len() as f64).round();
        assert_eq!(
            per.iter().map(|t| t.slo_ok).sum::<usize>(),
            agg_ok as usize
        );
        for t in &per {
            assert_eq!(t.requests, 4);
            assert!(t.mean_ttft().is_finite());
        }
        assert!(TenantStats::default().mean_ttft().is_nan());
    }

    #[test]
    fn slo_throughput_picks_knee() {
        let points = [
            (0.5, 0.2),
            (1.0, 0.3),
            (1.5, 0.6),
            (2.0, 1.2), // exceeds 5 * 0.2 = 1.0
            (2.5, 3.0),
        ];
        assert_eq!(slo_throughput(&points, 5.0), 1.5);
        assert_eq!(slo_throughput(&[], 5.0), 0.0);
    }
}
