//! Serving metrics: TTFT, throughput, hit rate (§7 Metrics).

use crate::util::Summary;
use std::collections::BTreeMap;

/// Per-request lifecycle timestamps.
#[derive(Debug, Clone, Default)]
pub struct RequestRecord {
    pub arrival: f64,
    pub retrieval_done: Option<f64>,
    pub first_token: Option<f64>,
    pub finished: Option<f64>,
    /// Retrieved / hit document counts for the §7.3 hit-rate definition.
    pub docs_retrieved: usize,
    pub docs_hit: usize,
    /// Tokens cached (α) vs computed (β) at prefill.
    pub cached_tokens: usize,
    pub computed_tokens: usize,
    /// Non-overlapping vector-search time (Table 3): retrieval time not
    /// hidden behind LLM work.
    pub non_overlapped_search: f64,
    /// Output tokens generated (for TPOT, paper §8).
    pub output_tokens: usize,
}

/// Collects per-request records and derives the paper's metrics.
/// `Clone` supports cheap snapshots out of a live (locked or owned)
/// pipeline without freezing the serving path.
#[derive(Debug, Clone, Default)]
pub struct Recorder {
    records: BTreeMap<u64, RequestRecord>,
}

impl Recorder {
    pub fn new() -> Self {
        Recorder::default()
    }

    pub fn arrival(&mut self, id: u64, t: f64) {
        self.records.entry(id).or_default().arrival = t;
    }

    pub fn retrieval_done(&mut self, id: u64, t: f64) {
        self.records.entry(id).or_default().retrieval_done = Some(t);
    }

    pub fn first_token(&mut self, id: u64, t: f64) {
        let r = self.records.entry(id).or_default();
        if r.first_token.is_none() {
            r.first_token = Some(t);
        }
    }

    pub fn finished(&mut self, id: u64, t: f64) {
        self.records.entry(id).or_default().finished = Some(t);
    }

    pub fn output_tokens(&mut self, id: u64, n: usize) {
        self.records.entry(id).or_default().output_tokens = n;
    }

    pub fn docs(&mut self, id: u64, retrieved: usize, hit: usize) {
        let r = self.records.entry(id).or_default();
        r.docs_retrieved = retrieved;
        r.docs_hit = hit;
    }

    pub fn tokens(&mut self, id: u64, cached: usize, computed: usize) {
        let r = self.records.entry(id).or_default();
        r.cached_tokens = cached;
        r.computed_tokens = computed;
    }

    pub fn non_overlapped_search(&mut self, id: u64, secs: f64) {
        self.records.entry(id).or_default().non_overlapped_search = secs;
    }

    pub fn record(&self, id: u64) -> Option<&RequestRecord> {
        self.records.get(&id)
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// TTFT summary over completed requests (seconds).
    pub fn ttft(&self) -> Summary {
        let mut s = Summary::new();
        for r in self.records.values() {
            if let Some(ft) = r.first_token {
                s.add(ft - r.arrival);
            }
        }
        s
    }

    /// Time per output token (paper §8): (finish − first token) /
    /// (output tokens − 1), over requests with ≥ 2 output tokens.
    pub fn tpot(&self) -> Summary {
        let mut s = Summary::new();
        for r in self.records.values() {
            if let (Some(ft), Some(fin)) = (r.first_token, r.finished) {
                if r.output_tokens >= 2 {
                    s.add((fin - ft) / (r.output_tokens - 1) as f64);
                }
            }
        }
        s
    }

    /// §7.3 hit rate: hit documents / retrieved documents.
    pub fn hit_rate(&self) -> f64 {
        let (mut hit, mut total) = (0usize, 0usize);
        for r in self.records.values() {
            hit += r.docs_hit;
            total += r.docs_retrieved;
        }
        if total == 0 {
            0.0
        } else {
            hit as f64 / total as f64
        }
    }

    /// Token-level hit rate: cached / (cached + computed).
    pub fn token_hit_rate(&self) -> f64 {
        let (mut cached, mut total) = (0usize, 0usize);
        for r in self.records.values() {
            cached += r.cached_tokens;
            total += r.cached_tokens + r.computed_tokens;
        }
        if total == 0 {
            0.0
        } else {
            cached as f64 / total as f64
        }
    }

    /// Mean non-overlapping vector-search time (Table 3), seconds.
    pub fn mean_non_overlapped_search(&self) -> f64 {
        let mut s = Summary::new();
        for r in self.records.values() {
            s.add(r.non_overlapped_search);
        }
        s.mean()
    }

    /// Completed-request throughput over the observed span, req/s.
    pub fn throughput(&self) -> f64 {
        let mut finishes: Vec<f64> = self
            .records
            .values()
            .filter_map(|r| r.finished)
            .collect();
        if finishes.len() < 2 {
            return 0.0;
        }
        finishes.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let first_arrival = self
            .records
            .values()
            .map(|r| r.arrival)
            .fold(f64::INFINITY, f64::min);
        let span = finishes.last().unwrap() - first_arrival;
        if span <= 0.0 {
            0.0
        } else {
            finishes.len() as f64 / span
        }
    }
}

/// The paper's throughput definition: the highest request rate whose
/// average TTFT stays below `slo_factor ×` the TTFT at the lowest rate
/// (§7 Metrics). Input: (rate, mean TTFT) pairs sorted by rate.
pub fn slo_throughput(points: &[(f64, f64)], slo_factor: f64) -> f64 {
    if points.is_empty() {
        return 0.0;
    }
    let baseline = points[0].1;
    let slo = baseline * slo_factor;
    let mut best = 0.0;
    for &(rate, ttft) in points {
        if ttft <= slo {
            best = rate;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ttft_and_hit_rate() {
        let mut r = Recorder::new();
        r.arrival(1, 0.0);
        r.first_token(1, 0.5);
        r.finished(1, 0.6);
        r.docs(1, 2, 1);
        r.arrival(2, 1.0);
        r.first_token(2, 2.5);
        r.finished(2, 2.6);
        r.docs(2, 2, 2);
        let mut ttft = r.ttft();
        assert_eq!(ttft.len(), 2);
        assert!((ttft.mean() - 1.0).abs() < 1e-9);
        assert!((ttft.percentile(100.0) - 1.5).abs() < 1e-9);
        assert!((r.hit_rate() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn first_token_recorded_once() {
        let mut r = Recorder::new();
        r.arrival(1, 0.0);
        r.first_token(1, 1.0);
        r.first_token(1, 99.0); // speculative re-delivery ignored
        assert_eq!(r.record(1).unwrap().first_token, Some(1.0));
    }

    #[test]
    fn tpot_over_decode_tokens() {
        let mut r = Recorder::new();
        r.arrival(1, 0.0);
        r.first_token(1, 1.0);
        r.finished(1, 1.5);
        r.output_tokens(1, 6); // 5 decode steps over 0.5 s => 0.1 s each
        r.arrival(2, 0.0);
        r.first_token(2, 1.0);
        r.finished(2, 1.0);
        r.output_tokens(2, 1); // single-token output excluded
        let mut t = r.tpot();
        assert_eq!(t.len(), 1);
        assert!((t.mean() - 0.1).abs() < 1e-9);
    }

    #[test]
    fn token_hit_rate() {
        let mut r = Recorder::new();
        r.arrival(1, 0.0);
        r.tokens(1, 300, 100);
        assert!((r.token_hit_rate() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn throughput_span() {
        let mut r = Recorder::new();
        for i in 0..10u64 {
            r.arrival(i, i as f64);
            r.finished(i, i as f64 + 1.0);
        }
        // 10 requests finishing between t=1 and t=10, first arrival 0.
        assert!((r.throughput() - 1.0).abs() < 0.01);
    }

    #[test]
    fn slo_throughput_picks_knee() {
        let points = [
            (0.5, 0.2),
            (1.0, 0.3),
            (1.5, 0.6),
            (2.0, 1.2), // exceeds 5 * 0.2 = 1.0
            (2.5, 3.0),
        ];
        assert_eq!(slo_throughput(&points, 5.0), 1.5);
        assert_eq!(slo_throughput(&[], 5.0), 0.0);
    }
}
