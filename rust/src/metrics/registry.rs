//! Declarative metrics registry: ONE schema driving the stats wire
//! format, the cross-engine merge, the tree-counter aggregation, the
//! schema drift gate and the bench column/tolerance metadata.
//!
//! Every aggregate metric the serving stack reports is registered here
//! exactly once as a [`MetricDesc`]: wire field name, report label,
//! kind (counter/gauge/flag/vector/per-tenant), scope (who owns the
//! underlying state), merge semantics (see [`MergeKind`] and the
//! vocabulary in [`crate::metrics`]) and bench tolerance class. The
//! wire encoder/decoder ([`Registry::encode_stats`] /
//! [`Registry::parse_stats`]), the fan-out merge ([`Registry::merge`]),
//! the BENCH column set ([`serving_bench_columns`]), the bench_diff
//! tolerance bands ([`tolerance_of`]) and the CI schema snapshot
//! ([`schema_dump`]) are all table-driven off the same descriptors, so
//! adding a counter means ONE registry entry plus its increment site —
//! not six hand-edited layers.
//!
//! Sub-schemas registered alongside the top-level table:
//! - [`TENANT_FIELDS`]: the per-tenant line ([`TenantLine`]) merged
//!   `ByKey` (tenant id) — counts sum, the mean is request-weighted
//!   with a NaN/zero-served guard, the CAG mode takes the max code.
//! - [`TREE_COUNTER_FIELDS`]: the shared-tree counters
//!   ([`TreeCounters`]), whose per-shard aggregation is a field-wise
//!   sum driven by the same table.
//!
//! Ad-hoc extension counters ([`Registry::with_counter`]) ride the
//! `StatsResult::ext` vector through encode/parse/merge and the bench
//! column set without touching any struct definition — the
//! "add-a-metric means two edits" contract the conformance tests pin.

use crate::server::proto::{StatsResult, TenantLine};
use crate::tree::TreeCounters;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// What shape of measurement a metric is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricKind {
    /// Monotonic event count (requests, evictions, spills).
    Counter,
    /// Instantaneous or derived value (means, rates, occupancy).
    Gauge,
    /// Boolean capability marker (e.g. "this engine measured an SLO").
    Flag,
    /// Per-shard numeric array from one consistent snapshot.
    Vector,
    /// Keyed sub-table of per-tenant lines.
    PerTenant,
}

impl MetricKind {
    pub fn as_str(self) -> &'static str {
        match self {
            MetricKind::Counter => "counter",
            MetricKind::Gauge => "gauge",
            MetricKind::Flag => "flag",
            MetricKind::Vector => "vector",
            MetricKind::PerTenant => "per_tenant",
        }
    }
}

/// Who owns the state behind a metric — the property that dictates its
/// merge semantics (see the vocabulary in [`crate::metrics`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricScope {
    /// Each engine owns its slice (its recorder, its sessions): values
    /// from different engines describe disjoint work.
    PerEngine,
    /// The one shared sharded cache: every engine snapshots the SAME
    /// monotonic counters, so cross-engine aggregation must not
    /// double-count.
    SharedTree,
    /// The one shared cross-shard rebalancer.
    SharedRebalancer,
    /// Point-in-time gauges that are only self-consistent within one
    /// engine's snapshot.
    Snapshot,
}

impl MetricScope {
    pub fn as_str(self) -> &'static str {
        match self {
            MetricScope::PerEngine => "per_engine",
            MetricScope::SharedTree => "shared_tree",
            MetricScope::SharedRebalancer => "shared_rebalancer",
            MetricScope::Snapshot => "snapshot",
        }
    }
}

/// How a metric combines across the per-engine parts of one fanned-out
/// `stats` request. The vocabulary is documented in [`crate::metrics`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MergeKind {
    /// Σ over parts — per-engine counters over disjoint work.
    Sum,
    /// max over parts — shared monotonic counters (latest snapshot
    /// wins) and worst-case tails.
    Max,
    /// Boolean any() — capability flags.
    Or,
    /// Request-weighted mean with the NaN-skip rule: parts with zero
    /// requests or a non-finite value contribute neither value nor
    /// weight; all-skipped merges report 0.0.
    RequestWeightedMean,
    /// [`MergeKind::RequestWeightedMean`] gated on `slo_enabled`: only
    /// engines that ran SLO admission control carry weight.
    SloGatedMean,
    /// The merged value is the part count itself (`engines`).
    EngineCount,
    /// Taken verbatim from ONE freshest part (most shard gauges
    /// reported, then most rebalance progress) so grouped gauges stay
    /// self-consistent — mixing snapshots taken across a capacity move
    /// could report phantom capacity.
    SnapshotConsistentGroup,
    /// Keyed sub-table merge: lines combine element-wise by key, each
    /// sub-field by its own [`MergeKind`].
    ByKey,
    /// The sub-table key itself (never merged — it identifies the
    /// line).
    Key,
}

impl MergeKind {
    pub fn as_str(self) -> &'static str {
        match self {
            MergeKind::Sum => "sum",
            MergeKind::Max => "max",
            MergeKind::Or => "or",
            MergeKind::RequestWeightedMean => "request_weighted_mean",
            MergeKind::SloGatedMean => "slo_gated_mean",
            MergeKind::EngineCount => "engine_count",
            MergeKind::SnapshotConsistentGroup => {
                "snapshot_consistent_group"
            }
            MergeKind::ByKey => "by_key",
            MergeKind::Key => "key",
        }
    }
}

/// bench_diff tolerance class: `Tight` for deterministic token/byte
/// counters (0.15 relative by default), `Loose` for wall-clock columns
/// that measure the host, not the code (0.75 relative).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tolerance {
    Tight,
    Loose,
}

impl Tolerance {
    pub fn as_str(self) -> &'static str {
        match self {
            Tolerance::Tight => "tight",
            Tolerance::Loose => "loose",
        }
    }
}

/// A dynamically-typed metric value — the generic snapshot cell the
/// table-driven encode/parse/merge operate on.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    U64(u64),
    F64(f64),
    Bool(bool),
    Shards(Vec<u64>),
    Tenants(Vec<TenantLine>),
}

impl Value {
    fn to_u64(&self) -> u64 {
        match self {
            Value::U64(x) => *x,
            _ => panic!("metric value is not a u64"),
        }
    }

    fn to_f64(&self) -> f64 {
        match self {
            Value::F64(x) => *x,
            _ => panic!("metric value is not an f64"),
        }
    }

    fn to_bool(&self) -> bool {
        match self {
            Value::Bool(b) => *b,
            _ => panic!("metric value is not a bool"),
        }
    }
}

/// One registered metric: the single source of truth for its wire
/// field, report label, classification, merge semantics and bench
/// tolerance class, plus the typed accessors the table-driven
/// encode/parse/merge use.
pub struct MetricDesc {
    /// Wire field name — also the registry name and the bench column
    /// name wherever the metric is emitted.
    pub wire: &'static str,
    /// Human-readable report label.
    pub label: &'static str,
    pub kind: MetricKind,
    pub scope: MetricScope,
    pub merge: MergeKind,
    pub tolerance: Tolerance,
    pub get: fn(&StatsResult) -> Value,
    pub set: fn(&mut StatsResult, Value),
}

/// The standard metric table, in wire-schema order (the JSON object is
/// a sorted map, so this order is documentation, not wire layout).
static METRICS: [MetricDesc; 31] = [
    MetricDesc {
        wire: "requests",
        label: "requests served",
        kind: MetricKind::Counter,
        scope: MetricScope::PerEngine,
        merge: MergeKind::Sum,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::U64(s.requests as u64),
        set: |s: &mut StatsResult, v: Value| s.requests = v.to_u64() as usize,
    },
    MetricDesc {
        wire: "mean_ttft_ms",
        label: "mean TTFT (ms)",
        kind: MetricKind::Gauge,
        scope: MetricScope::PerEngine,
        merge: MergeKind::RequestWeightedMean,
        tolerance: Tolerance::Loose,
        get: |s: &StatsResult| Value::F64(s.mean_ttft_ms),
        set: |s: &mut StatsResult, v: Value| s.mean_ttft_ms = v.to_f64(),
    },
    MetricDesc {
        wire: "hit_rate",
        label: "cache hit rate",
        kind: MetricKind::Gauge,
        scope: MetricScope::PerEngine,
        merge: MergeKind::RequestWeightedMean,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::F64(s.hit_rate),
        set: |s: &mut StatsResult, v: Value| s.hit_rate = v.to_f64(),
    },
    MetricDesc {
        wire: "engines",
        label: "engine replicas merged",
        kind: MetricKind::Gauge,
        scope: MetricScope::PerEngine,
        merge: MergeKind::EngineCount,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::U64(s.engines as u64),
        set: |s: &mut StatsResult, v: Value| s.engines = v.to_u64() as usize,
    },
    MetricDesc {
        wire: "tree_inserts",
        label: "knowledge-tree inserts",
        kind: MetricKind::Counter,
        scope: MetricScope::SharedTree,
        merge: MergeKind::Max,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::U64(s.tree_inserts),
        set: |s: &mut StatsResult, v: Value| s.tree_inserts = v.to_u64(),
    },
    MetricDesc {
        wire: "tree_gpu_evictions",
        label: "GPU-tier evictions",
        kind: MetricKind::Counter,
        scope: MetricScope::SharedTree,
        merge: MergeKind::Max,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::U64(s.tree_gpu_evictions),
        set: |s: &mut StatsResult, v: Value| {
            s.tree_gpu_evictions = v.to_u64()
        },
    },
    MetricDesc {
        wire: "tree_host_evictions",
        label: "host-tier evictions",
        kind: MetricKind::Counter,
        scope: MetricScope::SharedTree,
        merge: MergeKind::Max,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::U64(s.tree_host_evictions),
        set: |s: &mut StatsResult, v: Value| {
            s.tree_host_evictions = v.to_u64()
        },
    },
    MetricDesc {
        wire: "spec_started",
        label: "speculations started",
        kind: MetricKind::Counter,
        scope: MetricScope::PerEngine,
        merge: MergeKind::Sum,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::U64(s.spec_started),
        set: |s: &mut StatsResult, v: Value| s.spec_started = v.to_u64(),
    },
    MetricDesc {
        wire: "spec_wasted",
        label: "speculations wasted",
        kind: MetricKind::Counter,
        scope: MetricScope::PerEngine,
        merge: MergeKind::Sum,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::U64(s.spec_wasted),
        set: |s: &mut StatsResult, v: Value| s.spec_wasted = v.to_u64(),
    },
    MetricDesc {
        wire: "spec_promoted",
        label: "speculations promoted",
        kind: MetricKind::Counter,
        scope: MetricScope::PerEngine,
        merge: MergeKind::Sum,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::U64(s.spec_promoted),
        set: |s: &mut StatsResult, v: Value| s.spec_promoted = v.to_u64(),
    },
    MetricDesc {
        wire: "tree_gpu_hit_bytes",
        label: "GPU cache-hit bytes",
        kind: MetricKind::Counter,
        scope: MetricScope::SharedTree,
        merge: MergeKind::Max,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::U64(s.tree_gpu_hit_bytes),
        set: |s: &mut StatsResult, v: Value| {
            s.tree_gpu_hit_bytes = v.to_u64()
        },
    },
    MetricDesc {
        wire: "chunk_hits",
        label: "chunk-cache hits",
        kind: MetricKind::Counter,
        scope: MetricScope::SharedTree,
        merge: MergeKind::Max,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::U64(s.chunk_hits),
        set: |s: &mut StatsResult, v: Value| s.chunk_hits = v.to_u64(),
    },
    MetricDesc {
        wire: "chunk_hit_bytes",
        label: "chunk-cache hit bytes",
        kind: MetricKind::Counter,
        scope: MetricScope::SharedTree,
        merge: MergeKind::Max,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::U64(s.chunk_hit_bytes),
        set: |s: &mut StatsResult, v: Value| {
            s.chunk_hit_bytes = v.to_u64()
        },
    },
    MetricDesc {
        wire: "boundary_recompute_tokens",
        label: "boundary tokens recomputed",
        kind: MetricKind::Counter,
        scope: MetricScope::SharedTree,
        merge: MergeKind::Max,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::U64(s.boundary_recompute_tokens),
        set: |s: &mut StatsResult, v: Value| {
            s.boundary_recompute_tokens = v.to_u64()
        },
    },
    MetricDesc {
        wire: "rebalance_recomputes",
        label: "rebalancer slice recomputes",
        kind: MetricKind::Counter,
        scope: MetricScope::SharedRebalancer,
        merge: MergeKind::Max,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::U64(s.rebalance_recomputes),
        set: |s: &mut StatsResult, v: Value| {
            s.rebalance_recomputes = v.to_u64()
        },
    },
    MetricDesc {
        wire: "rebalance_moved_bytes",
        label: "rebalancer capacity bytes moved",
        kind: MetricKind::Counter,
        scope: MetricScope::SharedRebalancer,
        merge: MergeKind::Max,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::U64(s.rebalance_moved_bytes),
        set: |s: &mut StatsResult, v: Value| {
            s.rebalance_moved_bytes = v.to_u64()
        },
    },
    MetricDesc {
        wire: "shard_gpu_used",
        label: "per-shard GPU bytes used",
        kind: MetricKind::Vector,
        scope: MetricScope::Snapshot,
        merge: MergeKind::SnapshotConsistentGroup,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::Shards(s.shard_gpu_used.clone()),
        set: |s: &mut StatsResult, v: Value| match v {
            Value::Shards(a) => s.shard_gpu_used = a,
            _ => panic!("metric value is not a shard array"),
        },
    },
    MetricDesc {
        wire: "shard_gpu_capacity",
        label: "per-shard GPU capacity",
        kind: MetricKind::Vector,
        scope: MetricScope::Snapshot,
        merge: MergeKind::SnapshotConsistentGroup,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| {
            Value::Shards(s.shard_gpu_capacity.clone())
        },
        set: |s: &mut StatsResult, v: Value| match v {
            Value::Shards(a) => s.shard_gpu_capacity = a,
            _ => panic!("metric value is not a shard array"),
        },
    },
    MetricDesc {
        wire: "goodput_rps",
        label: "goodput under SLO (req/s)",
        kind: MetricKind::Gauge,
        scope: MetricScope::PerEngine,
        merge: MergeKind::Sum,
        tolerance: Tolerance::Loose,
        get: |s: &StatsResult| Value::F64(s.goodput_rps),
        set: |s: &mut StatsResult, v: Value| s.goodput_rps = v.to_f64(),
    },
    MetricDesc {
        wire: "ttft_p999_ms",
        label: "p99.9 TTFT (ms)",
        kind: MetricKind::Gauge,
        scope: MetricScope::PerEngine,
        merge: MergeKind::Max,
        tolerance: Tolerance::Loose,
        get: |s: &StatsResult| Value::F64(s.ttft_p999_ms),
        set: |s: &mut StatsResult, v: Value| s.ttft_p999_ms = v.to_f64(),
    },
    MetricDesc {
        wire: "shed_requests",
        label: "requests shed",
        kind: MetricKind::Counter,
        scope: MetricScope::PerEngine,
        merge: MergeKind::Sum,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::U64(s.shed_requests),
        set: |s: &mut StatsResult, v: Value| s.shed_requests = v.to_u64(),
    },
    MetricDesc {
        wire: "downgraded_requests",
        label: "arrivals downgraded",
        kind: MetricKind::Counter,
        scope: MetricScope::PerEngine,
        merge: MergeKind::Sum,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::U64(s.downgraded_requests),
        set: |s: &mut StatsResult, v: Value| {
            s.downgraded_requests = v.to_u64()
        },
    },
    MetricDesc {
        wire: "slo_attainment",
        label: "SLO attainment fraction",
        kind: MetricKind::Gauge,
        scope: MetricScope::PerEngine,
        merge: MergeKind::SloGatedMean,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::F64(s.slo_attainment),
        set: |s: &mut StatsResult, v: Value| {
            s.slo_attainment = v.to_f64()
        },
    },
    MetricDesc {
        wire: "slo_enabled",
        label: "SLO admission control active",
        kind: MetricKind::Flag,
        scope: MetricScope::PerEngine,
        merge: MergeKind::Or,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::Bool(s.slo_enabled),
        set: |s: &mut StatsResult, v: Value| s.slo_enabled = v.to_bool(),
    },
    MetricDesc {
        wire: "disk_spills",
        label: "disk-tier spills",
        kind: MetricKind::Counter,
        scope: MetricScope::SharedTree,
        merge: MergeKind::Max,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::U64(s.disk_spills),
        set: |s: &mut StatsResult, v: Value| s.disk_spills = v.to_u64(),
    },
    MetricDesc {
        wire: "disk_spill_bytes",
        label: "disk-tier spill bytes",
        kind: MetricKind::Counter,
        scope: MetricScope::SharedTree,
        merge: MergeKind::Max,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::U64(s.disk_spill_bytes),
        set: |s: &mut StatsResult, v: Value| {
            s.disk_spill_bytes = v.to_u64()
        },
    },
    MetricDesc {
        wire: "disk_restage_hits",
        label: "disk-tier restage hits",
        kind: MetricKind::Counter,
        scope: MetricScope::SharedTree,
        merge: MergeKind::Max,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::U64(s.disk_restage_hits),
        set: |s: &mut StatsResult, v: Value| {
            s.disk_restage_hits = v.to_u64()
        },
    },
    MetricDesc {
        wire: "disk_restage_bytes",
        label: "disk-tier restage bytes",
        kind: MetricKind::Counter,
        scope: MetricScope::SharedTree,
        merge: MergeKind::Max,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::U64(s.disk_restage_bytes),
        set: |s: &mut StatsResult, v: Value| {
            s.disk_restage_bytes = v.to_u64()
        },
    },
    MetricDesc {
        wire: "disk_used",
        label: "disk bytes in use",
        kind: MetricKind::Gauge,
        scope: MetricScope::Snapshot,
        merge: MergeKind::SnapshotConsistentGroup,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::U64(s.disk_used),
        set: |s: &mut StatsResult, v: Value| s.disk_used = v.to_u64(),
    },
    MetricDesc {
        wire: "disk_capacity",
        label: "disk capacity bytes",
        kind: MetricKind::Gauge,
        scope: MetricScope::Snapshot,
        merge: MergeKind::SnapshotConsistentGroup,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::U64(s.disk_capacity),
        set: |s: &mut StatsResult, v: Value| s.disk_capacity = v.to_u64(),
    },
    MetricDesc {
        wire: "tenants",
        label: "per-tenant breakdown",
        kind: MetricKind::PerTenant,
        scope: MetricScope::PerEngine,
        merge: MergeKind::ByKey,
        tolerance: Tolerance::Tight,
        get: |s: &StatsResult| Value::Tenants(s.tenants.clone()),
        set: |s: &mut StatsResult, v: Value| match v {
            Value::Tenants(ts) => s.tenants = ts,
            _ => panic!("metric value is not a tenant table"),
        },
    },
];

/// The standard metric descriptors, in schema order.
pub fn descriptors() -> &'static [MetricDesc] {
    &METRICS
}

/// One field of the per-tenant line sub-schema. Values travel as f64
/// (the wire carries every number as f64 anyway); `float` selects the
/// wire parse rule — `as_f64` for real-valued fields, `as_u64` for
/// counts, so garbage like fractional counts falls to the default
/// exactly as the hand-written parser did.
pub struct TenantFieldDesc {
    pub name: &'static str,
    pub merge: MergeKind,
    pub float: bool,
    pub get: fn(&TenantLine) -> f64,
    pub set: fn(&mut TenantLine, f64),
}

/// The per-tenant line sub-schema, merged [`MergeKind::ByKey`].
pub static TENANT_FIELDS: [TenantFieldDesc; 8] = [
    TenantFieldDesc {
        name: "tenant",
        merge: MergeKind::Key,
        float: false,
        get: |t: &TenantLine| t.tenant as f64,
        set: |t: &mut TenantLine, v: f64| t.tenant = v as u32,
    },
    TenantFieldDesc {
        name: "requests",
        merge: MergeKind::Sum,
        float: false,
        get: |t: &TenantLine| t.requests as f64,
        set: |t: &mut TenantLine, v: f64| t.requests = v as u64,
    },
    TenantFieldDesc {
        name: "completed",
        merge: MergeKind::Sum,
        float: false,
        get: |t: &TenantLine| t.completed as f64,
        set: |t: &mut TenantLine, v: f64| t.completed = v as u64,
    },
    TenantFieldDesc {
        name: "shed",
        merge: MergeKind::Sum,
        float: false,
        get: |t: &TenantLine| t.shed as f64,
        set: |t: &mut TenantLine, v: f64| t.shed = v as u64,
    },
    TenantFieldDesc {
        name: "downgraded",
        merge: MergeKind::Sum,
        float: false,
        get: |t: &TenantLine| t.downgraded as f64,
        set: |t: &mut TenantLine, v: f64| t.downgraded = v as u64,
    },
    TenantFieldDesc {
        name: "slo_ok",
        merge: MergeKind::Sum,
        float: false,
        get: |t: &TenantLine| t.slo_ok as f64,
        set: |t: &mut TenantLine, v: f64| t.slo_ok = v as u64,
    },
    TenantFieldDesc {
        name: "mean_ttft_ms",
        merge: MergeKind::RequestWeightedMean,
        float: true,
        get: |t: &TenantLine| t.mean_ttft_ms,
        set: |t: &mut TenantLine, v: f64| t.mean_ttft_ms = v,
    },
    TenantFieldDesc {
        name: "mode",
        merge: MergeKind::Max,
        float: false,
        get: |t: &TenantLine| t.mode as f64,
        set: |t: &mut TenantLine, v: f64| t.mode = v as u8,
    },
];

/// One field of the shared-tree counter block ([`TreeCounters`]), whose
/// per-shard aggregation is a field-wise sum.
pub struct CounterFieldDesc {
    pub name: &'static str,
    pub get: fn(&TreeCounters) -> u64,
    pub set: fn(&mut TreeCounters, u64),
}

/// The [`TreeCounters`] sub-schema: every field, in declaration order.
/// [`TreeCounters::merge`] iterates this table, so a new counter added
/// here is summed across shards with no hand-written merge line.
pub static TREE_COUNTER_FIELDS: [CounterFieldDesc; 14] = [
    CounterFieldDesc {
        name: "gpu_evictions",
        get: |c: &TreeCounters| c.gpu_evictions,
        set: |c: &mut TreeCounters, v: u64| c.gpu_evictions = v,
    },
    CounterFieldDesc {
        name: "host_evictions",
        get: |c: &TreeCounters| c.host_evictions,
        set: |c: &mut TreeCounters, v: u64| c.host_evictions = v,
    },
    CounterFieldDesc {
        name: "swap_out_bytes",
        get: |c: &TreeCounters| c.swap_out_bytes,
        set: |c: &mut TreeCounters, v: u64| c.swap_out_bytes = v,
    },
    CounterFieldDesc {
        name: "zero_copy_evictions",
        get: |c: &TreeCounters| c.zero_copy_evictions,
        set: |c: &mut TreeCounters, v: u64| c.zero_copy_evictions = v,
    },
    CounterFieldDesc {
        name: "inserts",
        get: |c: &TreeCounters| c.inserts,
        set: |c: &mut TreeCounters, v: u64| c.inserts = v,
    },
    CounterFieldDesc {
        name: "rejected_inserts",
        get: |c: &TreeCounters| c.rejected_inserts,
        set: |c: &mut TreeCounters, v: u64| c.rejected_inserts = v,
    },
    CounterFieldDesc {
        name: "gpu_hit_bytes",
        get: |c: &TreeCounters| c.gpu_hit_bytes,
        set: |c: &mut TreeCounters, v: u64| c.gpu_hit_bytes = v,
    },
    CounterFieldDesc {
        name: "chunk_hits",
        get: |c: &TreeCounters| c.chunk_hits,
        set: |c: &mut TreeCounters, v: u64| c.chunk_hits = v,
    },
    CounterFieldDesc {
        name: "chunk_hit_bytes",
        get: |c: &TreeCounters| c.chunk_hit_bytes,
        set: |c: &mut TreeCounters, v: u64| c.chunk_hit_bytes = v,
    },
    CounterFieldDesc {
        name: "boundary_recompute_tokens",
        get: |c: &TreeCounters| c.boundary_recompute_tokens,
        set: |c: &mut TreeCounters, v: u64| {
            c.boundary_recompute_tokens = v
        },
    },
    CounterFieldDesc {
        name: "disk_spills",
        get: |c: &TreeCounters| c.disk_spills,
        set: |c: &mut TreeCounters, v: u64| c.disk_spills = v,
    },
    CounterFieldDesc {
        name: "disk_spill_bytes",
        get: |c: &TreeCounters| c.disk_spill_bytes,
        set: |c: &mut TreeCounters, v: u64| c.disk_spill_bytes = v,
    },
    CounterFieldDesc {
        name: "disk_restage_hits",
        get: |c: &TreeCounters| c.disk_restage_hits,
        set: |c: &mut TreeCounters, v: u64| c.disk_restage_hits = v,
    },
    CounterFieldDesc {
        name: "disk_restage_bytes",
        get: |c: &TreeCounters| c.disk_restage_bytes,
        set: |c: &mut TreeCounters, v: u64| c.disk_restage_bytes = v,
    },
];

/// An extension counter registered beyond the standard table: it rides
/// `StatsResult::ext` through encode/parse/merge and (with `bench`)
/// the serving bench column set, so adding it touches exactly the
/// registry entry and the increment site.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExtCounter {
    pub name: &'static str,
    /// [`MergeKind::Sum`] or [`MergeKind::Max`] — extension counters
    /// are plain u64 event counts.
    pub merge: MergeKind,
    pub tolerance: Tolerance,
    /// Whether the counter joins the serving bench column set.
    pub bench: bool,
}

/// The metric registry: the standard descriptor table plus any
/// extension counters. Cheap to construct; the wire/merge entry points
/// in [`crate::server`] use [`Registry::standard`].
pub struct Registry {
    exts: Vec<ExtCounter>,
}

impl Registry {
    /// The standard schema: exactly the [`descriptors`] table.
    pub fn standard() -> Registry {
        Registry { exts: Vec::new() }
    }

    /// Register an extension counter. Panics on a name that collides
    /// with a standard metric or an already-registered extension —
    /// registration is a build-time act, not a runtime condition.
    pub fn with_counter(mut self, ext: ExtCounter) -> Registry {
        assert!(
            descriptors().iter().all(|d| d.wire != ext.name),
            "{} collides with a standard metric",
            ext.name
        );
        assert!(
            self.exts.iter().all(|e| e.name != ext.name),
            "{} is already registered",
            ext.name
        );
        assert!(
            matches!(ext.merge, MergeKind::Sum | MergeKind::Max),
            "extension counters merge Sum or Max"
        );
        self.exts.push(ext);
        self
    }

    pub fn ext_counters(&self) -> &[ExtCounter] {
        &self.exts
    }

    /// Encode one stats answer as the wire JSON object (including the
    /// `"type":"stats"` tag). Field set and values are exactly the
    /// hand-written encoder's; the object is a sorted map, so pair
    /// order cannot matter.
    pub fn encode_stats(&self, s: &StatsResult) -> Json {
        let mut pairs: Vec<(&str, Json)> =
            vec![("type", Json::str("stats"))];
        for d in descriptors() {
            pairs.push((d.wire, value_to_json((d.get)(s))));
        }
        for e in &self.exts {
            if let Some(&(_, x)) =
                s.ext.iter().find(|(n, _)| *n == e.name)
            {
                pairs.push((e.name, Json::num(x as f64)));
            }
        }
        Json::obj(pairs)
    }

    /// Parse one stats wire object. Missing or wrong-typed fields fall
    /// to their defaults (`engines` defaults to 1, everything else to
    /// zero/empty), mirroring the hand-written parser.
    pub fn parse_stats(&self, v: &Json) -> StatsResult {
        let mut s = StatsResult {
            engines: 1,
            ..Default::default()
        };
        for d in descriptors() {
            let Some(jv) = v.get(d.wire) else { continue };
            let parsed = match (d.get)(&s) {
                Value::U64(_) => jv.as_u64().map(Value::U64),
                Value::F64(_) => jv.as_f64().map(Value::F64),
                Value::Bool(_) => jv.as_bool().map(Value::Bool),
                Value::Shards(_) => jv.as_arr().map(|a| {
                    Value::Shards(
                        a.iter().filter_map(Json::as_u64).collect(),
                    )
                }),
                Value::Tenants(_) => jv.as_arr().map(|a| {
                    Value::Tenants(
                        a.iter().map(parse_tenant_line).collect(),
                    )
                }),
            };
            if let Some(val) = parsed {
                (d.set)(&mut s, val);
            }
        }
        for e in &self.exts {
            if let Some(x) = v.get(e.name).and_then(Json::as_u64) {
                s.ext.push((e.name, x));
            }
        }
        s
    }

    /// Table-driven fan-out merge: one loop over the descriptors
    /// replaces the field-by-field merge, applying each metric's
    /// registered [`MergeKind`] — including the NaN-skip weighting,
    /// the `slo_enabled` gating and the one-snapshot shard-array rule
    /// the hand-written merge implemented.
    pub fn merge(&self, parts: &[StatsResult]) -> StatsResult {
        // The freshest self-consistent snapshot: most shard gauges
        // reported, then most rebalance progress. `max_by_key` keeps
        // the LAST maximum, matching the hand-written merge exactly.
        let freshest = parts.iter().max_by_key(|p| {
            (p.shard_gpu_capacity.len(), p.rebalance_recomputes)
        });
        let mut m = StatsResult::default();
        for d in descriptors() {
            let template = (d.get)(&m);
            let merged = match d.merge {
                MergeKind::Sum => match template {
                    Value::U64(_) => Value::U64(
                        parts
                            .iter()
                            .map(|p| (d.get)(p).to_u64())
                            .sum(),
                    ),
                    _ => Value::F64(
                        parts
                            .iter()
                            .map(|p| (d.get)(p).to_f64())
                            .sum(),
                    ),
                },
                MergeKind::Max => match template {
                    Value::U64(_) => Value::U64(
                        parts
                            .iter()
                            .map(|p| (d.get)(p).to_u64())
                            .max()
                            .unwrap_or(0),
                    ),
                    _ => Value::F64(
                        parts
                            .iter()
                            .map(|p| (d.get)(p).to_f64())
                            .fold(0.0, f64::max),
                    ),
                },
                MergeKind::Or => Value::Bool(
                    parts.iter().any(|p| (d.get)(p).to_bool()),
                ),
                MergeKind::RequestWeightedMean => Value::F64(
                    request_weighted(parts, |p| (d.get)(p).to_f64(), false),
                ),
                MergeKind::SloGatedMean => Value::F64(
                    request_weighted(parts, |p| (d.get)(p).to_f64(), true),
                ),
                MergeKind::EngineCount => {
                    Value::U64(parts.len() as u64)
                }
                MergeKind::SnapshotConsistentGroup => match freshest {
                    Some(p) => (d.get)(p),
                    None => template,
                },
                MergeKind::ByKey => {
                    Value::Tenants(merge_tenant_lines(parts))
                }
                MergeKind::Key => template,
            };
            (d.set)(&mut m, merged);
        }
        for e in &self.exts {
            let vals: Vec<u64> = parts
                .iter()
                .filter_map(|p| {
                    p.ext
                        .iter()
                        .find(|(n, _)| *n == e.name)
                        .map(|&(_, x)| x)
                })
                .collect();
            if vals.is_empty() {
                continue;
            }
            let x = match e.merge {
                MergeKind::Sum => vals.iter().sum(),
                _ => vals.iter().copied().max().unwrap_or(0),
            };
            m.ext.push((e.name, x));
        }
        m
    }
}

fn value_to_json(v: Value) -> Json {
    match v {
        Value::U64(x) => Json::num(x as f64),
        Value::F64(x) => Json::num(x),
        Value::Bool(b) => Json::Bool(b),
        Value::Shards(a) => Json::Arr(
            a.iter().map(|&b| Json::num(b as f64)).collect(),
        ),
        Value::Tenants(ts) => Json::Arr(
            ts.iter().map(encode_tenant_line).collect(),
        ),
    }
}

fn encode_tenant_line(t: &TenantLine) -> Json {
    Json::obj(
        TENANT_FIELDS
            .iter()
            .map(|f| (f.name, Json::num((f.get)(t))))
            .collect(),
    )
}

fn parse_tenant_line(v: &Json) -> TenantLine {
    let mut t = TenantLine::default();
    for f in TENANT_FIELDS.iter() {
        let parsed = if f.float {
            v.get(f.name).and_then(Json::as_f64)
        } else {
            v.get(f.name).and_then(Json::as_u64).map(|x| x as f64)
        };
        if let Some(x) = parsed {
            (f.set)(&mut t, x);
        }
    }
    t
}

/// The NaN-skip request-weighted mean: parts with zero requests or a
/// non-finite value contribute neither value nor weight (one engine's
/// NaN mean must not poison — or dilute — the engines that measured);
/// with `slo_gated`, only engines running SLO admission control carry
/// weight. All-skipped merges report 0.0.
fn request_weighted(
    parts: &[StatsResult],
    f: impl Fn(&StatsResult) -> f64,
    slo_gated: bool,
) -> f64 {
    let (sum, weight) = parts
        .iter()
        .filter(|p| {
            (!slo_gated || p.slo_enabled)
                && p.requests > 0
                && f(p).is_finite()
        })
        .fold((0.0, 0usize), |(s, w), p| {
            (s + f(p) * p.requests as f64, w + p.requests)
        });
    if weight == 0 {
        0.0
    } else {
        sum / weight as f64
    }
}

/// Element-wise merge of the per-tenant lines by tenant id
/// ([`MergeKind::ByKey`]): counts sum, the CAG mode takes the max
/// code, and `mean_ttft_ms` merges request-weighted with the same
/// NaN/zero-served guard as the top-level mean — a line with no
/// requests, no completions or a non-finite mean contributes neither
/// value nor weight.
pub fn merge_tenant_lines(parts: &[StatsResult]) -> Vec<TenantLine> {
    let mut by: BTreeMap<u32, TenantLine> = BTreeMap::new();
    let mut ttft_weight: BTreeMap<u32, f64> = BTreeMap::new();
    for p in parts {
        for t in &p.tenants {
            let e = by.entry(t.tenant).or_insert_with(|| TenantLine {
                tenant: t.tenant,
                ..Default::default()
            });
            for f in TENANT_FIELDS.iter() {
                match f.merge {
                    MergeKind::Sum => {
                        let v = (f.get)(e) + (f.get)(t);
                        (f.set)(e, v);
                    }
                    MergeKind::Max => {
                        let v = (f.get)(e).max((f.get)(t));
                        (f.set)(e, v);
                    }
                    // Key and the mean handled outside the loop.
                    _ => {}
                }
            }
            if t.requests > 0
                && t.completed > 0
                && t.mean_ttft_ms.is_finite()
            {
                let w = t.requests as f64;
                // Weighted sum for now; normalized below.
                e.mean_ttft_ms += t.mean_ttft_ms * w;
                *ttft_weight.entry(t.tenant).or_insert(0.0) += w;
            }
        }
    }
    for (tenant, line) in by.iter_mut() {
        let w = ttft_weight.get(tenant).copied().unwrap_or(0.0);
        line.mean_ttft_ms =
            if w > 0.0 { line.mean_ttft_ms / w } else { 0.0 };
    }
    by.into_values().collect()
}

/// NaN-safe wire encoding of a mean: JSON cannot carry NaN, so an
/// unmeasured mean reports 0.0 (the merge's zero-served guard skips
/// such lines anyway).
pub fn wire_mean_ms(ms: f64) -> f64 {
    if ms.is_finite() {
        ms
    } else {
        0.0
    }
}

/// bench_diff tolerance class for a column, when the column is a
/// registered metric (standard, tree counter, or extension). Columns
/// the registry has never heard of return `None` — bench_diff falls
/// back to its wall-clock suffix rule for those.
pub fn tolerance_of(reg: &Registry, col: &str) -> Option<Tolerance> {
    if let Some(d) = descriptors().iter().find(|d| d.wire == col) {
        return Some(d.tolerance);
    }
    // Tree counters are deterministic event/byte counts: always tight.
    if TREE_COUNTER_FIELDS.iter().any(|f| f.name == col) {
        return Some(Tolerance::Tight);
    }
    reg.ext_counters()
        .iter()
        .find(|e| e.name == col)
        .map(|e| e.tolerance)
}

/// The BENCH_serving column set, with every metric-backed column pulled
/// from the registry (a typo'd or unregistered name panics at emit
/// time instead of silently diverging from the schema) and `bench`
/// extension counters appended. Workload-shape columns (row labels and
/// the bench's own wall-clock measurements) are bench-local.
pub fn serving_bench_columns(reg: &Registry) -> Vec<&'static str> {
    let wire = |name: &'static str| -> &'static str {
        descriptors()
            .iter()
            .find(|d| d.wire == name)
            .map(|d| d.wire)
            .expect("bench column not in the metric registry")
    };
    let tree = |name: &'static str| -> &'static str {
        TREE_COUNTER_FIELDS
            .iter()
            .find(|f| f.name == name)
            .map(|f| f.name)
            .expect("bench column not in the tree-counter registry")
    };
    let mut cols = vec![
        "chunk_cache",
        "requests",
        "ttft_p50_ms",
        "ttft_p99_ms",
        "throughput_rps",
        "sum_prefill_tokens",
        "ttft_proxy_s",
        tree("gpu_hit_bytes"),
        tree("chunk_hits"),
        tree("chunk_hit_bytes"),
        tree("boundary_recompute_tokens"),
        wire("tree_inserts"),
        tree("swap_out_bytes"),
        wire("goodput_rps"),
        wire("ttft_p999_ms"),
        wire("shed_requests"),
        "disk",
        tree("disk_spills"),
        tree("disk_restage_hits"),
        tree("disk_restage_bytes"),
    ];
    for e in reg.ext_counters() {
        if e.bench {
            cols.push(e.name);
        }
    }
    cols
}

/// The registry schema as stable text: one line per metric (and per
/// sub-schema field, and per serving bench column). ci.sh diffs this
/// against the committed `bench_baselines/stats_schema.txt`, so a stat
/// silently added or removed fails loudly — the schema analogue of the
/// bench_diff column-set rule.
pub fn schema_dump(reg: &Registry) -> String {
    let mut out = String::new();
    out.push_str(
        "# ragcache stats schema - generated by `ragcache stats-schema`\n",
    );
    out.push_str(
        "# one line per metric: wire name, kind, scope, merge \
         semantics, bench tolerance class\n",
    );
    out.push_str(
        "# regenerate and commit deliberately when the metric surface \
         changes; ci.sh diffs this file\n",
    );
    for d in descriptors() {
        out.push_str(&format!(
            "stat {} kind={} scope={} merge={} tolerance={}\n",
            d.wire,
            d.kind.as_str(),
            d.scope.as_str(),
            d.merge.as_str(),
            d.tolerance.as_str(),
        ));
    }
    for e in reg.ext_counters() {
        out.push_str(&format!(
            "stat {} kind=counter scope=per_engine merge={} \
             tolerance={} ext\n",
            e.name,
            e.merge.as_str(),
            e.tolerance.as_str(),
        ));
    }
    for f in TENANT_FIELDS.iter() {
        out.push_str(&format!(
            "tenant_field {} merge={}\n",
            f.name,
            f.merge.as_str(),
        ));
    }
    for f in TREE_COUNTER_FIELDS.iter() {
        out.push_str(&format!("tree_counter {} merge=sum\n", f.name));
    }
    for c in serving_bench_columns(reg) {
        out.push_str(&format!("bench_serving_column {c}\n"));
    }
    out
}
