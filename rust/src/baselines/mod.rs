//! Baseline system configurations (§7 Baselines).
//!
//! The baselines share the engine, workload and retrieval stack with
//! RAGCache — only the caching/scheduling feature matrix differs, which
//! is exactly how the paper configures them ("the baselines are
//! configured with the same model parallelism, maximum batch size, and
//! vector database settings").

use crate::config::{SystemConfig, SystemKind, SystemKindField};

/// vLLM + Faiss: paged KV within a request, no cross-request document
/// cache, FIFO scheduling, no speculative pipelining.
pub fn vllm(base: &SystemConfig) -> SystemConfig {
    let mut cfg = base.clone();
    cfg.kind = SystemKindField(SystemKind::VllmLike);
    cfg.sched.reorder = false;
    cfg.spec.enabled = false;
    cfg
}

/// SGLang: cross-request KV reuse in GPU memory only, LRU replacement,
/// FIFO scheduling, no speculative pipelining.
pub fn sglang(base: &SystemConfig) -> SystemConfig {
    let mut cfg = base.clone();
    cfg.kind = SystemKindField(SystemKind::SglangLike);
    cfg.cache.host_bytes = 0;
    cfg.cache.policy = crate::config::PolicyKind::Lru;
    cfg.sched.reorder = false;
    cfg.spec.enabled = false;
    cfg
}

/// RAGCache with everything enabled (identity helper for sweeps).
pub fn ragcache(base: &SystemConfig) -> SystemConfig {
    let mut cfg = base.clone();
    cfg.kind = SystemKindField(SystemKind::RagCache);
    cfg
}

/// All three systems for comparison sweeps, with display names.
pub fn all(base: &SystemConfig) -> Vec<(&'static str, SystemConfig)> {
    vec![
        ("ragcache", ragcache(base)),
        ("sglang", sglang(base)),
        ("vllm", vllm(base)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn feature_matrix() {
        let base = SystemConfig::default();
        let v = vllm(&base);
        assert_eq!(*v.kind, SystemKind::VllmLike);
        assert!(!v.sched.reorder);
        assert!(!v.spec.enabled);
        let s = sglang(&base);
        assert_eq!(s.cache.host_bytes, 0);
        assert_eq!(s.cache.policy, crate::config::PolicyKind::Lru);
        let r = ragcache(&base);
        assert!(r.sched.reorder);
        assert!(r.spec.enabled);
        assert_eq!(all(&base).len(), 3);
    }

    #[test]
    fn shared_settings_not_perturbed() {
        // "same model parallelism, maximum batch size, vector database".
        let base = SystemConfig::default();
        for (_, cfg) in all(&base) {
            assert_eq!(cfg.engine.max_batch, base.engine.max_batch);
            assert_eq!(cfg.engine.model, base.engine.model);
            assert_eq!(cfg.retrieval.top_k, base.retrieval.top_k);
            assert_eq!(cfg.retrieval.nlist, base.retrieval.nlist);
        }
    }
}
