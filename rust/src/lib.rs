//! # RAGCache
//!
//! A reproduction of *RAGCache: Efficient Knowledge Caching for
//! Retrieval-Augmented Generation* (Jin et al., 2024) as a three-layer
//! Rust + JAX + Pallas serving stack.
//!
//! The library is organised as a deployable serving framework:
//!
//! - [`controller`] — the global RAG controller (the paper's system
//!   contribution): request lifecycle, knowledge-tree cache orchestration,
//!   cache-aware reordering and dynamic speculative pipelining.
//! - [`tree`] — the knowledge tree: a prefix tree over document IDs whose
//!   nodes hold KV-cache block handles, partitioned into GPU / host / free
//!   segments.
//! - [`policy`] — replacement policies: the paper's PGDSF plus the GDSF,
//!   LRU and LFU baselines used in the ablation (§7.3).
//! - [`kvcache`] — paged KV-cache block allocator with a two-tier
//!   (GPU/host) hierarchy, swap-out-only-once semantics and a PCIe
//!   transfer model.
//! - [`llm`] — model/GPU specifications (paper Table 1), the analytic
//!   prefill/decode cost model, the offline `(alpha, beta)` profiler, and
//!   the iteration-level batching engine with pluggable executors.
//! - [`vectordb`] — the retrieval substrate: FlatL2 / IVF / HNSW indexes
//!   with *staged* search used by speculative pipelining.
//! - [`spec`] — dynamic speculative pipelining (paper Algorithm 2).
//! - [`sched`] — cache-aware reordering queue (§5.2).
//! - [`runtime`] — PJRT wrapper that loads AOT-compiled HLO artifacts
//!   produced by the Python compile path and executes them on CPU.
//! - [`workload`] — synthetic corpora, QA-dataset access patterns and
//!   Poisson arrival processes reproducing the paper's traces (§3.2, §7).
//! - [`baselines`] — vLLM-like and SGLang-like system configurations.
//! - [`sim`] — discrete-event simulation clock; the controller runs
//!   identically against the virtual clock (paper-scale experiments) and
//!   the real clock (end-to-end PJRT serving).
//!
//! Build-time Python (never on the request path) lives under `python/`:
//! the Pallas prefix-attention kernel (L1) and the JAX transformer (L2)
//! are AOT-lowered to HLO text that [`runtime`] loads.

pub mod util;
pub mod config;
pub mod testing;
pub mod sim;
pub mod bench;
pub mod cli;
pub mod runtime;
pub mod vectordb;
pub mod embed;
pub mod kvcache;
pub mod policy;
pub mod tree;
pub mod llm;
pub mod workload;
pub mod metrics;
pub mod sched;
pub mod spec;
pub mod controller;
pub mod baselines;
pub mod server;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
