//! Real KV payloads for the PJRT-backed path.
//!
//! In simulated mode nodes carry no bytes — only accounting. In real mode
//! each knowledge-tree node owns the token-major KV rows its document
//! produced (`tokens × kv_floats_per_token` f32), and assembling a prefix
//! is concatenation in path order — which is why the model's KV layout is
//! token-major (see `python/compile/model.py`).

use std::sync::Arc;

/// Immutable, shareable KV rows for one document (token-major).
#[derive(Debug, Clone)]
pub struct KvPayload {
    data: Arc<Vec<f32>>,
    tokens: usize,
}

impl KvPayload {
    pub fn new(data: Vec<f32>, tokens: usize) -> Self {
        assert!(
            tokens == 0 || data.len() % tokens == 0,
            "payload not token-divisible"
        );
        KvPayload {
            data: Arc::new(data),
            tokens,
        }
    }

    pub fn empty() -> Self {
        KvPayload {
            data: Arc::new(Vec::new()),
            tokens: 0,
        }
    }

    pub fn tokens(&self) -> usize {
        self.tokens
    }

    pub fn floats(&self) -> &[f32] {
        &self.data
    }

    pub fn is_empty(&self) -> bool {
        self.tokens == 0
    }

    /// Split a prefill output covering several documents into per-document
    /// payloads, in order.
    pub fn split(
        data: &[f32],
        token_counts: &[usize],
    ) -> Vec<KvPayload> {
        let total: usize = token_counts.iter().sum();
        assert!(total > 0 && data.len() % total == 0, "bad split");
        let per_token = data.len() / total;
        let mut out = Vec::with_capacity(token_counts.len());
        let mut offset = 0;
        for &t in token_counts {
            let end = offset + t * per_token;
            out.push(KvPayload::new(data[offset..end].to_vec(), t));
            offset = end;
        }
        out
    }

    /// Concatenate payloads in path order into one prefix buffer.
    pub fn concat(parts: &[&KvPayload]) -> Vec<f32> {
        let total: usize = parts.iter().map(|p| p.data.len()).sum();
        let mut out = Vec::with_capacity(total);
        for p in parts {
            out.extend_from_slice(&p.data);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_roundtrips_concat() {
        let per_token = 4;
        let data: Vec<f32> = (0..24).map(|x| x as f32).collect(); // 6 tokens
        let parts = KvPayload::split(&data, &[2, 3, 1]);
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0].tokens(), 2);
        assert_eq!(parts[0].floats().len(), 2 * per_token);
        let refs: Vec<&KvPayload> = parts.iter().collect();
        assert_eq!(KvPayload::concat(&refs), data);
    }

    #[test]
    fn empty_payload() {
        let p = KvPayload::empty();
        assert!(p.is_empty());
        assert_eq!(KvPayload::concat(&[&p]), Vec::<f32>::new());
    }

    #[test]
    #[should_panic(expected = "bad split")]
    fn split_rejects_misaligned() {
        KvPayload::split(&[1.0; 10], &[3]);
    }
}
